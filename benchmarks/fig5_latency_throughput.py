"""Fig. 5 — latency & throughput vs batch size: baseline (vanilla TGN) vs
the optimized NP(L/M/S) students, plus the real-time time-window replay
(the paper's "every 15 minutes" experiment).

Every row — the vanilla/cosine baseline included — runs through the SAME
variant-agnostic StreamingEngine session; the pipeline registry resolves
each Table-II name to its stage stack (Pallas kernel backends where they
exist, jnp references elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import save_json, timeit, paper_tgn_config
from repro.core import tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine


def sweep(batch_sizes=(25, 50, 100, 200, 400), n_edges: int = 3000,
          f_mem: int = 100,
          variants=("Baseline", "+NP(L)", "+NP(M)", "+NP(S)")):
    g = tgd.wikipedia_like(n_edges=n_edges)
    ef = jnp.asarray(g.edge_feats)
    lo = min(1000, n_edges // 3)
    rows = []

    for bs in batch_sizes:
        batch = next(iter(stream_mod.fixed_count(
            g, bs, window=slice(lo, n_edges))))
        for name in variants:
            cfg = paper_tgn_config(name, g.cfg.n_nodes, g.n_edges,
                                   f_mem=f_mem)
            params = tgn.init_params(jax.random.key(0), cfg)
            eng = StreamingEngine(EngineConfig(model=cfg), params, ef)
            dev = tuple(jnp.asarray(x) for x in
                        (batch.src, batch.dst, batch.eid, batch.ts,
                         batch.valid))
            t = timeit(lambda: eng.step_on_device(dev).emb_src, iters=5)
            rows.append({"model": name, "batch": bs,
                         "latency_ms": round(t * 1e3, 3),
                         "throughput_eps": round(bs / t)})
    return rows


def realtime_replay(window_s: float = 900.0, n_edges: int = 3000):
    """Real-time latency: batches formed by wall-clock windows."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    ef = jnp.asarray(g.edge_feats)
    cfg = paper_tgn_config("+NP(M)", g.cfg.n_nodes, g.n_edges)
    params = tgn.init_params(jax.random.key(2), cfg)
    eng = StreamingEngine(EngineConfig(model=cfg), params, ef)
    for batch, _out in eng.run(stream_mod.time_window(g, window_s, 256)):
        pass
    return eng.summary()


def main(full: bool = False):
    print("== Fig. 5: latency/throughput vs batch size ==")
    rows = sweep()
    for r in rows:
        print(f"  {r['model']:9s} B={r['batch']:4d} "
              f"lat={r['latency_ms']:8.3f}ms "
              f"thpt={r['throughput_eps']:8d} E/s")
    rt = realtime_replay()
    print(f"-- real-time window replay (NP(M), 15-min windows): {rt}")
    save_json("fig5.json", {"sweep": rows, "realtime": rt})


if __name__ == "__main__":
    main()
