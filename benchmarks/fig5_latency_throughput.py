"""Fig. 5 — latency & throughput vs batch size: baseline (vanilla TGN) vs
the optimized StreamingEngine with NP(L/M/S), plus the real-time
time-window replay (the paper's "every 15 minutes" experiment)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json, timeit, paper_tgn_config
from repro.core import tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine


def sweep(batch_sizes=(25, 50, 100, 200, 400), n_edges: int = 3000,
          f_mem: int = 100):
    g = tgd.wikipedia_like(n_edges=n_edges)
    ef = jnp.asarray(g.edge_feats)
    rows = []

    # baseline: vanilla TGN-attn through process_batch
    cfg_b = paper_tgn_config("Baseline", g.cfg.n_nodes, g.n_edges,
                             f_mem=f_mem)
    params_b = tgn.init_params(jax.random.key(0), cfg_b)

    for bs in batch_sizes:
        batch = next(iter(stream_mod.fixed_count(
            g, bs, window=slice(1000, 3000))))
        b = tuple(jnp.asarray(x) for x in (batch.src, batch.dst, batch.eid,
                                           batch.ts, batch.valid))
        state = tgn.init_state(cfg_b)
        fn = jax.jit(lambda p, s, bb: tgn.process_batch(
            p, cfg_b, s, None, ef, *bb).emb_src)
        t = timeit(fn, params_b, state, b, iters=5)
        rows.append({"model": "Baseline", "batch": bs,
                     "latency_ms": round(t * 1e3, 3),
                     "throughput_eps": round(bs / t)})

        for name, k in (("NP(L)", 6), ("NP(M)", 4), ("NP(S)", 2)):
            cfg_s = paper_tgn_config(f"+{name}", g.cfg.n_nodes, g.n_edges,
                                     f_mem=f_mem)
            params_s = tgn.init_params(jax.random.key(1), cfg_s)
            eng = StreamingEngine(EngineConfig(model=cfg_s), params_s, ef)
            dev = tuple(jnp.asarray(x) for x in
                        (batch.src, batch.dst, batch.eid, batch.ts,
                         batch.valid))
            t = timeit(lambda *a: eng._step(eng.params, eng.state, dev),
                       iters=5)
            rows.append({"model": name, "batch": bs,
                         "latency_ms": round(t * 1e3, 3),
                         "throughput_eps": round(bs / t)})
    return rows


def realtime_replay(window_s: float = 900.0, n_edges: int = 3000):
    """Real-time latency: batches formed by wall-clock windows."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    ef = jnp.asarray(g.edge_feats)
    cfg = paper_tgn_config("+NP(M)", g.cfg.n_nodes, g.n_edges)
    params = tgn.init_params(jax.random.key(2), cfg)
    eng = StreamingEngine(EngineConfig(model=cfg), params, ef)
    for batch, _out in eng.run(stream_mod.time_window(g, window_s, 256)):
        pass
    return eng.summary()


def main(full: bool = False):
    print("== Fig. 5: latency/throughput vs batch size ==")
    rows = sweep()
    for r in rows:
        print(f"  {r['model']:9s} B={r['batch']:4d} "
              f"lat={r['latency_ms']:8.3f}ms "
              f"thpt={r['throughput_eps']:8d} E/s")
    rt = realtime_replay()
    print(f"-- real-time window replay (NP(M), 15-min windows): {rt}")
    save_json("fig5.json", {"sweep": rows, "realtime": rt})


if __name__ == "__main__":
    main()
