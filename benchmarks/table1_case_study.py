"""Table I — case study: per-stage kMEM/kMAC (analytic, exact) and measured
per-stage execution time of OUR implementation on this host.

The paper profiles sample/memory/GNN/update on CPU/GPU; we reproduce the
complexity accounting exactly (core/complexity.py) and measure the same
four stages by timing the registered pipeline stages (core/stages.py) —
sampler, memory-updater, sampler+aggregator, committer+ring-insert —
separately jitted over a warmed vertex state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit, save_json
from repro.core import complexity as cx
from repro.core import mailbox, tgn
from repro.core.pipeline import build_pipeline
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd


def analytic_rows(dataset: str = "Wikipedia"):
    f_feat, f_edge = cx.DATASETS[dataset]
    cfg = cx.ComplexityConfig(f_feat=f_feat, f_edge=f_edge)
    macs, mems = cx.stage_macs(cfg), cx.stage_mems(cfg)
    rows = []
    for stage in ("sample", "memory", "GNN", "update", "total"):
        rows.append({
            "stage": stage,
            "kMEM": round(mems[stage] / 1e3, 2),
            "MEM_pct": round(100 * mems[stage] / mems["total"], 1),
            "kMAC": round(macs[stage] / 1e3, 1),
            "MAC_pct": round(100 * macs[stage] / macs["total"], 1),
        })
    return rows


def measured_stage_times(batch_size: int = 200, f_mem: int = 100):
    """Per-stage wall time (us per dynamic node embedding) of our impl."""
    g = tgd.wikipedia_like(n_edges=3000)
    cfg = tgn.TGNConfig(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges,
                        f_edge=172, f_mem=f_mem, f_time=f_mem, f_emb=f_mem,
                        m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)
    state = tgn.init_state(cfg)
    # warm the state over the first half of the stream
    for batch in stream_mod.fixed_count(g, batch_size,
                                        window=slice(0, 1500)):
        b = tuple(jnp.asarray(x) for x in (batch.src, batch.dst, batch.eid,
                                           batch.ts, batch.valid))
        state = tgn.process_batch(params, cfg, state, None, ef, *b).state

    batch = next(iter(stream_mod.fixed_count(g, batch_size,
                                             window=slice(1500, 3000))))
    src = jnp.asarray(batch.src)
    dst = jnp.asarray(batch.dst)
    eid = jnp.asarray(batch.eid)
    ts = jnp.asarray(batch.ts)
    vids = jnp.concatenate([src, dst])
    t_inst = jnp.concatenate([ts, ts])

    pipe = build_pipeline(cfg)            # reference stage backends
    aux = pipe.prepare(params)
    stg = pipe.stages

    @jax.jit
    def stage_sample(state):
        return stg.sampler(params, aux, state, ef, vids, t_inst)

    @jax.jit
    def stage_memory(state):
        return stg.memory_updater(params, aux, state, vids)

    @jax.jit
    def stage_gnn(state):
        h, _, _, _ = pipe.embed(params, aux, state, ef, None, vids, t_inst)
        return h

    @jax.jit
    def stage_update(state):
        s_upd = state.memory[vids]  # value content irrelevant for timing
        lu_upd = state.last_update[vids]
        w = stg.committer.winners(vids, jnp.ones(vids.shape, bool),
                                  src.shape[0])
        state = stg.committer.commit_memory(state, vids, w, s_upd, lu_upd)
        return mailbox.insert_neighbors(state, src, dst, eid, ts)

    n_emb = 2 * batch_size
    out = {}
    for name, fn in (("sample", stage_sample), ("memory", stage_memory),
                     ("GNN", stage_gnn), ("update", stage_update)):
        out[name] = timeit(fn, state) / n_emb * 1e9  # ns per embedding
    out["total"] = sum(out.values())
    return out


def main(full: bool = False):
    print("== Table I: per-stage complexity (analytic, paper dims) ==")
    for ds in ("Wikipedia", "Reddit", "GDELT"):
        print(f"-- {ds} --")
        for r in analytic_rows(ds):
            print(f"  {r['stage']:7s} kMEM={r['kMEM']:6.2f} "
                  f"({r['MEM_pct']:5.1f}%)  kMAC={r['kMAC']:7.1f} "
                  f"({r['MAC_pct']:5.1f}%)")
    print("-- measured per-stage time of our impl (ns/embedding, CPU) --")
    times = measured_stage_times()
    for k, v in times.items():
        print(f"  {k:7s} {v:10.0f}")
    save_json("table1.json",
              {"analytic": {ds: analytic_rows(ds)
                            for ds in ("Wikipedia", "Reddit", "GDELT")},
               "measured_ns_per_embedding": times})


if __name__ == "__main__":
    main()
