"""Fig. 7 — accuracy/latency frontier: AP (from the table2 --ap ladder, or
a quick re-train) against measured per-batch latency of each variant, every
one served by the variant-agnostic StreamingEngine."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (VARIANTS, load_json, paper_tgn_config,
                               save_json, timeit)
from repro.core import tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine


def latencies(n_edges: int = 2000, batch: int = 200, f_mem: int = 100):
    g = tgd.wikipedia_like(n_edges=n_edges)
    ef = jnp.asarray(g.edge_feats)
    b0 = next(iter(stream_mod.fixed_count(g, batch,
                                          window=slice(1000, 2000))))
    dev = tuple(jnp.asarray(x) for x in (b0.src, b0.dst, b0.eid, b0.ts,
                                         b0.valid))
    out = {}
    for name in VARIANTS:
        cfg = paper_tgn_config(name, g.cfg.n_nodes, g.n_edges, f_mem=f_mem)
        params = tgn.init_params(jax.random.key(0), cfg)
        eng = StreamingEngine(EngineConfig(model=cfg), params, ef)
        t = timeit(lambda: eng.step_on_device(dev).emb_src, iters=5)
        out[name] = round(t * 1e3, 3)
    return out


def main(full: bool = False):
    print("== Fig. 7: accuracy-latency frontier ==")
    lat = latencies()
    table2 = load_json("table2.json") or {}
    aps = table2.get("ap")
    for name in VARIANTS:
        ap_s = f"AP={aps[name]:.4f}" if aps else "AP=(run table2 --ap)"
        print(f"  {name:9s} latency={lat[name]:8.3f}ms  {ap_s}")
    save_json("fig7.json", {"latency_ms": lat, "ap": aps})


if __name__ == "__main__":
    main()
