"""Fig. 7 — accuracy/latency frontier: AP (from the table2 --ap ladder, or
a quick re-train) against measured per-batch latency of each variant, every
one served by the variant-agnostic StreamingEngine.

Beyond the paper, the SAMPLER-BACKEND axis (ROADMAP accuracy-benchmark
item): the np4 student's prune-then-fetch selection policy is pluggable
(``recent`` — the paper's SAT top-k — vs ``uniform`` vs time-decayed
``reservoir``), selection is parameter-free, so ONE distilled student
evaluates under all three. ``--full`` trains that student; default mode
reuses the previously saved AP points."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (VARIANTS, load_json, paper_tgn_config,
                               save_json, timeit)
from repro.core import tgn
from repro.core.pipeline import SAMPLER_VARIANTS, variant_config
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine


def latencies(n_edges: int = 2000, batch: int = 200, f_mem: int = 100,
              variants=VARIANTS):
    g = tgd.wikipedia_like(n_edges=n_edges)
    ef = jnp.asarray(g.edge_feats)
    b0 = next(iter(stream_mod.fixed_count(g, batch,
                                          window=slice(1000, 2000))))
    dev = tuple(jnp.asarray(x) for x in (b0.src, b0.dst, b0.eid, b0.ts,
                                         b0.valid))
    out = {}
    for name in variants:
        cfg = paper_tgn_config(name, g.cfg.n_nodes, g.n_edges, f_mem=f_mem)
        params = tgn.init_params(jax.random.key(0), cfg)
        eng = StreamingEngine(EngineConfig(model=cfg), params, ef)
        t = timeit(lambda: eng.step_on_device(dev).emb_src, iters=5)
        out[name] = round(t * 1e3, 3)
    return out


def sampler_ap(n_edges: int = 4000, f_mem: int = 32, epochs: int = 2):
    """AP of ONE distilled np4 student under each sampler backend.

    Teacher + one student train (same recipe as table2's --ap ladder);
    the three backends then replay the identical test stream with only
    the neighbor-selection policy swapped — the AP delta is purely the
    sampler's."""
    from repro.training import tgn_trainer as TT
    g = tgd.wikipedia_like(n_edges=n_edges)
    base = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f_mem, f_time=f_mem, f_emb=f_mem, m_r=10)
    tcfg = TT.TGNTrainConfig(batch_size=100, epochs=epochs)
    _tr, va, te_sl = stream_mod.chronological_split(g)
    t_cfg = variant_config("Baseline", **base)
    t_params, _ = TT.train_teacher(g, t_cfg, tcfg)
    s_params, _ = TT.distill_student(g, t_params, t_cfg,
                                     variant_config("sat+lut+np4", **base),
                                     tcfg)
    warm = slice(0, va.stop)
    out = {}
    for name in SAMPLER_VARIANTS:
        s_cfg = variant_config(name, **base)
        out[name] = TT.evaluate_ap(s_params, s_cfg, g, te_sl,
                                   warm_window=warm)
        print(f"  [sampler ap] {name}: {out[name]:.4f}")
    return out


def main(full: bool = False):
    print("== Fig. 7: accuracy-latency frontier ==")
    lat = latencies()
    table2 = load_json("table2.json") or {}
    aps = table2.get("ap")
    for name in VARIANTS:
        ap_s = f"AP={aps[name]:.4f}" if aps else "AP=(run table2 --ap)"
        print(f"  {name:9s} latency={lat[name]:8.3f}ms  {ap_s}")

    print("-- sampler-backend axis (np4 student: selection policy only) --")
    lat_s = latencies(variants=SAMPLER_VARIANTS)
    prev = load_json("fig7.json") or {}
    ap_s = sampler_ap() if full else prev.get("sampler_ap")
    for name in SAMPLER_VARIANTS:
        ap_str = (f"AP={ap_s[name]:.4f}" if ap_s and name in ap_s
                  else "AP=(run with --full)")
        print(f"  {name:24s} latency={lat_s[name]:8.3f}ms  {ap_str}")
    save_json("fig7.json", {"latency_ms": lat, "ap": aps,
                            "sampler_latency_ms": lat_s,
                            "sampler_ap": ap_s})


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
