"""Online front-end latency: per-event p50/p99 and edges/s vs deadline
and tenant count.

The offline sweeps (multitenant.py) measure the ROUND cost; this one
measures what an online client sees — the queue->flush->launch latency of
individual edge events under the deadline batcher (serving/frontend.py)
— over a (deadline x tenant-count) grid. Small deadlines trade throughput
(smaller flushed batches, more launches) for latency; the sweep makes the
knee measurable. Every configuration serves on a reserve-enabled session
(serving/admission.py capacity classes), so the numbers include the live
-admission serving path, and each run asserts it stayed zero-recompile
after warmup.

A second sweep prices durability: the same serve loop with the
write-ahead event journal (serving/journal.py) armed, over a grid of
fsync batching intervals (0 = fsync every append, the worst case). The
journal sits on the ingest hot path — every accepted event is framed,
crc'd and written before it is enqueued — so this axis is the direct
cost of the exactly-once recovery contract (docs/ROBUSTNESS.md).

    PYTHONPATH=src python -m benchmarks.frontend_latency
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_json
from repro.core import pipeline as pl, tgn
from repro.data import temporal_graph as tgd
from repro.serving.frontend import FrontendConfig, ServingFrontend
from repro.serving.session import SessionManager


def _setup(n_edges=800, f_mem=16):
    g = tgd.wikipedia_like(n_edges=n_edges)
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=f_mem,
                            f_time=f_mem, f_emb=f_mem, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    return g, cfg, params, jnp.asarray(g.edge_feats)


def _serve(g, cfg, params, ef, n_tenants, deadline_s, events_per_tenant,
           rate_eps=20_000.0, journal_fsync_ms=None):
    """Replay a Poisson-ish open-loop arrival process against the
    frontend (real wall clock), pumping between arrivals exactly as the
    asyncio driver would. ``journal_fsync_ms`` arms the write-ahead
    journal with that fsync batching interval (``None`` = no journal)."""
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    tids = [mgr.add_tenant() for _ in range(n_tenants)]
    journal = None
    if journal_fsync_ms is not None:
        from repro.serving.journal import EventJournal
        journal = EventJournal(tempfile.mkdtemp(prefix="fe-lat-wal-"),
                               fsync_s=journal_fsync_ms / 1e3)
    # pad_quantum == max_rows: every flush compiles to the SAME width,
    # the strict zero-retrace recipe (a smaller quantum amortizes compile
    # over a few widths instead — cheaper rows, more executables)
    fe = ServingFrontend(mgr, FrontendConfig(
        max_wait_s=deadline_s, max_rows=64, queue_rows=4096,
        pad_quantum=64), journal=journal)

    # warmup: one full-width round through every tenant, then freeze the
    # compile counters — serving must stay inside this executable
    for tid in tids:
        for i in range(64):
            fe.submit(tid, int(g.src[i]), int(g.dst[i]), i,
                      float(g.ts[i]), int(g.dst[(i + 3) % g.n_edges]))
    fe.pump(force=True)
    mgr.sync()
    fe.event_latencies.reset()       # obs.Histogram: drop warmup samples
    c0 = mgr.compile_counters()

    gap = 1.0 / rate_eps                 # inter-arrival per tenant column
    t0 = time.perf_counter()
    for i in range(events_per_tenant):
        e = (16 + i) % g.n_edges
        for tid in tids:
            fe.submit(tid, int(g.src[e]), int(g.dst[e]), e,
                      float(g.ts[e]), int(g.dst[(e + 3) % g.n_edges]))
        fe.pump()
        deadline = t0 + (i + 1) * gap
        while time.perf_counter() < deadline:
            fe.pump()
    fe.pump(force=True)
    mgr.sync()
    wall = time.perf_counter() - t0

    c1 = mgr.compile_counters()
    assert c1["round_traces"] == c0["round_traces"], (c0, c1)
    lat = fe.event_latencies              # obs.Histogram (streaming)
    edges = events_per_tenant * n_tenants
    row = {
        "tenants": n_tenants,
        "deadline_ms": deadline_s * 1e3,
        "events": edges,
        "rounds": fe.rounds,
        "p50_ms": (lat.quantile(0.50) or 0.0) * 1e3,
        "p99_ms": (lat.quantile(0.99) or 0.0) * 1e3,
        "eps": int(edges / wall),
        # the unified registry view of the same run (satellite of the
        # obs layer: benchmarks persist registry snapshots alongside
        # their own derived rows)
        "registry": mgr.obs.snapshot(),
    }
    if journal is not None:
        js = journal.stats()
        journal.close()
        row["journal"] = {"fsync_ms": journal_fsync_ms,
                          "appends": js["appends"], "fsyncs": js["fsyncs"]}
    return row


def sweep(tenant_counts=(1, 4), deadlines_ms=(1.0, 5.0, 20.0),
          events_per_tenant=400):
    g, cfg, params, ef = _setup()
    rows = []
    for n in tenant_counts:
        for d in deadlines_ms:
            rows.append(_serve(g, cfg, params, ef, n, d / 1e3,
                               events_per_tenant))
    return rows


def journal_sweep(fsync_intervals_ms=(None, 0.0, 1.0, 10.0),
                  events_per_tenant=400, n_tenants=4, deadline_ms=5.0):
    """The durability axis: one serve configuration, journal off vs on
    at several fsync batching intervals."""
    g, cfg, params, ef = _setup()
    rows = []
    for f in fsync_intervals_ms:
        rows.append(_serve(g, cfg, params, ef, n_tenants, deadline_ms / 1e3,
                           events_per_tenant, journal_fsync_ms=f))
    return rows


def main(full: bool = False):
    print("== online frontend: per-event latency vs deadline x tenants ==")
    rows = sweep(tenant_counts=(1, 4, 8) if full else (1, 4),
                 events_per_tenant=1200 if full else 400)
    for r in rows:
        print(f"  T={r['tenants']:2d} deadline={r['deadline_ms']:5.1f}ms "
              f"p50={r['p50_ms']:7.2f}ms p99={r['p99_ms']:7.2f}ms "
              f"{r['eps']:8d} E/s  ({r['rounds']} rounds)")
    print("== durability axis: journal off/on vs fsync interval ==")
    jrows = journal_sweep(events_per_tenant=1200 if full else 400)
    for r in jrows:
        j = r.get("journal")
        tag = "off" if j is None else f"fsync={j['fsync_ms']:4.1f}ms " \
                                      f"({j['fsyncs']} fsyncs)"
        print(f"  T={r['tenants']:2d} journal {tag:28s} "
              f"p50={r['p50_ms']:7.2f}ms p99={r['p99_ms']:7.2f}ms "
              f"{r['eps']:8d} E/s")
    save_json("frontend_latency.json", {"sweep": rows,
                                        "journal_sweep": jrows})


if __name__ == "__main__":
    main()
