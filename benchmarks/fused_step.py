"""Fused single-pass step kernel vs the staged kernel tier.

Three views of the same claim (the paper's §IV single-pass pipelining,
ported: after prune metadata, the step should touch HBM once):

  * launch count — kernel launches per compiled step: the staged tier pays
    one per unit (LUT encode + GRU + SAT aggregate), the fused tier ONE
    for the whole post-prune datapath (trace-time counter in kernels/ops);
  * materialized intermediate bytes — HLO-level accounting
    (launch/hlo_analysis.py) over the cross-lowered TPU module with the
    Pallas kernels as opaque custom-calls, counting only traffic through
    buffers the step itself materializes (the ``(B, k, Dkv)`` neighbor
    tensor, kv concats, inter-kernel operands); falls back to the
    jaxpr-level view when the toolchain cannot cross-lower;
  * host-backend wall clock — edges/s of the interpret-mode step on this
    host. NOTE: interpret mode executes the kernel as XLA ops, so this
    measures dispatch/fusion structure, not TPU DMA overlap; the byte
    accounting above is the hardware-relevant metric.

    PYTHONPATH=src python -m benchmarks.fused_step
"""
from __future__ import annotations

import time


def sweep(batch_sizes=(64, 256), rounds: int = 10, n_edges: int = 3000,
          f_mem: int = 100, variant: str = "sat+lut+np4"):
    """Rows of staged-vs-fused metrics, one per batch size."""
    import jax
    import jax.numpy as jnp

    from repro.core import pipeline as pl, tgn
    from repro.data import stream as stream_mod
    from repro.data import temporal_graph as tgd
    from repro.kernels import ops as kops
    from repro.launch import hlo_analysis as hlo

    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f_mem, f_time=f_mem, f_emb=f_mem, m_r=10)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)

    import numpy as np

    rows = []
    for B in batch_sizes:
        batches = [tuple(jnp.asarray(x) for x in
                         (b.src, b.dst, b.eid, b.ts, b.valid))
                   for b in stream_mod.fixed_count(
                       g, B, window=slice(0, min(B * (rounds + 3),
                                                 g.n_edges)))]
        per_tier = {}
        for tier in ("staged", "fused"):
            pipe = pl.build_pipeline(cfg, use_kernels=tier)
            aux = pipe.prepare(params)

            def fn(s, b, _pipe=pipe, _aux=aux):
                return _pipe.step(params, _aux, s, b, ef)

            # launches per compiled step (trace-time pallas-call counter)
            kops.reset_launch_count()
            jax.jit(fn).lower(pipe.init_state(), batches[0])
            launches = kops.launch_count()

            # materialized intermediate HBM bytes (kernels opaque)
            with kops.force_interpret(False):
                traffic = hlo.step_traffic(fn, pipe.init_state(),
                                           batches[0])

            # compile + warm into steady state (ring buffers filling)
            step = jax.jit(fn)
            state = pipe.init_state()
            for b in batches[:3]:
                state = step(state, b).state
            jax.block_until_ready(state)
            per_tier[tier] = {"launches": launches,
                              "bytes": float(traffic["bytes"]),
                              "accounting": traffic["accounting"],
                              "step": step, "state": state, "walls": []}

        # host-backend wall clock (interpret mode, the only backend this
        # host has): the tiers' rounds are INTERLEAVED and summarized by
        # the median so background load skews both equally.
        for b in batches[3:rounds + 3]:
            for t in ("staged", "fused"):
                pt = per_tier[t]
                t0 = time.perf_counter()
                pt["state"] = pt["step"](pt["state"], b).state
                jax.block_until_ready(pt["state"])
                pt["walls"].append(time.perf_counter() - t0)
        for pt in per_tier.values():
            pt["eps"] = B / float(np.median(pt["walls"]))
            del pt["step"], pt["state"], pt["walls"]
        s, f = per_tier["staged"], per_tier["fused"]
        rows.append({
            "batch": B, "variant": variant, "f_mem": f_mem,
            "staged_launches": s["launches"], "fused_launches": f["launches"],
            "staged_bytes": round(s["bytes"]), "fused_bytes": round(f["bytes"]),
            "bytes_reduction": round(1.0 - f["bytes"] / s["bytes"], 3),
            "staged_eps": round(s["eps"]), "fused_eps": round(f["eps"]),
            "speedup": round(f["eps"] / s["eps"], 2) if s["eps"] else 0.0,
            "accounting": f["accounting"],
        })
    return rows


def main(full: bool = False):
    from benchmarks.common import save_json

    print("== fused single-pass step vs staged kernels ==")
    rows = sweep(batch_sizes=(64, 256) if not full else (64, 256, 512))
    for r in rows:
        print(f"  B={r['batch']:4d} launches {r['staged_launches']}->"
              f"{r['fused_launches']}  intermediates "
              f"{r['staged_bytes']/1e6:7.2f}->{r['fused_bytes']/1e6:7.2f} MB"
              f" (-{r['bytes_reduction']:.0%})  host "
              f"{r['staged_eps']:7d}->{r['fused_eps']:7d} E/s "
              f"({r['speedup']:.2f}x)")
    save_json("fused_step.json", {"sweep": rows})


if __name__ == "__main__":
    main()
