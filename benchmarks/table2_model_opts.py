"""Table II — accumulated model optimizations: exact analytic kMAC/kMEM per
variant, measured single-thread throughput/speedup of our implementation,
and (with --ap) distilled-student AP for every ladder row.

The analytic MEM column reproduces the paper's numbers exactly
(5.7/3.8/2.9/1.9 kMEM on Wikipedia); MAC reductions are reported under our
documented counting convention next to the paper's (EXPERIMENTS.md
§Paper-fidelity discusses the delta).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (VARIANTS, load_json, paper_tgn_config,
                               save_json, timeit)
from repro.core import complexity as cx
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd


def analytic_ladder(dataset: str):
    return [
        {"variant": name,
         "kMAC": round(macs["total"] / 1e3, 1),
         "MAC_pct": round(pct_mac, 1),
         "paper_MAC_pct": cx.PAPER_MAC_PERCENT[name],
         "kMEM": round(mems["total"] / 1e3, 2),
         "MEM_pct": round(pct_mem, 1),
         "paper_MEM_pct": cx.PAPER_MEM_PERCENT[name]}
        for name, macs, mems, pct_mac, pct_mem in cx.table2(dataset)
    ]


def measured_throughput(dataset_fn=tgd.wikipedia_like, n_edges: int = 2000,
                        batch_size: int = 200, f_mem: int = 100):
    """Edges/s of each ladder variant on this host (single CPU)."""
    from repro.core.pipeline import build_pipeline
    g = dataset_fn(n_edges=n_edges)
    ef = (jnp.asarray(g.edge_feats) if g.edge_feats.shape[1] else
          jnp.zeros((g.n_edges, 172), jnp.float32))
    nf = jnp.asarray(g.node_feats) if g.node_feats is not None else None
    warm_hi = n_edges // 2
    batch = next(iter(stream_mod.fixed_count(g, batch_size,
                                             window=slice(warm_hi,
                                                          n_edges))))
    rows = {}
    base = None
    for name in VARIANTS:
        cfg = paper_tgn_config(name, g.cfg.n_nodes, g.n_edges,
                               f_feat=g.cfg.f_feat, f_edge=172,
                               f_mem=f_mem)
        pipe = build_pipeline(cfg)
        params = pipe.init_params(jax.random.key(0))
        state = pipe.init_state()
        step = jax.jit(pipe.step_fn)
        # warm state so neighbor buffers are populated
        for wb in stream_mod.fixed_count(g, batch_size,
                                         window=slice(0, warm_hi)):
            b = tuple(jnp.asarray(x) for x in (wb.src, wb.dst, wb.eid,
                                               wb.ts, wb.valid))
            state = step(params, state, b, ef, nf).state

        b = tuple(jnp.asarray(x) for x in (batch.src, batch.dst, batch.eid,
                                           batch.ts, batch.valid))
        fn = jax.jit(lambda p, s, bb: pipe.step_fn(p, s, bb, ef, nf).emb_src)
        t = timeit(fn, params, state, b)
        thpt = batch_size / t
        if base is None:
            base = thpt
        rows[name] = {"throughput_eps": round(thpt),
                      "speedup": round(thpt / base, 2)}
    return rows


def ap_ladder(n_edges: int = 4000, f_mem: int = 32, epochs: int = 2):
    """Full distillation ladder AP (slow: trains teacher + 5 students)."""
    from repro.core.pipeline import variant_config
    from repro.training import tgn_trainer as TT
    g = tgd.wikipedia_like(n_edges=n_edges)
    base = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f_mem, f_time=f_mem, f_emb=f_mem, m_r=10)
    tcfg = TT.TGNTrainConfig(batch_size=100, epochs=epochs)
    tr, va, te_sl = stream_mod.chronological_split(g)
    t_cfg = variant_config("Baseline", **base)
    t_params, _ = TT.train_teacher(g, t_cfg, tcfg)
    warm = slice(0, va.stop)
    out = {"Baseline": TT.evaluate_ap(t_params, t_cfg, g, te_sl,
                                      warm_window=warm)}
    for name in VARIANTS[1:]:
        s_cfg = variant_config(name, **base)
        s_params, _ = TT.distill_student(g, t_params, t_cfg, s_cfg, tcfg)
        out[name] = TT.evaluate_ap(s_params, s_cfg, g, te_sl,
                                   warm_window=warm)
        print(f"  [ap] {name}: {out[name]:.4f} "
              f"({out[name]-out['Baseline']:+.4f})")
    return out


def main(full: bool = False):
    print("== Table II: accumulated optimizations ==")
    result = {}
    for ds in ("Wikipedia", "Reddit", "GDELT"):
        result[ds] = analytic_ladder(ds)
        print(f"-- {ds} (analytic) --")
        for r in result[ds]:
            print(f"  {r['variant']:9s} kMAC={r['kMAC']:7.1f} "
                  f"({r['MAC_pct']:5.1f}% | paper {r['paper_MAC_pct']:5.1f}%)"
                  f"  kMEM={r['kMEM']:5.2f} ({r['MEM_pct']:5.1f}% | paper "
                  f"{r['paper_MEM_pct']:5.1f}%)")
    print("-- measured throughput (this host, batch 200) --")
    thpt = measured_throughput()
    for name, r in thpt.items():
        print(f"  {name:9s} {r['throughput_eps']:7d} E/s   "
              f"{r['speedup']:4.2f}x")
    result["measured_throughput"] = thpt
    if full:
        print("-- AP ladder (training + distillation) --")
        result["ap"] = ap_ladder()
    else:  # keep a previously-trained AP ladder (expensive to recompute)
        prev = load_json("table2.json") or {}
        if prev.get("ap"):
            result["ap"] = prev["ap"]
    save_json("table2.json", result)


if __name__ == "__main__":
    import sys
    main(full="--ap" in sys.argv)
