"""Sharded tenant fabric scaling: aggregate edges/s vs (tenants x devices).

The ShardedSessionManager (serving/cluster.py) spreads every cohort's
stacked ``(tenant, V, ...)`` VertexState over the mesh ``tenant`` axis —
the jax analogue of the paper's banked Graph Storage. This sweep measures
aggregate throughput of one fleet as BOTH the tenant count and the mesh
width grow (mesh=1 is the unsharded SessionManager baseline; trajectories
are bitwise-identical across the whole grid, so rows differ only in
placement).

Run it on a forced multi-device host (the Makefile's test-sharded flags):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.sharded_session

Without the flag (1 visible device) the sweep degrades to the mesh=1
column and says so. Imports are deferred so ``main()`` can print that
hint before jax initializes.
"""
from __future__ import annotations

import time


def _divisor_meshes(n_devices: int, tenants: int) -> list:
    """Mesh widths to sweep: device-count divisors up to the fleet size."""
    return [d for d in (1, 2, 4, 8, 16) if d <= n_devices
            and n_devices % d == 0 and d <= tenants]


def sweep(tenant_counts=(2, 4, 8), batch: int = 100, rounds: int = 6,
          n_edges: int = 3000, f_mem: int = 32,
          variant: str = "sat+lut+np4"):
    """edges/s of one fleet across the (tenants x mesh width) grid."""
    import jax
    import jax.numpy as jnp

    from repro.core import pipeline as pl, tgn
    from repro.data import stream as stream_mod
    from repro.data import temporal_graph as tgd
    from repro.serving.cluster import ShardedSessionManager
    from repro.serving.session import SessionManager

    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f_mem, f_time=f_mem, f_emb=f_mem, m_r=10)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)

    def feeds(T):
        return [list(stream_mod.fixed_count(
            g, batch,
            window=slice((37 * i) % max(1, g.n_edges - batch * rounds),
                         (37 * i) % max(1, g.n_edges - batch * rounds)
                         + batch * rounds),
            seed=i)) for i in range(T)]

    rows = []
    for T in tenant_counts:
        fs = feeds(T)
        for width in _divisor_meshes(jax.device_count(), T):
            mgr = (SessionManager(params, ef, model=cfg) if width == 1 else
                   ShardedSessionManager(params, ef, model=cfg,
                                         mesh=f"tenant={width}"))
            tids = [mgr.add_tenant() for _ in range(T)]
            mgr.step({t: fs[i][0] for i, t in enumerate(tids)})  # warmup/jit
            mgr.sync()                  # steps are async: drain before/after
            t0 = time.perf_counter()
            for r in range(1, rounds):
                mgr.step({t: fs[i][r] for i, t in enumerate(tids)})
            mgr.sync()
            dt = time.perf_counter() - t0
            rows.append({
                "tenants": T, "mesh": width, "batch": batch,
                "variant": variant,
                "eps": round((rounds - 1) * batch * T / dt),
            })
    return rows


def main(full: bool = False):
    import jax

    from benchmarks.common import save_json

    n_dev = jax.device_count()
    print(f"== sharded tenant fabric: edges/s vs (tenants x devices) "
          f"[{n_dev} device(s)] ==")
    if n_dev == 1:
        print("   (1 visible device: only the mesh=1 baseline column — "
              "rerun under XLA_FLAGS=--xla_force_host_platform_device_"
              "count=8 for the full grid)")
    else:
        print("   (forced host devices share one physical CPU: wider "
              "meshes pay partition overhead without extra silicon — "
              "speedups need real multi-device hardware)")
    counts = (2, 4, 8, 16) if full else (2, 4, 8)
    rows = sweep(tenant_counts=counts)
    base = {r["tenants"]: r["eps"] for r in rows if r["mesh"] == 1}
    for r in rows:
        rel = r["eps"] / base[r["tenants"]] if base.get(r["tenants"]) else 0
        print(f"  T={r['tenants']:3d} mesh={r['mesh']:2d} "
              f"{r['eps']:8d} E/s  ({rel:4.2f}x vs unsharded)")
    save_json("sharded_session.json", {"devices": n_dev, "sweep": rows})


if __name__ == "__main__":
    main()
