"""Run every benchmark (one per paper table/figure) and the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--full]

--full additionally trains the AP ladder (table2 --ap), which takes
minutes; default mode is analytic + measured-performance only.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    full = "--full" in sys.argv
    from benchmarks import (fig5_latency_throughput, fig6_perf_model,
                            fig7_accuracy_latency, frontend_latency,
                            fused_step, multitenant, roofline,
                            sharded_session, table1_case_study,
                            table2_model_opts, vertex_collectives)
    benches = [
        ("table1_case_study", table1_case_study),
        ("table2_model_opts", table2_model_opts),
        ("fig5_latency_throughput", fig5_latency_throughput),
        ("fig6_perf_model", fig6_perf_model),
        ("fig7_accuracy_latency", fig7_accuracy_latency),
        ("fused_step", fused_step),
        ("multitenant", multitenant),
        ("frontend_latency", frontend_latency),
        ("sharded_session", sharded_session),
        ("vertex_collectives", vertex_collectives),
        ("roofline", roofline),
    ]
    for name, mod in benches:
        t0 = time.time()
        print(f"\n######## {name} ########")
        mod.main(full=full)
        print(f"[{name}: {time.time()-t0:.1f}s]")


if __name__ == "__main__":
    main()
