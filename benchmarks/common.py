"""Shared benchmark helpers: timing, model builders, result IO."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def timeit(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall seconds per call (blocking on the result)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2)
    return path


def load_json(name: str):
    path = os.path.join(RESULTS_DIR, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def paper_tgn_config(variant: str, n_nodes: int, n_edges: int,
                     f_feat: int = 0, f_edge: int = 172, f_mem: int = 100):
    """TGNConfig for a Table-II ladder variant at PAPER dims.

    ``variant`` is any core.pipeline registry spec — a Table-II row name
    ("Baseline", "+NP(M)", ...) or a canonical string ("sat+lut+np4").
    """
    from repro.core.pipeline import variant_config
    return variant_config(variant, n_nodes=n_nodes, n_edges=n_edges,
                          f_feat=f_feat, f_edge=f_edge, f_mem=f_mem,
                          f_time=f_mem, f_emb=f_mem, m_r=10)


# Table-II row labels in ladder order (aliases of the pipeline registry).
VARIANTS = ("Baseline", "+SAT", "+LUT", "+NP(L)", "+NP(M)", "+NP(S)")
