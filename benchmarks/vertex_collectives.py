"""``vertex``-axis collectives profiling (ROADMAP open item).

The sharded tenant fabric can split each tenant's vertex tables over a
``vertex`` mesh axis — the jax analogue of the paper's banked Graph
Storage (§IV-A). Banking is free on the FPGA (BRAM ports); on a device
mesh every cross-bank gather/scatter of a step (neighbor fetch, LWW
commit, ring insert) becomes collective traffic XLA inserts. This sweep
measures what a real vertex-sharded mesh PAYS per step:

  * per-step collective bytes + op mix — ``launch/hlo_analysis.analyze``
    over the COMPILED (post-SPMD) cohort launch, ring-weighted per device;
  * wall clock per round through the ShardedSessionManager on the forced
    host mesh (devices share one CPU, so walls show overhead, not
    speedup — the collective bytes are the hardware-relevant signal).

Run on a forced multi-device host (the Makefile's test-sharded flags):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.vertex_collectives

With fewer devices the sweep degrades to the widths that fit and says so.
Baseline: results/vertex_collectives.json.
"""
from __future__ import annotations

import time


def sweep(tenants: int = 2, batch: int = 100, rounds: int = 4,
          n_edges: int = 2000, f_mem: int = 32,
          vertex_widths=(1, 2, 4), variant: str = "sat+lut+np4"):
    """One row per vertex-axis width: per-step collective traffic of the
    compiled cohort launch + measured round walls."""
    import jax
    import jax.numpy as jnp

    from repro.core import pipeline as pl, tgn
    from repro.data import stream as stream_mod
    from repro.data import temporal_graph as tgd
    from repro.launch import hlo_analysis as hlo
    from repro.serving.cluster import ShardedSessionManager
    from repro.serving.session import SessionManager

    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f_mem, f_time=f_mem, f_emb=f_mem, m_r=10)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)
    n_dev = jax.device_count()

    feeds = [list(stream_mod.fixed_count(
        g, batch, window=slice(60 * i, 60 * i + batch * rounds), seed=i))
        for i in range(tenants)]

    rows = []
    for width in vertex_widths:
        if width == 1:
            # unsharded baseline column: runs on any host (no mesh)
            mgr = SessionManager(params, ef, model=cfg)
        elif tenants * width > n_dev or n_dev % (tenants * width):
            continue
        else:
            mgr = ShardedSessionManager(params, ef, model=cfg,
                                        mesh=f"tenant={tenants},"
                                             f"vertex={width}")
        tids = [mgr.add_tenant() for _ in range(tenants)]
        mgr.step({t: feeds[i][0] for i, t in enumerate(tids)})  # compile
        mgr.sync()

        # post-SPMD HLO of the per-cohort launch: the compiled collective
        # schedule a vertex-sharded mesh actually executes per step. The
        # width=1 row uses the unsharded cohort's launch — 0 collective
        # bytes by construction, the comparison floor.
        cohort = mgr.cohort_of(tids[0])
        C = cohort.capacity
        zi = jnp.zeros((C, batch), jnp.int32)
        stacked = (zi, zi, zi, jnp.zeros((C, batch), jnp.float32),
                   jnp.zeros((C, batch), bool))
        lowered = cohort._vstep.lower(params, cohort.state, stacked, ef,
                                      None)
        res = hlo.analyze(lowered.compile().as_text())

        t0 = time.perf_counter()
        for r in range(1, rounds):
            mgr.step({t: feeds[i][r] for i, t in enumerate(tids)})
        mgr.sync()
        wall = (time.perf_counter() - t0) / (rounds - 1)
        edges = batch * tenants
        rows.append({
            "tenants": tenants, "vertex": width, "batch": batch,
            "variant": variant,
            "collective_bytes_per_step": round(res["collective_bytes"]),
            "collective_bytes_per_edge": round(
                res["collective_bytes"] / edges, 1),
            "collectives_by_op": {k: round(v) for k, v in
                                  res["collectives_by_op"].items()},
            "hbm_bytes_per_step": round(res["bytes"]),
            "round_ms": round(wall * 1e3, 2),
            "eps": round(edges / wall),
        })
    return rows


def main(full: bool = False):
    import jax

    from benchmarks.common import save_json

    n_dev = jax.device_count()
    print(f"== vertex-axis collectives: gather/scatter traffic per step "
          f"[{n_dev} device(s)] ==")
    if n_dev < 4:
        print("   (needs a multi-device host for the vertex>1 columns — "
              "rerun under XLA_FLAGS=--xla_force_host_platform_device_"
              "count=8)")
    rows = sweep()
    for r in rows:
        print(f"  vertex={r['vertex']}  "
              f"coll {r['collective_bytes_per_step']/1e6:7.3f} MB/step "
              f"({r['collective_bytes_per_edge']:8.1f} B/edge)  "
              f"round {r['round_ms']:7.2f} ms  {r['eps']:7d} E/s")
        if r["collectives_by_op"]:
            print(f"           by op: {r['collectives_by_op']}")
    if any(r["vertex"] > 1 for r in rows):
        save_json("vertex_collectives.json",
                  {"devices": n_dev, "sweep": rows})
    else:
        # baseline-only run (too few devices for a vertex axis): keep the
        # committed 8-device baseline instead of clobbering it
        print("   (vertex>1 columns unavailable — committed baseline left "
              "untouched)")


if __name__ == "__main__":
    main()
