"""Fig. 6 — performance-model accuracy.

Reproduces the paper's Section-V analytical model at its published design
points (U200 / ZCU104) and validates the max(compute, load-store) structure
against THIS host: we microbenchmark the host's effective matmul FLOP/s and
memory bandwidth, instantiate the same two-term model with those constants,
and compare its latency predictions against measured engine latencies per
NP variant — the paper reports 9.9–12.8% error on FPGA; we report ours.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json, timeit, paper_tgn_config
from repro.core import perf_model as pm
from repro.core import tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine


def fpga_design_points():
    rows = []
    for name, cfg in (("U200", pm.U200), ("ZCU104", pm.ZCU104)):
        for bs in (100, 200, 400):
            p = pm.predict(cfg, bs)
            rows.append({"board": name, "batch": bs,
                         "pred_latency_ms": round(p["latency_s"] * 1e3, 3),
                         "pred_throughput_keps":
                             round(p["throughput_eps"] / 1e3, 1),
                         "compute_bound": p["compute_bound"]})
    return rows


def host_constants():
    """Microbenchmark this host: matmul FLOP/s and streaming bytes/s."""
    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    t = timeit(f, a, iters=5)
    flops = 2 * n ** 3 / t
    big = jnp.ones((64 * 1024 * 1024 // 4,), jnp.float32)  # 64 MB
    g = jax.jit(lambda x: x * 2.0 + 1.0)
    t2 = timeit(g, big, iters=5)
    bw = 3 * big.size * 4 / t2  # read + write + read-modify
    return {"flops": flops, "bw": bw}


def host_model_vs_measured(n_edges: int = 3000, f_mem: int = 100):
    """Two-term model with host constants vs measured engine latency."""
    const = host_constants()
    g = tgd.wikipedia_like(n_edges=n_edges)
    ef = jnp.asarray(g.edge_feats)
    batch = next(iter(stream_mod.fixed_count(g, 200,
                                             window=slice(1000, 3000))))
    dev = tuple(jnp.asarray(x) for x in (batch.src, batch.dst, batch.eid,
                                         batch.ts, batch.valid))
    rows = []
    from repro.core import complexity as cx
    for name, k in (("+NP(L)", 6), ("+NP(M)", 4), ("+NP(S)", 2)):
        cfg = paper_tgn_config(name, g.cfg.n_nodes, g.n_edges, f_mem=f_mem)
        params = tgn.init_params(jax.random.key(0), cfg)
        eng = StreamingEngine(EngineConfig(model=cfg), params, ef)
        t_meas = timeit(lambda: eng.step_on_device(dev), iters=5)
        ccfg = cx.ComplexityConfig(f_edge=172, f_mem=f_mem, f_time=f_mem,
                                   f_emb=f_mem, attention="sat",
                                   encoder="lut", prune_k=k)
        n_emb = 2 * 200
        macs = cx.stage_macs(ccfg)["total"] * n_emb
        mems = cx.stage_mems(ccfg)["total"] * n_emb * 4  # fp32 bytes
        t_comp = 2 * macs / const["flops"]
        t_ls = mems / const["bw"]
        t_pred = max(t_comp, t_ls)
        rows.append({
            "variant": name,
            "measured_ms": round(t_meas * 1e3, 3),
            "pred_ms": round(t_pred * 1e3, 3),
            "pred_err_pct": round(100 * abs(t_pred - t_meas) / t_meas, 1),
            "bound": "compute" if t_comp > t_ls else "loadstore",
        })
    return const, rows


def main(full: bool = False):
    print("== Fig. 6: Section-V performance model ==")
    print("-- published FPGA design points (Eq. 18-22) --")
    for r in fpga_design_points():
        print(f"  {r['board']:7s} B={r['batch']:4d} "
              f"lat={r['pred_latency_ms']:7.3f}ms "
              f"thpt={r['pred_throughput_keps']:7.1f}kE/s "
              f"{'compute' if r['compute_bound'] else 'memory'}-bound")
    const, rows = host_model_vs_measured()
    print(f"-- host constants: {const['flops']/1e9:.1f} GFLOP/s, "
          f"{const['bw']/1e9:.1f} GB/s --")
    for r in rows:
        print(f"  {r['variant']:7s} measured={r['measured_ms']:7.3f}ms "
              f"pred={r['pred_ms']:7.3f}ms err={r['pred_err_pct']:5.1f}% "
              f"({r['bound']}-bound)")
    save_json("fig6.json", {"fpga": fpga_design_points(),
                            "host_constants": const, "host_rows": rows})


if __name__ == "__main__":
    main()
