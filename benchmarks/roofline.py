"""§Roofline — read the dry-run JSONs and emit the per-(arch x shape) table:
three roofline terms, dominant bottleneck, MODEL_FLOPS ratio, and a one-line
what-would-move-it note. Single-pod cells only (per the assignment)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_json
from repro.core import perf_model as pm

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def _advice(bound: str, r: dict) -> str:
    ucr = r.get("useful_compute_ratio", 0)
    if bound == "compute":
        if ucr < 0.4:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute / causal-block waste")
        return "compute-bound near useful peak: more chips or lower remat"
    if bound == "memory":
        return ("memory-bound: fuse elementwise chains, shrink remat "
                "residual traffic, bf16 more activations")
    return ("collective-bound: reshard to cut all-gathers (see "
            "collectives_by_op), overlap with compute")


def load_rows(multi_pod: bool = False):
    rows = []
    tag = "2pod" if multi_pod else "1pod"
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*__{tag}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r["status"].startswith("skip"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r["status"]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "FAIL"})
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "bound": rl["bound"],
            "model_flops_per_device": r["model_flops_per_device"],
            "useful_compute_ratio": r["useful_compute_ratio"],
            "peak_gib": (r["memory"]["peak_bytes"] or 0) / 2 ** 30,
            "advice": _advice(rl["bound"], r),
        })
    return rows


def main(full: bool = False):
    rows = load_rows(multi_pod=False)
    if not rows:
        print("== §Roofline: no dry-run results found; run "
              "`python -m repro.launch.dryrun --arch all --shape all "
              "--both-meshes --out results/dryrun` first ==")
        return
    print("== §Roofline (single-pod 16x16, per-device terms, seconds) ==")
    print(f"{'arch':22s}{'shape':13s}{'compute':>10s}{'memory':>10s}"
          f"{'collective':>11s}  {'bound':10s}{'useful':>7s}{'peakGiB':>8s}")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:22s}{r['shape']:13s}  -> {r['status']}")
            continue
        print(f"{r['arch']:22s}{r['shape']:13s}"
              f"{r['compute_s']:10.4f}{r['memory_s']:10.4f}"
              f"{r['collective_s']:11.4f}  {r['bound']:10s}"
              f"{r['useful_compute_ratio']:7.2f}{r['peak_gib']:8.2f}")
    save_json("roofline.json", rows)


if __name__ == "__main__":
    main()
