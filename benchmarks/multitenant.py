"""Multi-tenant throughput scaling: launch coalescing vs tenant count.

Two dispatch axes, both measured here:

  * batched vs sequential — the SessionManager advances every same-variant
    tenant in ONE vmapped launch; the alternative is stepping N
    StreamingEngine sessions back-to-back (N launches);
  * coalesced vs per-cohort — a MIXED fleet (several variants) used to pay
    one launch PER COHORT per round; ``pipeline.CoalescedRound`` fuses the
    whole round into one compiled execution fed by one in-place-staged
    ``device_put`` (``SessionManager(coalesce=True)``, the default).
    ``coalesced_sweep`` measures aggregate edges/s of both dispatch modes
    over a (cohorts x tenants) grid — the dispatch-bound small-batch
    streaming regime the paper's single-pass pipeline targets.

    PYTHONPATH=src python -m benchmarks.multitenant
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_json
from repro.core import pipeline as pl, tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import StreamingEngine
from repro.serving.session import SessionManager

#: Cohort ladder of the mixed fleets on the DEFAULT parameter set: the
#: prune axis plus a sampler cohort (tenants without their own registered
#: weights must match the session's attention+encoder; ``mixed_models``
#: below benchmarks the fleets that bring their own — teacher vs student).
MIXED_VARIANTS = ("sat+lut+np4", "sat+lut+np2", "sat+lut+np4+reservoir",
                  "sat+lut+np4+uniform", "sat+lut+np6")


def _dims(g, f_mem):
    return dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f_mem, f_time=f_mem, f_emb=f_mem, m_r=10)


def _tenant_batches(g, i, batch, rounds):
    lo = (37 * i) % max(1, g.n_edges - batch * rounds)
    return list(stream_mod.fixed_count(
        g, batch, window=slice(lo, lo + batch * rounds), seed=i))


def _time_rounds(step_round, rounds, warmup=1, sync=None):
    """Wall seconds for rounds [warmup, rounds); ``sync`` drains async
    session dispatch before each clock read (engines block themselves)."""
    for r in range(warmup):
        step_round(r)
    if sync is not None:
        sync()
    t0 = time.perf_counter()
    for r in range(warmup, rounds):
        step_round(r)
    if sync is not None:
        sync()
    return time.perf_counter() - t0


def sweep(tenant_counts=(1, 2, 4, 8), batch: int = 100, rounds: int = 6,
          n_edges: int = 3000, f_mem: int = 32,
          variant: str = "sat+lut+np4", use_kernels: bool = False):
    """Batched (one launch) vs sequential (N launches) aggregate edges/s."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = _dims(g, f_mem)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)
    rows = []
    for T in tenant_counts:
        feeds = [_tenant_batches(g, i, batch, rounds) for i in range(T)]

        mgr = SessionManager(params, ef, model=cfg, use_kernels=use_kernels)
        tids = [mgr.add_tenant() for _ in range(T)]
        dt_b = _time_rounds(
            lambda r: mgr.step({t: feeds[i][r]
                                for i, t in enumerate(tids)}), rounds,
            sync=mgr.sync)

        engines = [StreamingEngine.from_variant(variant, params, ef,
                                                use_kernels=use_kernels,
                                                **dims) for _ in range(T)]

        def seq_round(r):
            for i, eng in enumerate(engines):
                eng.process(feeds[i][r])

        dt_s = _time_rounds(seq_round, rounds)

        timed = (rounds - 1) * batch * T
        rows.append({
            "tenants": T, "batch": batch, "variant": variant,
            "batched_eps": round(timed / dt_b),
            "sequential_eps": round(timed / dt_s),
            "speedup": round(dt_s / dt_b, 2),
        })
    return rows


def mixed_fleet(batch: int = 100, rounds: int = 6, n_edges: int = 3000,
                f_mem: int = 32):
    """A fleet mixing sampler policies: 3 cohorts, ONE coalesced launch."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = _dims(g, f_mem)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    variants = ("sat+lut+np4", "sat+lut+np4", "sat+lut+np4+uniform",
                "sat+lut+np4+reservoir")
    tids = [mgr.add_tenant(v) for v in variants]
    feeds = [_tenant_batches(g, i, batch, rounds) for i in range(len(tids))]
    for r in range(rounds):
        mgr.step({t: feeds[i][r] for i, t in enumerate(tids)})
    return {"cohorts": len(mgr.describe()), **mgr.summary()}


def mixed_models(batch: int = 100, rounds: int = 8, n_edges: int = 3000,
                 f_mem: int = 32, students: int = 2):
    """The A/B-serving fleet: one teacher lane (vanilla+cosine, its own
    weights) + ``students`` re-distilled student lanes on per-lane
    registered parameter sets, all advancing in ONE coalesced launch per
    round — vs the same fleet as separate per-model sessions (one launch
    per model per round)."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = _dims(g, f_mem)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    tcfg = pl.variant_config("teacher", **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)
    lanes = [("sat+lut+np4", None, cfg, params),
             ("teacher", "teacher-v1", tcfg,
              tgn.init_params(jax.random.key(1), tcfg))]
    for s in range(students):
        lanes.append(("sat+lut+np4", f"student-{s}", cfg,
                      tgn.init_params(jax.random.key(2 + s), cfg)))
    feeds = [_tenant_batches(g, i, batch, rounds)
             for i in range(len(lanes))]

    mgr = SessionManager(params, ef, model=cfg)
    for _v, pname, _c, p in lanes[1:]:
        mgr.register_params(pname, p)
    tids = [mgr.add_tenant(v, params=pname) for v, pname, _c, _p in lanes]
    dt_one = _time_rounds(
        lambda r: mgr.step({t: feeds[i][r] for i, t in enumerate(tids)}),
        rounds, warmup=2, sync=mgr.sync)
    launches = {m["launches"] for m in mgr.metrics[2:]}

    # baseline: one separate session per model (per-model launches)
    sessions = []
    for i, (v, _pname, c, p) in enumerate(lanes):
        m = SessionManager(p, ef, model=c)
        sessions.append((m, m.add_tenant(v if c is cfg else None)))

    def sep_round(r):
        for i, (m, t) in enumerate(sessions):
            m.step({t: feeds[i][r]})

    dt_sep = _time_rounds(sep_round, rounds, warmup=2,
                          sync=lambda: [m.sync() for m, _t in sessions])
    timed = (rounds - 2) * batch * len(lanes)
    return {
        "models": len(lanes), "batch": batch,
        "param_sets": len(mgr.param_store.names()),
        "launches_per_round": sorted(launches),
        "coalesced_eps": round(timed / dt_one),
        "per_model_eps": round(timed / dt_sep),
        "speedup": round(dt_sep / dt_one, 2),
    }


def coalesced_sweep(tenant_counts=(2, 4, 8, 16), cohort_counts=(1, 2, 3),
                    batch: int = 25, rounds: int = 22, n_edges: int = 4000,
                    f_mem: int = 32):
    """Coalesced (one fused launch per round) vs per-cohort (one launch
    per cohort per round) aggregate edges/s over a (cohorts x tenants)
    grid of mixed fleets — small streaming batches, the dispatch-bound
    regime the coalesced round targets."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = _dims(g, f_mem)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)
    rows = []
    for C in cohort_counts:
        variants = MIXED_VARIANTS[:C]
        for T in tenant_counts:
            if T < C:
                continue
            feeds = [_tenant_batches(g, i, batch, rounds) for i in range(T)]
            eps = {}
            for mode, coalesce in (("coalesced", True),
                                   ("per_cohort", False)):
                mgr = SessionManager(params, ef, model=cfg,
                                     coalesce=coalesce)
                tids = [mgr.add_tenant(variants[i % C]) for i in range(T)]
                dt = _time_rounds(
                    lambda r: mgr.step({t: feeds[i][r]
                                        for i, t in enumerate(tids)}),
                    rounds, warmup=2, sync=mgr.sync)
                eps[mode] = (rounds - 2) * batch * T / dt
                eps[f"{mode}_launches"] = mgr.metrics[-1]["launches"]
                if coalesce:
                    registry = mgr.obs.snapshot()
            rows.append({
                "cohorts": C, "tenants": T, "batch": batch,
                "coalesced_eps": round(eps["coalesced"]),
                "per_cohort_eps": round(eps["per_cohort"]),
                "speedup": round(eps["coalesced"] / eps["per_cohort"], 2),
                "launches_per_round": (eps["coalesced_launches"],
                                       eps["per_cohort_launches"]),
                # unified obs view of the coalesced run (rounds, launches,
                # compile counters) persisted with the derived numbers
                "registry": registry,
            })
    return rows


def main(full: bool = False):
    print("== multi-tenant throughput scaling (SessionManager vmap vs "
          "sequential engines) ==")
    counts = (1, 2, 4, 8) if not full else (1, 2, 4, 8, 16)
    rows = sweep(tenant_counts=counts)
    for r in rows:
        print(f"  T={r['tenants']:3d} batched={r['batched_eps']:8d} E/s  "
              f"sequential={r['sequential_eps']:8d} E/s  "
              f"speedup={r['speedup']:.2f}x")
    mixed = mixed_fleet()
    print(f"-- mixed-sampler fleet (np4 x2 / uniform / reservoir): {mixed}")
    models = mixed_models()
    print(f"-- mixed-MODEL fleet (teacher + {models['models'] - 2} "
          f"students + default): {models}")
    save_json("multitenant.json", {"sweep": rows, "mixed": mixed,
                                   "mixed_models": models})

    print("== coalesced round (one launch) vs per-cohort launches ==")
    crows = coalesced_sweep()
    for r in crows:
        print(f"  C={r['cohorts']} T={r['tenants']:3d} "
              f"coalesced={r['coalesced_eps']:8d} E/s  "
              f"per-cohort={r['per_cohort_eps']:8d} E/s  "
              f"speedup={r['speedup']:.2f}x  "
              f"launches/round={r['launches_per_round']}")
    save_json("multitenant_coalesced.json", {"sweep": crows})


if __name__ == "__main__":
    main()
