"""Multi-tenant throughput scaling: one vmapped launch vs tenant count.

The SessionManager advances every same-variant tenant stream in ONE device
launch (stacked VertexState + ``jax.vmap``); the alternative is stepping N
StreamingEngine sessions back-to-back (N launches). This sweep measures
aggregate edges/s of both dispatch modes as the tenant fleet grows, plus a
mixed-sampler fleet (one cohort per sampler backend).

    PYTHONPATH=src python -m benchmarks.multitenant
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_json
from repro.core import pipeline as pl, tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import StreamingEngine
from repro.serving.session import SessionManager


def _dims(g, f_mem):
    return dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f_mem, f_time=f_mem, f_emb=f_mem, m_r=10)


def _tenant_batches(g, i, batch, rounds):
    lo = (37 * i) % max(1, g.n_edges - batch * rounds)
    return list(stream_mod.fixed_count(
        g, batch, window=slice(lo, lo + batch * rounds), seed=i))


def _time_rounds(step_round, rounds, warmup=1):
    for r in range(warmup):
        step_round(r)
    t0 = time.perf_counter()
    for r in range(warmup, rounds):
        step_round(r)
    return time.perf_counter() - t0


def sweep(tenant_counts=(1, 2, 4, 8), batch: int = 100, rounds: int = 6,
          n_edges: int = 3000, f_mem: int = 32,
          variant: str = "sat+lut+np4", use_kernels: bool = False):
    """Batched (one launch) vs sequential (N launches) aggregate edges/s."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = _dims(g, f_mem)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)
    rows = []
    for T in tenant_counts:
        feeds = [_tenant_batches(g, i, batch, rounds) for i in range(T)]

        mgr = SessionManager(params, ef, model=cfg, use_kernels=use_kernels)
        tids = [mgr.add_tenant() for _ in range(T)]
        dt_b = _time_rounds(
            lambda r: mgr.step({t: feeds[i][r]
                                for i, t in enumerate(tids)}), rounds)

        engines = [StreamingEngine.from_variant(variant, params, ef,
                                                use_kernels=use_kernels,
                                                **dims) for _ in range(T)]

        def seq_round(r):
            for i, eng in enumerate(engines):
                eng.process(feeds[i][r])

        dt_s = _time_rounds(seq_round, rounds)

        timed = (rounds - 1) * batch * T
        rows.append({
            "tenants": T, "batch": batch, "variant": variant,
            "batched_eps": round(timed / dt_b),
            "sequential_eps": round(timed / dt_s),
            "speedup": round(dt_s / dt_b, 2),
        })
    return rows


def mixed_fleet(batch: int = 100, rounds: int = 6, n_edges: int = 3000,
                f_mem: int = 32):
    """A fleet mixing sampler policies: one launch per cohort per round."""
    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = _dims(g, f_mem)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    variants = ("sat+lut+np4", "sat+lut+np4", "sat+lut+np4+uniform",
                "sat+lut+np4+reservoir")
    tids = [mgr.add_tenant(v) for v in variants]
    feeds = [_tenant_batches(g, i, batch, rounds) for i in range(len(tids))]
    for r in range(rounds):
        mgr.step({t: feeds[i][r] for i, t in enumerate(tids)})
    return {"cohorts": len(mgr.describe()),
            "launches_per_round": mgr.metrics[-1]["launches"],
            **mgr.summary()}


def main(full: bool = False):
    print("== multi-tenant throughput scaling (SessionManager vmap vs "
          "sequential engines) ==")
    counts = (1, 2, 4, 8) if not full else (1, 2, 4, 8, 16)
    rows = sweep(tenant_counts=counts)
    for r in rows:
        print(f"  T={r['tenants']:3d} batched={r['batched_eps']:8d} E/s  "
              f"sequential={r['sequential_eps']:8d} E/s  "
              f"speedup={r['speedup']:.2f}x")
    mixed = mixed_fleet()
    print(f"-- mixed-sampler fleet (np4 x2 / uniform / reservoir): {mixed}")
    save_json("multitenant.json", {"sweep": rows, "mixed": mixed})


if __name__ == "__main__":
    main()
