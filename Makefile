# Uniform verify targets for the builder and future PRs.
#
#   make test         tier-1 suite (the ROADMAP verify command)
#   make test-sharded sharded tenant-fabric tests (tests/test_cluster.py)
#                     on a forced 8-device host mesh — tier-1 runs them
#                     skipped because conftest.py keeps XLA_FLAGS unset
#   make test-kernels the kernel equivalence suite (staged + fused Pallas
#                     kernels vs their jnp oracles, interpret mode)
#   make bench-smoke  one tiny fig5 sweep through the streaming engine +
#                     a toy-scale coalesced-vs-per-cohort multitenant sweep
#                     + a toy-scale fused-vs-staged step sweep
#   make docs-check   intra-repo doc links resolve + every variant spec in
#                     docs exists in the pipeline registry
#   make serve-smoke  online-frontend smoke: 3 tenants / 2 cohorts, a few
#                     hundred deadline-batched edges, a live mid-stream
#                     tenant attach+detach — asserts ZERO recompiles of
#                     the coalesced round (tools/serve_smoke.py)
#   make chaos-smoke  fault-injection smoke: a deterministic fault plan
#                     (NaN state, snapshot IO, kernel fail, stall) against
#                     a guarded 3-cohort fleet — quarantine + auto-restore
#                     + tier degradation, survivors BITWISE
#                     (tools/chaos_smoke.py; docs/ROBUSTNESS.md)
#   make journal-smoke durable-journal smoke: ingest -> kill mid-stream
#                     -> recover (snapshot + journal replay) -> bitwise
#                     vs an uninterrupted twin, plus a duplicate-ingest
#                     fuzz leg (tools/journal_smoke.py;
#                      docs/ROBUSTNESS.md recovery semantics)
#   make session-lint the serving round path stages through the in-place
#                     _HostStager ring buffers (no jnp.pad/jnp.stack/...
#                     per-tenant staging regressions) AND the fused step
#                     path never re-materializes neighbor gathers/concats
#   make coverage     line-coverage floor over the serving stack + the
#                     observability layer (pytest-cov when installed,
#                      else an in-process settrace fallback;
#                      tools/coverage_gate.py)
#   make bench-gate   throughput regression gate: re-runs the toy-scale
#                     coalesced/fused/fig5 sweeps and fails on >25%
#                     edges/s regression vs results/bench_gate.json
#                     (refresh an intended change with
#                      `python tools/bench_gate.py --update`)
#   make lint         pyflakes over src/ tests/ benchmarks/ examples/
#                     (falls back to a bytecode-compile check when
#                      pyflakes is not installed; see requirements-dev.txt)
#                     + docs-check + session-lint + serve-smoke +
#                     chaos-smoke + journal-smoke + test-sharded +
#                     test-kernels + coverage + bench-gate

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-sharded test-kernels bench-smoke serve-smoke \
	chaos-smoke journal-smoke lint docs-check session-lint coverage \
	bench-gate

test:
	$(PY) -m pytest -x -q

test-sharded:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	    $(PY) -m pytest -x -q tests/test_cluster.py tests/test_tgn_sharding.py

test-kernels:
	$(PY) -m pytest -x -q tests/test_kernels.py

bench-smoke:
	$(PY) -c "from benchmarks.fig5_latency_throughput import sweep; \
	          rows = sweep(batch_sizes=(25,), n_edges=600, f_mem=16); \
	          [print(r) for r in rows]"
	$(PY) -c "from benchmarks.multitenant import coalesced_sweep; \
	          rows = coalesced_sweep(tenant_counts=(3,), cohort_counts=(3,), \
	              batch=16, rounds=4, n_edges=600, f_mem=16); \
	          [print(r) for r in rows]"
	$(PY) -c "from benchmarks.fused_step import sweep; \
	          rows = sweep(batch_sizes=(16,), rounds=4, n_edges=600, \
	              f_mem=16); \
	          [print(r) for r in rows]"

serve-smoke:
	$(PY) tools/serve_smoke.py

chaos-smoke:
	$(PY) tools/chaos_smoke.py

journal-smoke:
	$(PY) tools/journal_smoke.py

docs-check:
	$(PY) tools/docs_check.py

session-lint:
	$(PY) tools/session_lint.py

coverage:
	$(PY) tools/coverage_gate.py

bench-gate:
	$(PY) tools/bench_gate.py

lint: docs-check session-lint serve-smoke chaos-smoke journal-smoke \
		test-sharded test-kernels coverage bench-gate
	@if $(PY) -c "import pyflakes" 2>/dev/null; then \
	    $(PY) -m pyflakes src benchmarks examples tests/*.py; \
	else \
	    echo 'pyflakes not installed; falling back to compileall'; \
	    $(PY) -m compileall -q src benchmarks examples tests; \
	fi
