"""Chronological batching of a temporal edge stream (Section II-A setup).

Two batch-forming policies, as in the paper:
  * ``fixed_count``  — batches of a fixed number of graph signals;
  * ``time_window``  — all signals inside fixed wall-clock windows (the
    paper's "every 15 minutes" real-time latency experiment, Fig. 5 right).

Batches are padded to a fixed shape so a single jit'd ``process_batch``
serves the whole stream (padding rows are masked via eid/valid). Also
provides the train/val/test chronological split and negative destination
sampling used by the self-supervised link task.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple

import numpy as np

from repro.data.temporal_graph import TemporalGraph


class EdgeBatch(NamedTuple):
    src: np.ndarray     # (B,) int32 (padded rows repeat the last edge)
    dst: np.ndarray     # (B,) int32
    eid: np.ndarray     # (B,) int32 — row into the edge-feature store
    ts: np.ndarray      # (B,) float32
    valid: np.ndarray   # (B,) bool — False on padding rows
    neg_dst: np.ndarray # (B,) int32 — sampled negative destinations


def chronological_split(g: TemporalGraph, val: float = 0.15,
                        test: float = 0.15):
    """Return (train_slice, val_slice, test_slice) index ranges."""
    E = g.n_edges
    n_test = int(E * test)
    n_val = int(E * val)
    n_train = E - n_val - n_test
    return slice(0, n_train), slice(n_train, n_train + n_val), \
        slice(n_train + n_val, E)


def _pad(x: np.ndarray, B: int) -> np.ndarray:
    if x.shape[0] == B:
        return x
    reps = np.repeat(x[-1:], B - x.shape[0], axis=0)
    return np.concatenate([x, reps], axis=0)


def fixed_count(g: TemporalGraph, batch_size: int, *,
                window: slice | None = None, seed: int = 0,
                item_range: tuple[int, int] | None = None
                ) -> Iterator[EdgeBatch]:
    """Yield padded fixed-size chronological batches over ``window``."""
    rng = np.random.RandomState(seed)
    lo = (window.start or 0) if window else 0
    hi = window.stop if window and window.stop is not None else g.n_edges
    if item_range is None:
        item_range = (g.cfg.n_users, g.cfg.n_nodes)
    for s in range(lo, hi, batch_size):
        e = min(s + batch_size, hi)
        idx = np.arange(s, e)
        n = idx.shape[0]
        neg = rng.randint(item_range[0], item_range[1],
                          size=batch_size).astype(np.int32)
        yield EdgeBatch(
            src=_pad(g.src[idx], batch_size),
            dst=_pad(g.dst[idx], batch_size),
            eid=_pad(idx.astype(np.int32), batch_size),
            ts=_pad(g.ts[idx], batch_size),
            valid=np.arange(batch_size) < n,
            neg_dst=neg,
        )


def time_window(g: TemporalGraph, window_s: float, max_batch: int, *,
                window: slice | None = None, seed: int = 0
                ) -> Iterator[EdgeBatch]:
    """Yield batches of all edges inside consecutive ``window_s``-second
    windows (padded/truncated to ``max_batch`` — the paper's real-time
    inference mode)."""
    rng = np.random.RandomState(seed)
    lo = (window.start or 0) if window else 0
    hi = window.stop if window and window.stop is not None else g.n_edges
    i = lo
    while i < hi:
        t0 = g.ts[i]
        j = i
        while j < hi and g.ts[j] < t0 + window_s and j - i < max_batch:
            j += 1
        idx = np.arange(i, j)
        n = idx.shape[0]
        neg = rng.randint(g.cfg.n_users, g.cfg.n_nodes,
                          size=max_batch).astype(np.int32)
        yield EdgeBatch(
            src=_pad(g.src[idx], max_batch),
            dst=_pad(g.dst[idx], max_batch),
            eid=_pad(idx.astype(np.int32), max_batch),
            ts=_pad(g.ts[idx], max_batch),
            valid=np.arange(max_batch) < n,
            neg_dst=neg,
        )
        i = j
