"""Synthetic temporal-graph generators shaped like the paper's datasets.

No dataset downloads are possible in this environment, so we generate
streams with the statistical properties the paper's techniques exploit:

  * bipartite user->item interactions (Wikipedia/Reddit are user-page /
    user-subreddit streams),
  * Zipfian endpoint popularity (a few very active vertices),
  * power-law inter-event times (the LUT encoder's equal-frequency bucketing
    premise — Fig. 1 of the paper),
  * LEARNABLE structure: each user/item has a latent preference vector;
    interaction probability follows latent affinity, and edge features are a
    noisy projection of the endpoint latents. Link prediction AP >> 0.5 is
    achievable, so teacher-vs-student accuracy comparisons are meaningful.

``wikipedia_like`` / ``reddit_like`` emit 172-dim edge features and no node
features; ``gdelt_like`` emits 200-dim static node features and no edge
features (matching Table II's input-dimension header).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.utils import FrozenConfig


@dataclasses.dataclass(frozen=True)
class StreamConfig(FrozenConfig):
    n_users: int = 600
    n_items: int = 400
    n_edges: int = 20_000
    f_edge: int = 172
    f_feat: int = 0            # static node feature dim
    latent: int = 16
    zipf_a: float = 1.2        # endpoint popularity skew
    pareto_a: float = 1.1      # inter-event time tail
    t_scale: float = 60.0      # median inter-event seconds
    noise: float = 0.3
    seed: int = 0

    @property
    def n_nodes(self) -> int:
        return self.n_users + self.n_items


@dataclasses.dataclass
class TemporalGraph:
    """A chronological edge stream + feature stores (host numpy)."""
    src: np.ndarray        # (E,) int32 — user ids in [0, n_users)
    dst: np.ndarray        # (E,) int32 — item ids in [n_users, n_nodes)
    ts: np.ndarray         # (E,) float32 — strictly non-decreasing
    edge_feats: np.ndarray # (E, f_edge) float32 (f_edge may be 0)
    node_feats: np.ndarray | None  # (n_nodes, f_feat) or None
    cfg: StreamConfig

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


def _zipf_choice(rng: np.random.RandomState, n: int, size: int,
                 a: float) -> np.ndarray:
    """Zipf-distributed ids in [0, n) via inverse-rank sampling."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    return rng.choice(n, size=size, p=p)


def generate(cfg: StreamConfig) -> TemporalGraph:
    rng = np.random.RandomState(cfg.seed)
    U, I, E = cfg.n_users, cfg.n_items, cfg.n_edges

    # latent affinity structure
    zu = rng.randn(U, cfg.latent).astype(np.float32) / np.sqrt(cfg.latent)
    zi = rng.randn(I, cfg.latent).astype(np.float32) / np.sqrt(cfg.latent)

    src = _zipf_choice(rng, U, E, cfg.zipf_a).astype(np.int32)
    # each user interacts preferentially with high-affinity items:
    # sample a candidate set and pick by softmax affinity (vectorized)
    n_cand = 8
    cand = _zipf_choice(rng, I, E * n_cand, cfg.zipf_a).reshape(E, n_cand)
    aff = np.einsum("el,ecl->ec", zu[src], zi[cand])
    aff += cfg.noise * rng.randn(E, n_cand).astype(np.float32)
    pick = np.argmax(aff, axis=1)
    dst_item = cand[np.arange(E), pick].astype(np.int32)

    # power-law inter-event times -> strictly increasing timestamps
    gaps = (rng.pareto(cfg.pareto_a, size=E) + 1.0) * cfg.t_scale
    ts = np.cumsum(gaps).astype(np.float32)

    # edge features: noisy projection of endpoint latents (learnable signal)
    if cfg.f_edge > 0:
        proj = rng.randn(2 * cfg.latent, cfg.f_edge).astype(np.float32)
        proj /= np.sqrt(2 * cfg.latent)
        lat = np.concatenate([zu[src], zi[dst_item]], axis=1)
        edge_feats = lat @ proj + cfg.noise * rng.randn(E, cfg.f_edge).astype(
            np.float32)
        edge_feats = edge_feats.astype(np.float32)
    else:
        edge_feats = np.zeros((E, 0), np.float32)

    if cfg.f_feat > 0:
        projn = rng.randn(cfg.latent, cfg.f_feat).astype(np.float32)
        projn /= np.sqrt(cfg.latent)
        node_feats = np.concatenate([zu, zi], axis=0) @ projn
        node_feats = node_feats.astype(np.float32)
    else:
        node_feats = None

    return TemporalGraph(src=src, dst=(dst_item + U).astype(np.int32),
                         ts=ts, edge_feats=edge_feats,
                         node_feats=node_feats, cfg=cfg)


def wikipedia_like(n_edges: int = 20_000, seed: int = 0) -> TemporalGraph:
    return generate(StreamConfig(n_users=600, n_items=400, n_edges=n_edges,
                                 f_edge=172, f_feat=0, seed=seed))


def reddit_like(n_edges: int = 20_000, seed: int = 1) -> TemporalGraph:
    return generate(StreamConfig(n_users=800, n_items=200, n_edges=n_edges,
                                 f_edge=172, f_feat=0, zipf_a=1.4, seed=seed))


def gdelt_like(n_edges: int = 20_000, seed: int = 2) -> TemporalGraph:
    return generate(StreamConfig(n_users=500, n_items=500, n_edges=n_edges,
                                 f_edge=0, f_feat=200, seed=seed))


DATASETS = {"wikipedia": wikipedia_like, "reddit": reddit_like,
            "gdelt": gdelt_like}
