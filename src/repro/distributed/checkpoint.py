"""Fault-tolerant checkpointing: atomic, versioned, checksummed, reshardable.

Layout (one directory per step):

    <root>/step_00001000.tmp/     # written here first
        manifest.json             # treedef, shapes, dtypes, checksums, meta
        arr_00000.npy ...         # one file per leaf (host-gathered)
    <root>/step_00001000/         # atomic rename on completion

Guarantees:
  * a crash mid-write never corrupts a restorable checkpoint (tmp dirs are
    ignored and garbage-collected on the next save);
  * every leaf carries a crc32 — silent corruption is detected at load;
  * load is RESHARDING: arrays are placed with whatever NamedShardings the
    (possibly different) target mesh prescribes — the restore path is the
    elastic-scaling path (see elastic.py);
  * ``save_async`` runs host-gather + IO on a background thread, double
    buffered — the device keeps training.

Single-process scope: leaves are host-gathered full arrays. A multi-host
deployment would write per-shard files (same manifest format, one payload
per (host, shard)) — the structure here is deliberately compatible.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import warnings
import zlib
from typing import Any

import jax
import ml_dtypes
import numpy as np

PyTree = Any
_MANIFEST = "manifest.json"

# numpy can't serialize ml_dtypes (bfloat16 etc.) through np.save — they load
# back as void. Store them as unsigned views and record the logical dtype.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _to_saveable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1]), name
    return arr, name


def _from_saveable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][0])
    return arr


def _leaf_paths(tree: PyTree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, _ in flat:
        parts = []
        for k in kp:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(str(k.idx))
            else:
                parts.append(str(getattr(k, "name", k)))
        out.append(".".join(parts))
    return out


def tree_digest(tree: PyTree) -> str:
    """Order-stable crc32 digest over a pytree's leaf paths AND values —
    the identity a snapshot manifest records for the parameter set a
    tenant was serving on (cheap content fingerprint, not cryptographic).
    Two sets digest equal iff every leaf path and every byte match, so a
    restore can verify it is resuming on the SAME weights."""
    crc = 0
    for path, leaf in zip(_leaf_paths(tree), jax.tree.leaves(tree)):
        arr, _ = _to_saveable(np.asarray(jax.device_get(leaf)))
        crc = zlib.crc32(path.encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes(), crc)
    return f"{crc:08x}"


def save(root: str, step: int, tree: PyTree, *, meta: dict | None = None,
         keep: int = 3, floor: int | None = None) -> str:
    """Blocking save. Returns the final checkpoint directory.

    ``floor`` pins steps >= it outside the GC keep window — the journal
    coordination backstop: the snapshot anchoring un-truncated WAL
    records must survive every later save's GC (serving/journal.py)."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = jax.tree.leaves(tree)
    paths = _leaf_paths(tree)
    entries = []
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        arr_s, dtype_name = _to_saveable(arr)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr_s)
        entries.append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": dtype_name,
            "crc32": zlib.crc32(np.ascontiguousarray(arr_s).tobytes()),
        })
    manifest = {"step": step, "leaves": entries, "meta": meta or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    _gc(root, keep, protect=os.path.basename(final), floor=floor)
    return final


def _gc(root: str, keep: int, protect: str | None = None,
        floor: int | None = None) -> None:
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        # never collect the checkpoint this very save just committed, even
        # when its step sorts below the keep window (e.g. a restarted
        # writer whose step counter lags the directory's history)
        if d == protect:
            continue
        # nor any step at/above the caller's floor (a journal-replay
        # anchor must outlive the keep window until the WAL truncates)
        if floor is not None and int(d.split("_")[1]) >= floor:
            continue
        shutil.rmtree(os.path.join(root, d))
    for d in os.listdir(root):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(root, d))


def list_steps(root: str) -> list:
    """Every committed step under ``root`` (ascending; tmp dirs and
    manifest-less directories excluded — they are not restorable)."""
    if not os.path.isdir(root):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(root)
                  if d.startswith("step_") and not d.endswith(".tmp")
                  and os.path.exists(os.path.join(root, d, _MANIFEST)))


def latest_step(root: str) -> int | None:
    steps = list_steps(root)
    return steps[-1] if steps else None


#: exceptions that mean "this step is corrupt, an older one may not be":
#: unreadable/truncated files, crc mismatch (IOError ⊂ OSError), mangled
#: manifest JSON, missing leaves, shape drift from a half-written array.
CORRUPTION_ERRORS = (OSError, json.JSONDecodeError, KeyError, ValueError)


def restore_valid(root: str, tree_like: PyTree, *,
                  shardings: PyTree | None = None) -> tuple:
    """``restore`` with corrupt-latest fallback: walk the committed steps
    newest -> oldest, skipping (with a warning) any whose manifest or
    payload fails to load/verify, and return the newest VALID one as
    ``(tree, meta, step)``. Raises ``FileNotFoundError`` when no step
    exists and re-raises the newest step's error when every step is
    corrupt — a fallback never invents a restorable state."""
    steps = list_steps(root)
    if not steps:
        raise FileNotFoundError(f"no checkpoint under {root}")
    first_err = None
    for step in reversed(steps):
        try:
            tree, meta = restore(root, tree_like, step=step,
                                 shardings=shardings)
            return tree, meta, step
        except CORRUPTION_ERRORS as e:
            if first_err is None:
                first_err = e
            warnings.warn(
                f"checkpoint {root} step {step} is corrupt ({e}); "
                "falling back to the newest prior valid step")
    raise first_err


def restore(root: str, tree_like: PyTree, *, step: int | None = None,
            shardings: PyTree | None = None) -> tuple[PyTree, dict]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings``: optional NamedSharding tree (congruent to tree_like) —
    arrays are placed with those shardings (elastic resharding path).
    Returns (tree, meta). Raises on checksum mismatch or structure drift.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)

    by_path = {e["path"]: e for e in manifest["leaves"]}
    paths = _leaf_paths(tree_like)
    leaves_like, treedef = jax.tree.flatten(tree_like)
    shard_leaves = (jax.tree.leaves(shardings,
                                    is_leaf=lambda x: hasattr(x, "spec"))
                    if shardings is not None else [None] * len(paths))

    out = []
    for path, like, shard in zip(paths, leaves_like, shard_leaves):
        e = by_path.get(path)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        arr = np.load(os.path.join(d, e["file"]))
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != e["crc32"]:
            raise IOError(f"checksum mismatch on {path!r}")
        arr = _from_saveable(arr, e["dtype"])
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{path!r}: shape {arr.shape} != {like.shape}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), manifest["meta"]


class AsyncCheckpointer:
    """Background-thread checkpointing. ``save`` snapshots to host memory
    synchronously (cheap vs. IO) and writes on the worker thread; ``wait``
    joins the in-flight write (call before process exit)."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree: PyTree, meta: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.root, step, host_tree, meta=meta, keep=self.keep)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
