"""Sharding rules for TGN vertex state: the paper's banks, as mesh axes.

The accelerator keeps its Graph Storage in banked BRAM partitions so the
MUU/EU pipelines can hit many vertices per cycle (§IV-A). Our jax analogue
of "more banks" is placing the multi-tenant SessionManager's stacked
``(tenant, V, ...)`` VertexState tables on a ``jax.sharding.Mesh``:

  * ``tenant`` axis — the shard axis of the stacked tables and of every
    padded batch input: each device advances its slice of the fleet, and
    because the vmapped step has no cross-tenant reduction the partitioned
    launch is BITWISE-identical to the single-device one;
  * ``vertex``  axis — optional second axis splitting the V dimension of
    each tenant's tables (memory, mailbox, ring buffers), the direct
    analogue of the paper's vertex-id bank interleaving. Gathers/scatters
    across it become collective transfers XLA inserts; numerics unchanged.

This module is the rule table mapping the ``VertexState`` pytree (single
or tenant-stacked), the padded batch tuples, and the ``BatchOut`` result
to PartitionSpecs — the same first-match-wins pattern as the parameter
rules in ``distributed/sharding.py``. Axes that do not divide a dimension
are dropped (replicated) rather than rejected, so one rule table serves
any mesh shape; ``serving/cluster.py`` consumes these specs.
"""
from __future__ import annotations

import math
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mailbox, tgn

PyTree = Any

TENANT_AXIS = "tenant"
VERTEX_AXIS = "vertex"

# (regex on VertexState field name, spec for the UNSTACKED leaf, V leading).
# First match wins; a stacked (tenant, V, ...) leaf left-pads TENANT_AXIS.
STATE_RULES = [
    # 2-D tables: (V, f_mem) memory, (V, f_mail_raw) mail,
    # (V, m_r) ring buffers — V over the vertex axis, feature dims local
    (r"^(memory|mail|nbr_ids|nbr_ts|nbr_eid)$", P(VERTEX_AXIS, None)),
    # 1-D per-vertex scalars
    (r"^(last_update|mail_ts|mail_valid|nbr_cursor)$", P(VERTEX_AXIS)),
    (r".*", P()),
]

_FIELDS = mailbox.VertexState._fields


def _axis_size(mesh: Mesh, name: str) -> int:
    return int(mesh.shape.get(name, 1))


def _fit_axes(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes absent from the mesh or not dividing their dim
    (same degrade-to-replicated policy as sharding._validate)."""
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    for i, (dim, ax) in enumerate(zip(shape, entries)):
        if ax is None:
            continue
        n = _axis_size(mesh, ax)
        if n <= 1 or dim % n != 0:
            entries[i] = None
    return P(*entries)


def _field_spec(field: str) -> P:
    for pat, spec in STATE_RULES:
        if re.match(pat, field):
            return spec
    raise AssertionError("unreachable")


def _tenant_axis(mesh: Mesh):
    """The tenant shard axis, or None on a mesh without one (vertex-only
    meshes replicate the tenant dim)."""
    return TENANT_AXIS if TENANT_AXIS in mesh.axis_names else None


def state_specs(mesh: Mesh, state_like: mailbox.VertexState, *,
                stacked: bool = True) -> mailbox.VertexState:
    """PartitionSpec pytree for a VertexState of UNSTACKED leaves (arrays
    or ShapeDtypeStructs, V leading).

    ``stacked=True``: specs describe leaves carrying a leading tenant dim
    ``(T, V, ...)`` sharded over ``tenant`` (T is always a capacity —
    a multiple of the axis size); the V dim additionally shards over
    ``vertex`` when that axis exists and divides.
    """
    out = []
    for field, leaf in zip(_FIELDS, state_like):
        spec = _fit_axes(_field_spec(field), leaf.shape, mesh)
        if stacked:
            spec = P(_tenant_axis(mesh), *tuple(spec))
        out.append(spec)
    return mailbox.VertexState(*out)


def batch_specs(mesh: Mesh) -> tuple:
    """Specs for the stacked padded batch tuple: five (T, B) arrays
    (src, dst, eid, ts, valid), row-sharded over the tenant axis."""
    return tuple(P(_tenant_axis(mesh), None) for _ in range(5))


def out_specs(mesh: Mesh, state_like: mailbox.VertexState) -> tgn.BatchOut:
    """Specs for the cohort launch's BatchOut: the committed stacked state
    keeps its input layout, every per-tenant output is tenant-sharded on
    its leading axis."""
    t = P(_tenant_axis(mesh))
    return tgn.BatchOut(state=state_specs(mesh, state_like, stacked=True),
                        emb_src=t, emb_dst=t, attn_logits=t,
                        nbr_valid=t, nbr_dt=t)


def replicated(mesh: Mesh) -> NamedSharding:
    """The placement of cohort-shared operands (params, edge/node feature
    stores): one full copy per device."""
    return NamedSharding(mesh, P())


def make_shardings(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def tenant_capacity(n_tenants: int, mesh: Mesh) -> int:
    """Stacked-table rows for ``n_tenants``: the smallest multiple of the
    tenant-axis size that fits them (pad slots are idle-masked)."""
    n = max(1, _axis_size(mesh, TENANT_AXIS))
    return max(n, n * math.ceil(n_tenants / n))


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def make_tenant_mesh(spec: str | int | None = None, *,
                     devices=None) -> Mesh:
    """Build the tenant fabric's device mesh from a CLI-style spec.

    ``spec``: ``None``/``""`` (all devices on the tenant axis), an int or
    numeric string (``"8"`` — tenant axis of that size), or an explicit
    ``"tenant=4,vertex=2"`` assignment. Axis order follows the spec; only
    ``tenant`` and ``vertex`` are meaningful to the state rules above.
    """
    devices = list(jax.devices() if devices is None else devices)
    if spec is None or spec == "":
        sizes = {TENANT_AXIS: len(devices)}
    elif isinstance(spec, int) or str(spec).isdigit():
        sizes = {TENANT_AXIS: int(spec)}
    else:
        sizes = {}
        for clause in str(spec).split(","):
            if "=" not in clause:
                raise ValueError(
                    f"bad mesh clause {clause!r} in {spec!r}; expected "
                    "'<axis>=<size>[,...]' e.g. 'tenant=4,vertex=2'")
            name, _, size = clause.partition("=")
            name = name.strip()
            if name in sizes:
                raise ValueError(f"duplicate mesh axis {name!r} in {spec!r}")
            if not size.strip().isdigit() or int(size) < 1:
                raise ValueError(f"bad size for mesh axis {name!r} in "
                                 f"{spec!r}")
            sizes[name] = int(size)
    n = 1
    for s in sizes.values():
        n *= s
    if n > len(devices):
        raise RuntimeError(
            f"mesh {sizes} needs {n} devices, found {len(devices)} — on a "
            "CPU host run under XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n} (make test-sharded does), or shrink the mesh")
    arr = np.asarray(devices[:n]).reshape(tuple(sizes.values()))
    return Mesh(arr, tuple(sizes))
