"""Elastic scaling: resume the same logical job on a different topology.

Because checkpoints are stored as full logical arrays (checkpoint.py) and
shardings are a pure function of (param tree, mesh) (sharding.py), changing
the chip count is just: build the new mesh -> recompute specs -> restore
with the new NamedShardings. ``remesh`` does the same for live arrays
(device-to-device through host; a real multi-host deployment would use
jax.device_put with donation across slices).

Straggler/failure model (DESIGN.md §4): data order is a pure function of
(seed, step), so a replacement worker reproduces exactly the shard the dead
worker would have consumed — restart-consistency is property-tested in
tests/test_checkpoint.py.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.distributed import checkpoint as ckpt
from repro.distributed import sharding as shd

PyTree = Any


def remesh(tree: PyTree, new_mesh, spec_tree: PyTree) -> PyTree:
    """Move live arrays onto a new mesh with new specs."""
    shardings = shd.make_shardings(new_mesh, spec_tree)
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), tree, shardings)


def resume(root: str, tree_like: PyTree, new_mesh, mode: str,
           step: int | None = None):
    """Restore a checkpoint onto ``new_mesh`` (any compatible topology)."""
    n_model = new_mesh.shape.get("model", 1)
    specs = shd.param_specs(tree_like, mode, n_model)
    shardings = shd.make_shardings(new_mesh, specs)
    return ckpt.restore(root, tree_like, step=step, shardings=shardings)
