"""Gradient compression: int8 block quantization with error feedback.

Two entry points:

  * ``ef_int8_roundtrip`` — pure quantize->dequantize with an error-feedback
    residual (EF-SGD / 1-bit-Adam family). Inside a pjit'd SPMD step this
    models the numerics of compressed aggregation exactly (the residual
    carries the quantization error into the next step, which is what makes
    these schemes converge); the wire format is what a real deployment would
    put on the DCN between pods.

  * ``compressed_psum`` — the explicit collective, for shard_map code paths:
    workers agree on a shared scale (pmax), all-reduce int8 payloads as
    int32, dequantize once. 4x less DCN traffic than fp32 all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BLOCK = 256


def _block_quant(x: jax.Array):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)[:, None]).astype(jnp.int8)
    return q, scale, n


def _block_dequant(q: jax.Array, scale: jax.Array, n: int, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def ef_int8_roundtrip(grads, residual=None):
    """(grads, residual) -> (decompressed grads, new residual).

    new_residual = (g + residual) - dequant(quant(g + residual)).
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale, n = _block_quant(corrected)
        deq = _block_dequant(q, scale, n, g.shape)
        return deq, corrected - deq

    out = jax.tree.map(one, grads, residual)
    g_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    r_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return g_new, r_new


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce inside shard_map: shared scale via pmax, int32
    accumulation, single dequantize. Returns the (approximate) sum."""
    _, scale_local, n = _block_quant(x)
    scale = jax.lax.pmax(scale_local, axis_name)          # agree on scales
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    q_shared = jnp.round(
        blocks / jnp.maximum(scale, 1e-20)[:, None]).astype(jnp.int32)
    total = jax.lax.psum(q_shared, axis_name)
    deq = (total.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return deq[:flat.shape[0]].reshape(x.shape)
