"""Compute/communication overlap helpers.

On TPU the heavy lifting is XLA's latency-hiding scheduler: collectives
issued as async pairs overlap with compute when the flags below are set.
The launcher calls ``xla_overlap_flags()`` before jax initializes. What the
framework controls directly:

  * ``prefetch`` — double-buffered host->device pipeline for input batches
    (the paper's DMA prefetch, §IV-C, at the framework layer);
  * remat policy + scan structure (models/) keep the backward pass
    overlappable (no giant serialized all-gathers);
  * gradient-accumulation micro-batching (train_loop) lets the DP
    reduce-scatter of micro-batch k overlap the backward of k+1 under the
    latency-hiding scheduler.
"""
from __future__ import annotations

import collections
import os
from typing import Iterable, Iterator

import jax

OVERLAP_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true"
)


def xla_overlap_flags() -> None:
    """Append the overlap flags to XLA_FLAGS (call before first jax use)."""
    cur = os.environ.get("XLA_FLAGS", "")
    if "async_collective_fusion" not in cur:
        os.environ["XLA_FLAGS"] = (cur + " " + OVERLAP_FLAGS).strip()


def prefetch(it: Iterable, size: int = 2, device_put=None) -> Iterator:
    """Double-buffered prefetch: keeps ``size`` batches in flight on device
    while the step function runs — host IO and H2D copies overlap compute."""
    put = device_put or jax.device_put
    buf = collections.deque()
    it = iter(it)
    try:
        for _ in range(size):
            buf.append(put(next(it)))
    except StopIteration:
        pass
    while buf:
        out = buf.popleft()
        try:
            buf.append(put(next(it)))
        except StopIteration:
            pass
        yield out
