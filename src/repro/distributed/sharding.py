"""Sharding rules: parameter-path patterns -> PartitionSpecs.

Two weight layouts, selected per architecture by size (configs set
``shard_mode``):

  * ``tp``     — Megatron-style: weights replicated over the DP axes,
                 tensor-parallel over ``model``; optimizer moments
                 additionally shard over ``data`` (ZeRO-1).
  * ``fsdp2d`` — 2-D sharded weights (data x model) for models whose
                 parameters cannot be DP-replicated (dbrx-132B, grok-314B);
                 XLA inserts the per-layer all-gathers (ZeRO-3 semantics).

Leaf-name conventions come from models/layers.py. Stacked scan dims (leading
``n_blocks`` axes under blocks./layers./enc./dec.) are absorbed by
left-padding the spec with None up to the leaf rank.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# (regex on dot-path, tp spec, fsdp2d spec) — first match wins; specs are for
# the trailing dims of the logical weight (leading scan dims padded None).
_RULES = [
    # MoE experts (E, D, F) / (E, F, D): EP over model when E divides it,
    # else TP inside the expert — decided at runtime in _moe_spec.
    (r"\.router$", P(), P()),
    (r"moe\.w_(gate|up)$", "moe_in", "moe_in"),
    (r"moe\.w_down$", "moe_out", "moe_out"),
    # embeddings
    (r"\.embed$", P("model", None), P("model", "data")),
    (r"\.unembed$", P(None, "model"), P("data", "model")),
    (r"\.pos_dec$", P(), P()),
    # attention / mlp / recurrent projections: (D_in, D_out) column-parallel
    (r"\.(wq|wk|wv|w_gate|w_up|in_proj|w_gate_in|w_main_in)$",
     P(None, "model"), P("data", "model")),
    # row-parallel back-projections: (D_out, D_in)
    (r"\.(wo|w_down|out_proj|w_out)$", P("model", None), P("model", "data")),
    # RG-LRU block-diagonal gates (H, bw, bw)
    (r"\.(w_a|w_x)$", P("model", None, None), P("model", None, None)),
    # small/1-D leaves: replicate
    (r".*", P(), P()),
]


def _moe_spec(kind: str, shape, n_model: int) -> P:
    E = shape[-3]
    if E % n_model == 0:
        # expert parallelism
        return (P("model", "data", None) if kind == "moe_in"
                else P("model", None, "data"))
    # TP inside each expert (grok: 8 experts on a 16-way model axis)
    return (P(None, "data", "model") if kind == "moe_in"
            else P(None, "model", "data"))


def spec_for(path: str, shape, mode: str, n_model: int) -> P:
    for pat, tp_spec, fsdp_spec in _RULES:
        if re.search(pat, path):
            spec = tp_spec if mode == "tp" else fsdp_spec
            if isinstance(spec, str):
                spec = _moe_spec(spec, shape, n_model)
            # left-pad for stacked scan dims
            pad = len(shape) - len(spec)
            if pad > 0:
                spec = P(*((None,) * pad + tuple(spec)))
            elif pad < 0:  # 1-D leaf matched a 2-D rule (shouldn't happen)
                spec = P()
            # drop axes that don't divide and would waste padding badly
            spec = _validate(spec, shape, n_model)
            return spec
    raise AssertionError("unreachable")


AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _axis_size(ax) -> int:
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= AXIS_SIZES.get(a, 1)
    return n


def _validate(spec: P, shape, n_model: int) -> P:
    """pjit argument shardings need exact divisibility. Drop axes whose dim
    doesn't divide, then greedily re-home each dropped axis onto another
    still-unsharded dim that does divide (e.g. a 49155-row vocab embedding
    falls back to sharding its d_model dim)."""
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    dropped = []
    for i, (dim, ax) in enumerate(zip(shape, entries)):
        if ax is None:
            continue
        if dim % _axis_size(ax) != 0 or dim < _axis_size(ax):
            dropped.append(ax)
            entries[i] = None
    for ax in dropped:
        for i, (dim, cur) in enumerate(zip(shape, entries)):
            if cur is None and dim % _axis_size(ax) == 0 \
                    and dim >= _axis_size(ax):
                entries[i] = ax
                break
    return P(*entries)


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(getattr(k, "name", k)))
    return ".".join(parts)


def param_specs(tree: PyTree, mode: str, n_model: int = 16) -> PyTree:
    """PartitionSpec tree congruent to ``tree`` (arrays or SDS leaves)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [spec_for(_path_str(kp), leaf.shape, mode, n_model)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero1_specs(tree: PyTree, mode: str, n_model: int = 16,
                dp_axis: str = "data") -> PyTree:
    """Optimizer-moment specs: params' specs with the first free (None) dim
    of each >=2-D leaf sharded over the DP axis (ZeRO-1). fsdp2d weights are
    already fully sharded — moments just mirror them."""
    if mode == "fsdp2d":
        return param_specs(tree, mode, n_model)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        spec = spec_for(_path_str(kp), leaf.shape, mode, n_model)
        entries = list(tuple(spec) + (None,) * (len(leaf.shape) - len(spec)))
        if leaf.ndim >= 2:
            for i, (dim, ax) in enumerate(zip(leaf.shape, entries)):
                if ax is None and dim >= 16 and dim % 16 == 0:
                    entries[i] = dp_axis
                    break
        out.append(P(*entries))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def dp_axes(mesh: Mesh):
    """The data-parallel axes of a mesh (('pod','data') on multipod)."""
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return axes if len(axes) > 1 else axes[0]


def batch_spec(mesh: Mesh, batch: int, ndim: int) -> P:
    """Shard the leading batch dim over as many DP axes as divide it."""
    axes = [a for a in mesh.axis_names if a in ("pod", "data")]
    use = []
    prod = 1
    for a in axes:
        n = mesh.shape[a]
        if batch % (prod * n) == 0:
            use.append(a)
            prod *= n
    lead = tuple(use) if len(use) > 1 else (use[0] if use else None)
    return P(lead, *((None,) * (ndim - 1)))


def cache_spec(mesh: Mesh, leaf_shape, batch: int) -> P:
    """KV-cache leaves: (L?, B, S, kv, hd) -> batch over DP (when divisible),
    sequence over `model` (distributed decode attention: partial softmax +
    combine emerges from the partitioner). Small leaves replicate."""
    nd = len(leaf_shape)
    if nd <= 1:
        return P()
    # find the batch dim: first dim equal to `batch`
    entries = [None] * nd
    try:
        b_idx = next(i for i, d in enumerate(leaf_shape) if d == batch)
    except StopIteration:
        return P()
    bs = batch_spec(mesh, batch, 1)
    entries[b_idx] = bs[0]
    n_model = mesh.shape["model"]
    # the dim right after batch is sequence/window/state: shard over model
    if b_idx + 1 < nd and leaf_shape[b_idx + 1] % n_model == 0 \
            and leaf_shape[b_idx + 1] >= n_model:
        entries[b_idx + 1] = "model"
    return P(*entries)


def make_shardings(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation sharding constraints (sequence parallelism)
# ---------------------------------------------------------------------------
#
# Models call ``constrain(x, "carry")`` at scan-block boundaries. When the
# launcher has installed rules (inside a mesh context), the carry is pinned
# to a (dp, model, None) layout — SEQUENCE PARALLELISM: the remat residual
# per block shrinks by the model-axis size, which is what makes train_4k on
# 64-layer/314B models fit HBM (DESIGN.md §4). Off (empty rules) for
# single-host smoke tests: a no-op.

_ACTIVATION_RULES: dict[str, P] = {}


def set_activation_rules(rules: dict[str, P]) -> None:
    _ACTIVATION_RULES.clear()
    _ACTIVATION_RULES.update(rules)


def constrain(x, kind: str):
    spec = _ACTIVATION_RULES.get(kind)
    if spec is None:
        return x
    entries = tuple(spec) + (None,) * (x.ndim - len(tuple(spec)))
    mesh = None
    try:
        from jax.sharding import get_abstract_mesh
        mesh = get_abstract_mesh()
    except Exception:
        pass
    # drop axes that don't divide the dim
    fixed = []
    for dim, ax in zip(x.shape, entries[:x.ndim]):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        ok = True
        size = 1
        if mesh is not None and getattr(mesh, "shape", None):
            for a in axes:
                size *= mesh.shape.get(a, 1)
            ok = size > 0 and dim % size == 0
        fixed.append(ax if ok else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))
