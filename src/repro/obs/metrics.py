"""Typed metrics with bounded memory: counters, gauges, histograms.

One ``MetricsRegistry`` per fleet (the ``SessionManager`` owns it; the
frontend, admission controller and coalesced round all write through the
same instance). Three metric types:

``Counter``
    monotonic accumulator (``inc``); resets only explicitly.
``Gauge``
    last-write-wins point-in-time value (``set``).
``Histogram``
    streaming distribution over FIXED log-spaced buckets —
    ``PER_DECADE`` buckets per decade between ``LO`` and ``HI`` plus
    underflow/overflow, so memory is bounded no matter how many samples
    stream through, and two histograms with the same geometry merge by
    adding bucket counts (cross-shard / cross-run aggregation). Exact
    ``count``/``sum``/``min``/``max`` ride along; quantiles come from
    the cumulative bucket counts at the geometric bucket midpoint,
    clamped to the observed ``[min, max]`` — exact for constant samples,
    within one bucket ratio (``10 ** (1 / PER_DECADE)``, ~7.5%)
    otherwise. The empty-sample case is DEFINED: ``quantile``/``mean``
    return ``None`` instead of making every caller pre-check.

``MetricsRegistry.snapshot()`` walks every metric under one lock, so a
single stats/metrics response is internally consistent — the frontend
and the admission controller can no longer observe two mid-round views
of the same counters (see ``SessionManager.compile_counters``).
"""
from __future__ import annotations

import math
import threading


class Counter:
    """Monotonic counter. ``inc`` only; negative increments are an error
    (a decreasing "counter" is a gauge)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r}: negative increment "
                             f"{n}; use a Gauge for values that go down")
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins point-in-time value (queue depth, current traces)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming histogram over fixed log-spaced buckets (see module
    docstring). Records are O(1); memory is a fixed ~350-int array."""

    #: bucket geometry — class-level so every histogram in the fleet
    #: shares it and any two can merge. [1e-7 s, 1e4 s] covers ns-scale
    #: span durations through hours-long drains.
    LO = 1e-7
    HI = 1e4
    PER_DECADE = 32

    __slots__ = ("name", "counts", "count", "total", "vmin", "vmax", "_n")

    def __init__(self, name: str = ""):
        self.name = name
        self._n = round(math.log10(self.HI / self.LO)) * self.PER_DECADE
        self.reset()

    def reset(self) -> None:
        self.counts = [0] * (self._n + 2)   # [under] + buckets + [over]
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None

    def _index(self, x: float) -> int:
        if x <= self.LO:
            return 0
        if x >= self.HI:
            return self._n + 1
        return 1 + min(self._n - 1,
                       int(math.log10(x / self.LO) * self.PER_DECADE))

    def _bucket_value(self, i: int) -> float:
        if i == 0:
            return self.LO
        if i == self._n + 1:
            return self.HI
        lo = self.LO * 10 ** ((i - 1) / self.PER_DECADE)
        hi = self.LO * 10 ** (i / self.PER_DECADE)
        return math.sqrt(lo * hi)           # geometric bucket midpoint

    def record(self, x, n: int = 1) -> None:
        x = float(x)
        self.counts[self._index(x)] += n
        self.count += n
        self.total += x * n
        self.vmin = x if self.vmin is None else min(self.vmin, x)
        self.vmax = x if self.vmax is None else max(self.vmax, x)

    def mean(self):
        return self.total / self.count if self.count else None

    def quantile(self, q: float):
        """The q-quantile (0..1) or ``None`` when empty. Same rank
        convention as the sorted-list ``lat[int(q * len)]`` it replaced."""
        if not self.count:
            return None
        rank = min(self.count - 1, int(q * self.count))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc > rank:
                if i == 0:                  # underflow: best info is vmin
                    return self.vmin
                if i == self._n + 1:        # overflow: best info is vmax
                    return self.vmax
                v = self._bucket_value(i)
                return min(max(v, self.vmin), self.vmax)
        return self.vmax

    def merge(self, other: "Histogram") -> None:
        if other._n != self._n:
            raise ValueError("histograms with different bucket geometry "
                             "cannot merge")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        for v in (other.vmin, other.vmax):
            if v is not None:
                self.vmin = v if self.vmin is None else min(self.vmin, v)
                self.vmax = v if self.vmax is None else max(self.vmax, v)

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.total, "mean": self.mean(),
                "min": self.vmin, "max": self.vmax,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Named metrics with get-or-create accessors and one atomic view.

    ::

        obs = MetricsRegistry()
        obs.counter("session.rounds").inc()
        obs.histogram("frontend.event_latency_s").record(0.003)
        obs.snapshot()          # one lock-consistent dict of everything

    A name is bound to ONE type for the registry's lifetime; asking for
    it as another type raises (silent shadowing would split a metric's
    history across two objects).
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif type(m) is not cls:
                raise TypeError(f"metric {name!r} is a {type(m).__name__}, "
                                f"requested as {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> tuple:
        return tuple(sorted(self._metrics))

    def snapshot(self, prefix: str = "") -> dict:
        """``{name: value-or-histogram-dict}`` taken in ONE pass under
        the registry lock — every reader of a stats response sees the
        same instant (the frontend/admission consistency contract)."""
        with self._lock:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())
                    if name.startswith(prefix)}

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry in (cross-shard aggregation): counters
        add, gauges take the other's value, histograms merge buckets."""
        for name in other.names():
            m = other.get(name)
            if isinstance(m, Counter):
                self.counter(name).inc(m.value)
            elif isinstance(m, Gauge):
                self.gauge(name).set(m.value)
            else:
                self.histogram(name).merge(m)
