"""Fleet observability: metrics registry, round tracer, SLO accounting.

The measurement plane of the serving stack (ROADMAP item 3's substrate):

``metrics``
    typed counters, gauges and streaming log-bucketed histograms behind
    one ``MetricsRegistry`` per fleet — bounded memory, mergeable,
    a single lock-consistent ``snapshot()``. Replaces the hand-rolled
    percentile math that used to live in ``serving/frontend.py``,
    ``serving/engine.py`` and ``serving/session.py``.

``trace``
    span-based round tracing with explicit clock injection (the
    frontend's fake-clock discipline) and Chrome/Perfetto
    ``trace_event`` + JSON-lines export. Sampled: fencing the async
    round pipeline happens at trace-sample rounds ONLY.

``slo``
    per-tenant latency-objective tracking — target vs observed p99 and
    error-budget burn rate — surfaced in ``summary()["per_tenant"]``
    and the frontend's ``metrics`` wire op.

See docs/OBSERVABILITY.md for metric names, the span taxonomy and the
SLO semantics.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.slo import SLOTracker
from repro.obs.trace import RoundTracer, Span

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "RoundTracer", "SLOTracker", "Span"]
