"""Span-based round tracing with sampled device fencing.

``RoundTracer`` records named spans — ``ingest``/``flush`` (frontend),
``stage``/``launch`` (host side of the coalesced round), ``h2d``/``drain``
(device attribution) — against an INJECTED clock, the same fake-clock
discipline the deadline batcher tests use, so every trace is
deterministic under test.

Sampling is the load-bearing design point: the serving round pipeline is
asynchronous (steps never block; per-round walls are reconstructed from
dispatch timestamps, edge counts stay pending device scalars), and a
``jax.block_until_ready`` per round would serialize it. The tracer
therefore gates itself: ``sample_round()`` is consulted once per round
and only every ``sample_every``-th round gets spans + device fences —
callers hold a ``trace`` reference that is ``None`` on unsampled rounds,
so the fast path stays fence-free (enforced by ``tools/session_lint.py``).

Export targets:

* ``to_chrome()`` / ``write_chrome(path)`` — Chrome/Perfetto
  ``trace_event`` JSON (complete "X" events, microsecond ts/dur, one
  ``tid`` track per span category). Open in ``ui.perfetto.dev`` or
  ``chrome://tracing``.
* ``write_jsonl(path)`` — one span dict per line, the grep/pandas form.

Span storage is bounded (``max_spans``); overflow increments ``dropped``
rather than growing without bound mid-serve.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Span:
    """One closed interval on the tracer's clock."""
    name: str           #: taxonomy name (ingest/flush/stage/launch/...)
    cat: str            #: category -> Perfetto track (frontend/host/device)
    t0: float           #: start, tracer-clock seconds
    t1: float           #: end, tracer-clock seconds
    args: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def as_dict(self) -> dict:
        return {"name": self.name, "cat": self.cat, "t0": self.t0,
                "t1": self.t1, "dur": self.dur, **self.args}


#: stable Perfetto track ids per category (unknown categories get the
#: next free track at first use).
_TRACKS = {"frontend": 1, "host": 2, "device": 3, "round": 4}


class RoundTracer:
    """Sampled span recorder over an injected clock.

    ``sample_round()`` advances the round cursor and returns True on
    sampled rounds (round 0 and every ``sample_every``-th after);
    ``would_sample()`` peeks WITHOUT advancing — the frontend uses it to
    decide whether to time its ingest/flush work before the session's
    ``step`` consumes the round slot.
    """

    def __init__(self, clock=time.monotonic, sample_every: int = 8,
                 max_spans: int = 65536):
        self.clock = clock
        self.sample_every = max(1, int(sample_every))
        self.max_spans = int(max_spans)
        self.spans: list[Span] = []
        self.dropped = 0
        self.rounds_seen = 0
        self.rounds_sampled = 0

    # ------------------------------------------------------- sampling
    def would_sample(self) -> bool:
        return (self.rounds_seen % self.sample_every) == 0

    def sample_round(self) -> bool:
        hit = self.would_sample()
        self.rounds_seen += 1
        if hit:
            self.rounds_sampled += 1
        return hit

    # ------------------------------------------------------ recording
    def add(self, name: str, t0: float, t1: float, cat: str = "round",
            **args) -> None:
        if len(self.spans) >= self.max_spans:
            self.dropped += 1
            return
        self.spans.append(Span(name, cat, float(t0), float(t1), args))

    @contextmanager
    def span(self, name: str, cat: str = "round", **args):
        t0 = self.clock()
        yield
        self.add(name, t0, self.clock(), cat=cat, **args)

    # -------------------------------------------------------- reading
    def summary(self) -> dict:
        """``{span name: {count, total_s}}`` plus the sampling tallies."""
        per: dict[str, dict] = {}
        for s in self.spans:
            d = per.setdefault(s.name, {"count": 0, "total_s": 0.0})
            d["count"] += 1
            d["total_s"] += s.dur
        return {"rounds_seen": self.rounds_seen,
                "rounds_sampled": self.rounds_sampled,
                "spans": len(self.spans), "dropped": self.dropped,
                "by_name": per}

    # --------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON object format: complete
        ("X") events with microsecond ``ts``/``dur``, categories mapped
        to distinct ``tid`` tracks."""
        tracks = dict(_TRACKS)
        events = []
        for s in self.spans:
            tid = tracks.setdefault(s.cat, len(tracks) + 1)
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": s.t0 * 1e6, "dur": s.dur * 1e6,
                "pid": 1, "tid": tid,
                "args": {k: v for k, v in s.args.items()},
            })
        # thread_name metadata gives Perfetto readable track labels
        for cat, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            events.append({"name": "thread_name", "ph": "M", "pid": 1,
                           "tid": tid, "args": {"name": cat}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for s in self.spans:
                f.write(json.dumps(s.as_dict()) + "\n")
