"""Per-tenant latency SLOs: objective tracking + error-budget burn.

An SLO here is "``objective`` of a tenant's observations complete within
``target_ms``" (e.g. 99% under 25 ms). Per tenant the tracker keeps a
bounded ``obs.Histogram`` of the observed latencies plus exact event /
violation counts, and derives SRE-style burn accounting:

* ``error_rate``   — violations / events.
* ``burn_rate``    — error_rate / (1 - objective): how fast the error
  budget is being consumed relative to what the objective allows.
  1.0 means exactly on budget; > 1 the objective is being missed
  ("fast burn"); the window is the tracker's lifetime (one serving
  run), so cumulatively ``burn_rate`` IS the fraction of the run's
  budget consumed.
* ``budget_remaining`` — max(0, 1 - burn_rate) of the run's budget.

What a "latency observation" is depends on the deployment: the online
frontend observes per-EVENT queue->flush latency (``source="event"``);
an offline session run observes per-ROUND walls reconstructed from the
dispatch timestamps (``source="round"``, fed by ``summary()``). The
``source`` tag keeps the two from double-feeding one tracker.

``tenant(tid)`` always returns a full dict — zero-observation tenants
report ``events=0, burn_rate=0.0, observed_p99_ms=None`` rather than
being absent, so ``summary()["per_tenant"]`` carries SLO burn for EVERY
tenant (the acceptance criterion, and what the autotuner will poll).
"""
from __future__ import annotations

from repro.obs.metrics import Histogram


class SLOTracker:
    """Latency-objective tracking per tenant (see module docstring)."""

    def __init__(self, target_ms: float, objective: float = 0.99,
                 source: str = "round"):
        if not target_ms > 0:
            raise ValueError(f"target_ms must be > 0, got {target_ms}")
        if not 0.0 < objective < 1.0:
            raise ValueError("objective must be in (0, 1) — 1.0 leaves "
                             f"zero error budget to burn; got {objective}")
        self.target_ms = float(target_ms)
        self.target_s = self.target_ms / 1e3
        self.objective = float(objective)
        #: what one observation is: "round" (summary-fed walls) or
        #: "event" (frontend-fed per-event latencies).
        self.source = source
        self._t: dict[str, dict] = {}

    def _slot(self, tid: str) -> dict:
        d = self._t.get(tid)
        if d is None:
            d = self._t[tid] = {"hist": Histogram(f"slo.{tid}.latency_s"),
                                "events": 0, "violations": 0}
        return d

    def observe(self, tid: str, latency_s: float, n: int = 1) -> None:
        d = self._slot(tid)
        d["hist"].record(latency_s, n)
        d["events"] += n
        if latency_s > self.target_s:
            d["violations"] += n

    def violation(self, tid: str, n: int = 1) -> None:
        """Record ``n`` outright violations WITHOUT a latency sample — an
        outage observation (e.g. the guard charging each round a tenant
        sits quarantined), where "how late" is unbounded/meaningless but
        the error budget must still burn. Counts into ``events`` too, so
        ``error_rate`` stays violations/events over everything observed."""
        d = self._slot(tid)
        d["events"] += n
        d["violations"] += n

    def tenant(self, tid: str) -> dict:
        """The tenant's SLO view (a full dict even before any
        observation — see module docstring)."""
        d = self._t.get(tid)
        events = d["events"] if d else 0
        violations = d["violations"] if d else 0
        p99 = d["hist"].quantile(0.99) if d else None
        err = violations / events if events else 0.0
        burn = err / (1.0 - self.objective)
        return {"target_ms": self.target_ms, "objective": self.objective,
                "source": self.source, "events": events,
                "violations": violations,
                "observed_p99_ms": None if p99 is None else p99 * 1e3,
                "error_rate": err, "burn_rate": burn,
                "budget_remaining": max(0.0, 1.0 - burn)}

    def snapshot(self) -> dict:
        return {tid: self.tenant(tid) for tid in sorted(self._t)}
