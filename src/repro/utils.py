"""Small shared utilities: parameter init, padding, tree helpers.

The framework is flax-free: parameters are nested dicts of jnp arrays,
models are pure functions ``apply(params, ...)`` with ``init(rng, cfg)``
constructors. This keeps every layer pjit/shard_map friendly and makes
sharding rules a pure function of the parameter tree path.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Callable, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# RNG helpers
# ---------------------------------------------------------------------------


def rng_seq(key: jax.Array):
    """Infinite stream of fresh PRNG keys from a root key."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


def fold_path(key: jax.Array, path: str) -> jax.Array:
    """Deterministic per-path key derivation (stable across refactors AND
    processes — crc32, not the per-process-salted builtin hash)."""
    h = np.uint32(zlib.crc32(path.encode()) % (2**32 - 1))
    return jax.random.fold_in(key, h)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Sequence[int], *, scale: float | None = None,
               dtype=jnp.float32) -> jax.Array:
    """LeCun-normal style init for dense kernels: (fan_in, fan_out...)."""
    fan_in = shape[0]
    if scale is None:
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, tuple(shape)) * scale).astype(dtype)


def embed_init(key: jax.Array, shape: Sequence[int], *, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, tuple(shape)) * 0.02).astype(dtype)


def zeros(shape: Sequence[int], dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(tuple(shape), dtype=dtype)


def ones(shape: Sequence[int], dtype=jnp.float32) -> jax.Array:
    return jnp.ones(tuple(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# Shape / padding helpers (TPU lane alignment)
# ---------------------------------------------------------------------------

LANE = 128  # MXU/VPU lane width on TPU

#: Masking value for invalid attention logits — the single source of truth
#: shared by the jnp reference path (core/pruning.py) and every Pallas
#: kernel (kernels/sat_aggregate.py, kernels/fused_step.py, kernels/ref.py).
#: A drift between the reference and kernel values would silently break the
#: fused-vs-staged numeric equivalence the kernel tests pin, so nobody may
#: define a private copy.
NEG_INF = -1e30


def round_up(x: int, m: int = LANE) -> int:
    return ((x + m - 1) // m) * m


def pad_axis(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad ``axis`` of x up to length ``target``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    if cur > target:
        raise ValueError(f"cannot pad axis {axis} from {cur} down to {target}")
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - cur)
    return jnp.pad(x, pads)


def pad_to_lanes(x: jax.Array, axis: int = -1, m: int = LANE) -> jax.Array:
    axis = axis % x.ndim
    return pad_axis(x, axis, round_up(x.shape[axis], m))


# ---------------------------------------------------------------------------
# Tree helpers
# ---------------------------------------------------------------------------


def tree_size(tree: PyTree) -> int:
    """Total number of parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_paths(tree: PyTree) -> list[tuple[str, Any]]:
    """Flatten a tree to (dot.path, leaf) pairs."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = ".".join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def assert_finite(tree: PyTree, name: str = "tree") -> None:
    for path, leaf in tree_paths(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            if not bool(jnp.all(jnp.isfinite(leaf))):
                raise AssertionError(f"non-finite values in {name}.{path}")


# ---------------------------------------------------------------------------
# Config base
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrozenConfig:
    """Base class for immutable configs with ``replace``/``asdict``."""

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)
