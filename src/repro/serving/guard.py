"""FleetGuard: supervised recovery for the multi-tenant serving fleet.

``SessionManager`` gives a sick tenant exactly one cheap isolation
primitive — quarantine, which idle-masks its lane slot (all-
``valid=False`` batches, the established bitwise no-op) with zero
recompiles and zero effect on cohort-mates. This module is the
supervisor that decides WHEN to pull that lever and how to come back
from it:

detection (per round, after the launch is dispatched)
    * finite-state sentinel — one tiny jitted reduction per cohort
      (``all(isfinite(...))`` per lane, a per-tenant bool vector) catches
      NaN/Inf-poisoned resident state. Reading it is the guard's ONE
      host sync; ``check_every > 1`` samples the check to preserve the
      async round pipeline between checks.
    * SLO-burn threshold — with an armed ``obs.SLOTracker`` and
      ``quarantine_slo_burn > 0``, a tenant whose burn rate crosses the
      threshold is quarantined (its error budget is being torched).
    * round watchdog — ``watchdog_s > 0`` flags rounds whose wall (on
      the guard's injected clock) exceeds the bound
      (``guard.watchdog_trips``; a ``watchdog`` span when traced).

recovery
    * quarantine -> auto-restore: after a deterministic capped
      exponential backoff (``backoff_s`` doubling to ``backoff_cap_s``
      on the injected clock) the guard reloads the tenant's state IN
      PLACE from its newest VALID snapshot (``cluster.
      restore_tenant_state`` -> ``checkpoint.restore_valid``: corrupt
      steps are skipped with a warning), joining the tenant's in-flight
      background write first. A restore only counts when the reloaded
      state passes the finite sentinel; otherwise the next attempt backs
      off further, and after ``max_restores`` failed attempts the tenant
      is permanently EVICTED (detached; ``guard.evictions``).
    * kernel-tier degradation: a classified launch failure
      (``faults.KernelFault``, carrying the lane's tenant) degrades the
      whole cohort one tier down the ladder fused -> staged -> ref and
      retries the SAME round. Cohorts are keyed by tier, so this is a
      lane MOVE (states carried over bitwise, one relayout), not a fork;
      at ``ref`` there is nowhere left to go and the fault re-raises.

Every quarantined round burns the tenant's SLO error budget
(``SLOTracker.violation`` — an outage observation with no latency
sample), counters land in the fleet ``MetricsRegistry``
(``guard.quarantines`` / ``guard.restores`` / ``guard.degradations`` /
``guard.evictions`` / ``guard.watchdog_trips`` and the
``guard.quarantined_now`` gauge), and recovery events emit ``cat=
"guard"`` spans into the round tracer when one is armed. The bitwise
contract: survivors of a quarantine round replay identically to a fleet
that never had the sick tenant attached (tools/chaos_smoke.py pins it).

The guard attaches itself as ``mgr.guard`` at construction;
``SessionManager.guarded_step`` (and through it ``run`` and the
frontend's pump) then routes every round through ``step`` here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.faults import KernelFault

#: the degradation ladder: each classified launch failure moves the
#: failing cohort one tier down; ``ref`` (pure jnp) has no fallback.
DEGRADE_LADDER = {"fused": "staged", "staged": "ref"}


@jax.jit
def _finite_lanes(state) -> jax.Array:
    """Per-lane health sentinel: ``(capacity,)`` bool, True where every
    floating leaf of the lane's stacked state is finite. A handful of
    fused reductions per cohort — cheap device scalars, computed without
    pulling any table to the host."""
    flags = None
    for leaf in jax.tree.leaves(state):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        f = jnp.all(jnp.isfinite(leaf), axis=tuple(range(1, leaf.ndim)))
        flags = f if flags is None else flags & f
    if flags is None:                      # no floating state: healthy
        first = jax.tree.leaves(state)[0]
        flags = jnp.ones((first.shape[0],), bool)
    return flags


class FleetGuard:
    """Per-round health supervisor over a ``SessionManager`` fleet
    (see module docstring for the detection/recovery model).

    ::

        guard = FleetGuard(mgr, snapshot_root="/ckpt/fleet",
                           writer=writer, clock=clock,
                           max_restores=3, backoff_s=1.0)
        mgr.run(streams)        # rounds now route through guard.step

    ``clock`` must be the same injected clock the fault plan / tracer /
    frontend use — backoff schedules and watchdog walls are measured on
    it, which is what makes chaos runs deterministic.
    """

    def __init__(self, mgr, *, snapshot_root: str | None = None,
                 writer=None, clock=time.monotonic, max_restores: int = 3,
                 backoff_s: float = 1.0, backoff_cap_s: float = 30.0,
                 quarantine_slo_burn: float = 0.0, watchdog_s: float = 0.0,
                 check_every: int = 1, degrade_after: int = 1,
                 journal=None):
        if max_restores < 1:
            raise ValueError(f"max_restores must be >= 1, got "
                             f"{max_restores}")
        if backoff_s <= 0 or backoff_cap_s < backoff_s:
            raise ValueError("need 0 < backoff_s <= backoff_cap_s, got "
                             f"{backoff_s}/{backoff_cap_s}")
        if check_every < 1 or degrade_after < 1:
            raise ValueError("check_every and degrade_after must be >= 1")
        self.mgr = mgr
        #: snapshot root (``cluster.TenantSnapshotWriter`` layout) auto-
        #: restores reload from; None = no state reload, recovery only
        #: succeeds if the tenant's CURRENT state passes the sentinel.
        self.snapshot_root = snapshot_root
        #: the fleet's background snapshot writer (joined per tenant
        #: before a restore so the newest write is committed) or None.
        self.writer = writer
        #: the fleet's ``EventJournal`` (serving/journal.py) or None.
        #: Armed, an auto-restore is LOSSLESS: after the snapshot state
        #: reloads, the journal suffix past its cursor replays through
        #: the normal step pipeline, so the tenant resumes bitwise
        #: where it left off — post-snapshot events included.
        self.journal = journal
        self.clock = clock
        self.max_restores = int(max_restores)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.quarantine_slo_burn = float(quarantine_slo_burn)
        self.watchdog_s = float(watchdog_s)
        self.check_every = int(check_every)
        self.degrade_after = int(degrade_after)
        self.obs = mgr.obs
        # counters (mirrored into the fleet registry under ``guard.``)
        self.quarantines = 0
        self.restores = 0
        self.degradations = 0
        self.evictions = 0
        self.watchdog_trips = 0
        self._rounds = 0
        #: per-tenant recovery ledger: ``{tid: {quarantines, restores,
        #: attempts, attempt_times, backoff_s, next_attempt_t, evicted,
        #: last_reason}}`` — survives eviction (the post-mortem record).
        self._t: dict[str, dict] = {}
        #: consecutive classified launch failures per cohort key.
        self._launch_failures: dict[tuple, int] = {}
        mgr.guard = self

    # ------------------------------------------------------------ round
    def step(self, batches) -> dict:
        """One supervised round: dispatch through ``SessionManager.step``
        (catching classified launch failures -> tier degradation + retry
        of the SAME round), then run the health checks, charge
        quarantined tenants' SLO burn, and attempt any backoff-due
        restores. Returns the round's ``{tid: BatchOut}``."""
        mgr = self.mgr
        t0 = self.clock()
        try:
            outs = mgr.step(batches)
        except KernelFault as e:
            outs = self._on_kernel_fault(e, batches)
        wall = self.clock() - t0
        self._rounds += 1
        if self.watchdog_s and wall > self.watchdog_s:
            self.watchdog_trips += 1
            self.obs.counter("guard.watchdog_trips").inc()
            self._span("watchdog", t0, wall_s=wall)
        if self._rounds % self.check_every == 0:
            self._health_check()
        self._slo_check()
        self._charge_outage()
        self._recover_due()
        return outs

    # ------------------------------------------------------- detection
    def _health_check(self) -> None:
        """Finite-state sentinel over every cohort; quarantines lanes
        whose resident state went NaN/Inf. The ``np.asarray`` read is
        the guard's one host sync per checked round."""
        mgr = self.mgr
        for cohort in list(mgr._cohorts.values()):
            if cohort.state is None or not cohort.tids:
                continue
            ok = np.asarray(_finite_lanes(cohort.state))
            for i, tid in enumerate(cohort.tids):
                if not ok[i] and not mgr.is_quarantined(tid):
                    self.quarantine(tid, reason="nonfinite_state")

    def _slo_check(self) -> None:
        mgr = self.mgr
        if self.quarantine_slo_burn <= 0 or mgr.slo is None:
            return
        for tid in mgr.tenants:
            if mgr.is_quarantined(tid):
                continue
            burn = mgr.slo.tenant(tid)["burn_rate"]
            if burn > self.quarantine_slo_burn:
                self.quarantine(tid, reason="slo_burn")

    def _charge_outage(self) -> None:
        """Every round a tenant sits quarantined is an outage violation:
        burn its SLO error budget even though no latency was observed."""
        mgr = self.mgr
        if mgr.slo is None:
            return
        for tid in mgr.quarantined:
            mgr.slo.violation(tid)

    # ------------------------------------------------------ quarantine
    def quarantine(self, tid: str, reason: str = "manual") -> None:
        """Idle-mask ``tid``'s lane (``SessionManager.quarantine``) and
        schedule its first restore attempt one backoff from now."""
        t0 = self.clock()
        self.mgr.quarantine(tid)
        rec = self._rec(tid)
        rec["quarantines"] += 1
        rec["last_reason"] = reason
        rec["attempts"] = 0
        rec["attempt_times"] = []
        rec["backoff_s"] = self.backoff_s
        rec["next_attempt_t"] = t0 + self.backoff_s
        self.quarantines += 1
        self.obs.counter("guard.quarantines").inc()
        self._span("quarantine", t0, tenant=tid, reason=reason)

    def _rec(self, tid: str) -> dict:
        rec = self._t.get(tid)
        if rec is None:
            rec = self._t[tid] = {
                "quarantines": 0, "restores": 0, "attempts": 0,
                "attempt_times": [], "backoff_s": self.backoff_s,
                "next_attempt_t": 0.0, "evicted": False,
                "last_reason": None}
        return rec

    # --------------------------------------------------------- restore
    def _recover_due(self) -> None:
        for tid in sorted(self.mgr.quarantined):
            rec = self._t.get(tid)
            if rec is None or rec["evicted"]:
                continue
            if self.clock() >= rec["next_attempt_t"]:
                self._attempt_restore(tid, rec)

    def _attempt_restore(self, tid: str, rec: dict) -> None:
        """One restore attempt: join the tenant's in-flight snapshot
        write, reload its newest VALID snapshot in place (when a root is
        configured), replay the journal suffix past that snapshot's
        cursor (when a journal is armed — the lossless half), and count
        success only if the resulting state passes the finite sentinel.
        Failure backs off exponentially (capped); ``max_restores``
        failures evict permanently."""
        from repro.distributed import checkpoint as ckpt

        mgr = self.mgr
        t0 = self.clock()
        rec["attempts"] += 1
        rec["attempt_times"].append(t0)
        err, healthy, replayed = None, False, 0
        try:
            if self.snapshot_root is not None:
                if self.writer is not None:
                    try:
                        self.writer.join(tid)
                    except Exception as e:  # a failed write: older steps
                        err = e             # may still restore below
                from repro.serving import cluster
                used = cluster.restore_tenant_state(
                    mgr, self.snapshot_root, tid)
                if self.journal is not None:
                    # lossless resume: replay every journaled flush past
                    # the RESTORED step's cursor through the normal step
                    # pipeline (mgr.step, not guarded_step — no guard
                    # recursion). The lane must serve during replay, so
                    # the quarantine lifts for it and re-arms after; the
                    # sentinel below decides whether it stays lifted.
                    cur = cluster.snapshot_meta(
                        self.snapshot_root, tid, step=used).get("journal")
                    if cur is not None:
                        mgr.unquarantine(tid)
                        try:
                            res = self.journal.replay(tid, cur, mgr.step)
                        finally:
                            mgr.quarantine(tid)
                        replayed = res.rounds
            healthy = self._tenant_healthy(tid)
        except (FileNotFoundError, *ckpt.CORRUPTION_ERRORS) as e:
            err = e
        if healthy:
            mgr.unquarantine(tid)
            rec["restores"] += 1
            self.restores += 1
            self.obs.counter("guard.restores").inc()
            self._span("restore", t0, tenant=tid,
                       attempts=rec["attempts"], replayed=replayed)
            return
        if rec["attempts"] >= self.max_restores:
            self._evict(tid, rec, err)
            return
        rec["backoff_s"] = min(rec["backoff_s"] * 2, self.backoff_cap_s)
        rec["next_attempt_t"] = self.clock() + rec["backoff_s"]

    def _evict(self, tid: str, rec: dict, err) -> None:
        """Permanent eviction: the recovery ceiling is exhausted, detach
        the tenant (its lane slot frees/idles per the reserve policy)."""
        t0 = self.clock()
        rec["evicted"] = True
        rec["last_reason"] = (f"evicted after {rec['attempts']} failed "
                              f"restores"
                              + (f" ({err})" if err is not None else ""))
        self.mgr.remove_tenant(tid)
        self.evictions += 1
        self.obs.counter("guard.evictions").inc()
        self._span("evict", t0, tenant=tid, attempts=rec["attempts"])

    def _tenant_healthy(self, tid: str) -> bool:
        cohort = self.mgr.cohort_of(tid)
        ok = np.asarray(_finite_lanes(cohort.state))
        return bool(ok[cohort.tids.index(tid)])

    # ----------------------------------------------------- degradation
    def _cohort_key(self, cohort) -> tuple:
        from repro.core import pipeline as pl
        return (pl.variant_name(cohort.cfg), cohort.tier, cohort.param_set)

    def _on_kernel_fault(self, e: KernelFault, batches) -> dict:
        """A classified launch failure: count it against the failing
        cohort, degrade the cohort's kernel tier once the count reaches
        ``degrade_after``, and retry the SAME round (the injector rolled
        its round cursor back, so the retry replays the same logical
        round and already-fired faults stay fired)."""
        mgr = self.mgr
        cohort = mgr.cohort_of(e.tid)
        key = self._cohort_key(cohort)
        n = self._launch_failures.get(key, 0) + 1
        self._launch_failures[key] = n
        if n >= self.degrade_after:
            self._launch_failures.pop(key, None)
            self._degrade(cohort, because=e)
        return mgr.step(batches)

    def _degrade(self, cohort, because=None) -> None:
        """Move every tenant of ``cohort`` one tier down the ladder.

        A lane move, not a fork: cohorts are keyed by (cfg, tier,
        param set), so re-admitting the tenants at the lower tier lands
        them in the (possibly pre-existing) lower lane with their
        states, serving counters, and quarantine flags carried over —
        exactly ONE relayout of the coalesced round."""
        from repro.core import pipeline as pl

        mgr = self.mgr
        nxt = DEGRADE_LADDER.get(cohort.tier)
        if nxt is None:
            if because is not None:
                raise because
            raise RuntimeError(f"cohort {pl.variant_name(cohort.cfg)!r} is "
                               "already at the 'ref' tier; no fallback "
                               "left")
        t0 = self.clock()
        variant = pl.variant_name(cohort.cfg)
        tau = cohort.cfg.reservoir_tau
        pname = cohort.param_set
        moved = list(cohort.tids)
        mgr.sync()
        states = {t: mgr.state_of(t) for t in moved}
        stats = {t: dict(mgr._tenant_stats.get(t) or {}) for t in moved}
        quarantined = [t for t in moved if mgr.is_quarantined(t)]
        for t in moved:
            mgr.remove_tenant(t)
        for t in moved:
            mgr.add_tenant(variant, name=t, reservoir_tau=tau,
                           use_kernels=nxt, params=pname)
            mgr.set_state(t, states[t])
            if stats[t]:
                mgr._tenant_stats[t] = stats[t]
        for t in quarantined:
            mgr.quarantine(t)
        self.degradations += 1
        self.obs.counter("guard.degradations").inc()
        self._span("degrade", t0, variant=variant, tier=nxt,
                   tenants=len(moved))

    # --------------------------------------------------------- reading
    def _span(self, name: str, t0: float, **args) -> None:
        """Emit a recovery span (``cat="guard"``) when a tracer is
        armed. Recovery events are rare, so they record on EVERY round,
        not only sampled ones — the outage window must never be
        invisible in a trace."""
        tr = getattr(self.mgr, "tracer", None)
        if tr is not None:
            tr.add(name, t0, tr.clock(), cat="guard", **args)

    def tenant_view(self, tid: str) -> dict:
        """The tenant's recovery record for ``tenant_stats()``:
        quarantine/restore tallies, pending-attempt countdown, eviction
        flag, and the last quarantine reason."""
        rec = self._t.get(tid)
        quarantined = (tid in getattr(self.mgr, "quarantined", ()))
        if rec is None:
            return {"quarantined": quarantined, "quarantines": 0,
                    "restores": 0, "evicted": False, "last_reason": None,
                    "next_attempt_in_s": None}
        nxt = (max(0.0, rec["next_attempt_t"] - self.clock())
               if quarantined and not rec["evicted"] else None)
        return {"quarantined": quarantined,
                "quarantines": rec["quarantines"],
                "restores": rec["restores"],
                "restore_attempts": rec["attempts"],
                "evicted": rec["evicted"],
                "last_reason": rec["last_reason"],
                "next_attempt_in_s": nxt}

    def snapshot(self) -> dict:
        """The fleet-level recovery view a metrics response embeds —
        counters plus the live quarantine set and eviction post-mortems."""
        return {"quarantines": self.quarantines,
                "restores": self.restores,
                "degradations": self.degradations,
                "evictions": self.evictions,
                "watchdog_trips": self.watchdog_trips,
                "quarantined_now": sorted(self.mgr.quarantined),
                "evicted": sorted(t for t, r in self._t.items()
                                  if r["evicted"])}
