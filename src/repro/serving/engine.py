"""Streaming TGNN inference engine — the paper's accelerator, end to end.

This is the production path that realizes the co-design (Fig. 2 + Alg. 1):

  Edge Parser   -> stream.EdgeBatch (chronological, padded, masked)
  Data Loader   -> PRUNE-THEN-FETCH: SAT logits from the neighbor ring
                   buffer's timestamps ONLY; top-k; gather just k rows of
                   vertex memory / edge features from the tables (the HBM
                   saving the paper measures as 67% fewer MEMs)
  MUU           -> fused Pallas GRU kernel (kernels/gru_cell.py) with the
                   LUT time rows pre-folded through W_i (kernels/ops.py)
  EU            -> fused Pallas SAT-aggregate kernel (logits -> masked
                   softmax -> V-projection+LUT -> weighted sum)
  Updater       -> vectorized last-write-wins chronological commit
                   (core/updater.py)
  prefetch      -> double-buffered host->device input pipeline
                   (distributed/overlap.py)

``use_kernels=False`` swaps in the pure-jnp reference path (identical
semantics; used by tests to pin kernel == engine behaviour). The teacher /
unoptimized baseline runs through core.tgn.process_batch instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import FrozenConfig
from repro.core import attention as attn_mod
from repro.core import mailbox, memory, pruning, time_encode as te
from repro.core import tgn, updater
from repro.data.stream import EdgeBatch
from repro.distributed import overlap
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class EngineConfig(FrozenConfig):
    model: tgn.TGNConfig = tgn.TGNConfig(attention="sat", encoder="lut",
                                         prune_k=4)
    use_kernels: bool = True
    prefetch: int = 2


class StreamingEngine:
    """Stateful streaming inference over a chronological edge stream."""

    def __init__(self, cfg: EngineConfig, params: dict,
                 edge_feats: jax.Array, node_feats: jax.Array | None = None):
        m = cfg.model
        assert m.attention == "sat" and m.encoder == "lut", \
            "the engine is the optimized student path; run baselines via tgn"
        self.cfg = cfg
        self.params = params
        self.edge_feats = jnp.asarray(edge_feats)
        self.node_feats = (jnp.asarray(node_feats)
                           if node_feats is not None else None)
        self.state = tgn.init_state(m)

        # ---- precompute folded tables / packed kernel params (§III-C) ----
        gcfg = m.gru
        gru_p = params["gru"]
        lut_gru = te.fold_projection(params["time"],
                                     gru_p["w_i"][gcfg.f_mail_raw:])
        attn_p = params["attn"]
        dkv = m.f_mem + m.f_edge
        lut_attn = te.fold_projection(params["time"], attn_p["w_v"][dkv:])
        self._folded = {"gru": lut_gru, "attn": lut_attn}
        self._packed_gru = kops.pad_gru_params(
            {"w_i": gru_p["w_i"][:gcfg.f_mail_raw],
             "w_h": gru_p["w_h"], "b_i": gru_p["b_i"], "b_h": gru_p["b_h"]},
            gcfg.f_mail_raw, m.f_mem)
        self._packed_sat = kops.pad_sat_params(
            attn_p["w_v"][:dkv], attn_p["b_v"],
            lut_attn["boundaries"], lut_attn["table"])
        self._packed_lut_gru = kops.pad_lut_params(
            lut_gru["boundaries"], lut_gru["table"])

        self._step = jax.jit(self._make_step())
        self.metrics: list[dict] = []

    # ------------------------------------------------------------------
    def _make_step(self):
        cfg = self.cfg
        m = cfg.model
        k = m.prune_k if m.prune_k is not None else m.m_r

        def step(params, state, batch):
            src, dst, eid, ts, valid = batch
            B = src.shape[0]
            vids = jnp.concatenate([src, dst])
            t_inst = jnp.concatenate([ts, ts])
            vvalid = jnp.concatenate([valid, valid])

            # ---- MUU: consume cached mail (LUT path) --------------------
            mail_raw = state.mail[vids]
            mail_ts = state.mail_ts[vids]
            mail_valid = state.mail_valid[vids]
            s_prev = state.memory[vids]
            lu_prev = state.last_update[vids]
            dt_mail = mail_ts - lu_prev
            if cfg.use_kernels:
                # LUT row fetch (Pallas) -> fused GRU (Pallas): the folded
                # time rows enter the kernel as an additive input-gate term
                time_rows = kops.lut_encode(dt_mail, self._packed_lut_gru)
                s_upd = kops.gru_cell(mail_raw, s_prev, self._packed_gru,
                                      extra=time_rows)
            else:
                time_rows = te.lut_encode(self._folded["gru"], dt_mail)
                s_upd = memory.gru_cell_lut(params["gru"], mail_raw,
                                            time_rows, s_prev)
            ok = mail_valid & vvalid
            s_upd = jnp.where(ok[:, None], s_upd, s_prev)
            lu_upd = jnp.where(ok, mail_ts, lu_prev)

            chron = updater.interleave_order(B)
            winners = updater.last_write_wins(vids, vvalid, chron)
            mem_t = updater.commit(state.memory, vids, s_upd, winners)
            lu_t = updater.commit_scalar(state.last_update, vids, lu_upd,
                                         winners)
            mv_t = updater.commit_scalar(state.mail_valid, vids,
                                         jnp.zeros_like(mail_valid), winners)
            state = state._replace(memory=mem_t, last_update=lu_t,
                                   mail_valid=mv_t)

            # ---- EU: prune-then-fetch + fused aggregate -----------------
            nbr_ids, nbr_ts, nbr_eid, nvalid = mailbox.gather_neighbors(
                state, vids)
            dt_n = jnp.maximum(t_inst[:, None] - nbr_ts, 0.0) * nvalid
            logits = attn_mod.sat_logits(params["attn"], dt_n)  # ts ONLY
            idx, sel_logits, sel_valid = pruning.topk_select(logits, nvalid,
                                                             k)
            # fetch ONLY the k winners' state (the point of the co-design)
            sel_ids = jnp.take_along_axis(nbr_ids, idx, axis=1)
            sel_eid = jnp.take_along_axis(nbr_eid, idx, axis=1)
            sel_dt = jnp.take_along_axis(dt_n, idx, axis=1)
            s_nbr = mem_t[sel_ids] * sel_valid[..., None]
            e_nbr = self.edge_feats[sel_eid] * sel_valid[..., None]
            kv = jnp.concatenate([s_nbr, e_nbr], axis=-1)

            if cfg.use_kernels:
                agg = kops.sat_aggregate(kv, sel_dt, sel_logits,
                                         sel_valid, self._packed_sat)
            else:
                attnw = pruning.masked_softmax(sel_logits, sel_valid)
                v = (kv @ params["attn"]["w_v"][:kv.shape[-1]]
                     + te.lut_encode(self._folded["attn"], sel_dt)
                     + params["attn"]["b_v"])
                agg = jnp.einsum("bn,bnd->bd", attnw, v)

            s_self = mem_t[vids]
            f_self = (self.node_feats[vids]
                      if self.node_feats is not None else None)
            fp = attn_mod.feat_proj(params["attn"]["feat"], s_self, f_self)
            h = jnp.concatenate([fp, agg], axis=-1) \
                @ params["attn"]["w_out"] + params["attn"]["b_out"]

            # ---- Updater: cache new mail + ring-buffer insert -----------
            fe = self.edge_feats[eid]
            mail_src = memory.build_mail_raw(mem_t[src], mem_t[dst], fe)
            mail_dst = memory.build_mail_raw(mem_t[dst], mem_t[src], fe)
            new_mail = jnp.concatenate([mail_src, mail_dst], axis=0)
            w2 = updater.last_write_wins(vids, vvalid, chron)
            mail_t = updater.commit(state.mail, vids, new_mail, w2)
            mts_t = updater.commit_scalar(state.mail_ts, vids, t_inst, w2)
            mvv_t = updater.commit_scalar(
                state.mail_valid, vids, jnp.ones_like(vvalid), w2)
            state = state._replace(mail=mail_t, mail_ts=mts_t,
                                   mail_valid=mvv_t)
            state = mailbox.insert_neighbors(state, src, dst, eid, ts, valid)
            return state, h[:B], h[B:]

        return step

    # ------------------------------------------------------------------
    def process(self, batch: EdgeBatch):
        """Process one batch; returns (emb_src, emb_dst) and records
        latency/throughput metrics."""
        dev = tuple(jnp.asarray(x) for x in
                    (batch.src, batch.dst, batch.eid, batch.ts, batch.valid))
        t0 = time.perf_counter()
        self.state, h_src, h_dst = self._step(self.params, self.state, dev)
        h_src.block_until_ready()
        dt = time.perf_counter() - t0
        n = int(batch.valid.sum())
        self.metrics.append({"latency_s": dt, "edges": n,
                             "throughput_eps": n / dt if dt > 0 else 0.0})
        return h_src, h_dst

    def run(self, stream: Iterable[EdgeBatch]):
        """Drive the engine over a stream with input prefetching."""
        for batch in overlap.prefetch(iter(stream), self.cfg.prefetch,
                                      device_put=lambda b: b):
            yield batch, self.process(batch)

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        if not self.metrics:
            return {}
        lat = np.array([m["latency_s"] for m in self.metrics[1:]])  # skip jit
        edges = sum(m["edges"] for m in self.metrics[1:])
        return {
            "batches": len(self.metrics) - 1,
            "mean_latency_ms": float(lat.mean() * 1e3) if len(lat) else 0.0,
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3)
            if len(lat) else 0.0,
            "throughput_eps": float(edges / lat.sum()) if len(lat) else 0.0,
        }
