"""Streaming TGNN inference engine — the paper's accelerator, end to end.

This is the production path that realizes the co-design (Fig. 2 + Alg. 1),
now a thin STATEFUL SESSION over any built ``core.pipeline.TGNPipeline``:

  Edge Parser   -> stream.EdgeBatch (chronological, padded, masked)
  Data Loader   -> sampler stage: PRUNE-THEN-FETCH for SAT variants (top-k
                   from the ring buffer's timestamps ONLY, then gather just
                   k rows — the HBM saving the paper measures as 67% fewer
                   MEMs); fetch-all for the vanilla teacher
  MUU           -> memory-updater stage (fused Pallas GRU with LUT rows
                   pre-folded through W_i, or the jnp reference)
  EU            -> aggregator stage (fused Pallas SAT-aggregate kernel,
                   jnp SAT reference, or vanilla attention)
  Updater       -> committer stage: vectorized last-write-wins chronological
                   commit, winners computed once per batch
  prefetch      -> double-buffered host->device input pipeline
                   (distributed/overlap.py) with real ``device_put`` and
                   per-batch transfer-time metrics

Every Table-II variant — the vanilla/cosine teacher included — runs through
the same session; ``use_kernels`` selects the Pallas stage backends where
they exist (SAT+LUT paths) and the identical-semantics jnp references
elsewhere. Folded/packed kernel parameters are prepared by the pipeline's
``prepare`` at session construction, not per step.

Since the multi-tenant SessionManager (serving/session.py) the engine is a
SINGLE-TENANT VIEW of a session: one tenant in a one-member cohort, stepped
through the same compiled round launch as a full fleet (the coalesced
``pipeline.CoalescedRound`` — trivially one segment here — fed by the
in-place host stager). That keeps single-stream and multi-tenant serving
bitwise-identical per tenant (vmapped numerics are invariant to the mapped
batch size), so an engine can be consolidated into a shared session — or a
tenant split out into its own engine — without a replay divergence.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import FrozenConfig
from repro.core import pipeline as pl
from repro.core import tgn
from repro.data.stream import EdgeBatch
from repro.distributed import overlap
from repro.obs import Histogram
from repro.serving.session import SessionManager


@dataclasses.dataclass(frozen=True)
class EngineConfig(FrozenConfig):
    model: tgn.TGNConfig = tgn.TGNConfig(attention="sat", encoder="lut",
                                         prune_k=4)
    # kernel tier: "ref" | "staged" | "fused" (legacy bools accepted —
    # see core/stages.KERNEL_TIERS)
    use_kernels: bool | str = True
    prefetch: int = 2


class _DeviceBatch(NamedTuple):
    """A batch whose host->device transfer has been dispatched (async)."""
    host: EdgeBatch
    dev: tuple
    enq_s: float            # host time spent enqueueing the transfer


class StreamingEngine:
    """Stateful streaming inference over a chronological edge stream.

    A session wraps one pipeline (any registry variant, kernel or reference
    backends) plus the mutable vertex state and metrics. Construct from an
    ``EngineConfig`` or via :meth:`from_variant` with a registry string.
    """

    def __init__(self, cfg: EngineConfig, params: dict,
                 edge_feats: jax.Array, node_feats: jax.Array | None = None):
        self.cfg = cfg
        # A one-tenant session: the same vmapped launch as multi-tenant
        # serving, so trajectories are bitwise-portable between the two.
        self.session = SessionManager(params, edge_feats, node_feats,
                                      model=cfg.model,
                                      use_kernels=cfg.use_kernels)
        self.tid = self.session.add_tenant()
        cohort = self.session.cohort_of(self.tid)
        self.pipeline = cohort.pipeline
        self.params = params
        self.edge_feats = self.session.edge_feats
        self.node_feats = self.session.node_feats
        # folded LUT tables / lane-packed kernel params, prepared once per
        # session (§III-C); training paths re-derive them in-trace instead.
        self.aux = cohort.aux
        self.metrics: list[dict] = []

    @property
    def state(self):
        """The tenant's VertexState (committed by ``process``)."""
        return self.session.state_of(self.tid)

    @state.setter
    def state(self, st):
        self.session.set_state(self.tid, st)

    @classmethod
    def from_variant(cls, variant: str, params: dict, edge_feats: jax.Array,
                     node_feats: jax.Array | None = None,
                     use_kernels: bool = True, prefetch: int = 2,
                     **dims) -> "StreamingEngine":
        """Session over a registry variant (``"sat+lut+np4"``, ``"teacher"``,
        Table-II row names, ...). ``dims`` are TGNConfig table/feature
        fields (n_nodes, n_edges, f_mem, ...)."""
        model = pl.variant_config(variant, **dims)
        return cls(EngineConfig(model=model, use_kernels=use_kernels,
                                prefetch=prefetch), params, edge_feats,
                   node_feats)

    # ------------------------------------------------------------------
    def describe(self) -> dict:
        """Variant and resolved stage backends of this session."""
        return self.pipeline.describe()

    def step_on_device(self, dev: tuple) -> tgn.BatchOut:
        """One jitted pipeline step over already-on-device batch arrays
        WITHOUT committing state (no metrics; benchmarking hook)."""
        return self.session.peek(self.tid, dev)

    # ------------------------------------------------------------------
    def _to_device(self, batch: EdgeBatch) -> _DeviceBatch:
        """Dispatch one batch's host->device transfer WITHOUT blocking —
        transfers issued by the prefetcher overlap the in-flight step."""
        t0 = time.perf_counter()
        dev = jax.device_put((np.asarray(batch.src), np.asarray(batch.dst),
                              np.asarray(batch.eid), np.asarray(batch.ts),
                              np.asarray(batch.valid)))
        return _DeviceBatch(host=batch, dev=dev,
                            enq_s=time.perf_counter() - t0)

    def process(self, batch: EdgeBatch | _DeviceBatch):
        """Process one batch; returns (emb_src, emb_dst) and records
        latency/throughput/transfer metrics. ``h2d_s`` is the EXPOSED
        transfer cost: enqueue time plus whatever wait the step actually
        incurred (≈0 when the prefetcher staged the batch early enough)."""
        if not isinstance(batch, _DeviceBatch):
            batch = self._to_device(batch)
        t0 = time.perf_counter()
        jax.block_until_ready(batch.dev)
        h2d = batch.enq_s + (time.perf_counter() - t0)
        t1 = time.perf_counter()
        out = self.session.step({self.tid: batch.dev})[self.tid]
        out.emb_src.block_until_ready()
        dt = time.perf_counter() - t1
        n = int(batch.host.valid.sum())
        self.metrics.append({"latency_s": dt, "edges": n,
                             "h2d_s": h2d,
                             "throughput_eps": n / dt if dt > 0 else 0.0})
        return out.emb_src, out.emb_dst

    def run(self, stream: Iterable[EdgeBatch]):
        """Drive the engine over a stream. The prefetcher dispatches the
        next batches' H2D transfers (async device_put) before each step, so
        host batch formation and transfers overlap the in-flight step;
        ``metrics[i]["h2d_s"]`` records the transfer cost the step could
        not hide."""
        for db in overlap.prefetch(iter(stream), self.cfg.prefetch,
                                   device_put=self._to_device):
            yield db.host, self.process(db)

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        if not self.metrics:
            return {}
        lat = Histogram("engine.latency_s")
        h2d = Histogram("engine.h2d_s")
        for m in self.metrics[1:]:          # skip the jit-warmup batch
            lat.record(m["latency_s"])
            h2d.record(m["h2d_s"])
        edges = sum(m["edges"] for m in self.metrics[1:])
        # Histogram returns a DEFINED None on empty (a one-batch run has
        # nothing after warmup); map it to the 0.0 this summary reports
        return {
            "batches": len(self.metrics) - 1,
            "mean_latency_ms": (lat.mean() or 0.0) * 1e3,
            "p99_latency_ms": (lat.quantile(0.99) or 0.0) * 1e3,
            "mean_h2d_ms": (h2d.mean() or 0.0) * 1e3,
            "throughput_eps": (float(edges / lat.total)
                               if lat.total > 0 else 0.0),
        }
