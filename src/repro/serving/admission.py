"""Live tenant admission: capacity classes over the coalesced lane table.

The coalesced round (``core/pipeline.py::CoalescedRound``) compiles ONE
launch whose lane table — which cohort owns which contiguous rows of the
super-batch — is static. Growing a cohort's stacked tables therefore
recompiles the round, which an *online* frontend cannot afford mid-stream.

This module supplies the reservation policy that makes attach/detach a
fast path instead:

``CapacityLadder``
    maps a tenant count to a pre-allocated capacity CLASS (2, 4, 8, ...)
    with ``headroom`` spare slots guaranteed after every relayout. Spare
    slots hold init-state rows and are idle-masked every round — the
    established all-``valid=False`` bitwise no-op — so they cost one
    masked lane row, not a recompile. A relayout happens only when a
    class is exhausted, i.e. O(log n) times over a tenant ramp instead of
    every attach.

``AdmissionController``
    a thin audited wrapper over ``SessionManager.add_tenant`` /
    ``remove_tenant`` / ``prewarm_cohort`` that records, per admission,
    whether it landed on the fast path (in-place slot write) or forced a
    relayout — the ledger the frontend's stats endpoint and the
    zero-recompile acceptance tests read.

The manager itself enforces the semantics (``serving/session.py``
``_Cohort.add``/``remove``); everything here is policy + bookkeeping, so
the offline drivers keep their exact-size eager-shrink behavior simply by
not passing a reserve.
"""
from __future__ import annotations

from dataclasses import dataclass


class CapacityLadder:
    """Capacity classes for cohort lane slots.

    ``capacity_for(n)`` returns the stacked-table rows to lay out for
    ``n`` resident tenants: the smallest class holding ``n + headroom``,
    so immediately after any relayout there are at least ``headroom``
    spare slots — the NEXT attaches are guaranteed fast-path. Past the
    top of the explicit ladder, classes keep doubling.

    The default ladder (2, 4, 8, ..., 64; headroom 1) relays out a
    single-cohort fleet at sizes 2->3, 4->5, 8->9, ...: growth costs
    amortize to O(log n) recompiles while idle-slot overhead stays under
    2x, the classic doubling trade.
    """

    def __init__(self, classes: tuple = (2, 4, 8, 16, 32, 64),
                 headroom: int = 1):
        if not classes or list(classes) != sorted(set(classes)):
            raise ValueError("classes must be strictly increasing")
        if headroom < 1:
            raise ValueError("headroom must be >= 1 (zero headroom means "
                             "every attach relays out — that is the "
                             "reserve=None behavior)")
        self.classes = tuple(int(c) for c in classes)
        self.headroom = int(headroom)

    def capacity_for(self, n_tenants: int) -> int:
        """Smallest class with room for ``n_tenants`` plus headroom."""
        need = max(n_tenants + self.headroom, self.classes[0])
        for c in self.classes:
            if c >= need:
                return c
        c = self.classes[-1]
        while c < need:        # geometric growth past the ladder top
            c *= 2
        return c

    def __repr__(self) -> str:
        return (f"CapacityLadder(classes={self.classes}, "
                f"headroom={self.headroom})")


@dataclass(frozen=True)
class Admission:
    """One audited attach/detach/prewarm outcome."""
    tid: str | None       #: tenant id (None for prewarm)
    action: str           #: "attach" | "detach" | "prewarm"
    fast: bool            #: True = landed in the compiled program as-is
    relayout: bool        #: True = coalesced layout rebuilt (slow path)
    new_cohort: bool      #: True = a new variant lane was created
    size: int             #: cohort tenants AFTER the admission
    capacity: int         #: cohort stacked rows AFTER the admission


class AdmissionController:
    """Audited live admission over a reserve-enabled ``SessionManager``.

    ::

        mgr = SessionManager(params, ef, model=cfg, reserve=True)
        adm = AdmissionController(mgr)
        adm.prewarm("np4")              # lane compiled before tenant 1
        tid = adm.attach("np4")         # fast path: in-place slot write
        adm.detach(tid)                 # fast path: swap-remove, slot idles
        adm.log[-1].fast                # -> True
    """

    def __init__(self, mgr):
        if getattr(mgr, "reserve", None) is None:
            raise ValueError(
                "AdmissionController needs a reserve-enabled manager "
                "(SessionManager(..., reserve=True) or an explicit "
                "CapacityLadder); without spare lane slots every "
                "admission is a relayout")
        self.mgr = mgr
        #: chronological ``Admission`` records, newest last.
        self.log: list[Admission] = []

    def _record(self, tid, action) -> Admission:
        last = self.mgr.last_admission or {}
        cohort = self.mgr._tenant_cohort.get(tid)
        size = cohort.size if cohort is not None else 0
        cap = cohort.capacity if cohort is not None else 0
        adm = Admission(tid=tid, action=action,
                        fast=not (last.get("relayout")
                                  or last.get("new_cohort")),
                        relayout=bool(last.get("relayout")),
                        new_cohort=bool(last.get("new_cohort")),
                        size=size, capacity=cap)
        self.log.append(adm)
        self.mgr.obs.counter(
            "admission.fast" if adm.fast else "admission.slow").inc()
        return adm

    def attach(self, variant=None, *, name: str | None = None,
               reservoir_tau: float | None = None,
               use_kernels=None, params: str | None = None) -> str:
        tid = self.mgr.add_tenant(variant, name=name,
                                  reservoir_tau=reservoir_tau,
                                  use_kernels=use_kernels, params=params)
        self._record(tid, "attach")
        return tid

    def detach(self, tid: str) -> Admission:
        self.mgr.remove_tenant(tid)
        return self._record(tid, "detach")

    def prewarm(self, variant=None, *,
                reservoir_tau: float | None = None,
                use_kernels=None, params: str | None = None) -> None:
        """Materialize a variant lane at reserve capacity with zero
        tenants, so its first tenant attaches fast-path."""
        self.mgr.prewarm_cohort(variant, reservoir_tau=reservoir_tau,
                                use_kernels=use_kernels, params=params)
        self.log.append(Admission(tid=None, action="prewarm", fast=False,
                                  relayout=True, new_cohort=True,
                                  size=0, capacity=0))
        self.mgr.obs.counter("admission.slow").inc()

    def stats(self) -> dict:
        """Per-cohort occupancy plus the fast/slow admission tallies."""
        occupancy = [
            {"tenants": list(c.tids), "size": c.size,
             "capacity": c.capacity, "spare": c.spare}
            for c in self.mgr._cohorts.values()
        ]
        return {
            "cohorts": occupancy,
            "admissions": len(self.log),
            "fast": sum(1 for a in self.log if a.fast),
            "relayouts": sum(1 for a in self.log if a.relayout),
            # compile_counters is ONE registry snapshot now, so this view
            # and a frontend stats() in the same response always agree
            "compile": self.mgr.compile_counters(),
        }
