"""Durable per-tenant event journal: the write-ahead log behind
lossless, exactly-once recovery.

Snapshots alone make the fleet crash-TOLERANT, not crash-LOSSLESS: a
restore rolls a tenant back to its newest snapshot and silently drops
every event ingested since, and the backpressure contract
(``RetryAfter`` -> client retries) invites at-least-once delivery with
nothing stopping a retried event from double-applying. This module
closes both holes (docs/ROBUSTNESS.md, "Recovery semantics"):

``EventJournal``
    an append-only, crc32-framed, segment-rotated write-ahead log, one
    directory per tenant. The frontend appends every accepted event
    BEFORE enqueueing it (write-ahead: an acked event is on disk) and a
    flush marker for every round the session actually applies, so the
    log records not just the events but the exact batch boundaries —
    which is what makes replay BITWISE, not merely value-preserving
    (batch boundaries change mailbox commit granularity). ``fsync`` is
    batched on a configurable interval (``fsync_s``; ``0`` = every
    append) measured on an injected clock.

exactly-once ingest
    each event may carry a client-supplied ``(client_id, seq)`` stamp.
    A sliding per-client dedup window (rebuilt from the journal on
    open, so it survives restarts) makes retried ingests idempotent:
    a duplicate is acknowledged (``{"ok": true, "dedup": true}``) and
    never re-journaled or re-enqueued.

recovery = snapshot + replay
    snapshot manifests record a journal ``cursor`` — ``(segment,
    offset, events, last_seq)`` — and ``replay`` drives the journal
    suffix after that cursor back through the normal ``DeadlineBatcher
    -> SessionManager.step`` pipeline, rebuilding each recorded flush
    with its original rows and padded width. Torn final records (a
    crash mid-append) are truncated on open, never fabricated; a
    crc-corrupt record stops replay with a warning (events past it are
    unrecoverable — the log is the source of truth, it never guesses).

truncation, coordinated with snapshot GC
    ``truncate_upto`` drops whole segments strictly below a retained
    snapshot's cursor, oldest first, so a crash mid-truncation leaves a
    contiguous (still replayable) suffix; ``cluster.truncate_journal``
    picks the OLDEST retained snapshot's cursor as the bound, so every
    snapshot ``checkpoint._gc`` keeps can still anchor a full replay
    (and ``checkpoint.save(floor=...)`` pins the anchor step outside
    the keep window as the belt-and-braces backstop).
"""
from __future__ import annotations

import json
import os
import struct
import time
import warnings
import zlib
from collections import deque
from dataclasses import dataclass, field

_HEADER = struct.Struct("<II")          # (payload length, crc32(payload))
_SEG_FMT = "seg_{:08d}.wal"
#: sanity bound on one framed record — a length field past this is
#: corruption, not a huge event.
_MAX_RECORD = 1 << 20


def _seg_path(d: str, idx: int) -> str:
    return os.path.join(d, _SEG_FMT.format(idx))


def _seg_index(name: str) -> int:
    return int(name[4:-4])


@dataclass
class ReplayResult:
    """What one ``replay`` call did: ``rounds`` flushes re-applied,
    ``events`` rows inside them, ``pending`` journaled-but-never-flushed
    events (the caller re-enqueues them — they were accepted but no
    round consumed them before the crash), and ``corrupt`` when replay
    stopped early at a crc-corrupt record."""
    rounds: int = 0
    events: int = 0
    pending: list = field(default_factory=list)
    corrupt: bool = False


class _DedupWindow:
    """Per-client sliding seq window: ``seen(seq)`` is True for any seq
    already accepted within the last ``size`` sequence numbers — and,
    conservatively, for anything OLDER than the window (a retry that
    stale was almost certainly applied; re-applying would be the worse
    failure). Out-of-order first deliveries inside the window are
    accepted exactly once."""

    def __init__(self, size: int):
        self.size = int(size)
        self.max_seq: int | None = None
        self._in_window: set[int] = set()

    def seen(self, seq: int) -> bool:
        if self.max_seq is None or seq > self.max_seq:
            return False
        if seq <= self.max_seq - self.size:
            return True
        return seq in self._in_window

    def accept(self, seq: int) -> None:
        self._in_window.add(seq)
        if self.max_seq is None or seq > self.max_seq:
            self.max_seq = seq
            lo = self.max_seq - self.size
            self._in_window = {s for s in self._in_window if s > lo}


class _TenantLog:
    """One tenant's segment chain + counters + dedup state."""

    def __init__(self, d: str, *, segment_bytes: int, dedup_window: int):
        self.dir = d
        self.segment_bytes = int(segment_bytes)
        self.dedup_window = int(dedup_window)
        self.appended = 0        # next event index
        self.flushed = 0         # events covered by flush markers
        #: (event idx, segment, offset) of every journaled-not-flushed
        #: event — head is the replay cursor's low-water mark.
        self.unflushed: deque = deque()
        self.windows: dict[str, _DedupWindow] = {}
        self.seg = 0
        self.off = 0
        self._f = None
        self._dirty = False
        self._wedged = False     # a torn write happened: appends refuse
        os.makedirs(d, exist_ok=True)
        self._recover()

    # ----------------------------------------------------------- open
    def segments(self) -> list[int]:
        return sorted(_seg_index(f) for f in os.listdir(self.dir)
                      if f.startswith("seg_") and f.endswith(".wal"))

    def _recover(self) -> None:
        """Scan every retained segment: rebuild counters + dedup windows
        (replaying the log's own bookkeeping), truncate a torn tail in
        the final segment, and position the append head."""
        segs = self.segments()
        if not segs:
            self._open_segment(0, 0)
            return
        for si, seg in enumerate(segs):
            last = si == len(segs) - 1
            end, status = 0, "clean"
            for off, rec in _scan(_seg_path(self.dir, seg)):
                if rec is None:
                    status = off       # "torn" | "corrupt"
                    break
                end = off
                self._note_scanned(rec)
            if status == "torn" and last:
                # a crash mid-append: truncate the partial record —
                # it was never acked, so dropping it loses nothing
                warnings.warn(
                    f"journal {self.dir} segment {seg}: torn final "
                    f"record truncated at offset {end}")
                with open(_seg_path(self.dir, seg), "r+b") as f:
                    f.truncate(end)
            elif status != "clean":
                warnings.warn(
                    f"journal {self.dir} segment {seg}: {status} record; "
                    "records beyond it are unreachable")
        self._open_segment(segs[-1],
                           os.path.getsize(_seg_path(self.dir, segs[-1])))

    def _note_scanned(self, rec: dict) -> None:
        if rec["k"] == "ev":
            i = rec["i"]
            self.appended = max(self.appended, i + 1)
            if rec.get("c") is not None:
                self.window_for(rec["c"]).accept(rec["q"])
        elif rec["k"] == "fl":
            top = rec["a"] + rec["n"]
            self.flushed = max(self.flushed, top)
            self.appended = max(self.appended, top)
        while self.unflushed and self.unflushed[0][0] < self.flushed:
            self.unflushed.popleft()
        if rec["k"] == "ev" and rec["i"] >= self.flushed:
            self.unflushed.append((rec["i"], rec["_seg"], rec["_off"]))

    def _open_segment(self, idx: int, off: int) -> None:
        if self._f is not None:
            self._f.close()
        self.seg, self.off = idx, off
        self._f = open(_seg_path(self.dir, idx), "ab")

    # --------------------------------------------------------- append
    def write(self, rec: dict, torn: bool = False) -> tuple[int, int]:
        """Append one framed record; returns its ``(segment, offset)``
        position (rotation may move the append head first)."""
        if self._wedged:
            raise OSError(f"journal {self.dir} is wedged after a torn "
                          "write; reopen to recover")
        if self.off >= self.segment_bytes:
            self.fsync()
            self._open_segment(self.seg + 1, 0)
        pos = (self.seg, self.off)
        payload = json.dumps(rec, separators=(",", ":")).encode()
        frame = _HEADER.pack(len(payload), zlib.crc32(payload)) + payload
        if torn:
            # simulate a crash mid-write: half the frame reaches disk,
            # the process is as good as dead for this log
            self._f.write(frame[:max(_HEADER.size, len(frame) // 2)])
            self._f.flush()
            self._wedged = True
            raise OSError(f"torn journal write in {self.dir} (injected)")
        self._f.write(frame)
        # write-through to the OS now (a reopen sees it); durability is
        # the batched fsync's job
        self._f.flush()
        self.off += len(frame)
        self._dirty = True
        return pos

    def fsync(self) -> None:
        if self._f is not None and self._dirty:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._dirty = False

    def window_for(self, client_id: str) -> _DedupWindow:
        w = self.windows.get(client_id)
        if w is None:
            w = self.windows[client_id] = _DedupWindow(self.dedup_window)
        return w

    def close(self) -> None:
        if self._f is not None:
            if not self._wedged:
                self.fsync()
            self._f.close()
            self._f = None


def _scan(path: str):
    """Yield ``(end offset, record dict)`` per intact record; on a bad
    frame yield ``(status, None)`` — ``"torn"`` (incomplete bytes at the
    tail) or ``"corrupt"`` (full frame, crc/length mismatch) — and stop.
    Each record dict carries its own position as ``_seg``/``_off``."""
    seg = _seg_index(os.path.basename(path))
    with open(path, "rb") as f:
        off = 0
        while True:
            head = f.read(_HEADER.size)
            if not head:
                return
            if len(head) < _HEADER.size:
                yield "torn", None
                return
            length, crc = _HEADER.unpack(head)
            if length > _MAX_RECORD:
                yield "corrupt", None
                return
            payload = f.read(length)
            if len(payload) < length:
                yield "torn", None
                return
            if zlib.crc32(payload) != crc:
                yield "corrupt", None
                return
            try:
                rec = json.loads(payload)
            except json.JSONDecodeError:
                yield "corrupt", None
                return
            rec["_seg"], rec["_off"] = seg, off
            off += _HEADER.size + length
            yield off, rec


class EventJournal:
    """The fleet's write-ahead event log: one ``_TenantLog`` per tenant
    under ``root`` (see module docstring).

    ``fsync_s`` batches durability: an append fsyncs only when the
    injected ``clock`` says the last fsync is at least that old
    (``0.0`` = fsync every append). ``segment_bytes`` bounds segment
    files (rotation keeps truncation granular); ``dedup_window`` sizes
    the per-client sliding seq window — it must exceed a client's
    maximum in-flight retry depth (docs/ROBUSTNESS.md).
    """

    def __init__(self, root: str, *, fsync_s: float = 0.0,
                 segment_bytes: int = 1 << 20, dedup_window: int = 1024,
                 clock=time.monotonic):
        if dedup_window < 1:
            raise ValueError(f"dedup_window must be >= 1, got "
                             f"{dedup_window}")
        self.root = root
        self.fsync_s = float(fsync_s)
        self.segment_bytes = int(segment_bytes)
        self.dedup_window = int(dedup_window)
        self.clock = clock
        self._logs: dict[str, _TenantLog] = {}
        self._last_fsync = clock()
        self.appends = 0
        self.fsyncs = 0
        self.last_replay: ReplayResult | None = None
        os.makedirs(root, exist_ok=True)

    def log_for(self, tid: str) -> _TenantLog:
        log = self._logs.get(tid)
        if log is None:
            log = self._logs[tid] = _TenantLog(
                os.path.join(self.root, tid),
                segment_bytes=self.segment_bytes,
                dedup_window=self.dedup_window)
        return log

    # ------------------------------------------------------ hot path
    def is_duplicate(self, tid: str, client_id: str, seq: int) -> bool:
        """Query-only dedup check (the accept happens in
        ``append_event`` — a rejected/failed append never burns a seq)."""
        return self.log_for(tid).window_for(str(client_id)).seen(int(seq))

    def last_seq(self, tid: str, client_id) -> int | None:
        """Highest accepted seq for ``(tid, client_id)`` — what a
        reconnecting client resumes after (``RetryAfter.last_seq``)."""
        if client_id is None:
            return None
        w = self.log_for(tid).windows.get(str(client_id))
        return None if w is None else w.max_seq

    def append_event(self, tid: str, src: int, dst: int, eid: int,
                     ts: float, neg_dst: int = 0, *,
                     client_id=None, seq=None, torn: bool = False) -> None:
        """Journal one accepted event (call BEFORE enqueueing it).
        Raises ``OSError`` on write failure — the caller must then
        REJECT the ingest (transient), because an event that is not on
        disk is a durability promise the fleet cannot keep."""
        log = self.log_for(tid)
        rec = {"k": "ev", "i": log.appended,
               "e": [int(src), int(dst), int(eid), float(ts),
                     int(neg_dst)]}
        if client_id is not None and seq is not None:
            rec["c"] = str(client_id)
            rec["q"] = int(seq)
        pos = log.write(rec, torn=torn)
        log.unflushed.append((log.appended, *pos))
        log.appended += 1
        if client_id is not None and seq is not None:
            log.window_for(str(client_id)).accept(int(seq))
        self.appends += 1
        self._maybe_fsync()

    def note_flush(self, tid: str, n: int, width: int) -> None:
        """Journal one flush marker: the session is about to apply the
        tenant's oldest ``n`` pending events as a batch padded to
        ``width`` rows. Markers are what make replay rebuild the EXACT
        batch boundaries (and therefore the exact trajectory)."""
        log = self.log_for(tid)
        log.write({"k": "fl", "a": log.flushed, "n": int(n),
                   "w": int(width)})
        log.flushed += int(n)
        for _ in range(int(n)):
            if log.unflushed:
                log.unflushed.popleft()
        self._maybe_fsync()

    def append_batch(self, tid: str, batch) -> None:
        """Journal one offline ``EdgeBatch`` as its valid rows plus one
        flush marker — the ``--mode tgn`` stream path's WAL hook (the
        driver hands whole batches to the session, so the batch IS the
        flush boundary; ``w`` records the padded width replay rebuilds)."""
        import numpy as np
        valid = np.asarray(batch.valid)
        n = int(valid.sum())
        src, dst = np.asarray(batch.src), np.asarray(batch.dst)
        eid, ts = np.asarray(batch.eid), np.asarray(batch.ts)
        neg = np.asarray(batch.neg_dst)
        for i in np.flatnonzero(valid):
            self.append_event(tid, src[i], dst[i], eid[i], ts[i], neg[i])
        if n:
            self.note_flush(tid, n, int(valid.shape[0]))

    def _maybe_fsync(self) -> None:
        now = self.clock()
        if self.fsync_s > 0 and (now - self._last_fsync) < self.fsync_s:
            return
        self.flush()

    def flush(self) -> None:
        """fsync every dirty tenant log now (also the close/exit path)."""
        for log in self._logs.values():
            if log._dirty:
                log.fsync()
                self.fsyncs += 1
        self._last_fsync = self.clock()

    # ------------------------------------------------------- cursors
    def cursor(self, tid: str) -> dict:
        """The tenant's replay cursor, recorded into snapshot manifests:
        ``segment``/``offset`` locate the oldest record a replay from
        this snapshot needs (the head of the unflushed queue, or the
        append tail when nothing is pending), ``events`` counts the
        flushes already inside the snapshotted state, and ``last_seq``
        is the per-client dedup high-water mark at capture time."""
        log = self.log_for(tid)
        if log.unflushed:
            _idx, seg, off = log.unflushed[0]
        else:
            seg, off = log.seg, log.off
        return {"segment": seg, "offset": off, "events": log.flushed,
                "last_seq": {c: w.max_seq
                             for c, w in sorted(log.windows.items())
                             if w.max_seq is not None}}

    # -------------------------------------------------------- replay
    def records(self, tid: str, segment: int = 0, offset: int = 0):
        """Iterate intact records from ``(segment, offset)`` to the end
        of the log, across segment boundaries. Ends with a warning at
        the first corrupt record (yields nothing past it)."""
        log = self.log_for(tid)
        for seg in log.segments():
            if seg < segment:
                continue
            path = _seg_path(log.dir, seg)
            start = offset if seg == segment else 0
            for end, rec in _scan(path):
                if rec is None:
                    warnings.warn(
                        f"journal {log.dir} segment {seg}: replay "
                        f"stopped at a {end} record")
                    yield None
                    return
                if rec["_off"] >= start:
                    yield rec

    def replay(self, tid: str, cursor: dict, step_fn, *,
               as_tid: str | None = None) -> ReplayResult:
        """Re-apply the journal suffix after ``cursor`` through the
        normal ``DeadlineBatcher -> step`` pipeline: each recorded flush
        marker rebuilds its batch from the journaled events — same rows,
        same order, same padded width — and hands it to ``step_fn`` as
        one round. ``as_tid`` renames the batches when the tenant was
        restored under a different id. Returns a ``ReplayResult`` (also
        stashed as ``self.last_replay``); ``pending`` holds journaled
        events no marker ever covered — accepted but never applied, the
        caller re-enqueues them into its live batcher."""
        from repro.serving.frontend import DeadlineBatcher, FrontendConfig

        out = as_tid or tid
        e0 = int(cursor.get("events", 0))
        res = ReplayResult()
        pending: list = []        # (idx, src, dst, eid, ts, neg, c, q)
        for rec in self.records(tid, int(cursor.get("segment", 0)),
                                int(cursor.get("offset", 0))):
            if rec is None:
                res.corrupt = True
                break
            if rec["k"] == "ev":
                if rec["i"] >= e0:
                    pending.append((rec["i"], *rec["e"], rec.get("c"),
                                    rec.get("q")))
            elif rec["k"] == "fl":
                a, n, w = rec["a"], rec["n"], rec["w"]
                if a + n <= e0:
                    continue              # flush already in the snapshot
                take = [p for p in pending[:n] if p[0] >= max(a, e0)]
                pending = pending[len(take):]
                if not take:
                    continue
                batcher = DeadlineBatcher(
                    FrontendConfig(max_rows=len(take), pad_quantum=w,
                                   queue_rows=max(len(take), 1)),
                    clock=lambda: 0.0)
                batcher.add_tenant(out)
                for _idx, src, dst, eid, ts, neg, _c, _q in take:
                    batcher.submit(out, src, dst, eid, ts, neg)
                batches, _arrivals = batcher.take()
                step_fn(batches)
                res.rounds += 1
                res.events += len(take)
        res.pending = [p[1:] for p in pending]
        self.last_replay = res
        return res

    # ---------------------------------------------------- truncation
    def truncate_upto(self, tid: str, cursor: dict) -> int:
        """Drop whole segments strictly below ``cursor["segment"]``,
        OLDEST FIRST — a crash mid-truncation leaves a contiguous
        suffix, so the journal stays replayable from every cursor at or
        above the bound and re-running the truncation completes it.
        Returns the number of segments removed. The caller owns the
        coordination contract: ``cursor`` must be the OLDEST retained
        snapshot's (``cluster.truncate_journal``)."""
        log = self.log_for(tid)
        bound = int(cursor.get("segment", 0))
        removed = 0
        for seg in log.segments():
            if seg >= bound or seg == log.seg:
                break
            os.remove(_seg_path(log.dir, seg))
            removed += 1
        return removed

    # ------------------------------------------------------- lifecycle
    def stats(self) -> dict:
        return {"appends": self.appends, "fsyncs": self.fsyncs,
                "tenants": {tid: {"appended": log.appended,
                                  "flushed": log.flushed,
                                  "segments": len(log.segments())}
                            for tid, log in sorted(self._logs.items())}}

    def close(self) -> None:
        for log in self._logs.values():
            log.close()
        self._logs.clear()
