"""Online serving front-end: async ingestion + deadline batching.

Layered over ``SessionManager`` (ideally reserve-enabled — see
``serving/admission.py``) this module turns per-tenant edge EVENTS into
the per-round edge BATCHES the coalesced launch consumes:

``DeadlineBatcher``
    pure, clock-injected micro-batching. Events enqueue into bounded
    per-tenant FIFO queues; a round flushes when any tenant has
    ``max_rows`` pending OR the oldest pending event has waited
    ``max_wait_s``, whichever first. Full queues reject with
    ``RetryAfter`` (bounded memory, never silent drops). Flushed batches
    are padded (repeat-last-row, ``valid=False``) to a ``pad_quantum``
    multiple so the round's static widths vector — and therefore the
    compiled executable — stays stable under jittery arrival rates.

``ServingFrontend``
    the serving shell: a synchronous ``pump()`` core (testable without an
    event loop) driving ``SessionManager.step`` plus an asyncio driver
    (``start``/``stop``) and a request dispatcher (``handle``) speaking a
    dict protocol — op "ingest" | "attach" | "detach" | "stats" |
    "metrics" | "flush". Live attach/detach land mid-stream on the
    reserve fast path: no recompile, surviving tenants' trajectories
    bitwise-unchanged. Event latencies stream into the fleet's
    ``obs.MetricsRegistry``; ``metrics`` returns its lock-consistent
    snapshot plus per-tenant SLO burn (docs/OBSERVABILITY.md).

``serve_jsonl``
    the stdlib wire transport: newline-delimited JSON over
    ``asyncio.start_server``, one request dict per line, one response
    dict per line. ``launch/serve.py --listen HOST:PORT`` boots it.

The batcher never touches the device: it hands ``EdgeBatch`` dicts to
``SessionManager.step``, which stages through the in-place host ring
buffers as always. A fake ``clock`` makes every deadline path
deterministic under test.
"""
from __future__ import annotations

import asyncio
import json
import math
import time
from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.data.stream import EdgeBatch


class RetryAfter(Exception):
    """A TRANSIENT ingest rejection: retry later, nothing is wrong with
    the request itself.

    Two sources: the tenant's bounded queue is full (``reason=
    "queue_full"`` — classic backpressure), or the tenant is quarantined
    by the FleetGuard and its auto-restore is pending (``reason=
    "quarantined"``). Carries the suggested retry delay; the transport
    maps it to a structured ``{"ok": false, "error": "retry_after",
    "transient": true, ...}`` response (HTTP would say 429/503) instead
    of growing the queue without bound. Permanent rejections —
    malformed events, unknown tenants — are ``invalid_request`` /
    ``unknown_tenant`` with ``"transient": false`` instead.
    """

    def __init__(self, tid: str, seconds: float, depth: int,
                 reason: str = "queue_full", last_seq=None):
        super().__init__(f"tenant {tid!r} {reason} ({depth} rows); "
                         f"retry after {seconds:.3f}s")
        self.tid = tid
        self.seconds = seconds
        self.depth = depth
        self.reason = reason
        #: with a journal armed, the client's highest accepted seq — a
        #: reconnecting client resumes after it without a stats
        #: round-trip (docs/SERVING.md retry contract)
        self.last_seq = last_seq


class DuplicateEvent(Exception):
    """An ingest retry the journal's dedup window already accepted.

    NOT an error: the event is durably journaled (and possibly already
    applied), so the transport ACKS it — ``{"ok": true, "dedup": true}``
    — and never re-enqueues. This is the server half of the exactly-once
    contract: clients retry at-least-once, the dedup window makes the
    retries idempotent (docs/ROBUSTNESS.md)."""

    def __init__(self, tid: str, client_id: str, seq: int):
        super().__init__(f"tenant {tid!r} client {client_id!r} seq {seq} "
                         "already accepted")
        self.tid = tid
        self.client_id = client_id
        self.seq = seq


@dataclass(frozen=True)
class FrontendConfig:
    """Knobs of the deadline batcher + backpressure contract."""
    max_wait_s: float = 0.010   #: flush when the oldest event is this old
    max_rows: int = 128         #: flush when any tenant has this many rows
    queue_rows: int = 1024      #: per-tenant bound; beyond it -> RetryAfter
    retry_after_s: float = 0.05  #: suggested client backoff on rejection
    #: pad flushed batches (repeat-last, ``valid=False``) to a multiple of
    #: this, so the compiled round sees a stable widths vector. 0 = exact
    #: (every new flush size is a potential retrace).
    pad_quantum: int = 0


def _pad_rows(cols: tuple, quantum: int) -> tuple:
    """Repeat-last-row pad ``(src, dst, eid, ts, valid, neg)`` columns up
    to a ``quantum`` multiple, padding rows ``valid=False`` — numerically
    a masked no-op, exactly the offline driver's padding convention."""
    n = len(cols[0])
    if quantum <= 0 or n % quantum == 0:
        return cols
    b = ((n + quantum - 1) // quantum) * quantum
    out = []
    for i, c in enumerate(cols):
        reps = np.repeat(c[-1:], b - n, axis=0)
        if i == 4:                       # the valid mask
            reps = np.zeros(b - n, dtype=bool)
        out.append(np.concatenate([c, reps], axis=0))
    return tuple(out)


class DeadlineBatcher:
    """Bounded per-tenant event queues with deadline/size flush triggers.

    Pure host-side bookkeeping — inject a fake ``clock`` to test every
    trigger deterministically. Each pending event is one edge
    ``(src, dst, eid, ts, neg_dst)`` plus its arrival wall time.
    """

    def __init__(self, cfg: FrontendConfig, clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self._q: dict[str, deque] = {}
        self.rejected = 0       #: events refused with RetryAfter
        self.accepted = 0       #: events enqueued
        self.flushes = 0        #: rounds handed out by take()

    def add_tenant(self, tid: str) -> None:
        self._q.setdefault(tid, deque())

    def drop_tenant(self, tid: str) -> deque:
        """Detach bookkeeping; returns (possibly non-empty) leftovers."""
        return self._q.pop(tid, deque())

    def check_capacity(self, tid: str) -> None:
        """Raise ``RetryAfter`` if the tenant's bounded queue is full.
        The frontend pre-checks this BEFORE a write-ahead journal append
        — a journaled-then-rejected event would dedup the client's retry
        into a silently lost event."""
        q = self._q[tid]
        if len(q) >= self.cfg.queue_rows:
            self.rejected += 1
            raise RetryAfter(tid, self.cfg.retry_after_s, len(q))

    def submit(self, tid: str, src: int, dst: int, eid: int, ts: float,
               neg_dst: int = 0) -> int:
        """Enqueue one edge event; returns the tenant's queue depth.
        Raises ``RetryAfter`` when the bounded queue is full."""
        self.check_capacity(tid)
        q = self._q[tid]
        q.append((int(src), int(dst), int(eid), float(ts), int(neg_dst),
                  self.clock()))
        self.accepted += 1
        return len(q)

    def depths(self) -> dict:
        """{tid: pending rows} — the manager's queue-depth provider."""
        return {tid: len(q) for tid, q in self._q.items()}

    def oldest(self) -> float | None:
        """Arrival time of the oldest pending event, None when idle."""
        arrivals = [q[0][5] for q in self._q.values() if q]
        return min(arrivals) if arrivals else None

    def due(self, now: float | None = None) -> bool:
        """Should a round flush now? True when any tenant hit
        ``max_rows`` or the oldest pending event aged past
        ``max_wait_s``."""
        if any(len(q) >= self.cfg.max_rows for q in self._q.values()):
            return True
        oldest = self.oldest()
        if oldest is None:
            return False
        now = self.clock() if now is None else now
        return (now - oldest) >= self.cfg.max_wait_s

    def next_deadline(self) -> float | None:
        """Absolute clock time of the pending deadline, None when idle."""
        oldest = self.oldest()
        return None if oldest is None else oldest + self.cfg.max_wait_s

    def take(self) -> tuple:
        """Drain up to ``max_rows`` per tenant into ``EdgeBatch``es
        (leftovers stay queued FIFO for the next round). Tenants with
        nothing pending are omitted — the coalesced round idle-masks
        them. Returns ``(batches, arrivals)``: the round's ``{tid:
        EdgeBatch}`` plus ``{tid: arrival clock times}`` of the drained
        events (per-tenant, so latency accounting and SLO burn can
        attribute each event; padding rows excluded)."""
        batches, arrivals = {}, {}
        for tid, q in self._q.items():
            if not q:
                continue
            rows = [q.popleft() for _ in range(min(len(q),
                                                   self.cfg.max_rows))]
            src, dst, eid, ts, neg, arrival = zip(*rows)
            arrivals[tid] = arrival
            cols = (np.asarray(src, np.int32), np.asarray(dst, np.int32),
                    np.asarray(eid, np.int32), np.asarray(ts, np.float32),
                    np.ones(len(rows), bool), np.asarray(neg, np.int32))
            batches[tid] = EdgeBatch(*_pad_rows(cols, self.cfg.pad_quantum))
        if batches:
            self.flushes += 1
        return batches, arrivals


class ServingFrontend:
    """Deadline-batched online serving over a ``SessionManager``.

    The synchronous core (``submit``/``pump``/``handle``) is complete on
    its own — tests drive it with a fake clock and zero event-loop
    machinery. ``start()``/``stop()`` wrap it in an asyncio task that
    sleeps until the next deadline (or an ingest wake) and pumps.

    ``record_rounds=True`` keeps a log of every flushed ``{tid: batch}``
    mapping — the replay tape the bitwise acceptance test feeds to an
    offline ``SessionManager`` driver.
    """

    def __init__(self, mgr, cfg: FrontendConfig | None = None,
                 clock=time.monotonic, record_rounds: bool = False,
                 tracer=None, slo_ms: float | None = None,
                 slo_objective: float = 0.99, journal=None):
        self.mgr = mgr
        #: optional ``EventJournal`` (serving/journal.py). Armed, every
        #: accepted ingest is write-ahead journaled BEFORE enqueue and
        #: ``(client_id, seq)`` retries dedup; disarmed, the hot path
        #: pays one attribute test (session_lint rule 5).
        self.journal = journal
        self.dedups = 0     #: retried ingests absorbed by the window
        self.cfg = cfg or FrontendConfig()
        self.clock = clock
        self.batcher = DeadlineBatcher(self.cfg, clock)
        for tid in mgr.tenants:
            self.batcher.add_tenant(tid)
        # one source of truth: summary()["per_tenant"].queue_depth reads
        # the live frontend queues
        mgr.queue_depths = self.batcher.depths
        #: the fleet registry (shared with the manager): one consistent
        #: snapshot backs both the stats and metrics responses
        self.obs = mgr.obs
        #: per-event queue->flush latency distribution — a bounded-memory
        #: streaming histogram in the fleet registry (was a raw deque
        #: with hand-rolled percentile math)
        self.event_latencies = self.obs.histogram("frontend.event_latency_s")
        if tracer is not None:
            # span coherence needs one clock: ingest spans carry batcher
            # arrival times, so the tracer should share ``clock``
            mgr.set_tracer(tracer)
        if slo_ms is not None:
            mgr.set_slo(slo_ms, slo_objective, source="event")
        elif getattr(mgr, "slo", None) is not None:
            # an SLO armed before the frontend existed: per-event
            # latencies are the observation source once we're online
            mgr.slo.source = "event"
        self.rounds = 0
        self.events = 0
        self.orphaned = 0   #: rows dropped by out-of-band detaches
        self.round_log: list | None = [] if record_rounds else None
        self._task: asyncio.Task | None = None
        self._wake: asyncio.Event | None = None
        self._stopping = False

    # ------------------------------------------------------------- core
    def submit(self, tid: str, src: int, dst: int, eid: int, ts: float,
               neg_dst: int = 0, *, client_id=None, seq=None) -> int:
        """Validate + (journal-armed) write-ahead log + enqueue one
        event. ``(client_id, seq)`` is the client's idempotency stamp:
        a seq the dedup window already accepted raises
        ``DuplicateEvent`` (ack, don't re-enqueue); a journal write
        failure raises ``RetryAfter(reason="journal_io")`` with the seq
        NOT committed, so the client's retry is accepted."""
        if tid not in self.mgr.tenants:
            raise KeyError(f"unknown tenant {tid!r}")
        try:
            if getattr(self.mgr, "is_quarantined", None) is not None \
                    and self.mgr.is_quarantined(tid):
                # transient: the guard's auto-restore is pending —
                # suggest its next-attempt countdown when scheduled
                guard = getattr(self.mgr, "guard", None)
                view = guard.tenant_view(tid) if guard is not None else {}
                after = view.get("next_attempt_in_s")
                raise RetryAfter(tid, (after if after
                                       else self.cfg.retry_after_s),
                                 0, reason="quarantined")
            faults = getattr(self.mgr, "_faults", None)
            if faults is not None:
                # chaos-only wire-corruption hook (gated: lint rule 4)
                src, dst, eid, ts, neg_dst = faults.on_ingest(
                    tid, src, dst, eid, ts, neg_dst)
            # ingest validation: corruption past this point would poison
            # the tenant's resident state, so reject at the wire
            ts = float(ts)
            if not math.isfinite(ts):
                raise ValueError(f"non-finite timestamp {ts!r} for "
                                 f"tenant {tid!r}")
            src, dst, eid, neg_dst = (int(src), int(dst), int(eid),
                                      int(neg_dst))
            if min(src, dst, eid, neg_dst) < 0:
                raise ValueError(f"negative id in event ({src}, {dst}, "
                                 f"{eid}, neg {neg_dst}) for tenant "
                                 f"{tid!r}")
            # tenants attached straight through the manager (or an
            # AdmissionController) get their queue on first ingest
            self.batcher.add_tenant(tid)
            if self.journal is not None:
                # write-ahead + exactly-once (gated: lint rule 5):
                # dedup query -> capacity pre-check -> WAL append, in
                # that order — a duplicate never re-journals, and an
                # event is only ever on disk once it is guaranteed a
                # queue slot
                if client_id is not None and seq is not None \
                        and self.journal.is_duplicate(tid, client_id,
                                                      seq):
                    self.dedups += 1
                    raise DuplicateEvent(tid, client_id, seq)
                self.batcher.check_capacity(tid)
                torn = None
                if faults is not None:
                    # chaos-only WAL failure hook (gated: lint rule 4)
                    torn = faults.on_journal_append(tid)
                self.journal.append_event(tid, src, dst, eid, ts,
                                          neg_dst, client_id=client_id,
                                          seq=seq, torn=torn == "torn")
            depth = self.batcher.submit(tid, src, dst, eid, ts, neg_dst)
        except RetryAfter as e:
            if self.journal is not None and client_id is not None:
                e.last_seq = self.journal.last_seq(tid, client_id)
            raise
        except OSError as e:
            # the WAL append failed: nothing reached disk, the seq was
            # never committed to the dedup window — reject transiently
            # and the client's retry of the SAME seq is accepted
            err = RetryAfter(tid, self.cfg.retry_after_s,
                             self.batcher.depths().get(tid, 0),
                             reason="journal_io")
            if self.journal is not None and client_id is not None:
                err.last_seq = self.journal.last_seq(tid, client_id)
            raise err from e
        self.events += 1
        if self._wake is not None:
            self._wake.set()
        return depth

    def pump(self, now: float | None = None, force: bool = False) -> dict:
        """Flush one round if due (or ``force``). Returns ``{tid:
        BatchOut}`` (empty when nothing flushed)."""
        now = self.clock() if now is None else now
        if not force and not self.batcher.due(now):
            return {}
        # a tenant detached out-of-band (straight through the manager or
        # an AdmissionController, not frontend.detach) leaves an orphaned
        # queue; drop it rather than step() an unknown tenant
        known = set(self.mgr.tenants)
        for tid in [t for t in self.batcher._q if t not in known]:
            self.orphaned += len(self.batcher.drop_tenant(tid))
        tracer = getattr(self.mgr, "tracer", None)
        # peek (not sample_round — the session consumes the round slot):
        # time flush/ingest only when this round will carry spans
        trace = (tracer if tracer is not None and tracer.would_sample()
                 else None)
        if trace is not None:
            t_flush = trace.clock()
        batches, arrivals = self.batcher.take()
        if not batches:
            return {}
        if self.round_log is not None:
            self.round_log.append(batches)
        if self.journal is not None:
            # WAL flush markers (gated: session_lint rule 5), written
            # BEFORE the state transition so replay can rebuild this
            # exact batch boundary. A quarantined tenant's batch is
            # DROPPED by step() — no marker, so its journaled events
            # stay pending and a post-restore replay re-applies them.
            qset = getattr(self.mgr, "quarantined", frozenset())
            for jtid, arr in arrivals.items():
                if jtid in qset:
                    continue
                self.journal.note_flush(jtid, len(arr),
                                        batches[jtid].src.shape[0])
        if trace is not None:
            t_step = trace.clock()
            trace.add("flush", t_flush, t_step, cat="frontend",
                      tenants=len(batches))
            oldest = min(a for arr in arrivals.values() for a in arr)
            # queueing span of the round's oldest event: its arrival on
            # the shared clock -> the moment the round enters the session
            trace.add("ingest", oldest, t_step, cat="frontend",
                      events=sum(len(a) for a in arrivals.values()))
        outs = self.mgr.guarded_step(batches)
        done = self.clock()
        slo = getattr(self.mgr, "slo", None)
        if slo is not None and slo.source != "event":
            slo = None
        for tid, arr in arrivals.items():
            for a in arr:
                lat = done - a
                self.event_latencies.record(lat)
                if slo is not None:
                    slo.observe(tid, lat)
        self.rounds += 1
        return outs

    def attach(self, variant=None, *, name: str | None = None,
               use_kernels=None, params: str | None = None) -> str:
        tid = self.mgr.add_tenant(variant, name=name,
                                  use_kernels=use_kernels, params=params)
        self.batcher.add_tenant(tid)
        return tid

    def detach(self, tid: str) -> None:
        """Flush the tenant's pending rows (so no accepted event is
        silently dropped), then release its lane slot."""
        if self.batcher.depths().get(tid):
            self.pump(force=True)
        self.batcher.drop_tenant(tid)
        self.mgr.remove_tenant(tid)

    def stats(self) -> dict:
        lat = self.event_latencies
        return {
            "tenants": list(self.mgr.tenants),
            "rounds": self.rounds,
            "events": self.events,
            "accepted": self.batcher.accepted,
            "rejected": self.batcher.rejected,
            "flushes": self.batcher.flushes,
            "queue_depths": self.batcher.depths(),
            "latency_p50_s": lat.quantile(0.50),    # None until an event
            "latency_p99_s": lat.quantile(0.99),
            # one atomic registry read (compile_counters snapshots) — an
            # AdmissionController.stats() in the same response reads the
            # identical view, never a mid-round disagreement
            "compile": self.mgr.compile_counters(),
            **({"guard": self.mgr.guard.snapshot()}
               if getattr(self.mgr, "guard", None) is not None else {}),
            **({"journal": {**self.journal.stats(),
                            "dedups": self.dedups}}
               if self.journal is not None else {}),
        }

    def metrics_snapshot(self) -> dict:
        """The ``metrics`` wire-op payload: one lock-consistent registry
        snapshot plus per-tenant SLO burn (every resident tenant), the
        tracer's span tallies, and the FleetGuard's recovery counters
        (quarantines/restores/degradations/evictions + the live
        quarantine set) when those are armed."""
        out = {"registry": self.obs.snapshot(),
               "compile": self.mgr.compile_counters()}
        slo = getattr(self.mgr, "slo", None)
        if slo is not None:
            out["slo"] = {tid: slo.tenant(tid) for tid in self.mgr.tenants}
        tracer = getattr(self.mgr, "tracer", None)
        if tracer is not None:
            out["trace"] = tracer.summary()
        guard = getattr(self.mgr, "guard", None)
        if guard is not None:
            out["guard"] = guard.snapshot()
        return out

    # -------------------------------------------------------- dispatcher
    def handle(self, req: dict) -> dict:
        """One request dict -> one response dict (the wire protocol).

        ops: ``ingest`` (tid, src, dst, eid, ts[, neg_dst]
        [, client_id, seq — the exactly-once idempotency stamp]) |
        ``attach`` ([variant][, name][, use_kernels][, params]) |
        ``detach`` (tid) | ``stats`` | ``metrics`` (registry snapshot +
        SLO burn + trace tallies) | ``flush`` (force a round now).

        ``attach.params`` names a parameter set already registered via
        ``SessionManager.register_params``; an unknown name is rejected
        with ``invalid_request`` BEFORE any lane state changes — the
        wire protocol carries names, never weights.

        Every error response carries ``"transient"``: ``retry_after``
        (backpressure, quarantine) means try again later; everything
        else (malformed request, unknown tenant/op) is permanent —
        resubmitting the same request cannot succeed. A malformed
        request NEVER raises out of here: the dispatcher is the
        transport's crash barrier.
        """
        if not isinstance(req, dict):
            return {"ok": False, "error": "invalid_request",
                    "transient": False,
                    "detail": f"request must be a JSON object, got "
                              f"{type(req).__name__}"}
        try:
            op = req.get("op")
            if op == "ingest":
                missing = [k for k in ("tid", "src", "dst", "ts")
                           if k not in req]
                if missing:
                    return {"ok": False, "error": "invalid_request",
                            "transient": False,
                            "detail": f"ingest missing fields {missing}"}
                depth = self.submit(req["tid"], req["src"], req["dst"],
                                    req.get("eid", 0), req["ts"],
                                    req.get("neg_dst", 0),
                                    client_id=req.get("client_id"),
                                    seq=req.get("seq"))
                return {"ok": True, "queued": depth}
            if op == "attach":
                tid = self.attach(req.get("variant"),
                                  name=req.get("name"),
                                  use_kernels=req.get("use_kernels"),
                                  params=req.get("params"))
                return {"ok": True, "tid": tid,
                        "admission": dict(self.mgr.last_admission or {})}
            if op == "detach":
                self.detach(req["tid"])
                return {"ok": True,
                        "admission": dict(self.mgr.last_admission or {})}
            if op == "stats":
                return {"ok": True, "stats": self.stats()}
            if op == "metrics":
                return {"ok": True, "metrics": self.metrics_snapshot()}
            if op == "flush":
                outs = self.pump(force=True)
                return {"ok": True, "flushed": sorted(outs)}
            return {"ok": False, "error": "unknown_op", "op": op,
                    "transient": False}
        except DuplicateEvent as e:
            # exactly-once ack: the event is already journaled (and
            # possibly applied) — acknowledge, never re-enqueue
            return {"ok": True, "dedup": True, "tid": e.tid,
                    "client_id": e.client_id, "seq": e.seq}
        except RetryAfter as e:
            resp = {"ok": False, "error": "retry_after",
                    "transient": True, "reason": e.reason,
                    "retry_after_s": e.seconds, "tid": e.tid,
                    "depth": e.depth}
            if e.last_seq is not None:
                # resume hint: the client's highest accepted seq
                resp["last_seq"] = e.last_seq
            return resp
        except KeyError as e:
            return {"ok": False, "error": "unknown_tenant",
                    "transient": False, "detail": str(e)}
        except (ValueError, TypeError) as e:
            # e.g. attach naming an unregistered param set, an ingest
            # with a non-numeric/non-finite field — rejected before any
            # lane mutation, so compile counters and resident tenants
            # are untouched
            return {"ok": False, "error": "invalid_request",
                    "transient": False, "detail": str(e)}

    # ----------------------------------------------------- asyncio shell
    async def start(self) -> None:
        """Run the pump loop until ``stop()``."""
        self._wake = asyncio.Event()
        self._stopping = False
        self._task = asyncio.ensure_future(self._run())

    async def stop(self) -> None:
        self._stopping = True
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        self.pump(force=True)        # drain whatever is still queued

    async def _run(self) -> None:
        while not self._stopping:
            self.pump()
            deadline = self.batcher.next_deadline()
            wait = (self.cfg.max_wait_s if deadline is None
                    else max(0.0, deadline - self.clock()))
            try:
                await asyncio.wait_for(self._wake.wait(), timeout=wait)
            except asyncio.TimeoutError:
                pass
            self._wake.clear()


async def serve_jsonl(frontend: ServingFrontend, host: str = "127.0.0.1",
                      port: int = 0, max_line: int = 1 << 20):
    """Newline-delimited-JSON transport: one request dict per line, one
    response per line. Returns the listening ``asyncio.Server`` (query
    ``server.sockets[0].getsockname()`` for the bound port).

    Hardened against a hostile/buggy peer: reads are BOUNDED
    (``max_line`` bytes; an oversized line gets one ``invalid_request``
    response and the connection is dropped — there is no way to resync
    mid-line), malformed JSON and non-object payloads come back as
    structured errors, and any unexpected dispatcher failure answers
    ``internal_error`` on that one request. No input can kill the
    server task; other connections keep serving.
    """

    async def client(reader, writer):
        try:
            while True:
                try:
                    line = await reader.readline()
                except (asyncio.LimitOverrunError, ValueError):
                    # bounded read tripped: reject and drop the
                    # connection — the line has no parseable end
                    writer.write(json.dumps(
                        {"ok": False, "error": "invalid_request",
                         "transient": False,
                         "detail": f"line exceeds {max_line} bytes"}
                    ).encode() + b"\n")
                    await writer.drain()
                    break
                if not line:
                    break
                try:
                    req = json.loads(line)
                except json.JSONDecodeError as e:
                    resp = {"ok": False, "error": "bad_json",
                            "transient": False, "detail": str(e)}
                else:
                    try:
                        resp = frontend.handle(req)
                    except Exception as e:   # the transport never dies
                        resp = {"ok": False, "error": "internal_error",
                                "transient": False, "detail": str(e)}
                writer.write(json.dumps(resp).encode() + b"\n")
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            pass                             # peer vanished mid-exchange
        finally:
            writer.close()

    return await asyncio.start_server(client, host, port, limit=max_line)
