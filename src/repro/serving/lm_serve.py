"""LM serving: batched prefill + decode generation loop, and the
beyond-paper positional KV pruning (DESIGN.md §5).

``positional_kv_prune`` is the decode-time analogue of the paper's SAT
neighbor pruning: score every KV-cache entry from POSITION METADATA ONLY
(a + w * log1p(t_now - t_kv), per kv head), select top-k, and attend over
just those k entries — the cache gather shrinks from S to k rows exactly as
the paper's neighbor fetch shrinks from m_r to k. OFF by default; the
evaluation in EXPERIMENTS.md §Perf treats it as an optional optimization,
never silently enabled.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import FrozenConfig
from repro.models import layers as L
from repro.models import lm_common


# ---------------------------------------------------------------------------
# beyond-paper: SAT-style positional KV pruning
# ---------------------------------------------------------------------------


def init_kv_prune(n_kv_heads: int) -> dict:
    """Learnable recency scoring per kv head: score = a + w * log1p(age)."""
    return {"a": jnp.zeros((n_kv_heads,), jnp.float32),
            "w": jnp.full((n_kv_heads,), -1.0, jnp.float32)}


def kv_prune_scores(prune_p: dict, k_pos: jax.Array, now: jax.Array,
                    n_kv_heads: int) -> jax.Array:
    """k_pos (S,) absolute positions (-1 invalid) -> scores (kv, S)."""
    age = jnp.maximum(now - k_pos, 0).astype(jnp.float32)
    base = prune_p["a"][:, None] + prune_p["w"][:, None] * jnp.log1p(age)
    return jnp.where(k_pos[None, :] >= 0, base, -jnp.inf)


def pruned_decode_attention(p: dict, cfg: L.AttnCfg, x: jax.Array,
                            cache: dict, prune_p: dict, keep: int):
    """decode_attention with SAT-style positional top-k cache pruning.

    Identical interface to layers.decode_attention (full cache only).
    Scores depend only on positions -> the top-k index set is shared across
    the batch, so the gather is a cheap (k,)-indexed slice of the cache.
    """
    B, S, D = x.shape
    assert S == 1
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    pos0 = cache["pos"]
    Smax = cache["k"].shape[1]
    k_pos = jnp.arange(Smax, dtype=jnp.int32)
    k_pos = jnp.where(k_pos <= pos0, k_pos, -1)

    # write this token's kv first (it must be retrievable later)
    positions = pos0[None, None]
    q = (x @ p["wq"].astype(dt)).reshape(B, 1, h, hd)
    knew = (x @ p["wk"].astype(dt)).reshape(B, 1, kv, hd)
    vnew = (x @ p["wv"].astype(dt)).reshape(B, 1, kv, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        knew = L.rmsnorm(p["k_norm"], knew)
    if cfg.use_rope:
        q = L.rope(q, positions, theta=cfg.rope_theta,
                   scaling=cfg.rope_scaling)
        knew = L.rope(knew, positions, theta=cfg.rope_theta,
                      scaling=cfg.rope_scaling)
    ck = jax.lax.dynamic_update_slice(cache["k"], knew.astype(cache["k"].dtype),
                                      (0, pos0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], vnew.astype(cache["v"].dtype),
                                      (0, pos0, 0, 0))
    new_cache = {"k": ck, "v": cv, "pos": pos0 + 1}

    # SAT-style: score from positions ONLY, then fetch only the winners.
    # (head-0 scores pick the shared index set; per-head offsets shift
    # within the kept set during attention)
    scores_meta = kv_prune_scores(prune_p, k_pos, pos0, kv)      # (kv, Smax)
    _, idx = jax.lax.top_k(scores_meta[0], keep)                 # (keep,)
    k_sel = jnp.take(ck, idx, axis=1).astype(jnp.float32)        # (B,keep,kv,hd)
    v_sel = jnp.take(cv, idx, axis=1).astype(jnp.float32)
    pos_sel = jnp.take(k_pos, idx)

    g = h // kv
    qg = q.reshape(B, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bngd,btnd->bngt", qg, k_sel) / math.sqrt(hd)
    if cfg.softcap is not None:
        s = jnp.tanh(s / cfg.softcap) * cfg.softcap
    valid = (pos_sel >= 0) & (pos_sel <= pos0)
    s = jnp.where(valid[None, None, None, :], s, L.NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngt,btnd->bngd", attn, v_sel).reshape(B, 1, h * hd)
    y = out.astype(dt) @ p["wo"].astype(dt)
    return y, new_cache


# ---------------------------------------------------------------------------
# generation loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeConfig(FrozenConfig):
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 = greedy
    seed: int = 0


def generate(params, cfg, prompts: jax.Array, scfg: ServeConfig,
             max_len: int | None = None) -> dict:
    """Batched generation for any registered family.

    prompts (B, S_prompt) int32. Returns {"tokens": (B, S_prompt+new),
    "prefill_s": ..., "decode_s_per_tok": ...}.
    """
    fam = lm_common.family_of(cfg)
    mod = lm_common.FAMILIES[fam]
    B, Sp = prompts.shape
    total = Sp + scfg.max_new_tokens if max_len is None else max_len

    caches = mod.init_caches(cfg, B, total, dtype=jnp.float32) \
        if fam in ("transformer",) else mod.init_caches(cfg, B, total)
    decode = jax.jit(lambda p, t, c: mod.decode_step(p, cfg, t, c))

    t0 = time.perf_counter()
    logits = None
    for t in range(Sp):  # teacher-forced prompt consumption via decode path
        logits, caches = decode(params, prompts[:, t:t + 1], caches)
    prefill_s = time.perf_counter() - t0

    key = jax.random.key(scfg.seed)
    out = [prompts]
    t0 = time.perf_counter()
    tok = None
    for i in range(scfg.max_new_tokens):
        if scfg.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / scfg.temperature,
                                         axis=-1)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(tok.astype(jnp.int32))
        logits, caches = decode(params, tok.astype(jnp.int32), caches)
    decode_s = (time.perf_counter() - t0) / max(scfg.max_new_tokens, 1)

    return {"tokens": jnp.concatenate(out, axis=1),
            "prefill_s": prefill_s, "decode_s_per_tok": decode_s}
