"""Deterministic fault injection for the serving stack.

Fault tolerance is only testable if faults are REPRODUCIBLE: a chaos run
must fire the same faults at the same rounds every time, so the guard's
recovery (``serving/guard.py``) can be pinned bitwise against an
undisturbed fleet. This module is the injection side of that contract:

``Fault``
    one planned fault, keyed by kind + tenant + position (``at``/
    ``count``). Positions are logical — round indices for round-scoped
    kinds, per-tenant event/write ordinals for ingest and snapshot
    kinds — never wall clock, so a plan replays identically regardless
    of host speed.

``FaultInjector``
    the armed plan. The serving layers call its hooks from
    zero-cost-gated sites (``if self._faults is not None: ...`` — the
    shape ``tools/session_lint.py`` rule 4 enforces), so a fleet that
    never arms an injector pays one attribute test per round and
    nothing else. Every fault that fires lands in the ``fired`` ledger;
    ``pending()`` lists what has not, which is how a chaos driver
    asserts the whole plan was detected.

Fault taxonomy (``KINDS``; docs/ROBUSTNESS.md):

* ``nan_state``    — corrupt a tenant's resident memory table to NaN at
  round ``at`` (a poisoned-state upset: the guard's finite-state
  sentinel must catch it).
* ``poison_batch`` — overwrite a tenant's submitted batch timestamps
  with NaN at round ``at`` (corruption past ingest validation).
* ``poison_event`` — corrupt the timestamp of the tenant's ``at``-th
  accepted ingest event (wire-level corruption; the frontend's
  validation must reject it before it reaches a queue).
* ``kernel_fail``  — raise ``KernelFault`` before the round launch at
  round ``at`` (a lowering/launch failure; the guard degrades the
  cohort's kernel tier).
* ``snapshot_io``  — raise ``SnapshotIOFault`` on the tenant's
  ``at``-th..``at+count-1``-th background snapshot write ATTEMPT
  (retries count as attempts, so ``count=1`` tests the writer's retry
  path and ``count > retries`` its failure path).
* ``stall``        — advance the injected clock by ``delay_s`` at round
  ``at`` (a stuck round; the guard's watchdog must flag it).
* ``journal_io``   — raise ``JournalIOFault`` on the tenant's
  ``at``-th..``at+count-1``-th journal append (a WAL write error; the
  frontend must REJECT the ingest — an event that is not on disk was
  never accepted, so the client's retry is safe).
* ``torn_write``   — make the tenant's ``at``-th journal append write a
  PARTIAL record and wedge the log (a crash mid-append; reopen must
  truncate the torn tail, never fabricate the record).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

#: every fault kind a plan may contain (see module docstring).
KINDS = ("nan_state", "poison_batch", "poison_event", "kernel_fail",
         "snapshot_io", "stall", "journal_io", "torn_write")

#: kinds keyed by the injector's round cursor.
_ROUND_KINDS = ("nan_state", "poison_batch", "kernel_fail", "stall")


class KernelFault(RuntimeError):
    """An injected (or classified) kernel-launch failure.

    Carries the tenant whose lane the failure is attributed to, so the
    guard can find the cohort to degrade."""

    def __init__(self, tid: str, detail: str = "injected launch failure"):
        super().__init__(f"kernel launch failed on tenant {tid!r} lane: "
                         f"{detail}")
        self.tid = tid


class SnapshotIOFault(OSError):
    """An injected snapshot-write IO error."""


class JournalIOFault(OSError):
    """An injected journal-append IO error."""


class FakeClock:
    """A callable, manually advanced clock — the injected time source of
    deterministic chaos runs and guard tests (``clock()`` reads,
    ``clock.advance(s)`` moves)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, s: float) -> float:
        self.t += float(s)
        return self.t


@dataclass
class Fault:
    """One planned fault. ``at`` is a logical position (round index or
    per-tenant ordinal — see module docstring); the fault is active for
    positions ``at <= p < at + count``. ``fired`` counts activations."""
    kind: str
    tenant: str | None = None
    at: int = 0
    count: int = 1
    delay_s: float = 0.0
    fired: int = field(default=0, compare=False)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {KINDS}")
        if self.kind in ("nan_state", "poison_batch", "poison_event",
                         "snapshot_io", "kernel_fail", "journal_io",
                         "torn_write") \
                and self.tenant is None:
            raise ValueError(f"fault kind {self.kind!r} needs tenant=")

    def _active(self, pos: int) -> bool:
        return self.at <= pos < self.at + self.count


class FaultInjector:
    """An armed fault plan (see module docstring).

    Hooks — each called from a ``fault``-gated site in exactly one
    layer, all deterministic in logical positions:

    * ``on_round(mgr, batches)``   — ``SessionManager.step`` entry
      (advances the round cursor; applies stalls, state poison, batch
      poison; returns the possibly-corrupted batches).
    * ``before_launch(mgr)``       — just before the round's compiled
      launch dispatch; raises ``KernelFault``.
    * ``on_ingest(tid, *event)``   — ``ServingFrontend.submit`` before
      validation; returns the possibly-corrupted event tuple.
    * ``on_snapshot_write(tid)``   — ``TenantSnapshotWriter`` worker
      thread, once per write attempt; raises ``SnapshotIOFault``.
    * ``on_journal_append(tid)``   — ``ServingFrontend.submit`` before
      the journal write; raises ``JournalIOFault`` or returns
      ``"torn"`` to make the append itself tear.
    """

    def __init__(self, faults, clock: FakeClock | None = None):
        self.faults = list(faults)
        for f in self.faults:
            if not isinstance(f, Fault):
                raise TypeError(f"fault plan entries must be Fault, "
                                f"got {f!r}")
            if f.kind == "stall" and clock is None:
                raise ValueError("a 'stall' fault needs an advanceable "
                                 "clock (FaultInjector(..., clock=...))")
        self.clock = clock
        self.round_idx = -1          # on_round increments first
        #: ledger of every activation: ``{kind, tenant, round, pos}`` —
        #: the chaos driver's "every planned fault was detected" proof.
        self.fired: list[dict] = []
        self._event_idx: dict[str, int] = {}
        self._write_idx: dict[str, int] = {}
        self._journal_idx: dict[str, int] = {}

    def _fire(self, f: Fault, pos: int) -> None:
        f.fired += 1
        self.fired.append({"kind": f.kind, "tenant": f.tenant,
                           "round": self.round_idx, "pos": pos})

    def pending(self) -> list:
        """Planned faults that have not fully fired yet."""
        return [f for f in self.faults if f.fired < f.count]

    # ---------------------------------------------------------- hooks
    def on_round(self, mgr, batches):
        """Round-entry hook: advance the round cursor, apply round-scoped
        faults. Returns the (possibly replaced) batches mapping."""
        self.round_idx += 1
        out = batches
        for f in self.faults:
            if f.kind not in _ROUND_KINDS or f.kind == "kernel_fail" \
                    or not f._active(self.round_idx) \
                    or f.fired >= f.count:
                continue
            if f.kind == "stall":
                self.clock.advance(f.delay_s)
                self._fire(f, self.round_idx)
            elif f.kind == "nan_state":
                if f.tenant in mgr.tenants:
                    st = mgr.state_of(f.tenant)
                    mgr.set_state(f.tenant, st._replace(
                        memory=jnp.full_like(st.memory, jnp.nan)))
                    self._fire(f, self.round_idx)
            elif f.kind == "poison_batch":
                if f.tenant in out:
                    if out is batches:
                        out = dict(batches)   # never mutate the caller's
                    b = out[f.tenant]
                    cols = (b if isinstance(b, tuple) and not hasattr(
                        b, "_replace") else None)
                    if cols is not None:
                        src, dst, eid, ts, valid = cols[:5]
                        ts = np.full_like(np.asarray(ts), np.nan,
                                          dtype=np.float32)
                        out[f.tenant] = (src, dst, eid, ts, valid)
                    else:
                        out[f.tenant] = b._replace(ts=np.full_like(
                            np.asarray(b.ts), np.nan))
                    self._fire(f, self.round_idx)
        return out

    def before_launch(self, mgr) -> None:
        """Pre-dispatch hook: raise the round's planned launch failure.

        The failed dispatch never completes a round, so the round cursor
        is rolled back one — the guard's retry of the SAME batches
        replays the same logical round index (and the fired-count guard
        keeps already-fired faults from firing again on the retry)."""
        for f in self.faults:
            if f.kind == "kernel_fail" and f._active(self.round_idx) \
                    and f.fired < f.count and f.tenant in mgr.tenants:
                self._fire(f, self.round_idx)
                self.round_idx -= 1
                raise KernelFault(f.tenant)

    def on_ingest(self, tid: str, src, dst, eid, ts, neg_dst):
        """Ingest hook: corrupt the tenant's ``at``-th submitted event.

        Runs BEFORE the frontend's field validation so an injected
        non-finite timestamp exercises the same rejection path a
        corrupted wire payload would.
        """
        pos = self._event_idx.get(tid, 0)
        self._event_idx[tid] = pos + 1
        for f in self.faults:
            if f.kind == "poison_event" and f.tenant == tid \
                    and f._active(pos):
                self._fire(f, pos)
                return src, dst, eid, float("nan"), neg_dst
        return src, dst, eid, ts, neg_dst

    def on_snapshot_write(self, tid: str) -> None:
        """Snapshot-write hook (worker thread): fail the tenant's
        ``at``-th..``at+count-1``-th write attempt."""
        pos = self._write_idx.get(tid, 0)
        self._write_idx[tid] = pos + 1
        for f in self.faults:
            if f.kind == "snapshot_io" and f.tenant == tid \
                    and f._active(pos):
                self._fire(f, pos)
                raise SnapshotIOFault(
                    f"injected snapshot IO error for tenant {tid!r} "
                    f"(write attempt {pos})")

    def on_journal_append(self, tid: str) -> str | None:
        """Journal-append hook: fail or tear the tenant's ``at``-th..
        ``at+count-1``-th WAL append. Returns ``"torn"`` when the
        append should write a partial record (and wedge the log), else
        ``None``; raises ``JournalIOFault`` for a clean IO failure."""
        pos = self._journal_idx.get(tid, 0)
        self._journal_idx[tid] = pos + 1
        for f in self.faults:
            if f.tenant != tid or not f._active(pos):
                continue
            if f.kind == "journal_io" and f.fired < f.count:
                self._fire(f, pos)
                raise JournalIOFault(
                    f"injected journal IO error for tenant {tid!r} "
                    f"(append {pos})")
            if f.kind == "torn_write" and f.fired < f.count:
                self._fire(f, pos)
                return "torn"
        return None
