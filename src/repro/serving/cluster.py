"""Sharded tenant fabric: the multi-tenant session on a device mesh.

``serving/session.py`` stacks every same-variant tenant's VertexState and
advances the cohort in one vmapped launch — the software analogue of the
paper's batched datapath. This module is the next scaling layer: place
those stacked ``(tenant, V, ...)`` tables and the padded batch inputs on a
``jax.sharding.Mesh`` so the fleet spreads over devices, the way the
accelerator spreads its Graph Storage over BRAM banks.

  * ``ShardedSessionManager`` — drop-in SessionManager whose cohorts pad
    their stacked tables to a multiple of the mesh ``tenant`` axis (pad
    slots are idle-masked rows, a bitwise no-op) and pin every launch
    operand with the PartitionSpec rules in ``distributed/tgn_sharding.py``:
    state/batches row-sharded over ``tenant`` (optionally ``vertex`` for
    the V dim), params and feature stores replicated. The committing
    launch donates the old state buffers, so resident tables are updated
    in place. Because the vmapped step has no cross-tenant reduction,
    per-tenant trajectories are BITWISE-identical to the unsharded
    SessionManager (tests/test_cluster.py pins this on a forced 8-device
    host mesh).

  * snapshot / restore / migration — built on ``distributed/checkpoint.py``
    (atomic tmp-dir+rename commit, per-leaf crc32, versioned steps): a
    tenant's VertexState plus its variant/config metadata is saved under
    ``<root>/<tenant>/step_XXXXXXXX/`` and restores into ANY manager whose
    shared parameter axes match — a different cohort, a different mesh
    shape, or the unsharded session (the elastic path: checkpoints hold
    full logical arrays, placement is recomputed by the target).

::

    mgr = ShardedSessionManager(params, edge_feats, model=cfg,
                                mesh="tenant=4,vertex=2")
    a = mgr.add_tenant()
    mgr.step({a: batch})
    snapshot_tenant(mgr, a, "/ckpt/fleet", step=rounds)
    # ... later / elsewhere, any mesh shape:
    b = restore_tenant(other_mgr, "/ckpt/fleet", a)
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core import mailbox, pipeline as pl, tgn
from repro.distributed import checkpoint as ckpt
from repro.distributed import tgn_sharding as tsh
from repro.serving.session import DEFAULT_PARAMS, SessionManager, _Cohort


class _ShardedCohort(_Cohort):
    """A cohort whose stacked tables live sharded on the fabric mesh."""

    def __init__(self, cfg: tgn.TGNConfig, use_kernels: bool, params: dict,
                 mesh: Mesh, reserve=None, param_set: str = DEFAULT_PARAMS):
        self.mesh = mesh
        super().__init__(cfg, use_kernels, params, reserve=reserve,
                         param_set=param_set)

    def _build_launches(self) -> None:
        super()._build_launches()        # keeps the unsharded _vstep1 peek
        like = jax.eval_shape(self.pipeline.init_state)
        self.state_shardings = tsh.make_shardings(
            self.mesh, tsh.state_specs(self.mesh, like))
        rep = tsh.replicated(self.mesh)
        batch_sh = tuple(NamedSharding(self.mesh, s)
                         for s in tsh.batch_specs(self.mesh))
        # the coalesced whole-round launch reuses these per-cohort specs
        self.out_shardings = tsh.make_shardings(
            self.mesh, tsh.out_specs(self.mesh, like))
        # node_feats may be None: leave its placement unspecified
        in_sh = (rep, self.state_shardings, batch_sh, rep, None)
        self._vstep = self.pipeline.batched_step(
            self.aux, in_shardings=in_sh, out_shardings=self.out_shardings)
        self._vstep_commit = self.pipeline.batched_step(
            self.aux, donate_state=True, in_shardings=in_sh,
            out_shardings=self.out_shardings)

    def _target_capacity(self, n: int) -> int:
        """Mesh-aligned capacity: the reserve ladder (when enabled) picks
        the class, then the mesh rounds it up to a tenant-axis multiple."""
        return tsh.tenant_capacity(super()._target_capacity(n), self.mesh)

    def _place(self, state):
        """Place every leaf with its PartitionSpec."""
        return jax.device_put(state, self.state_shardings)

    def launch(self, stacked_batch, edge_feats, node_feats,
               commit: bool = False) -> tgn.BatchOut:
        fn = self._vstep_commit if commit else self._vstep
        return fn(self.params, self.state, stacked_batch, edge_feats,
                  node_feats)


class ShardedSessionManager(SessionManager):
    """SessionManager on a device mesh: same API, same trajectories.

    ``mesh`` is a ``jax.sharding.Mesh`` or a spec string for
    ``tgn_sharding.make_tenant_mesh`` (``"8"``, ``"tenant=4,vertex=2"``,
    ``None`` = every device on the tenant axis). Shared operands (params,
    edge/node feature stores) are replicated across the mesh once at
    construction; each cohort's stacked state and batch inputs shard over
    the ``tenant`` axis. Everything else — tenant lifecycle, idle masking,
    chronological LWW commits, metrics — is inherited unchanged.
    """

    def __init__(self, params: dict, edge_feats, node_feats=None, *,
                 mesh: Mesh | str | int | None = None, **kw):
        if not isinstance(mesh, Mesh):
            mesh = tsh.make_tenant_mesh(mesh)
        self.mesh = mesh
        # the ParamStore places every registered set via _place_params, so
        # the default set (and any later register_params) replicate here
        super().__init__(params, edge_feats, node_feats, **kw)
        rep = tsh.replicated(mesh)
        self.edge_feats = jax.device_put(self.edge_feats, rep)
        if self.node_feats is not None:
            self.node_feats = jax.device_put(self.node_feats, rep)

    def _place_params(self, params: dict) -> dict:
        """Replicate a registered parameter set across the fabric mesh."""
        return jax.device_put(params, tsh.replicated(self.mesh))

    def _make_cohort(self, cfg: tgn.TGNConfig, use_kernels,
                     param_set: str = DEFAULT_PARAMS) -> _ShardedCohort:
        return _ShardedCohort(cfg, use_kernels,
                              self.param_store.get(param_set), self.mesh,
                              reserve=self.reserve, param_set=param_set)

    def _batch_shardings(self) -> tuple:
        return tuple(NamedSharding(self.mesh, s)
                     for s in tsh.batch_specs(self.mesh))

    def _make_coalesced(self) -> pl.CoalescedRound:
        """The fused whole-round launch with every operand's mesh placement
        pinned: per-cohort states keep their cohort's PartitionSpecs (and
        are DONATED — resident tables update in place, like the per-cohort
        commit launch), the super-batch row-shards over the tenant axis
        (each segment's row count is a capacity, i.e. a multiple of the
        axis), and the in-launch edge count replicates."""
        cohorts = list(self._cohorts.values())
        rep = tsh.replicated(self.mesh)
        # position 0 is the per-lane params TUPLE; a single replicated
        # sharding is a valid pytree prefix, broadcasting to every set
        in_sh = (rep, tuple(c.state_shardings for c in cohorts),
                 self._batch_shardings(), rep, None)
        out_sh = (tuple(c.out_shardings for c in cohorts), rep)
        return pl.CoalescedRound(
            [(c.pipeline, c.aux, c.capacity) for c in cohorts],
            donate_state=True, in_shardings=in_sh, out_shardings=out_sh,
            obs=self.obs)

    def _make_stager(self, rows: int, width: int):
        from repro.serving.session import _HostStager
        return _HostStager(rows, width, shardings=self._batch_shardings())

    def set_state(self, tid: str, st: mailbox.VertexState) -> None:
        super().set_state(tid, st)
        cohort = self.cohort_of(tid)
        cohort.state = jax.device_put(cohort.state, cohort.state_shardings)

    def describe(self) -> dict:
        return {**super().describe(), "mesh": dict(self.mesh.shape)}


# ---------------------------------------------------------------------------
# tenant snapshot / restore / migration (works on ANY SessionManager)
# ---------------------------------------------------------------------------


def _capture_tenant(mgr: SessionManager, tid: str,
                    extra_meta: dict | None = None) -> tuple[dict, dict]:
    """Grab a consistent (state pytree, manifest meta) pair for ``tid`` on
    the serving thread — device arrays are immutable, so the pair stays
    valid while a background writer gathers and persists it."""
    cohort = mgr.cohort_of(tid)
    st = mgr.state_of(tid)
    meta = {"tenant": tid,
            "variant": pl.variant_name(cohort.cfg),
            "config": dataclasses.asdict(cohort.cfg),
            # the TENANT's resolved kernel tier, not the session default:
            # lanes pick tiers independently (add_tenant(use_kernels=...))
            # and a restore must resume on the same numerics
            "use_kernels": cohort.tier,
            # the parameter set the tenant was serving on + its content
            # digest: a restore must resume on the SAME weights (a
            # trajectory is meaningless under different parameters), so
            # restore_tenant re-binds by name and verifies the digest
            "param_set": cohort.param_set,
            "params_digest": mgr.param_store.digest(cohort.param_set)}
    if extra_meta:
        meta.update(extra_meta)
    return st._asdict(), meta


def snapshot_tenant(mgr: SessionManager, tid: str, root: str, *,
                    step: int = 0, keep: int = 3,
                    extra_meta: dict | None = None,
                    keep_floor: int | None = None) -> str:
    """Atomically snapshot one tenant's VertexState + serving metadata.

    Layout: ``<root>/<tid>/step_XXXXXXXX/`` via ``checkpoint.save`` (tmp
    dir + rename, per-leaf crc32, last ``keep`` steps retained). ``step``
    is the caller's stream position (e.g. rounds served) so successive
    snapshots version the tenant's trajectory. The manifest meta carries
    the resolved variant and full TGNConfig, which ``restore_tenant``
    validates against the target session. With an armed journal the
    caller records the replay cursor via ``extra_meta={"journal":
    journal.cursor(tid)}`` and pins the WAL's anchor step with
    ``keep_floor`` (``checkpoint.save(floor=...)``).
    """
    tree, meta = _capture_tenant(mgr, tid, extra_meta)
    return ckpt.save(os.path.join(root, tid), step, tree, meta=meta,
                     keep=keep, floor=keep_floor)


class TenantSnapshotWriter:
    """Bounded per-tenant background snapshot writer: serving rounds never
    stall on snapshot IO.

    ``submit`` captures the tenant's state on the calling thread (device
    array references + manifest meta — cheap, no host gather) and hands
    the D2H gather plus the atomic ``checkpoint.save`` commit to a worker
    thread. At most ONE snapshot per tenant is in flight: while a
    tenant's previous write is still running, new submissions for it are
    skipped (counted in ``skipped``) — the periodic cadence is
    best-effort, durability comes from the final ``wait()`` + sync save
    at exit. The on-disk format and the tmp-dir + rename + crc32 commit
    of ``distributed/checkpoint.py`` are unchanged.

    A failed write attempt is RETRIED on the worker thread with capped
    exponential backoff (``retries`` attempts beyond the first,
    ``backoff_s`` doubling up to ``backoff_cap_s``) before it counts as
    a failure — transient IO errors never cost a snapshot cadence.
    Retries and exhausted failures land in the fleet metrics registry
    (``snapshot.retries`` / ``snapshot.failures``) when ``obs`` is given;
    exhausted failures still surface at the next ``submit``/``wait``.
    When the manager has an armed fault injector, each write attempt
    runs its ``on_snapshot_write`` hook (docs/ROBUSTNESS.md).
    """

    def __init__(self, root: str, *, keep: int = 3, max_workers: int = 2,
                 retries: int = 2, backoff_s: float = 0.05,
                 backoff_cap_s: float = 1.0, obs=None, sleep=None):
        import time
        from concurrent.futures import ThreadPoolExecutor
        self.root = root
        self.keep = keep
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.obs = obs                  # MetricsRegistry or None
        self._sleep = sleep if sleep is not None else time.sleep
        self.skipped = 0
        self.written = 0
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._inflight: dict[str, object] = {}

    def submit(self, mgr: SessionManager, tid: str, *, step: int = 0,
               extra_meta: dict | None = None,
               keep_floor: int | None = None) -> bool:
        """Queue a snapshot of ``tid`` at ``step``; returns False when the
        tenant's previous snapshot is still in flight (skipped). A
        previous write that FAILED (retries exhausted) re-raises here —
        with its slot cleared first, so the tenant's cadence resumes on
        the next submit instead of re-raising forever."""
        prev = self._inflight.get(tid)
        if prev is not None:
            if not prev.done():
                self.skipped += 1
                return False
            try:
                prev.result()            # surface a failed write loudly
            except Exception:
                del self._inflight[tid]
                raise
        tree, meta = _capture_tenant(mgr, tid, extra_meta)
        faults = getattr(mgr, "_faults", None)

        def work():
            delay = self.backoff_s
            for attempt in range(self.retries + 1):
                try:
                    if faults is not None:
                        faults.on_snapshot_write(tid)
                    return ckpt.save(os.path.join(self.root, tid), step,
                                     tree, meta=meta, keep=self.keep,
                                     floor=keep_floor)
                except Exception:
                    if attempt >= self.retries:
                        if self.obs is not None:
                            self.obs.counter("snapshot.failures").inc()
                        raise
                    if self.obs is not None:
                        self.obs.counter("snapshot.retries").inc()
                    self._sleep(min(delay, self.backoff_cap_s))
                    delay *= 2

        self._inflight[tid] = self._pool.submit(work)
        self.written += 1
        return True

    def join(self, tid: str) -> None:
        """Block until ``tid``'s in-flight write (if any) lands, clearing
        its slot; re-raises its failure. The guard calls this before an
        auto-restore so the newest snapshot is fully committed (or known
        failed) before the fallback walk picks a step."""
        fut = self._inflight.pop(tid, None)
        if fut is not None:
            fut.result()

    def wait(self) -> None:
        """Join EVERY in-flight write, then re-raise the first failure —
        a failed write never leaves later ones unjoined."""
        errors = []
        for tid, fut in list(self._inflight.items()):
            try:
                fut.result()
            except Exception as e:
                errors.append((tid, e))
            del self._inflight[tid]
        if errors:
            tid, err = errors[0]
            raise RuntimeError(
                f"background snapshot of tenant {tid!r} failed "
                f"({len(errors)} failure(s) total)") from err

    def close(self) -> None:
        try:
            self.wait()
        finally:
            self._pool.shutdown(wait=True)


def snapshot_meta(root: str, tid: str, *, step: int | None = None) -> dict:
    """Read a snapshot's manifest meta without loading any array."""
    d = os.path.join(root, tid)
    if step is None:
        step = ckpt.latest_step(d)
        if step is None:
            raise FileNotFoundError(f"no snapshot for tenant {tid!r} under "
                                    f"{root}")
    with open(os.path.join(d, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)["meta"]


def list_snapshots(root: str) -> dict:
    """``{tenant id: latest step}`` of every restorable snapshot."""
    if not os.path.isdir(root):
        return {}
    out = {}
    for tid in sorted(os.listdir(root)):
        step = ckpt.latest_step(os.path.join(root, tid))
        if step is not None:
            out[tid] = step
    return out


def restore_tenant(mgr: SessionManager, root: str, tid: str, *,
                   name: str | None = None, step: int | None = None,
                   params: str | None = None, journal=None) -> str:
    """Restore a snapshotted tenant into ``mgr`` and return its id.

    The target may be a different cohort, a different mesh shape, or the
    unsharded session — snapshots hold full logical arrays, the target
    recomputes placement (elastic path). The snapshot's full TGNConfig
    must match the config the target resolves for its variant; mismatch
    raises before any state is touched. Loads are crc-verified by
    ``checkpoint.restore``.

    The tenant resumes on the parameter set the manifest records
    (``param_set``): the target session must have it registered under the
    same name with the SAME content — the recorded ``params_digest`` is
    verified, so a trajectory never silently continues under different
    weights. Pass ``params=<name>`` to REBIND explicitly onto another
    registered set instead (an A/B promotion: the caller owns the
    numerics break, so the digest check is skipped).

    Corrupt-latest fallback (``step=None`` only): a newest step whose
    manifest or payload fails to load/verify is skipped with a warning
    and the restore falls back to the newest PRIOR valid step
    (``checkpoint.restore_valid``) — a torn background write never
    strands a restorable tenant. An explicit ``step=`` stays strict.

    With ``journal=`` (an ``EventJournal``), the restore becomes
    LOSSLESS: after the state lands, every journaled flush past the
    restored manifest's cursor replays through the normal batching
    pipeline, so the tenant resumes bitwise where the original left
    off — not merely at its last snapshot. The cursor is read from the
    manifest of the step ACTUALLY restored (the fallback walk may land
    below the newest), so replay always starts exactly where that
    state's history ends. ``journal.last_replay.pending`` then holds
    accepted-but-never-flushed events for the caller to re-enqueue.
    """
    d = os.path.join(root, tid)
    meta = _meta_with_fallback(root, tid, step)
    want = meta["config"]
    pname = params if params is not None else meta.get("param_set",
                                                       DEFAULT_PARAMS)
    try:
        mgr.param_store.get(pname)
    except ValueError as e:
        raise ValueError(
            f"snapshot {tid!r} is bound to param set {pname!r} which this "
            f"session has not registered — register_params({pname!r}, ...) "
            "with the original weights before restoring, or pass params= "
            f"to rebind explicitly ({e})") from None
    # resume on the tier the tenant was serving with (older manifests
    # recorded the session default — same key, still honored); missing
    # key = let the target session pick its default
    new = mgr.add_tenant(meta["variant"], name=name or tid,
                         reservoir_tau=want.get("reservoir_tau"),
                         use_kernels=meta.get("use_kernels"),
                         params=pname)
    cohort = mgr.cohort_of(new)
    got = dataclasses.asdict(cohort.cfg)
    if got != want:
        mgr.remove_tenant(new)
        diff = sorted(k for k in set(want) | set(got)
                      if want.get(k) != got.get(k))
        raise ValueError(
            f"snapshot {tid!r} was taken with config fields "
            f"{ {k: want.get(k) for k in diff} } but this session resolves "
            f"{ {k: got.get(k) for k in diff} } — shared parameter axes and "
            "table dims must match to continue the trajectory")
    if params is None and meta.get("params_digest") is not None:
        have = mgr.param_store.digest(pname)
        if have != meta["params_digest"]:
            mgr.remove_tenant(new)
            raise ValueError(
                f"snapshot {tid!r} records param set {pname!r} with digest "
                f"{meta['params_digest']} but this session's {pname!r} "
                f"digests {have} — the trajectory would continue under "
                "different weights; register the original parameters, or "
                "pass params= to rebind explicitly")
    tree_like = cohort.pipeline.init_state()._asdict()
    if step is None:
        state, rmeta, _used = ckpt.restore_valid(d, tree_like)
    else:
        state, rmeta = ckpt.restore(d, tree_like, step=step)
    mgr.set_state(new, mailbox.VertexState(**state))
    if journal is not None and rmeta.get("journal") is not None:
        journal.replay(tid, rmeta["journal"], mgr.step, as_tid=new)
    return new


def truncate_journal(journal, root: str, tid: str) -> int | None:
    """Truncate ``tid``'s WAL up to the OLDEST retained snapshot's
    cursor — the GC-coordination contract (docs/ROBUSTNESS.md): every
    snapshot ``checkpoint._gc`` keeps can still anchor a full replay,
    so truncation never outruns what recovery may need. Steps whose
    manifests are corrupt or pre-journal (no cursor) are skipped — no
    bound can be proven, nothing is deleted. Returns the anchor step
    the truncation is bounded by (pass it as the next snapshot's
    ``keep_floor``), or None when no cursor-bearing snapshot exists.
    """
    for s in ckpt.list_steps(os.path.join(root, tid)):
        try:
            meta = snapshot_meta(root, tid, step=s)
        except ckpt.CORRUPTION_ERRORS:
            return None
        cur = meta.get("journal")
        if cur is None:
            return None
        journal.truncate_upto(tid, cur)
        return s
    return None


def _meta_with_fallback(root: str, tid: str, step: int | None) -> dict:
    """Manifest meta for a restore: the requested step's, or (when
    ``step`` is None) the newest step whose manifest PARSES — a corrupt
    manifest is skipped with a warning, mirroring the payload-side walk
    of ``checkpoint.restore_valid``."""
    if step is not None:
        return snapshot_meta(root, tid, step=step)
    d = os.path.join(root, tid)
    steps = ckpt.list_steps(d)
    for s in reversed(steps):
        try:
            return snapshot_meta(root, tid, step=s)
        except ckpt.CORRUPTION_ERRORS as e:
            warnings.warn(
                f"snapshot manifest for tenant {tid!r} step {s} is "
                f"corrupt ({e}); falling back to the newest prior step")
    raise FileNotFoundError(f"no restorable snapshot for tenant {tid!r} "
                            f"under {root}")


def restore_tenant_state(mgr: SessionManager, root: str, tid: str, *,
                         step: int | None = None) -> int:
    """Reload a RESIDENT tenant's VertexState in place from its newest
    valid snapshot — the guard's auto-restore path (serving/guard.py).

    Unlike ``restore_tenant`` (which ADMITS a new tenant), the tenant is
    already attached and keeps its lane slot: only its state rows are
    replaced. The snapshot must fit the lane it reloads into — the
    recorded TGNConfig must equal the cohort's, and the recorded
    ``params_digest`` must match the lane's resident set (the lane's
    kernel TIER may differ: a guard-degraded lane restores the same
    numerics on a lower tier). With ``step=None`` corrupt steps are
    skipped with a warning (``checkpoint.restore_valid``). Returns the
    step restored from.
    """
    cohort = mgr.cohort_of(tid)
    d = os.path.join(root, tid)
    tree_like = cohort.pipeline.init_state()._asdict()
    if step is None:
        state, meta, used = ckpt.restore_valid(d, tree_like)
    else:
        state, meta = ckpt.restore(d, tree_like, step=step)
        used = step
    want = meta.get("config")
    if want is not None and want != dataclasses.asdict(cohort.cfg):
        diff = sorted(k for k in set(want)
                      if want.get(k) != dataclasses.asdict(
                          cohort.cfg).get(k))
        raise ValueError(
            f"snapshot {tid!r} step {used} was taken with config fields "
            f"{ {k: want.get(k) for k in diff} } but the tenant's lane "
            "resolves differently — an in-place restore must land in the "
            "SAME lane config")
    digest = meta.get("params_digest")
    if digest is not None and digest != mgr.param_store.digest(
            cohort.param_set):
        raise ValueError(
            f"snapshot {tid!r} step {used} records params digest "
            f"{digest} but the lane's {cohort.param_set!r} set digests "
            f"{mgr.param_store.digest(cohort.param_set)} — the "
            "trajectory would resume under different weights")
    mgr.set_state(tid, mailbox.VertexState(**state))
    return used


def migrate_tenant(src: SessionManager, tid: str, dst: SessionManager,
                   root: str, *, step: int | None = None,
                   name: str | None = None, keep: int = 3) -> str:
    """Move a live tenant between sessions through a durable snapshot:
    snapshot on ``src``, restore into ``dst`` (any mesh shape), then
    release the source slot. Returns the tenant's id in ``dst``.

    ``step`` defaults to one past the tenant's latest snapshot under
    ``root``, so a migration never writes a step that sorts below (and
    would lose the latest-step race against) its own history."""
    if step is None:
        prev = ckpt.latest_step(os.path.join(root, tid))
        step = 0 if prev is None else prev + 1
    snapshot_tenant(src, tid, root, step=step, keep=keep)
    new = restore_tenant(dst, root, tid, name=name, step=step)
    src.remove_tenant(tid)
    return new
