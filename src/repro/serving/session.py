"""Multi-tenant streaming sessions: many edge streams, one device launch.

The paper's accelerator serves ONE chronological edge stream. A production
deployment (ROADMAP north star; StreamTGN's framing in PAPERS.md) serves
many concurrent, independent streams — per-customer transaction feeds,
per-region event streams — over a registry of named parameter sets (the
teacher, its distilled students, per-tenant fine-tunes). ``SessionManager``
hosts those streams as *tenants*:

  * every tenant owns an independent ``VertexState`` pytree (its own memory
    table, mailbox, and neighbor ring buffer) and picks its own pipeline
    variant — sampler backends included, e.g. one tenant on
    ``sat+lut+np4`` and another on ``sat+lut+np4+reservoir``;
  * tenants with the SAME variant, kernel tier AND parameter set form a
    *cohort*: their states are stacked along a leading tenant axis and one
    ``jax.jit(jax.vmap(step))`` launch advances the whole cohort — batched
    gathers/scatters over the stacked tables, per-tenant chronological
    last-write-wins commits preserved;
  * named parameter sets (``register_params`` / ``ParamStore``) give each
    lane its OWN device-resident weights — ``add_tenant(..., params=
    "studentB")`` lands a tenant on that set, so a vanilla+cosine teacher
    and its sat+lut students A/B-serve in ONE coalesced launch;
  * tenants that submit no batch in a round are masked (an all-``valid=False``
    batch): the launch still has a fixed shape, and the LWW committer plus
    the OOB-redirected ring-buffer insert make a fully-masked step a bitwise
    no-op on that tenant's state.

Numerics contract (tests/test_session.py): a cohort of N tenants produces
BITWISE-identical per-tenant trajectories to N separate single-tenant
sessions, because every path — ``StreamingEngine`` included, which is now a
single-tenant view of this class — runs through the same vmapped step and
vmapped XLA numerics are invariant to the batch size along the mapped axis.
(The randomized sampler backends keep that guarantee by deriving their draws
from a stateless hash of the batch contents, not from threaded PRNG keys.)

Since the coalesced-round tentpole, a full round is ONE compiled launch
regardless of cohort count (``pipeline.CoalescedRound``: cohorts are
contiguous row segments of a common super-batch, variant stages selected
by the static lane table) and the host side of the round is
allocation-free: batches are written in place into pre-allocated,
double-buffered NumPy ring buffers and shipped with a single
``device_put`` per round, so the H2D transfer of round k+1 overlaps the
compute of round k. ``coalesce=False`` keeps the original one-launch-per-
cohort dispatch as the measured baseline (``benchmarks/multitenant.py``)
— both paths replay bitwise-identically.

Cohorts recompile when their tenant count or padded batch size changes;
steady-state serving (fixed fleet, fixed batch cap) reuses one executable
per cohort (per round, when coalesced).
"""
from __future__ import annotations

import functools
import time
from typing import Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mailbox, pipeline as pl, tgn
from repro.data.stream import EdgeBatch
from repro.obs import Histogram, MetricsRegistry


def _as_device_tuple(batch) -> tuple:
    """Normalize an EdgeBatch / 5-tuple to on-device (src,dst,eid,ts,valid)."""
    if isinstance(batch, EdgeBatch):
        batch = (batch.src, batch.dst, batch.eid, batch.ts, batch.valid)
    src, dst, eid, ts, valid = batch
    if valid is None:
        valid = jnp.ones(jnp.asarray(src).shape, bool)
    return (jnp.asarray(src), jnp.asarray(dst), jnp.asarray(eid),
            jnp.asarray(ts), jnp.asarray(valid))


def _as_host_tuple(batch) -> tuple:
    """Normalize an EdgeBatch / 5-tuple to HOST (src,dst,eid,ts,valid)
    arrays — the form the in-place ring-buffer stager consumes. Already-
    device arrays are brought back (the engine's pre-staged path); host
    NumPy batches (the streaming common case) pass through without a copy.
    """
    if isinstance(batch, EdgeBatch):
        batch = (batch.src, batch.dst, batch.eid, batch.ts, batch.valid)
    src, dst, eid, ts, valid = (np.asarray(x) if x is not None else None
                                for x in batch)
    if valid is None:
        valid = np.ones(src.shape, bool)
    return src, dst, eid, ts, valid


class _HostStager:
    """Pre-allocated, double-buffered host staging of a round's super-batch.

    The original round path allocated per tenant per round
    (``jnp.asarray`` + ``jnp.pad`` per batch, then a ``jnp.stack`` per
    cohort — each a separate device dispatch). The stager instead owns two
    sets of ``(rows, width)`` NumPy buffers (one per field of the batch
    five-tuple), fills the submitted rows IN PLACE on the host, and ships
    the whole super-batch with a single ``device_put`` per round.

    Double buffering: rounds alternate between the two buffer sets, so the
    (async) H2D transfer of round k can still be draining while round
    k+1's batches are written into the other set — the transfer overlaps
    the in-flight compute. Before a set is reused, its previous transfer
    AND the launch that consumed it are waited on (both two rounds stale,
    not a D2H sync of the current round). The transfer alone is NOT a
    sufficient reuse gate: ``device_put`` on the CPU backend zero-copies
    suitably aligned NumPy buffers, so the "device" array can alias this
    host memory and the round-k executable may still be reading it when
    round k+2 refills the set in place — the caller registers the launch
    outputs via ``note_consumer`` to close that race.

    ``width`` grows sticky to the largest batch seen (growth is a
    relayout: fresh buffers, new launch shape); extra columns and
    unsubmitted rows are ``valid=False`` padding, which the step turns
    into bitwise no-ops.
    """

    DTYPES = (np.int32, np.int32, np.int32, np.float32, np.bool_)

    def __init__(self, rows: int, width: int = 1, shardings=None):
        self.rows = int(rows)
        self.width = max(int(width), 1)
        self.shardings = shardings      # per-field placements (mesh fleets)
        self._alloc()

    def _alloc(self) -> None:
        self._bufs = [tuple(np.zeros((self.rows, self.width), dt)
                            for dt in self.DTYPES) for _ in range(2)]
        # per set: everything that must resolve before the set may be
        # rewritten — the device_put result, joined by the consuming
        # launch's outputs once note_consumer is called
        self._inflight: list[tuple | None] = [None, None]
        self._turn = 0
        self._last = 0

    def ensure_width(self, width: int) -> None:
        """Grow the staged batch width (sticky; a relayout)."""
        if width > self.width:
            self.drain()                 # old buffers may still be read
            self.width = int(width)
            self._alloc()

    def stage(self, row_batches: Mapping[int, tuple]) -> tuple:
        """Fill ``{row: host five-tuple}`` into the next buffer set and
        dispatch ONE ``device_put`` for the whole super-batch. Unlisted
        rows are all-``valid=False`` (idle). Returns the device tuple."""
        turn = self._turn
        self._turn = 1 - turn
        prev = self._inflight[turn]
        if prev is not None:             # reuse gate: transfer + consumer
            jax.block_until_ready(prev)
        buf = self._bufs[turn]
        for field in buf:
            field.fill(0)                # deterministic padding rows
        for row, host in row_batches.items():
            b = host[0].shape[0]
            for field, src in zip(buf, host):
                field[row, :b] = src
        dev = (jax.device_put(buf, self.shardings)
               if self.shardings is not None else jax.device_put(buf))
        self._inflight[turn] = dev
        self._last = turn
        return dev

    def note_consumer(self, outputs) -> None:
        """Join ``outputs`` (any pytree of device arrays produced by the
        launch that consumed the last staged set) into that set's reuse
        gate. Without this, a zero-copy-aliased set could be rewritten
        while the (async) consuming executable still reads it — see the
        class docstring. Blocking happens two rounds later, in ``stage``,
        so the never-block round contract is untouched."""
        dev = self._inflight[self._last]
        if dev is not None:
            self._inflight[self._last] = (dev, outputs)

    def drain(self) -> None:
        """Wait for every outstanding transfer + consumer (relayout /
        teardown)."""
        for dev in self._inflight:
            if dev is not None:
                jax.block_until_ready(dev)
        self._inflight = [None, None]


def _pad_dev(dev: tuple, B: int) -> tuple:
    """Pad a device tuple to B rows; padding rows are ``valid=False`` (their
    state writes are dropped, so results on real rows are unchanged)."""
    b = dev[0].shape[0]
    if b == B:
        return dev
    pad = B - b
    return (jnp.pad(dev[0], (0, pad)), jnp.pad(dev[1], (0, pad)),
            jnp.pad(dev[2], (0, pad)), jnp.pad(dev[3], (0, pad)),
            jnp.pad(dev[4], (0, pad)))  # bool pads with False


def _idle_dev(B: int) -> tuple:
    """An all-masked batch: advances a tenant's slot without changing it."""
    zi = jnp.zeros((B,), jnp.int32)
    return (zi, zi, zi, jnp.zeros((B,), jnp.float32), jnp.zeros((B,), bool))


#: the parameter-set name every tenant serves on unless it names another.
DEFAULT_PARAMS = "default"


def _tree_signature(tree) -> dict:
    """``{leaf path: (shape, dtype)}`` of a pytree — works on real arrays
    and on ``jax.eval_shape`` ShapeDtypeStructs alike."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(kp): (tuple(v.shape), str(v.dtype))
            for kp, v in flat}


@functools.lru_cache(maxsize=64)
def _cfg_param_signature(cfg: tgn.TGNConfig) -> dict:
    """The parameter signature ``cfg``'s step consumes (abstract init —
    no weights are materialized). Cached per config: ``add_tenant``
    validates every named-set binding against this."""
    want = jax.eval_shape(lambda: tgn.init_params(jax.random.key(0), cfg))
    return _tree_signature(want)


class ParamStore:
    """Named, device-resident parameter sets — the registry behind the
    coalesced round's per-lane params dimension.

    One set is registered at construction under ``DEFAULT_PARAMS``; more
    arrive via ``register`` (``SessionManager.register_params``). Sets are
    immutable once registered: re-registering a name with byte-identical
    content is a no-op, with different content an error — a lane's
    resident weights never change out from under its serving tenants
    (swap = register a new name, attach tenants to it, drain the old).
    ``digest`` (crc32 over leaf paths + bytes, ``checkpoint.tree_digest``)
    is the identity snapshot manifests record so a restore can verify it
    resumes on the same weights.

    ``place`` is the device-placement hook (the sharded session replicates
    every set across its mesh); the default leaves arrays where they are.
    """

    def __init__(self, default_params: dict, *, place=None):
        self._place = place if place is not None else (lambda p: p)
        self._sets: dict[str, dict] = {}
        self._digests: dict[str, str] = {}
        self.register(DEFAULT_PARAMS, default_params)

    def register(self, name: str, params: dict) -> dict:
        """Register (and place) a named set; returns the resident pytree."""
        if not isinstance(name, str) or not name:
            raise ValueError("param-set name must be a non-empty string, "
                             f"got {name!r}")
        from repro.distributed.checkpoint import tree_digest
        digest = tree_digest(params)
        if name in self._sets:
            if digest != self._digests[name]:
                raise ValueError(
                    f"param set {name!r} is already registered with "
                    f"different content (digest {self._digests[name]} vs "
                    f"{digest}); registered sets are immutable — register "
                    "the new weights under a new name and attach tenants "
                    "to that")
            return self._sets[name]          # idempotent re-register
        self._sets[name] = self._place(params)
        self._digests[name] = digest
        return self._sets[name]

    def get(self, name: str) -> dict:
        if name not in self._sets:
            raise ValueError(
                f"unknown param set {name!r}; registered: "
                f"{sorted(self._sets)}. Register it first "
                "(SessionManager.register_params(name, params)) — "
                "admission never invents weights")
        return self._sets[name]

    def digest(self, name: str) -> str:
        self.get(name)
        return self._digests[name]

    def names(self) -> tuple:
        return tuple(self._sets)

    def __contains__(self, name) -> bool:
        return name in self._sets

    def check_binding(self, name: str, cfg: tgn.TGNConfig) -> None:
        """Validate that the named set structurally fits ``cfg``'s step —
        pytree structure, leaf shapes and dtypes must match what
        ``tgn.init_params`` would produce for that config (a teacher set
        cannot drive a SAT lane and vice versa). Raises with the exact
        leaf-level diff; never touches device data."""
        got = _tree_signature(self.get(name))
        want = _cfg_param_signature(cfg)
        if got == want:
            return
        diff = sorted(k for k in set(want) | set(got)
                      if want.get(k) != got.get(k))
        raise ValueError(
            f"param set {name!r} does not fit a "
            f"{pl.variant_name(cfg)!r} lane: mismatched leaves "
            f"{ {k: {'want': want.get(k), 'got': got.get(k)} for k in diff} }"
            " — the set must be initialized/trained for the tenant's "
            "attention+encoder and table dims")


class _Cohort:
    """Tenants sharing one variant + kernel tier + parameter set: stacked
    states + one vmapped step over the cohort's OWN resident params.

    With a ``reserve`` (a capacity-class policy — ``serving/admission.py``
    ``CapacityLadder``) the stacked tables are laid out with SPARE
    idle-masked slots beyond the tenants present, so attaching a tenant
    lands in an existing slot (no shape change, the compiled round keeps
    serving) and detaching one leaves its slot idle-resident; only
    exhausting the class relays out. Without a reserve (the default) the
    tables stay exactly tenant-count-sized, shrinking eagerly on removal
    — the original offline behavior."""

    def __init__(self, cfg: tgn.TGNConfig, use_kernels, params: dict,
                 reserve=None, param_set: str = DEFAULT_PARAMS):
        self.cfg = cfg
        self.reserve = reserve      # capacity-class policy or None (exact)
        self.pipeline = pl.build_pipeline(cfg, use_kernels=use_kernels)
        #: resolved kernel tier — cohorts are keyed by (cfg, tier,
        #: param_set), so a fused-lane tenant and a staged-lane tenant of
        #: the SAME variant form two lanes of the coalesced round.
        self.tier = self.pipeline.tier
        #: the cohort's resident parameter set + its registry name: every
        #: launch of this lane consumes THESE weights (the coalesced
        #: round's per-lane params dimension).
        self.params = params
        self.param_set = param_set
        # folded/packed tables prepared once per cohort; closed over (not a
        # jit argument) because the packed layouts carry static metadata.
        self.aux = self.pipeline.prepare(params)
        self.tids: list[str] = []
        self.state = None           # stacked VertexState, leaves (C, ...)
        self._build_launches()

    def _build_launches(self) -> None:
        """Compile the cohort launches (subclass hook: the sharded cohort
        rebuilds these with mesh placements and state donation)."""
        self._vstep = self.pipeline.batched_step(self.aux)

        # single-tenant peek fast path: the same vmapped computation with
        # the expand/slice fused into ONE jit, so the hot timing hook
        # (StreamingEngine.step_on_device -> fig5/6/7 sweeps) pays no
        # eager re-stacking or out-of-jit vertex-table slicing.
        step, aux = self.pipeline.step, self.aux

        def one(params, state, batch, ef, nf):
            return step(params, aux, state, batch, ef, nf)

        def one_t(params, state, batch, ef, nf):
            out = jax.vmap(one, in_axes=(None, 0, 0, None, None))(
                params, state, jax.tree.map(lambda x: x[None], batch),
                ef, nf)
            return jax.tree.map(lambda x: x[0], out)

        self._vstep1 = jax.jit(one_t)

    @property
    def size(self) -> int:
        return len(self.tids)

    @property
    def capacity(self) -> int:
        """Rows of the stacked tables: ``size`` plus any reserved
        capacity-class spares and/or mesh padding (spare slots are
        idle-masked every round — bitwise no-ops)."""
        return 0 if self.state is None else int(self.state.memory.shape[0])

    @property
    def spare(self) -> int:
        """Idle reserved slots a fast-path attach can land in."""
        return self.capacity - self.size

    def _target_capacity(self, n: int) -> int:
        """Stacked-table rows to lay out for ``n`` tenants (subclass hook:
        the sharded cohort rounds up to a mesh tenant-axis multiple).
        With a reserve policy this includes headroom slots so the next
        attaches stay inside the existing compiled program."""
        return n if self.reserve is None else self.reserve.capacity_for(n)

    def _fit(self, state):
        """Lay out freshly grown/shrunk stacked tables: pad the real
        tenant rows up to the target capacity with idle init-state rows,
        then place them (subclass hook: mesh placement)."""
        n = int(state.memory.shape[0])
        cap = self._target_capacity(len(self.tids))
        if cap > n:
            row = self.pipeline.init_state()
            pads = jax.tree.map(lambda x: jnp.repeat(x[None], cap - n,
                                                     axis=0), row)
            state = jax.tree.map(lambda t, p: jnp.concatenate([t, p],
                                                              axis=0),
                                 state, pads)
        return self._place(state)

    def _place(self, state):
        """Device placement of freshly laid-out tables (subclass hook:
        the sharded cohort pins its PartitionSpecs)."""
        return state

    def ensure_capacity(self) -> None:
        """Materialize the reserve capacity with ZERO tenants (a prewarmed
        lane: the variant is resident in the compiled round before its
        first tenant arrives, so that first attach is a fast path)."""
        if self.state is None:
            empty = jax.tree.map(lambda x: x[None][:0],
                                 self.pipeline.init_state())
            self.state = self._fit(empty)

    def add(self, tid: str) -> bool:
        """Attach a tenant. Returns True when the stacked tables were
        relaid out (a shape change: the coalesced round must rebuild);
        False when a reserved spare slot absorbed the attach in place —
        the fast path live admission rides on."""
        n = self.size
        if self.reserve is not None and self.state is not None \
                and self.capacity > n:
            # fast path: the new tenant's init-state row overwrites an
            # idle spare slot (spares already hold init rows, but a slot
            # freed by a detach holds the departed tenant's stale rows)
            row = self.pipeline.init_state()
            self.state = self._place(jax.tree.map(
                lambda t, r: t.at[n].set(r), self.state, row))
            self.tids.append(tid)
            return False
        row = jax.tree.map(lambda x: x[None], self.pipeline.init_state())
        if self.state is None:
            st = row
        else:
            real = jax.tree.map(lambda x: x[:n], self.state)
            st = jax.tree.map(lambda t, r: jnp.concatenate([t, r], axis=0),
                              real, row)
        self.tids.append(tid)
        self.state = self._fit(st)
        return True

    def remove(self, tid: str) -> bool:
        """Release the tenant's slot. Returns True when the tables were
        relaid out. Without a reserve the slot is released eagerly: the
        stacked tables shrink to the remaining tenants (plus mesh padding
        in the sharded cohort) — a departed tenant never leaves a dead row
        behind. With a reserve the LAST tenant's row swaps into the hole
        and the freed slot stays resident idle-masked, so a detach never
        changes the compiled layout."""
        i = self.tids.index(tid)
        if self.reserve is not None:
            last = len(self.tids) - 1
            if i != last:
                self.state = self._place(jax.tree.map(
                    lambda x: x.at[i].set(x[last]), self.state))
                self.tids[i] = self.tids[last]
            self.tids.pop()
            return False
        n = self.size
        self.tids.pop(i)
        if not self.tids:
            self.state = None
            return True
        keep = np.array([j for j in range(n) if j != i])
        self.state = self._fit(jax.tree.map(lambda x: x[keep], self.state))
        return True

    def launch(self, stacked_batch: tuple, edge_feats, node_feats,
               commit: bool = False) -> tgn.BatchOut:
        """One device launch advancing every tenant slot of this cohort,
        on the cohort's OWN resident parameter set. ``commit`` marks
        launches whose returned state will replace ``self.state`` (the
        sharded cohort donates the old buffers then)."""
        return self._vstep(self.params, self.state, stacked_batch,
                           edge_feats, node_feats)


class SessionManager:
    """Batched multi-tenant serving over the TGNPipeline registry.

    Many independent tenant streams over a registry of named parameter
    sets. Tenants are grouped into cohorts by (variant config, kernel
    tier, parameter set); each round, one vmapped launch per cohort
    advances every tenant (idle tenants masked). See the module docstring
    for the numerics contract.

    ::

        mgr = SessionManager(params, edge_feats, model=cfg)
        a = mgr.add_tenant()                        # base variant
        b = mgr.add_tenant("sat+lut+np4+reservoir")  # same params, new policy
        mgr.register_params("teacher-v1", teacher_params)
        c = mgr.add_tenant("teacher", params="teacher-v1")  # own weights
        outs = mgr.step({a: b1, b: b2, c: b3})       # {tid: BatchOut}
        mgr.state_of(a)                              # tenant's VertexState

    Tenants on the DEFAULT set must share the session's attention+encoder
    axes (one set cannot drive two parameter pytrees); a tenant on a
    NAMED set brings its own weights, so any registry variant may serve —
    the teacher/student A/B lanes above still advance as ONE coalesced
    launch per round.
    """

    def __init__(self, params: dict, edge_feats, node_feats=None, *,
                 model: tgn.TGNConfig | None = None, variant=None,
                 use_kernels: bool = False, coalesce: bool = True,
                 reserve=None, obs: MetricsRegistry | None = None, **dims):
        if model is None:
            if variant is None:
                raise TypeError("pass model=TGNConfig or variant= + dims")
            model = pl.variant_config(variant, **dims)
        elif variant is not None or dims:
            raise TypeError("model= is exclusive with variant=/dims")
        if reserve is True:          # convenience: the default ladder
            from repro.serving.admission import CapacityLadder
            reserve = CapacityLadder()
        #: capacity-class policy (``admission.CapacityLadder`` or any
        #: object with ``capacity_for(n)``): cohorts hold spare
        #: idle-masked lane slots so live attach/detach lands in the
        #: existing compiled round. ``None`` (default) = exact-size
        #: cohorts, eager shrink — the offline behavior.
        self.reserve = reserve
        self.base_cfg = model
        self.use_kernels = use_kernels
        self.coalesce = coalesce
        #: named, device-resident parameter sets; ``params`` becomes the
        #: DEFAULT_PARAMS entry, more arrive via ``register_params``
        self.param_store = ParamStore(params, place=self._place_params)
        self.params = self.param_store.get(DEFAULT_PARAMS)
        self.edge_feats = jnp.asarray(edge_feats)
        self.node_feats = (jnp.asarray(node_feats)
                           if node_feats is not None else None)
        # keyed by (cfg, resolved kernel tier, param-set name): tenants
        # may pick a kernel tier (add_tenant(use_kernels=...)) and a
        # parameter set (add_tenant(params=...)) per lane, defaulting to
        # the session-wide setting / DEFAULT_PARAMS
        self._cohorts: dict[tuple, _Cohort] = {}
        self._tenant_cohort: dict[str, _Cohort] = {}
        self._next_id = 0
        self.metrics: list[dict] = []
        # coalesced-round layout (built lazily, dropped on fleet changes)
        self._coalesced: pl.CoalescedRound | None = None
        self._stager: _HostStager | None = None
        self._drained: tuple[int, float] | None = None   # summary() cache
        #: fleet-layout rebuilds of the coalesced launch (a relayout means
        #: the next round compiles a fresh program — the slow path the
        #: reserve classes exist to avoid)
        self.relayouts = 0
        #: what the last add_tenant/remove_tenant did to the layout —
        #: ``{"tid", "relayout", "new_cohort"}`` (read by the admission
        #: controller to label fast vs slow admissions)
        self.last_admission: dict | None = None
        #: per-tenant serving counters fed by ``step`` (see tenant_stats)
        self._tenant_stats: dict[str, dict] = {}
        #: live queue-depth provider (``() -> {tid: rows}``) a serving
        #: frontend registers, so ``summary()``/``tenant_stats()`` stay
        #: the one source of truth for the stats endpoint
        self.queue_depths = None
        #: the fleet's metrics registry (``obs.MetricsRegistry``) — ONE
        #: instance every layer writes through (frontend latencies,
        #: coalesced-round compile gauges, admission tallies), so
        #: ``snapshot()`` is the lock-consistent view a stats/metrics
        #: response embeds
        self.obs = obs if obs is not None else MetricsRegistry()
        #: sampled round tracer (``obs.RoundTracer``) — ``set_tracer``.
        #: None (default) keeps every round fence-free.
        self.tracer = None
        #: per-tenant latency-SLO burn tracker (``set_slo``) or None.
        self.slo = None
        self._obs_rounds = 0     # round walls already fed to registry/SLO
        #: armed fault-injection plan (``faults.FaultInjector``) or None
        #: — every hook site is gated ``if self._faults is not None:``
        #: (tools/session_lint.py rule 4), so an unarmed fleet pays one
        #: attribute test per round.
        self._faults = None
        #: supervising ``guard.FleetGuard`` (set by its constructor) or
        #: None; ``guarded_step`` routes rounds through it when present.
        self.guard = None
        #: tenants whose traffic is dropped and lane slot idle-masked
        #: (valid=False every round — the established bitwise no-op), so
        #: a sick tenant stops serving with ZERO recompiles and zero
        #: effect on cohort-mates' trajectories.
        self._quarantined: set[str] = set()

    # -- observability hooks -------------------------------------------
    def set_tracer(self, tracer) -> None:
        """Attach a sampled round tracer (``obs.RoundTracer``). Spans and
        the device drain fence happen at trace-sample rounds ONLY, so the
        async round pipeline keeps its never-block contract on every
        other round. ``None`` detaches."""
        self.tracer = tracer

    def set_slo(self, target_ms: float, objective: float = 0.99,
                source: str = "round"):
        """Arm per-tenant latency-SLO burn accounting (``obs.SLOTracker``)
        — surfaced in ``summary()["per_tenant"][tid]["slo"]`` and the
        frontend's ``metrics`` wire op. ``source`` names what one
        observation is: ``"round"`` (walls fed by ``summary()``) or
        ``"event"`` (the frontend's per-event latencies)."""
        from repro.obs import SLOTracker
        self.slo = SLOTracker(target_ms, objective=objective, source=source)
        return self.slo

    def set_faults(self, injector) -> None:
        """Arm (or with ``None`` disarm) a deterministic fault-injection
        plan (``faults.FaultInjector``) — chaos testing only; an unarmed
        session's hook sites are no-ops (docs/ROBUSTNESS.md)."""
        self._faults = injector

    # -- quarantine (the guard's isolation primitive) -------------------
    def quarantine(self, tid: str) -> None:
        """Stop serving ``tid`` WITHOUT detaching it: its batches are
        dropped from every round, so its lane slot idle-masks
        (all-``valid=False`` — a bitwise no-op on its state) while the
        compiled round keeps serving everyone else unchanged. Zero
        recompiles, zero effect on cohort-mates."""
        if tid not in self._tenant_cohort:
            raise KeyError(f"unknown tenant {tid!r}")
        self._quarantined.add(tid)
        self.obs.gauge("guard.quarantined_now").set(len(self._quarantined))

    def unquarantine(self, tid: str) -> None:
        self._quarantined.discard(tid)
        self.obs.gauge("guard.quarantined_now").set(len(self._quarantined))

    def is_quarantined(self, tid: str) -> bool:
        return tid in self._quarantined

    @property
    def quarantined(self) -> frozenset:
        return frozenset(self._quarantined)

    def guarded_step(self, batches: Mapping) -> dict:
        """``step`` routed through the supervising ``FleetGuard`` when
        one is attached (health checks, quarantine, auto-restore, tier
        degradation — serving/guard.py); plain ``step`` otherwise. The
        serving drivers (``run``, the frontend's pump) call this."""
        if self.guard is not None:
            return self.guard.step(batches)
        return self.step(batches)

    def _invalidate_layout(self) -> None:
        """Fleet layout changed: the next round builds (and compiles) a
        fresh ``CoalescedRound``. The current-launch compile gauges reset
        with it — ``compile_counters`` reports the CURRENT launch."""
        self._coalesced = None
        self.obs.gauge("compile.round_traces").set(0)
        self.obs.gauge("compile.round_calls").set(0)

    # -- tenant lifecycle ----------------------------------------------
    def _place_params(self, params: dict) -> dict:
        """Device placement of a registered parameter set (subclass hook:
        the sharded session replicates it across the mesh)."""
        return params

    def register_params(self, name: str, params: dict) -> str:
        """Register a NAMED parameter set (device-placed, immutable) for
        tenants to serve on: ``add_tenant(..., params=name)`` lands its
        tenant in a lane resident on THESE weights. Registration alone
        never touches the fleet layout — no relayout, no recompile; the
        teacher/student A/B flow is register -> (prewarm ->) attach.
        Returns ``name``."""
        self.param_store.register(name, params)
        return name

    def _make_cohort(self, cfg: tgn.TGNConfig, use_kernels,
                     param_set: str = DEFAULT_PARAMS) -> _Cohort:
        """Cohort factory (the sharded session swaps in mesh-placed ones)."""
        return _Cohort(cfg, use_kernels, self.param_store.get(param_set),
                       reserve=self.reserve, param_set=param_set)

    def _tenant_cfg(self, variant, reservoir_tau,
                    param_set: str = DEFAULT_PARAMS) -> tgn.TGNConfig:
        base = self.base_cfg
        if variant is None:
            cfg = base
        else:
            v = pl.resolve_variant(variant)
            if (v.attention, v.encoder) != (base.attention, base.encoder):
                if param_set == DEFAULT_PARAMS:
                    raise ValueError(
                        f"tenant variant {pl.variant_name(v)!r} needs "
                        f"{v.attention}+{v.encoder} parameters but this "
                        f"session shares {base.attention}+{base.encoder} "
                        "parameters; prune_k and sampler may vary per "
                        "tenant, the parameterized axes may not — unless "
                        "the tenant brings its own weights "
                        "(register_params + add_tenant(..., params=name))")
                # a named set brings its own weights: the tenant may pick
                # ANY registry variant; table/feature dims stay the
                # session's (one edge-feature store, one vertex universe)
                cfg = base.replace(attention=v.attention, encoder=v.encoder,
                                   prune_k=v.prune_k, sampler=v.sampler)
            else:
                cfg = base.replace(prune_k=v.prune_k, sampler=v.sampler)
        if reservoir_tau is not None:
            cfg = cfg.replace(reservoir_tau=reservoir_tau)
        return cfg

    def _resolve_lane(self, variant, reservoir_tau, use_kernels,
                      params) -> tuple:
        """Resolve an admission request to its lane key ``(cfg, tier,
        param-set name)``, validating the param-set binding BEFORE any
        fleet mutation (an unknown or ill-fitting set rejects cleanly —
        compile counters and the serving layout are untouched)."""
        pname = DEFAULT_PARAMS if params is None else params
        self.param_store.get(pname)          # unknown set: reject here
        cfg = self._tenant_cfg(variant, reservoir_tau, pname)
        self.param_store.check_binding(pname, cfg)
        tier = pl.stages.resolved_tier(
            cfg, self.use_kernels if use_kernels is None else use_kernels)
        return cfg, tier, pname

    def add_tenant(self, variant=None, *, name: str | None = None,
                   reservoir_tau: float | None = None,
                   use_kernels=None, params: str | None = None) -> str:
        """Register a tenant stream; returns its id.

        ``variant`` is any registry spec sharing the session's parameterized
        axes (attention+encoder); ``prune_k`` and the sampler backend may
        differ per tenant, and so may the kernel tier (``use_kernels``:
        ``"ref"``/``"staged"``/``"fused"`` or a bool; ``None`` = the
        session default) — lanes of the coalesced round select their tier
        independently. ``params`` names a registered parameter set
        (``register_params``): the tenant serves on THOSE weights, and may
        then pick any attention+encoder (teacher/student A/B lanes).
        Adding a tenant grows its cohort's stacked state (next launch
        recompiles for the new tenant count) unless a reserved spare slot
        absorbs it.
        """
        cfg, tier, pname = self._resolve_lane(variant, reservoir_tau,
                                              use_kernels, params)
        tid = name if name is not None else f"t{self._next_id}"
        self._next_id += 1
        if tid in self._tenant_cohort:
            raise ValueError(f"tenant {tid!r} already exists")
        cohort = self._cohorts.get((cfg, tier, pname))
        created = cohort is None
        if created:
            cohort = self._cohorts[(cfg, tier, pname)] = \
                self._make_cohort(cfg, tier, pname)
        relayout = cohort.add(tid)
        self._tenant_cohort[tid] = cohort
        self._tenant_stats[tid] = {"rounds": 0, "rows": 0,
                                   "last_flush_t": None}
        self.last_admission = {"tid": tid, "relayout": relayout,
                               "new_cohort": created}
        if created or relayout:
            self._invalidate_layout()    # fleet layout changed: relaunch
        return tid

    def prewarm_cohort(self, variant=None, *,
                       reservoir_tau: float | None = None,
                       use_kernels=None, params: str | None = None) -> None:
        """Materialize a variant's cohort with ZERO tenants at its reserve
        capacity: the lane is compiled into the next round while empty, so
        the FIRST tenant of that variant (and parameter set — ``params``
        names a registered set, e.g. a freshly distilled student about to
        be canaried) attaches on the fast path instead of forcing a
        mid-serving relayout. Requires ``reserve``."""
        if self.reserve is None:
            raise ValueError("prewarm_cohort needs a reserve policy "
                             "(SessionManager(reserve=...)); without spare "
                             "lane slots an empty cohort cannot admit "
                             "anything without a relayout anyway")
        cfg, tier, pname = self._resolve_lane(variant, reservoir_tau,
                                              use_kernels, params)
        if (cfg, tier, pname) in self._cohorts:
            return
        cohort = self._cohorts[(cfg, tier, pname)] = \
            self._make_cohort(cfg, tier, pname)
        cohort.ensure_capacity()
        self._invalidate_layout()        # new lane: relaunch (once, now)

    def remove_tenant(self, tid: str) -> None:
        cohort = self._tenant_cohort[tid]
        # drain in-flight async rounds BEFORE releasing the lane slot:
        # dispatched rounds still hold the cohort's stacked tables (and
        # the pending per-round edge scalars in ``metrics`` reference
        # them), so the slot's rows are shrunk/swapped away only after
        # everything in flight has landed
        self.sync()
        self._tenant_cohort.pop(tid)
        self._tenant_stats.pop(tid, None)
        if tid in self._quarantined:
            self.unquarantine(tid)
        relayout = cohort.remove(tid)
        if not cohort.tids and cohort.reserve is None:
            # reserve-less cohorts tear down when empty; reserved lanes
            # stay resident (capacity held) so re-attach is a fast path
            self._cohorts.pop((cohort.cfg, cohort.tier, cohort.param_set))
            relayout = True
        self.last_admission = {"tid": tid, "relayout": relayout,
                               "new_cohort": False}
        if relayout:
            self._invalidate_layout()    # fleet layout changed: relaunch

    def compile_counters(self) -> dict:
        """The zero-recompile guard's view: ``relayouts`` (coalesced
        layouts built), ``round_traces`` (compiled executables of the
        CURRENT round launch — one per new static widths vector), and
        ``round_calls`` (executions dispatched through it). A live
        attach/detach that landed in reserved slots leaves ``relayouts``
        and ``round_traces`` exactly where they were.

        All three come from ONE ``obs`` registry snapshot (the round
        launch maintains the gauges, ``_ensure_layout`` the counter), so
        a stats response that embeds these twice — the frontend's view
        and the admission controller's — cannot observe two mid-round
        states of the same counters."""
        snap = self.obs.snapshot(prefix="compile.")
        return {"relayouts": int(snap.get("compile.relayouts", 0)),
                "round_traces": int(snap.get("compile.round_traces", 0)),
                "round_calls": int(snap.get("compile.round_calls", 0))}

    @property
    def tenants(self) -> tuple:
        return tuple(self._tenant_cohort)

    def cohort_of(self, tid: str) -> _Cohort:
        return self._tenant_cohort[tid]

    def state_of(self, tid: str) -> mailbox.VertexState:
        """The tenant's (unbatched) VertexState view."""
        cohort = self._tenant_cohort[tid]
        i = cohort.tids.index(tid)
        return jax.tree.map(lambda x: x[i], cohort.state)

    def set_state(self, tid: str, st: mailbox.VertexState) -> None:
        cohort = self._tenant_cohort[tid]
        i = cohort.tids.index(tid)
        cohort.state = jax.tree.map(lambda t, r: t.at[i].set(r),
                                    cohort.state, st)

    def _cohort_info(self, c: _Cohort) -> dict:
        return {"tenants": tuple(c.tids), "capacity": c.capacity,
                "param_set": c.param_set, **c.pipeline.describe()}

    def describe(self) -> dict:
        """Cohort layout: variant -> (tenant ids, parameter set, resolved
        stage backends). Cohorts that differ only in ``reservoir_tau``,
        parameter set, or kernel tier share a variant name; the later ones
        are disambiguated with ``@tau=`` / ``@params=`` / ``@<tier>``
        suffixes so no cohort's entry is silently overwritten."""
        out, holders = {}, {}
        for c in self._cohorts.values():
            key = base = c.pipeline.variant
            if key in out:
                first = holders[base]
                if c.cfg.reservoir_tau != first.cfg.reservoir_tau:
                    key = f"{base}@tau={c.cfg.reservoir_tau:g}"
                if key in out and c.param_set != first.param_set:
                    key = f"{key}@params={c.param_set}"
                if key in out:
                    key = f"{key}@{c.tier}"
            holders.setdefault(base, c)
            out[key] = self._cohort_info(c)
        return out

    # -- the round step ------------------------------------------------
    def _cohort_round(self, cohort: _Cohort, submitted: dict,
                      commit: bool = False) -> tgn.BatchOut:
        B = max(d[0].shape[0] for d in submitted.values())
        devs = [( _pad_dev(submitted[tid], B) if tid in submitted
                  else _idle_dev(B)) for tid in cohort.tids]
        # mesh-padding slots of a sharded cohort idle every round
        devs += [_idle_dev(B)] * (cohort.capacity - len(devs))
        stacked = tuple(jnp.stack([d[j] for d in devs])
                        for j in range(5))
        return cohort.launch(stacked, self.edge_feats, self.node_feats,
                             commit=commit)

    @staticmethod
    def _slice_out(out: tgn.BatchOut, i: int, b: int,
                   with_state: bool = False) -> tgn.BatchOut:
        """Tenant ``i``'s unbatched BatchOut, cut back to its own ``b`` rows
        (the 2B-row distill views are concat([src rows, dst rows])).

        ``step`` returns outputs with ``state=None``: per-tenant states are
        committed inside the session (read them via ``state_of``), and
        slicing full vertex tables out of the stacked pytree per tenant per
        round would dwarf the step itself. ``peek`` keeps the state leaf.
        """
        st = (jax.tree.map(lambda x: x[i], out.state) if with_state
              else None)
        one = tgn.BatchOut(state=st, emb_src=out.emb_src[i],
                           emb_dst=out.emb_dst[i],
                           attn_logits=out.attn_logits[i],
                           nbr_valid=out.nbr_valid[i],
                           nbr_dt=out.nbr_dt[i])
        B = one.emb_src.shape[0]
        if b == B:
            return one
        two = jnp.concatenate([jnp.arange(b), B + jnp.arange(b)])
        return tgn.BatchOut(
            state=one.state, emb_src=one.emb_src[:b], emb_dst=one.emb_dst[:b],
            attn_logits=one.attn_logits[two], nbr_valid=one.nbr_valid[two],
            nbr_dt=one.nbr_dt[two])

    # -- coalesced dispatch (the default round path) -------------------
    def _make_coalesced(self) -> pl.CoalescedRound:
        """Build the fused whole-round launch for the current fleet layout
        (subclass hook: the sharded session pins mesh placements and
        donates the resident state buffers)."""
        return pl.CoalescedRound(((c.pipeline, c.aux, c.capacity)
                                  for c in self._cohorts.values()),
                                 obs=self.obs)

    def _make_stager(self, rows: int, width: int) -> _HostStager:
        """Host-stager factory (subclass hook: mesh batch placements)."""
        return _HostStager(rows, width)

    def _ensure_layout(self, width: int) -> pl.CoalescedRound:
        if self._coalesced is None:
            self._coalesced = self._make_coalesced()
            self.relayouts += 1
            self.obs.counter("compile.relayouts").inc()
        if self._stager is None or self._stager.rows != self._coalesced.rows:
            self._stager = self._make_stager(self._coalesced.rows, width)
        self._stager.ensure_width(width)
        return self._coalesced

    def _coalesced_round(self, batches: Mapping,
                         trace=None) -> tuple[dict, object]:
        """ONE compiled launch for the whole round: stage every submitted
        batch into the super-batch ring buffer in place (single
        ``device_put``), advance all cohorts through the fused launch, and
        commit each cohort's state. Returns ``(outs, pending edge count)``
        — the count is a device scalar resolved only in ``summary()``.

        ``trace`` is the sampled-round tracer handle (None on unsampled
        rounds — the fast path): stage/launch host spans plus an ``h2d``
        fence attributing where the super-batch transfer actually landed.
        Every fence sits inside the ``trace`` gate, so unsampled rounds
        never block (``tools/session_lint.py`` enforces this)."""
        host = {tid: _as_host_tuple(b) for tid, b in batches.items()}
        width = max(h[0].shape[0] for h in host.values())
        launch = self._ensure_layout(width)
        cohorts = list(self._cohorts.values())
        offsets, lo = {}, 0
        for c in cohorts:
            offsets[id(c)] = lo
            lo += c.capacity
        rows = {}
        widths = {}
        for tid, h in host.items():
            c = self._tenant_cohort[tid]
            rows[offsets[id(c)] + c.tids.index(tid)] = h
            widths[id(c)] = max(widths.get(id(c), 1), h[0].shape[0])
        if trace is not None:
            t_stage = trace.clock()
        superbatch = self._stager.stage(rows)
        if trace is not None:
            t_launch = trace.clock()
            trace.add("stage", t_stage, t_launch, cat="host",
                      rows=len(rows), width=width)
        states = tuple(c.state for c in cohorts)
        # per-segment padded widths (static): each cohort steps at ITS
        # round-max batch size — the exact B the per-cohort launch would
        # use, which the bitwise contract requires (idle cohorts run a
        # width-1 masked no-op lane). Params are per-lane too: each
        # segment consumes its cohort's resident set (teacher/student
        # A/B lanes in the same launch).
        outs_t, edges = launch(tuple(c.params for c in cohorts), states,
                               superbatch, self.edge_feats, self.node_feats,
                               widths=tuple(widths.get(id(c), 1)
                                            for c in cohorts))
        # the staged set may zero-copy alias host memory: its reuse must
        # also wait for this launch, not just the transfer. Gate on the
        # edge-count output — the state outputs become DONATED inputs of
        # the next round (sharded cohorts), which block_until_ready rejects
        self._stager.note_consumer(edges)
        if trace is not None:
            now = trace.clock()
            trace.add("launch", t_launch, now, cat="host",
                      lanes=len(cohorts))
            # H2D overlap attribution: the super-batch transfer was
            # dispatched inside stage; only fencing it (sampled rounds
            # only) shows how far past the dispatch it actually landed
            jax.block_until_ready(superbatch)
            trace.add("h2d", t_stage, trace.clock(), cat="device",
                      rows=len(rows))
        outs: dict[str, tgn.BatchOut] = {}
        for c, out in zip(cohorts, outs_t):
            c.state = out.state
            for i, tid in enumerate(c.tids):
                if tid in host:
                    outs[tid] = self._slice_out(out, i, host[tid][0].shape[0])
        return outs, edges

    def _device_staged(self, batches: Mapping) -> bool:
        """True when the fleet is a single-tenant view being fed an
        already-on-device batch tuple (StreamingEngine's prefetched
        path): round-tripping it through the host stager would cost a
        blocking D2H copy plus a second transfer, so such steps launch
        through the per-cohort dispatch instead — a one-cohort fleet, so
        still exactly one compiled launch per round."""
        if len(batches) != 1 or len(self._tenant_cohort) != 1:
            return False
        (b,) = batches.values()
        return (isinstance(b, tuple) and len(b) == 5
                and all(x is None or isinstance(x, jax.Array) for x in b))

    def _percohort_round(self, batches: Mapping) -> tuple[dict, object, int]:
        """The original dispatch — one compiled launch per cohort, batches
        staged through per-tenant device ops. Kept (``coalesce=False``) as
        the measured baseline of the coalesced path; trajectories are
        bitwise-identical between the two (tests/test_session.py)."""
        outs: dict[str, tgn.BatchOut] = {}
        launches = 0
        edge_counts = []
        for cohort in self._cohorts.values():
            submitted = {tid: _as_device_tuple(batches[tid])
                         for tid in cohort.tids if tid in batches}
            if not submitted:
                continue
            out = self._cohort_round(cohort, submitted, commit=True)
            cohort.state = out.state
            launches += 1
            for i, tid in enumerate(cohort.tids):
                if tid in submitted:
                    b = submitted[tid][0].shape[0]
                    outs[tid] = self._slice_out(out, i, b)
                    edge_counts.append(submitted[tid][4].sum())
        # pending device-side count — resolved in summary(), never here
        edges = jnp.stack(edge_counts).sum() if edge_counts else 0
        return outs, edges, launches

    def step(self, batches: Mapping[str, EdgeBatch | tuple]) -> dict:
        """Advance every tenant with a submitted batch. Coalesced (the
        default), the whole round — every cohort, idle members masked — is
        ONE compiled launch fed by one in-place-staged ``device_put``;
        with ``coalesce=False`` each submitted cohort launches separately.
        Returns ``{tid: BatchOut}`` for the submitted tenants with
        ``state=None`` — per-tenant states are committed in place; read
        them via ``state_of``.

        Steps are fully asynchronous: nothing here blocks on the device,
        so staging round k+1 overlaps the compute of round k. ``sync()``
        (or ``summary()``, which calls it) drains the fleet.
        """
        unknown = set(batches) - set(self._tenant_cohort)
        if unknown:
            raise KeyError(f"unknown tenants {sorted(unknown)}; "
                           f"registered: {sorted(self._tenant_cohort)}")
        if self._faults is not None:
            # chaos-only injection hook: one attribute test when unarmed
            batches = self._faults.on_round(self, batches)
        if self._quarantined:
            # quarantined traffic is dropped; the sick lane slot idle-
            # masks below (valid=False), a bitwise no-op on its state
            batches = {t: b for t, b in batches.items()
                       if t not in self._quarantined}
        trace = None
        if self.tracer is not None and batches:
            # sampled-trace gate: on unsampled rounds ``trace`` stays
            # None and the round dispatches fence-free, preserving the
            # async pipeline (and the pending edge scalars) untouched
            trace = self.tracer if self.tracer.sample_round() else None
        t0 = time.perf_counter()
        if self._faults is not None:
            self._faults.before_launch(self)   # may raise KernelFault
        if not batches:
            outs, edges, launches = {}, 0, 0
        elif self.coalesce and not self._device_staged(batches):
            outs, edges = self._coalesced_round(batches, trace=trace)
            launches = 1
        else:
            outs, edges, launches = self._percohort_round(batches)
        dt = time.perf_counter() - t0
        self._drained = None
        self.metrics.append({
            "t0": t0, "latency_s": dt, "edges": edges,
            "launches": launches, "tenants_active": len(outs),
            "tids": tuple(batches)})
        self.obs.counter("session.rounds").inc()
        self.obs.counter("session.launches").inc(launches)
        for tid, b in batches.items():
            rows = (b.src if isinstance(b, EdgeBatch) else b[0]).shape[0]
            ts = self._tenant_stats[tid]
            ts["rounds"] += 1
            ts["rows"] += int(rows)
            ts["last_flush_t"] = t0
        if trace is not None:
            # drain fence, sampled rounds ONLY: wait for this round's
            # commits so its device time is attributed to a span
            t_drain = trace.clock()
            jax.block_until_ready(tuple(c.state
                                        for c in self._cohorts.values()
                                        if c.state is not None))
            trace.add("drain", t_drain, trace.clock(), cat="device",
                      round=len(self.metrics) - 1)
        return outs

    def sync(self) -> None:
        """Drain the fleet: wait until every dispatched round's commits
        (and staged transfers) have landed. Steps never block — this is
        the one place the serving loop waits on the device."""
        for c in self._cohorts.values():
            if c.state is not None:
                jax.block_until_ready(c.state)
        if self._stager is not None:
            self._stager.drain()

    def peek(self, tid: str, batch) -> tgn.BatchOut:
        """The tenant's step output WITHOUT committing any state (timing /
        what-if hook; other cohort members are masked as idle)."""
        cohort = self._tenant_cohort[tid]
        dev = _as_device_tuple(batch)
        if cohort.size == 1 and cohort.capacity == 1:
            return cohort._vstep1(cohort.params, cohort.state, dev,
                                  self.edge_feats, self.node_feats)
        out = self._cohort_round(cohort, {tid: dev})
        return self._slice_out(out, cohort.tids.index(tid),
                               dev[0].shape[0], with_state=True)

    # -- stream driving ------------------------------------------------
    def run(self, streams: Mapping[str, Iterable]):
        """Drive tenant streams round-robin until all are exhausted.

        ``streams``: tid -> iterable of EdgeBatch. Yields
        ``(batches, outs)`` per round; tenants whose stream has ended are
        masked for the remaining rounds.
        """
        its = {tid: iter(s) for tid, s in streams.items()}
        while its:
            batches = {}
            for tid in list(its):
                try:
                    batches[tid] = next(its[tid])
                except StopIteration:
                    del its[tid]
            if not batches:
                return
            yield batches, self.guarded_step(batches)

    def tenant_stats(self) -> dict:
        """Per-tenant serving metrics — ``{tid: {queue_depth, rounds,
        rows, last_flush_t[, slo]}}``: the frontend's live ingest-queue
        depth (0 unless a frontend registered its ``queue_depths``
        provider), rounds participated, rows submitted (padding
        included), the wall clock of the last round the tenant joined,
        and — when ``set_slo`` armed a tracker — the tenant's SLO burn
        view (EVERY tenant reports one, zero-observation tenants
        included). This is the one source of truth the frontend's stats
        endpoint reads."""
        qd = dict(self.queue_depths()) if self.queue_depths else {}
        slo = self.slo
        guard = self.guard
        return {tid: {"queue_depth": int(qd.get(tid, 0)), **st,
                      "quarantined": tid in self._quarantined,
                      **({"slo": slo.tenant(tid)} if slo is not None
                         else {}),
                      **({"guard": guard.tenant_view(tid)}
                         if guard is not None else {})}
                for tid, st in self._tenant_stats.items()}

    def summary(self) -> dict:
        """Aggregate round metrics (first round skipped: jit warmup),
        plus ``per_tenant`` serving counters (``tenant_stats``).

        Steps are async, so per-round walls are reconstructed from the
        dispatch timestamps — ``wall(k) = t0(k+1) - t0(k)``, with the last
        round absorbing the final ``sync()`` drain — and the pending
        device-side edge counts are resolved here, the serving loop's only
        host sync. Call right after the last round for faithful numbers.
        """
        if len(self.metrics) < 2:
            return {}
        if self._drained is None or self._drained[0] != len(self.metrics):
            self.sync()
            self._drained = (len(self.metrics), time.perf_counter())
        t0s = [m["t0"] for m in self.metrics] + [self._drained[1]]
        walls = np.diff(np.array(t0s))[1:]
        # one Histogram replaces the hand-rolled percentile math; a
        # registry-resident copy accumulates across summary() calls for
        # the metrics endpoint, and a round-sourced SLO tracker observes
        # each participating tenant's wall. Both are fed exactly once
        # per round (the cursor) — the last wall's drain component may
        # shift if more rounds arrive, an accepted approximation.
        wall_h = Histogram("session.round_wall_s")
        for w in walls:
            wall_h.record(w)
        reg_h = self.obs.histogram("session.round_wall_s")
        slo = self.slo if (self.slo is not None
                           and self.slo.source == "round") else None
        for i in range(self._obs_rounds, len(walls)):
            reg_h.record(walls[i])
            if slo is not None:
                for tid in self.metrics[i + 1].get("tids", ()):
                    if tid in self._tenant_cohort:
                        slo.observe(tid, float(walls[i]))
        self._obs_rounds = len(walls)
        edges = sum(int(np.asarray(m["edges"])) for m in self.metrics[1:])
        return {
            "rounds": len(walls),
            "tenants": len(self._tenant_cohort),
            "cohorts": len(self._cohorts),
            # max, not last: tail rounds of uneven streams mask whole
            # cohorts, which would under-report the steady-state cost
            "launches_per_round": max(m["launches"]
                                      for m in self.metrics[1:]),
            "mean_round_ms": (wall_h.mean() or 0.0) * 1e3,
            "p99_round_ms": (wall_h.quantile(0.99) or 0.0) * 1e3,
            "throughput_eps": (float(edges / wall_h.total)
                               if wall_h.total > 0 else 0.0),
            "per_tenant": self.tenant_stats(),
        }
