"""Optimizers built from scratch (no optax): AdamW, Lion, SGD-momentum.

Design points for 1000+-node scale:
  * optimizer state is a pytree congruent to params — under pjit it inherits
    params' NamedShardings, and with the ZeRO-1 rules in
    ``distributed/sharding.py`` the moments additionally shard over the DP
    axes (state_sharding_rules), so un-shardable Adam states never exist;
  * moment dtype is configurable: fp32 (default), bf16, or int8
    (block-quantized with per-block scales, 8-bit-Adam style) — at
    grok-1-314B scale fp32 moments alone exceed HBM, so qint8 moments are a
    first-class feature, not an afterthought;
  * the update is a pure function (state, grads, params) -> (state, params):
    jit/pjit-friendly, donate-able, and testable against a numpy oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig

PyTree = Any
_QBLOCK = 256  # int8 quantization block (elements)


@dataclasses.dataclass(frozen=True)
class OptimConfig(FrozenConfig):
    name: str = "adamw"          # adamw | lion | sgd
    lr: float = 3e-4             # base lr (scaled by the schedule)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9        # sgd
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8
    global_clip: float = 1.0     # 0 disables


# ---------------------------------------------------------------------------
# int8 block quantization for moments
# ---------------------------------------------------------------------------


class QTensor(NamedTuple):
    q: jax.Array        # int8, padded flat (n_blocks * _QBLOCK,)
    scale: jax.Array    # fp32 (n_blocks,)


def _quantize(x: jax.Array) -> QTensor:
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % _QBLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-20)[:, None])
    return QTensor(q.astype(jnp.int8).reshape(-1), scale)


def _dequantize(qt: QTensor, shape, dtype=jnp.float32) -> jax.Array:
    flat = qt.q.astype(jnp.float32).reshape(-1, _QBLOCK) * qt.scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape).astype(dtype)


def _store_moment(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.bfloat16 if dtype == "bfloat16" else jnp.float32)


def _load_moment(m, shape):
    if isinstance(m, QTensor):
        return _dequantize(m, shape)
    return m.astype(jnp.float32)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def _is_decay_param(path: str, leaf) -> bool:
    """No weight decay on norms/biases/1-d params (standard practice)."""
    return leaf.ndim >= 2


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def init_state(cfg: OptimConfig, params: PyTree) -> dict:
    zeros = jax.tree.map(
        lambda p: _store_moment(jnp.zeros(p.shape, jnp.float32),
                                cfg.moment_dtype), params)
    state = {"step": jnp.zeros((), jnp.int32), "m": zeros}
    if cfg.name == "adamw":
        state["v"] = jax.tree.map(
            lambda p: _store_moment(jnp.zeros(p.shape, jnp.float32),
                                    cfg.moment_dtype), params)
    return state


def apply_updates(cfg: OptimConfig, state: dict, grads: PyTree,
                  params: PyTree, lr_scale: jax.Array | float = 1.0):
    """One optimizer step. Returns (new_state, new_params). Pure."""
    if cfg.global_clip > 0:
        grads, _ = clip_by_global_norm(grads, cfg.global_clip)
    step = state["step"] + 1
    lr = cfg.lr * lr_scale

    if cfg.name == "adamw":
        bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        def upd(g, p, m, v):
            gf = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            mf = _load_moment(m, p.shape) * cfg.b1 + (1 - cfg.b1) * gf
            vf = _load_moment(v, p.shape) * cfg.b2 + (1 - cfg.b2) * gf * gf
            mh = mf / bc1
            vh = vf / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if _is_decay_param("", p):
                delta = delta + cfg.weight_decay * pf
            return (pf - lr * delta).astype(p.dtype), \
                _store_moment(mf, cfg.moment_dtype), \
                _store_moment(vf, cfg.moment_dtype)

        out = jax.tree.map(upd, grads, params, state["m"], state["v"],
                           is_leaf=lambda x: isinstance(x, QTensor))
        # tree of (p, m, v) tuples -> three trees
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return {"step": step, "m": new_m, "v": new_v}, new_p

    if cfg.name == "lion":
        def upd(g, p, m):
            gf = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            mf = _load_moment(m, p.shape)
            direction = jnp.sign(cfg.b1 * mf + (1 - cfg.b1) * gf)
            if _is_decay_param("", p):
                direction = direction + cfg.weight_decay * pf
            m_new = cfg.b2 * mf + (1 - cfg.b2) * gf
            return (pf - lr * direction).astype(p.dtype), \
                _store_moment(m_new, cfg.moment_dtype)

        out = jax.tree.map(upd, grads, params, state["m"],
                           is_leaf=lambda x: isinstance(x, QTensor))
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return {"step": step, "m": new_m}, new_p

    if cfg.name == "sgd":
        def upd(g, p, m):
            gf = g.astype(jnp.float32)
            mf = _load_moment(m, p.shape) * cfg.momentum + gf
            return (p.astype(jnp.float32) - lr * mf).astype(p.dtype), \
                _store_moment(mf, cfg.moment_dtype)

        out = jax.tree.map(upd, grads, params, state["m"],
                           is_leaf=lambda x: isinstance(x, QTensor))
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return {"step": step, "m": new_m}, new_p

    raise ValueError(cfg.name)
