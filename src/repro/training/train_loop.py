"""Step-function factory: loss -> grad -> (optional compression) -> optimizer.

``make_train_step`` builds the jit-able pure function the launcher pjits:

    (params, opt_state, batch, step) -> (params, opt_state, metrics)

Features:
  * micro-batch gradient accumulation via lax.scan (bounds activation
    memory AND the blast radius of a preempted worker — see DESIGN.md §4);
  * optional error-feedback int8 gradient compression before the DP
    all-reduce (distributed/compression.py) — the EF residual rides in
    opt_state so the step stays pure;
  * donation-friendly: params/opt_state are returned with identical
    structure so callers can donate them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig
from repro.training import optim as opt_mod
from repro.training.lr_schedule import ScheduleConfig, schedule
from repro.distributed import compression


@dataclasses.dataclass(frozen=True)
class TrainConfig(FrozenConfig):
    optim: opt_mod.OptimConfig = opt_mod.OptimConfig()
    sched: ScheduleConfig = ScheduleConfig()
    grad_accum: int = 1            # micro-batches per step
    compress_grads: bool = False   # int8 + error-feedback DP compression


def make_train_step(loss_fn: Callable, tcfg: TrainConfig):
    """loss_fn(params, batch) -> scalar. Returns step(params, opt_state,
    batch, step_idx) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state, batch, step_idx):
        if tcfg.grad_accum > 1:
            # split the leading batch dim into micro-batches and scan
            def resplit(x):
                b = x.shape[0]
                assert b % tcfg.grad_accum == 0, (b, tcfg.grad_accum)
                return x.reshape(tcfg.grad_accum, b // tcfg.grad_accum,
                                 *x.shape[1:])

            micro = jax.tree.map(resplit, batch)

            def accum(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, g_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / tcfg.grad_accum
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
        else:
            loss, grads = grads_of(params, batch)

        if tcfg.compress_grads:
            residual = opt_state.get("ef_residual")
            grads, residual = compression.ef_int8_roundtrip(grads, residual)
            opt_state = dict(opt_state, ef_residual=residual)

        lr_scale = schedule(tcfg.sched, step_idx)
        inner = {k: v for k, v in opt_state.items() if k != "ef_residual"}
        inner, params = opt_mod.apply_updates(tcfg.optim, inner, grads,
                                              params, lr_scale)
        if "ef_residual" in opt_state:
            inner["ef_residual"] = opt_state["ef_residual"]
        metrics = {"loss": loss, "lr_scale": lr_scale,
                   "grad_norm": opt_mod.global_norm(grads)}
        return params, inner, metrics

    return step


def init_train_state(tcfg: TrainConfig, params):
    state = opt_mod.init_state(tcfg.optim, params)
    if tcfg.compress_grads:
        state["ef_residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state
