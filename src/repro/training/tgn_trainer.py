"""TGN training + knowledge distillation (the paper's §III-A/§VI workflow).

Teacher: TGN-attn (vanilla temporal attention, cosine time encoder), trained
with self-supervised temporal link prediction on the chronological stream.

Students: SAT [+LUT] [+NP(k)], trained with link loss + the Eq.-17 soft
cross-entropy against the FROZEN teacher's attention logits, replayed over
the same stream (teacher and student each maintain their own vertex state;
the neighbor ring-buffer trajectories coincide by construction since buffer
dynamics are parameter-free).

Gradient flow follows the reference TGN implementation: gradients propagate
within a batch (through the GRU memory update and the aggregator), and the
carried vertex state is detached between batches.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import FrozenConfig
from repro.core import distill, tgn
from repro.core.pipeline import build_pipeline
from repro.data import stream as stream_mod
from repro.data.temporal_graph import TemporalGraph
from repro.training import optim as opt_mod


@dataclasses.dataclass(frozen=True)
class TGNTrainConfig(FrozenConfig):
    batch_size: int = 100
    epochs: int = 3
    lr: float = 1e-3
    kd_weight: float = 1.0
    kd_temperature: float = 1.0   # paper sets T=1
    seed: int = 0


def _detach_state(state):
    return jax.tree.map(jax.lax.stop_gradient, state)


def _embed_negatives(pipe, params, aux, state, node_feats, edge_feats,
                     neg_dst, ts):
    h, _, _, _ = pipe.embed(params, aux, state, edge_feats, node_feats,
                            neg_dst, ts)
    return h


# ---------------------------------------------------------------------------
# teacher
# ---------------------------------------------------------------------------


def make_teacher_step(cfg: tgn.TGNConfig, ocfg: opt_mod.OptimConfig,
                      node_feats, edge_feats):
    pipe = build_pipeline(cfg)   # reference stage backends (differentiable)

    def loss_fn(params, state, b):
        src, dst, eid, ts, valid, neg = b
        aux = pipe.prepare(params)   # in-trace: gradients flow through folds
        out = pipe.step(params, aux, state, (src, dst, eid, ts, valid),
                        edge_feats, node_feats)
        neg_emb = _embed_negatives(pipe, params, aux, out.state, node_feats,
                                   edge_feats, neg, ts)
        pos = tgn.link_score(params, out.emb_src, out.emb_dst)
        negs = tgn.link_score(params, out.emb_src, neg_emb)
        w = valid.astype(jnp.float32)
        loss = (jnp.sum(jax.nn.softplus(-pos) * w)
                + jnp.sum(jax.nn.softplus(negs) * w)) / (2 * jnp.maximum(
                    jnp.sum(w), 1))
        return loss, out.state

    @jax.jit
    def step(params, opt_state, state, b):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, b)
        opt_state, params = opt_mod.apply_updates(ocfg, opt_state, grads,
                                                  params)
        return params, opt_state, _detach_state(new_state), loss

    return step


def train_teacher(g: TemporalGraph, cfg: tgn.TGNConfig,
                  tcfg: TGNTrainConfig = TGNTrainConfig()):
    node_feats = (jnp.asarray(g.node_feats)
                  if g.node_feats is not None else None)
    edge_feats = jnp.asarray(g.edge_feats) if g.edge_feats.shape[1] else \
        jnp.zeros((g.n_edges, cfg.f_edge), jnp.float32)
    params = tgn.init_params(jax.random.key(tcfg.seed), cfg)
    ocfg = opt_mod.OptimConfig(name="adamw", lr=tcfg.lr, weight_decay=0.0)
    opt_state = opt_mod.init_state(ocfg, params)
    step = make_teacher_step(cfg, ocfg, node_feats, edge_feats)

    train_sl, val_sl, _ = stream_mod.chronological_split(g)
    losses = []
    for epoch in range(tcfg.epochs):
        state = tgn.init_state(cfg)
        for batch in stream_mod.fixed_count(g, tcfg.batch_size,
                                            window=train_sl,
                                            seed=tcfg.seed + epoch):
            b = tuple(jnp.asarray(x) for x in batch)
            params, opt_state, state, loss = step(params, opt_state, state,
                                                  b)
            losses.append(float(loss))
    return params, losses


# ---------------------------------------------------------------------------
# student distillation
# ---------------------------------------------------------------------------


def make_distill_step(s_cfg: tgn.TGNConfig, t_cfg: tgn.TGNConfig,
                      ocfg: opt_mod.OptimConfig, tcfg: TGNTrainConfig,
                      node_feats, edge_feats):
    # teacher and student are two compositions of the same stage registry —
    # the teacher replays frozen through its own pipeline.
    t_pipe = build_pipeline(t_cfg)
    s_pipe = build_pipeline(s_cfg)

    def loss_fn(s_params, t_params, s_state, t_state, b):
        src, dst, eid, ts, valid, neg = b
        batch = (src, dst, eid, ts, valid)
        t_out = t_pipe.step(t_params, t_pipe.prepare(t_params), t_state,
                            batch, edge_feats, node_feats)
        s_aux = s_pipe.prepare(s_params)
        s_out = s_pipe.step(s_params, s_aux, s_state, batch, edge_feats,
                            node_feats)
        neg_emb = _embed_negatives(s_pipe, s_params, s_aux, s_out.state,
                                   node_feats, edge_feats, neg, ts)
        pos = tgn.link_score(s_params, s_out.emb_src, s_out.emb_dst)
        negs = tgn.link_score(s_params, s_out.emb_src, neg_emb)
        total, parts = distill.distill_loss(
            s_out.attn_logits, t_out.attn_logits,
            s_out.nbr_valid & t_out.nbr_valid, pos, negs,
            temperature=tcfg.kd_temperature, kd_weight=tcfg.kd_weight)
        return total, (s_out.state, t_out.state, parts)

    @jax.jit
    def step(s_params, t_params, opt_state, s_state, t_state, b):
        (loss, (s_new, t_new, parts)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(s_params, t_params, s_state, t_state, b)
        opt_state, s_params = opt_mod.apply_updates(ocfg, opt_state, grads,
                                                    s_params)
        return s_params, opt_state, _detach_state(s_new), \
            _detach_state(t_new), parts

    return step


def distill_student(g: TemporalGraph, teacher_params: dict,
                    t_cfg: tgn.TGNConfig, s_cfg: tgn.TGNConfig,
                    tcfg: TGNTrainConfig = TGNTrainConfig()):
    node_feats = (jnp.asarray(g.node_feats)
                  if g.node_feats is not None else None)
    edge_feats = jnp.asarray(g.edge_feats) if g.edge_feats.shape[1] else \
        jnp.zeros((g.n_edges, s_cfg.f_edge), jnp.float32)
    # LUT boundaries fitted on the empirical train dt distribution (§III-C)
    train_sl, _, _ = stream_mod.chronological_split(g)
    dt_samples = _dt_samples(g, train_sl)
    s_params = tgn.init_params(jax.random.key(tcfg.seed + 7), s_cfg,
                               dt_samples=dt_samples)
    ocfg = opt_mod.OptimConfig(name="adamw", lr=tcfg.lr, weight_decay=0.0)
    opt_state = opt_mod.init_state(ocfg, s_params)
    step = make_distill_step(s_cfg, t_cfg, ocfg, tcfg, node_feats,
                             edge_feats)

    kd_losses = []
    for epoch in range(tcfg.epochs):
        s_state = tgn.init_state(s_cfg)
        t_state = tgn.init_state(t_cfg)
        for batch in stream_mod.fixed_count(g, tcfg.batch_size,
                                            window=train_sl,
                                            seed=tcfg.seed + 31 + epoch):
            b = tuple(jnp.asarray(x) for x in batch)
            s_params, opt_state, s_state, t_state, parts = step(
                s_params, teacher_params, opt_state, s_state, t_state, b)
            kd_losses.append({k: float(v) for k, v in parts.items()})
    return s_params, kd_losses


def _dt_samples(g: TemporalGraph, sl: slice) -> np.ndarray:
    """Empirical inter-event time deltas per node over the train window —
    the LUT bucketing distribution (paper Fig. 1)."""
    last = {}
    out = []
    for i in range(sl.start or 0, sl.stop):
        for v in (int(g.src[i]), int(g.dst[i])):
            t = float(g.ts[i])
            if v in last:
                out.append(t - last[v])
            last[v] = t
    return np.asarray(out if out else [1.0], np.float64)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------


def evaluate_ap(params: dict, cfg: tgn.TGNConfig, g: TemporalGraph,
                window: slice, batch_size: int = 100,
                warm_window: slice | None = None, seed: int = 123) -> float:
    """Chronological replay AP over ``window`` (state warmed over
    ``warm_window`` first, as in transductive TGN evaluation)."""
    node_feats = (jnp.asarray(g.node_feats)
                  if g.node_feats is not None else None)
    edge_feats = jnp.asarray(g.edge_feats) if g.edge_feats.shape[1] else \
        jnp.zeros((g.n_edges, cfg.f_edge), jnp.float32)
    pipe = build_pipeline(cfg)

    @jax.jit
    def run(state, b):
        src, dst, eid, ts, valid, neg = b
        aux = pipe.prepare(params)
        out = pipe.step(params, aux, state, (src, dst, eid, ts, valid),
                        edge_feats, node_feats)
        neg_emb = _embed_negatives(pipe, params, aux, out.state, node_feats,
                                   edge_feats, neg, ts)
        pos = tgn.link_score(params, out.emb_src, out.emb_dst)
        negs = tgn.link_score(params, out.emb_src, neg_emb)
        return out.state, pos, negs

    state = tgn.init_state(cfg)
    if warm_window is not None:
        for batch in stream_mod.fixed_count(g, batch_size, window=warm_window,
                                            seed=seed):
            b = tuple(jnp.asarray(x) for x in batch)
            state, _, _ = run(state, b)

    pos_all, neg_all = [], []
    for batch in stream_mod.fixed_count(g, batch_size, window=window,
                                        seed=seed):
        b = tuple(jnp.asarray(x) for x in batch)
        state, pos, negs = run(state, b)
        m = batch.valid
        pos_all.append(np.asarray(pos)[m])
        neg_all.append(np.asarray(negs)[m])

    ap = distill.average_precision(jnp.asarray(np.concatenate(pos_all)),
                                   jnp.asarray(np.concatenate(neg_all)))
    return float(ap)
