"""Learning-rate schedules as pure step -> scale functions (scale multiplies
OptimConfig.lr)."""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.utils import FrozenConfig


@dataclasses.dataclass(frozen=True)
class ScheduleConfig(FrozenConfig):
    name: str = "warmup_cosine"   # warmup_cosine | warmup_linear | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_ratio: float = 0.1        # floor as a fraction of peak


def schedule(cfg: ScheduleConfig, step):
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.name == "constant":
        return warm
    frac = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.name == "warmup_linear":
        decay = 1.0 - (1.0 - cfg.min_ratio) * frac
    else:  # warmup_cosine
        decay = cfg.min_ratio + (1.0 - cfg.min_ratio) * 0.5 * (
            1.0 + jnp.cos(math.pi * frac))
    return warm * decay
