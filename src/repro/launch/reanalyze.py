"""Refresh dry-run JSONs from saved HLO texts with the CURRENT analyzer —
accounting improvements shouldn't force 80 recompiles.

    PYTHONPATH=src python -m repro.launch.reanalyze results/dryrun
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro.core import perf_model
from repro.launch import hlo_analysis


def refresh(out_dir: str) -> None:
    for jpath in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(jpath) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        base = os.path.basename(jpath)[:-5]
        hpath = os.path.join(out_dir, "hlo", base + ".hlo.gz")
        if not os.path.exists(hpath):
            print(f"[skip] {base}: no saved HLO")
            continue
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        stats = hlo_analysis.analyze(hlo)
        rl = perf_model.roofline(stats["flops"], stats["bytes"],
                                 stats["collective_bytes"], 1)
        r["per_device"] = {
            "flops": stats["flops"], "bytes": stats["bytes"],
            "collective_bytes": stats["collective_bytes"],
            "collectives_by_op": stats["collectives_by_op"],
            "collectives_count": stats["collectives_count"],
            "bytes_by_kind": stats["bytes_by_kind"],
            "top_bytes_ops": stats["top_bytes_ops"],
        }
        r["roofline"] = {"compute_s": rl.compute_s, "memory_s": rl.memory_s,
                         "collective_s": rl.collective_s, "bound": rl.bound}
        r["useful_compute_ratio"] = (r["model_flops_per_device"]
                                     / max(stats["flops"], 1.0))
        with open(jpath, "w") as f:
            json.dump(r, f, indent=2)
        print(f"[ok] {base}: mem={rl.memory_s:.3f}s "
              f"coll={rl.collective_s:.3f}s comp={rl.compute_s:.3f}s "
              f"-> {rl.bound}")


if __name__ == "__main__":
    refresh(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
