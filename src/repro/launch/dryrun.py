import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices let ``make_production_mesh`` build the real (2,16,16)
topology; ``.lower(...).compile()`` runs the full GSPMD partitioner and the
backend; ``memory_analysis()`` proves the per-device footprint fits a v5e
(16 GB HBM); the compiled HLO feeds the trip-count-aware roofline analyzer
(hlo_analysis.py).

Usage:
    python -m repro.launch.dryrun --arch gemma3_12b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multipod \
        --out results/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis
from repro.models import lm_common
from repro.distributed import sharding as shd
from repro.training import optim as opt_mod
from repro.training.lr_schedule import ScheduleConfig, schedule
from repro.core import perf_model


def _shardify(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _opt_state_specs(opt_abs, params_abs, mode, n_model):
    z1 = shd.zero1_specs(params_abs, mode, n_model)
    p_struct = jax.tree.structure(params_abs)
    out = {"step": P()}
    for k in ("m", "v"):
        if k not in opt_abs:
            continue
        if jax.tree.structure(opt_abs[k]) == p_struct:
            out[k] = z1
        else:  # QTensor moments: flat int8 payloads + scales. Payload
            # length is always a _QBLOCK (=256) multiple -> shard over the
            # full (data x model) = 256 chips; scales over data when they
            # divide. (Leaving these data-only once cost 38 GiB/device on
            # grok-314B — EXPERIMENTS.md §Dry-run.)
            def qspec(l):
                n = l.shape[0] if l.ndim == 1 else 0
                if n and n % 256 == 0:
                    return P(("data", "model"))
                if n and n % 16 == 0 and n >= 16:
                    return P("data")
                return P()
            out[k] = jax.tree.map(qspec, opt_abs[k])
    return out


def build_train_cell(spec, cfg, mesh, seq_len, global_batch):
    """-> (fn, abstract args, in_shardings, out_shardings, donate)."""
    mode = spec.shard_mode
    n_model = mesh.shape["model"]
    ocfg = opt_mod.OptimConfig(moment_dtype=spec.moment_dtype)
    scfg = ScheduleConfig()

    params_abs = lm_common.abstract_params(cfg)
    opt_abs = jax.eval_shape(lambda p: opt_mod.init_state(ocfg, p),
                             params_abs)
    batch_abs = lm_common.train_inputs(cfg, global_batch, seq_len)

    p_specs = shd.param_specs(params_abs, mode, n_model)
    o_specs = _opt_state_specs(opt_abs, params_abs, mode, n_model)
    b_specs = jax.tree.map(
        lambda l: shd.batch_spec(mesh, global_batch, len(l.shape)),
        batch_abs)

    accum = spec.grad_accum

    def train_step(params, opt_state, batch, step_idx):
        def loss_of(p, b):
            return lm_common.loss_fn(p, cfg, b)

        if accum > 1:
            def resplit(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro = jax.tree.map(resplit, batch)

            def body(carry, mb):
                l_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (l_acc + l, jax.tree.map(jnp.add, g_acc, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        lr_scale = schedule(scfg, step_idx)
        opt_state, params = opt_mod.apply_updates(ocfg, opt_state, grads,
                                                  params, lr_scale)
        return params, opt_state, loss

    in_sh = (_shardify(mesh, p_specs), _shardify(mesh, o_specs),
             _shardify(mesh, b_specs), NamedSharding(mesh, P()))
    out_sh = (_shardify(mesh, p_specs), _shardify(mesh, o_specs),
              NamedSharding(mesh, P()))
    args = (params_abs, opt_abs, batch_abs,
            jax.ShapeDtypeStruct((), jnp.int32))
    return train_step, args, in_sh, out_sh, (0, 1)


def build_decode_cell(spec, cfg, mesh, seq_len, global_batch,
                      params_bf16: bool = False):
    mode = spec.shard_mode
    n_model = mesh.shape["model"]
    params_abs = lm_common.abstract_params(cfg)
    if params_bf16:  # §Perf O1: serving weights stored bf16
        params_abs = jax.tree.map(
            lambda l: (jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
                       if l.dtype == jnp.float32 else l), params_abs)
    batch_abs = lm_common.decode_inputs(cfg, global_batch, seq_len)

    p_specs = shd.param_specs(params_abs, mode, n_model)
    tok_spec = shd.batch_spec(mesh, global_batch, 2)
    cache_specs = jax.tree.map(
        lambda l: shd.cache_spec(mesh, l.shape, global_batch),
        batch_abs["caches"])
    b_specs = {"token": tok_spec, "caches": cache_specs}

    def serve_step(params, batch):
        return lm_common.decode_fn(params, cfg, batch)

    logits_spec = shd.batch_spec(mesh, global_batch, 2)
    in_sh = (_shardify(mesh, p_specs), _shardify(mesh, b_specs))
    out_sh = (NamedSharding(mesh, logits_spec), _shardify(mesh, cache_specs))
    args = (params_abs, batch_abs)
    return serve_step, args, in_sh, out_sh, (1,)


def build_prefill_cell(spec, cfg, mesh, seq_len, global_batch):
    mode = spec.shard_mode
    n_model = mesh.shape["model"]
    params_abs = lm_common.abstract_params(cfg)
    batch_abs = lm_common.train_inputs(cfg, global_batch, seq_len)
    batch_abs.pop("targets")

    p_specs = shd.param_specs(params_abs, mode, n_model)
    b_specs = jax.tree.map(
        lambda l: shd.batch_spec(mesh, global_batch, len(l.shape)),
        batch_abs)

    fam = lm_common.family_of(cfg)
    mod = lm_common.FAMILIES[fam]

    def prefill_step(params, batch):
        if fam == "whisper":
            logits, _ = mod.prefill(params, cfg, batch["frames"],
                                    batch["tokens"])
        elif fam == "vision_lm":
            logits, _ = mod.prefill(params, cfg, batch["tokens"],
                                    batch["vision"])
        else:
            logits, _ = mod.prefill(params, cfg, batch["tokens"])
        return logits

    in_sh = (_shardify(mesh, p_specs), _shardify(mesh, b_specs))
    out_sh = NamedSharding(mesh, shd.batch_spec(mesh, global_batch, 2))
    args = (params_abs, batch_abs)
    return prefill_step, args, in_sh, out_sh, ()


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             save_hlo: str | None = None, override_cfg=None,
             extra_rules: dict | None = None,
             params_bf16: bool = False) -> dict:
    spec = configs.get(arch)
    cfg = override_cfg or spec.config()
    seq_len, global_batch, kind = configs.SHAPES[shape]

    if shape == "long_500k" and not lm_common.supports_long_context(cfg):
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skip(full-attn)",
                "note": "pure full-attention arch; see DESIGN.md §5"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = shd.dp_axes(mesh)
    rules = {"carry": P(dp, "model", None)} if kind == "train" else {}
    if extra_rules:
        rules.update(extra_rules)
    shd.set_activation_rules(rules)

    t0 = time.time()
    with mesh:
        if kind == "train":
            fn, args, in_sh, out_sh, donate = build_train_cell(
                spec, cfg, mesh, seq_len, global_batch)
        elif kind == "decode":
            fn, args, in_sh, out_sh, donate = build_decode_cell(
                spec, cfg, mesh, seq_len, global_batch,
                params_bf16=params_bf16)
        else:
            fn, args, in_sh, out_sh, donate = build_prefill_cell(
                spec, cfg, mesh, seq_len, global_batch)

        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    if save_hlo:
        import gzip
        with gzip.open(save_hlo, "wt") as f:
            f.write(hlo)
    stats = hlo_analysis.analyze(hlo)

    n_chips = mesh.size
    tokens = global_batch * (seq_len if kind != "decode" else 1)
    mf = perf_model.model_flops(cfg.n_active_params, tokens,
                                training=(kind == "train"))
    rl = perf_model.roofline(stats["flops"], stats["bytes"],
                             stats["collective_bytes"], 1)

    result = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok",
        "kind": kind, "n_chips": n_chips,
        "seq_len": seq_len, "global_batch": global_batch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed")},
        "per_device": {
            "flops": stats["flops"], "bytes": stats["bytes"],
            "collective_bytes": stats["collective_bytes"],
            "collectives_by_op": stats["collectives_by_op"],
            "collectives_count": stats["collectives_count"],
        },
        "roofline": {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "bound": rl.bound,
        },
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_compute_ratio": (mf / n_chips) / max(stats["flops"], 1.0),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output directory")
    args = ap.parse_args()

    archs = configs.all_archs() if args.arch == "all" else [args.arch]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]

    # sweep-level observability: lower/compile walls as streaming
    # histograms + ok/skip/fail counters, one snapshot at the end
    from repro.obs import MetricsRegistry
    obs = MetricsRegistry()

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}/{shape}/{'2pod' if mp else '1pod'}"
                out_path = None
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    out_path = os.path.join(
                        args.out, f"{arch}__{shape}__"
                        f"{'2pod' if mp else '1pod'}.json")
                    if os.path.exists(out_path):
                        print(f"[skip cached] {tag}")
                        with open(out_path) as f:
                            results.append(json.load(f))
                        continue
                print(f"[dryrun] {tag} ...", flush=True)
                hlo_path = None
                if args.out:
                    hlo_dir = os.path.join(args.out, "hlo")
                    os.makedirs(hlo_dir, exist_ok=True)
                    hlo_path = os.path.join(
                        hlo_dir, f"{arch}__{shape}__"
                        f"{'2pod' if mp else '1pod'}.hlo.gz")
                try:
                    r = run_cell(arch, shape, multi_pod=mp,
                                 save_hlo=hlo_path)
                except Exception as e:
                    r = {"arch": arch, "shape": shape, "multi_pod": mp,
                         "status": f"FAIL: {type(e).__name__}: {e}",
                         "traceback": traceback.format_exc()}
                results.append(r)
                status = r["status"]
                obs.counter("dryrun." + ("ok" if status == "ok" else
                                         "skip" if status.startswith("skip")
                                         else "fail")).inc()
                extra = ""
                if status == "ok":
                    obs.histogram("dryrun.lower_s").record(r["lower_s"])
                    obs.histogram("dryrun.compile_s").record(r["compile_s"])
                    pk = r["memory"]["peak_bytes"]
                    extra = (f" peak={pk/2**30:.2f}GiB"
                             f" bound={r['roofline']['bound']}"
                             f" compile={r['compile_s']}s")
                print(f"[done] {tag}: {status}{extra}", flush=True)
                if out_path:
                    with open(out_path, "w") as f:
                        json.dump(r, f, indent=2)

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"].startswith("skip"))
    n_fail = len(results) - n_ok - n_skip
    print(f"\n=== dry-run: {n_ok} ok, {n_skip} skip, {n_fail} FAIL "
          f"of {len(results)} cells ===")
    lower = obs.get("dryrun.lower_s")
    if lower is not None and lower.count:
        comp = obs.histogram("dryrun.compile_s")
        print(f"walls: lower p50={lower.quantile(0.5):.1f}s "
              f"max={lower.vmax:.1f}s; compile "
              f"p50={comp.quantile(0.5):.1f}s max={comp.vmax:.1f}s "
              f"over {lower.count} fresh cells")
    if n_fail:
        for r in results:
            if r["status"].startswith("FAIL"):
                print(f"  {r['arch']}/{r['shape']}: {r['status']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
