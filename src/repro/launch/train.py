"""End-to-end training drivers.

Two modes:

  * ``--mode tgn``  — the paper's workflow: train the TGN-attn teacher on a
    synthetic temporal-graph stream, then distill the SAT+LUT+NP students
    (Eq. 17), evaluating AP for every Table-II variant. Checkpoints each
    phase (fault-tolerant resume).

  * ``--mode lm``   — pretrain an assigned-architecture smoke config (or a
    ~100M custom config with --preset 100m) for a few hundred steps on a
    synthetic token stream, with checkpoint/restart: kill the process at
    any step and rerun — it resumes from the newest valid checkpoint, and
    the deterministic data order makes the resumed run bitwise-consistent
    with an uninterrupted one (tested in tests/test_checkpoint.py).

Examples:
    PYTHONPATH=src python -m repro.launch.train --mode tgn --edges 4000
    PYTHONPATH=src python -m repro.launch.train --mode lm \
        --arch qwen3_8b --steps 100 --ckpt /tmp/lm_ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_tgn(args) -> dict:
    from repro.core import tgn
    from repro.data import temporal_graph as tgd, stream
    from repro.training import tgn_trainer as TT
    from repro.distributed import checkpoint as ckpt

    g = tgd.DATASETS[args.dataset](n_edges=args.edges)
    base = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges,
                f_edge=g.cfg.f_edge, f_feat=g.cfg.f_feat,
                f_mem=args.f_mem, f_time=args.f_mem, f_emb=args.f_mem,
                m_r=10)
    tcfg = TT.TGNTrainConfig(batch_size=args.batch, epochs=args.epochs)
    tr, va, te = stream.chronological_split(g)

    t_cfg = tgn.TGNConfig(**base)
    t0 = time.time()
    t_params, losses = TT.train_teacher(g, t_cfg, tcfg)
    ap_teacher = TT.evaluate_ap(t_params, t_cfg, g, te, warm_window=slice(
        0, va.stop))
    print(f"[teacher] AP={ap_teacher:.4f} loss {losses[0]:.3f}->"
          f"{losses[-1]:.3f} ({time.time()-t0:.0f}s)")
    if args.ckpt:
        ckpt.save(args.ckpt + "/teacher", 0, t_params,
                  meta={"ap": ap_teacher})

    results = {"Baseline": ap_teacher}
    variants = [("+SAT", dict(attention="sat", encoder="cosine")),
                ("+LUT", dict(attention="sat", encoder="lut")),
                ("+NP(L)", dict(attention="sat", encoder="lut", prune_k=6)),
                ("+NP(M)", dict(attention="sat", encoder="lut", prune_k=4)),
                ("+NP(S)", dict(attention="sat", encoder="lut", prune_k=2))]
    for name, kw in variants:
        s_cfg = tgn.TGNConfig(**base, **kw)
        t0 = time.time()
        s_params, _ = TT.distill_student(g, t_params, t_cfg, s_cfg, tcfg)
        ap = TT.evaluate_ap(s_params, s_cfg, g, te,
                            warm_window=slice(0, va.stop))
        results[name] = ap
        print(f"[{name}] AP={ap:.4f} (diff {ap-ap_teacher:+.4f}) "
              f"({time.time()-t0:.0f}s)")
        if args.ckpt:
            ckpt.save(args.ckpt + f"/student_{name}", 0, s_params,
                      meta={"ap": ap})
    return results


def run_lm(args) -> dict:
    from repro import configs
    from repro.models import lm_common
    from repro.training import optim as opt_mod, train_loop as TL
    from repro.training.lr_schedule import ScheduleConfig
    from repro.distributed import checkpoint as ckpt, overlap

    if args.preset == "100m":
        from repro.models.transformer import LMConfig
        cfg = LMConfig(arch="lm100m", n_layers=12, d_model=768, n_heads=12,
                       n_kv_heads=12, d_head=64, d_ff=3072, vocab=32_000,
                       dtype="float32", remat="none", q_block=128,
                       k_block=128, loss_chunk=128)
    else:
        cfg = configs.get(args.arch).smoke_config()
    print(f"[lm] arch={getattr(cfg, 'arch', args.arch)} "
          f"params~{cfg.n_params/1e6:.1f}M")

    params = lm_common.init_params(jax.random.key(0), cfg)
    tcfg = TL.TrainConfig(
        optim=opt_mod.OptimConfig(lr=3e-4),
        sched=ScheduleConfig(warmup_steps=20, total_steps=args.steps),
        grad_accum=args.grad_accum)
    opt_state = TL.init_train_state(tcfg, params)
    step_fn = jax.jit(TL.make_train_step(
        lambda p, b: lm_common.loss_fn(p, cfg, b), tcfg))

    start = 0
    if args.ckpt:
        latest = ckpt.latest_step(args.ckpt)
        if latest is not None:
            tree = {"params": params, "opt": opt_state}
            tree, meta = ckpt.restore(args.ckpt, tree)
            params, opt_state = tree["params"], tree["opt"]
            start = latest
            print(f"[lm] resumed from step {start}")

    # deterministic synthetic data: step index seeds the batch
    def batches():
        for i in range(start, args.steps):
            rng = np.random.RandomState(1000 + i)
            toks = rng.randint(0, cfg.vocab,
                               size=(args.batch, args.seq)).astype(np.int32)
            batch = {"tokens": jnp.asarray(toks),
                     "targets": jnp.asarray(np.roll(toks, -1, axis=1))}
            if lm_common.family_of(cfg) == "whisper":
                batch["frames"] = jnp.asarray(
                    rng.randn(args.batch, cfg.n_frames, cfg.d_model)
                    .astype(np.float32))
            if lm_common.family_of(cfg) == "vision_lm":
                batch["vision"] = jnp.asarray(
                    rng.randn(args.batch, cfg.n_patches, cfg.d_model)
                    .astype(np.float32))
            yield i, batch

    losses = []
    t0 = time.time()
    saver = ckpt.AsyncCheckpointer(args.ckpt) if args.ckpt else None
    for i, batch in overlap.prefetch(batches(), 2, device_put=lambda x: x):
        params, opt_state, metrics = step_fn(params, opt_state, batch, i)
        losses.append(float(metrics["loss"]))
        if (i + 1) % args.log_every == 0:
            tok_s = args.batch * args.seq * args.log_every / (
                time.time() - t0)
            print(f"step {i+1}: loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"tok/s={tok_s:.0f}")
            t0 = time.time()
        if saver and (i + 1) % args.ckpt_every == 0:
            saver.save(i + 1, {"params": params, "opt": opt_state},
                       meta={"loss": losses[-1]})
    if saver:
        saver.wait()
    print(f"[lm] final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return {"losses": losses}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("tgn", "lm"), default="tgn")
    # tgn
    ap.add_argument("--dataset", default="wikipedia",
                    choices=("wikipedia", "reddit", "gdelt"))
    ap.add_argument("--edges", type=int, default=4000)
    ap.add_argument("--f-mem", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--batch", type=int, default=100)
    # lm
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--preset", default=None, choices=(None, "100m"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    if args.mode == "tgn":
        run_tgn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
