"""Serving drivers.

``--mode tgn``: stream a synthetic temporal graph through the optimized
StreamingEngine (Pallas kernels, prune-then-fetch, LUT, chronological
commit) and report latency/throughput — the deployment the paper targets.
With ``--tenants N`` (or ``--tenant-variants``) the stream is split across
N concurrent tenants served by the multi-tenant SessionManager: one
vmapped launch per cohort per round, per-tenant states isolated.

``--mode lm``: batched prefill+decode generation with a reduced-config LM.

Examples:
    PYTHONPATH=src python -m repro.launch.serve --mode tgn --edges 4000
    PYTHONPATH=src python -m repro.launch.serve --mode tgn --tenants 4
    PYTHONPATH=src python -m repro.launch.serve --mode tgn \\
        --tenant-variants sat+lut+np4,sat+lut+np4+reservoir
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen3_8b
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def run_tgn(args):
    from repro.core import tgn
    from repro.core.pipeline import variant_config
    from repro.data import temporal_graph as tgd, stream
    from repro.serving.engine import EngineConfig, StreamingEngine
    from repro.serving.session import SessionManager

    g = tgd.DATASETS[args.dataset](n_edges=args.edges)
    cfg = variant_config(
        args.variant,
        n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=g.cfg.f_edge,
        f_feat=g.cfg.f_feat, f_mem=args.f_mem, f_time=args.f_mem,
        f_emb=args.f_mem, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    node_feats = g.node_feats
    edge_feats = (jnp.asarray(g.edge_feats) if g.edge_feats.shape[1] else
                  jnp.zeros((g.n_edges, cfg.f_edge), jnp.float32))

    tenant_variants = ([v for v in args.tenant_variants.split(",") if v]
                       if args.tenant_variants else
                       [args.variant] * args.tenants)
    if args.tenant_variants or args.tenants > 1:
        # multi-tenant: split the stream into one contiguous feed per
        # tenant; same-variant tenants share one vmapped launch per round.
        mgr = SessionManager(params, edge_feats, node_feats, model=cfg,
                             use_kernels=True)
        tids = [mgr.add_tenant(v) for v in tenant_variants]
        print("session cohorts:", {v: i["tenants"]
                                   for v, i in mgr.describe().items()})
        span = g.n_edges // len(tids)
        streams = {tid: stream.fixed_count(
            g, args.batch, window=slice(i * span, (i + 1) * span))
            for i, tid in enumerate(tids)}
        for _batches, _outs in mgr.run(streams):
            pass
        print("session summary:", mgr.summary())
        return

    engine = StreamingEngine(EngineConfig(model=cfg), params, edge_feats,
                             node_feats)
    print("engine stages:", engine.describe())
    if args.window_s:
        batches = stream.time_window(g, args.window_s, args.batch)
    else:
        batches = stream.fixed_count(g, args.batch)
    for _batch, _out in engine.run(batches):
        pass
    print("engine summary:", engine.summary())


def run_lm(args):
    from repro import configs
    from repro.models import lm_common
    from repro.serving import lm_serve

    cfg = configs.get(args.arch).smoke_config()
    params = lm_common.init_params(jax.random.key(0), cfg)
    prompts = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, size=(args.batch, 8)),
        jnp.int32)
    out = lm_serve.generate(params, cfg, prompts,
                            lm_serve.ServeConfig(
                                max_new_tokens=args.new_tokens,
                                temperature=args.temperature))
    print(f"generated {out['tokens'].shape}; "
          f"prefill {out['prefill_s']*1e3:.1f}ms, "
          f"decode {out['decode_s_per_tok']*1e3:.2f}ms/token")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("tgn", "lm"), default="tgn")
    ap.add_argument("--dataset", default="wikipedia",
                    choices=("wikipedia", "reddit", "gdelt"))
    ap.add_argument("--edges", type=int, default=4000)
    ap.add_argument("--f-mem", type=int, default=32)
    ap.add_argument("--variant", default="sat+lut+np4",
                    help="pipeline-registry variant spec (e.g. teacher, "
                         "+NP(M), sat+lut+np2, sat+lut+np4+reservoir)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="serve N concurrent tenant streams through the "
                         "multi-tenant SessionManager (each gets 1/N of "
                         "the edge stream)")
    ap.add_argument("--tenant-variants", default="",
                    help="comma-separated per-tenant variant specs "
                         "(overrides --tenants; attention+encoder must "
                         "match --variant, sampler/pruning may differ)")
    ap.add_argument("--batch", type=int, default=200)
    ap.add_argument("--window-s", type=float, default=0.0)
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    (run_tgn if args.mode == "tgn" else run_lm)(args)


if __name__ == "__main__":
    main()
