"""Serving drivers.

``--mode tgn``: stream a synthetic temporal graph through the optimized
StreamingEngine (Pallas kernels, prune-then-fetch, LUT, chronological
commit) and report latency/throughput — the deployment the paper targets.
With ``--tenants N`` (or ``--tenant-variants``) the stream is split across
N concurrent tenants served by the multi-tenant SessionManager: the whole
mixed-cohort round is ONE coalesced compiled launch fed by in-place host
staging (``--per-cohort`` restores the one-launch-per-cohort baseline),
per-tenant states isolated.

``--mesh`` places the fleet on the sharded tenant fabric
(serving/cluster.py): stacked tenant states and batch inputs shard over
the mesh's ``tenant`` (and optional ``vertex``) axis, trajectories
bitwise-identical to the unsharded session. ``--snapshot-dir`` snapshots
every tenant's VertexState (atomic, crc-checked) every
``--snapshot-every`` rounds and at exit; ``--restore`` resumes any tenant
snapshotted there instead of starting it fresh — including onto a
different mesh shape.

``--listen HOST:PORT`` swaps the offline replay for the ONLINE serving
front-end (serving/frontend.py): a newline-delimited-JSON endpoint
accepting per-tenant edge events, micro-batched into coalesced rounds
under a latency deadline, with live tenant attach/detach over the wire
landing in the compiled round without a recompile (serving/admission.py
capacity classes). See docs/SERVING.md for the protocol.

Observability (both tgn paths): ``--slo-ms`` tracks per-tenant SLO burn
against a latency target, ``--metrics-every`` prints unified
metrics-registry snapshots mid-run, and ``--trace-out``/``--trace-every``
export a sampled span trace of the round loop (Chrome/Perfetto JSON or
JSONL) — see docs/OBSERVABILITY.md.

``--mode lm``: batched prefill+decode generation with a reduced-config LM.

Examples:
    PYTHONPATH=src python -m repro.launch.serve --mode tgn --edges 4000
    PYTHONPATH=src python -m repro.launch.serve --mode tgn --tenants 2 \\
        --listen 127.0.0.1:8471 --deadline-ms 5
    PYTHONPATH=src python -m repro.launch.serve --mode tgn --tenants 4
    PYTHONPATH=src python -m repro.launch.serve --mode tgn \\
        --tenant-variants sat+lut+np4,sat+lut+np4+reservoir
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python -m repro.launch.serve --mode tgn --tenants 8 --mesh tenant=8 \\
        --snapshot-dir /tmp/fleet --snapshot-every 5
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen3_8b
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


class _SnapshotHooks:
    """--snapshot-dir plumbing: periodic fleet snapshots + --restore.

    Periodic (``--snapshot-every``) saves go through a bounded per-tenant
    background writer (``cluster.TenantSnapshotWriter``): the round loop
    only captures device-array references, the D2H gather and the atomic
    commit run on worker threads, and a tenant whose previous snapshot is
    still being written is skipped that cadence — a snapshot round no
    longer stalls the fleet. The exit save is synchronous (drain the
    writer, then write every tenant once more) so shutdown is durable.
    """

    def __init__(self, mgr, args, journal=None):
        from repro.core import pipeline
        from repro.serving import cluster
        self.cluster = cluster
        self.pipeline = pipeline
        self.mgr = mgr
        self.root = args.snapshot_dir
        self.do_restore = args.restore
        self.available = cluster.list_snapshots(self.root)
        self.base_step = {}          # tid -> step its trajectory resumed at
        self.writer = cluster.TenantSnapshotWriter(self.root)
        #: the fleet's EventJournal or None. Armed, every snapshot
        #: manifest records the tenant's replay cursor, restores replay
        #: the WAL suffix (lossless resume), and the exit save
        #: truncates the WAL against the oldest retained snapshot.
        self.journal = journal
        self.floor = {}              # tid -> WAL anchor step (gc floor)

    def _meta(self, tid):
        if self.journal is None:
            return None
        return {"journal": self.journal.cursor(tid)}

    def restore(self, variant, name):
        """Revive ``name`` from disk if --restore and a snapshot exists
        (returns the tenant id) else None (caller adds it fresh)."""
        if not (self.do_restore and name in self.available):
            return None
        meta = self.cluster.snapshot_meta(self.root, name)
        want = self.pipeline.variant_name(
            self.pipeline.resolve_variant(variant))
        if want != meta["variant"]:
            raise ValueError(
                f"tenant {name!r} was snapshotted as {meta['variant']!r} "
                f"but this run requests {want!r} — a restored trajectory "
                "keeps its policy; drop the conflicting "
                "--variant/--tenant-variants entry or point --snapshot-dir "
                "at a fresh directory")
        tid = self.cluster.restore_tenant(self.mgr, self.root, name,
                                          journal=self.journal)
        base, replayed = self.available[name], 0
        if self.journal is not None \
                and self.journal.last_replay is not None:
            # the WAL replay advanced the trajectory past the snapshot:
            # the resumed stream window starts after the replayed rounds
            replayed = self.journal.last_replay.rounds
            base += replayed
        self.base_step[tid] = base
        print(f"restored tenant {tid!r} ({meta['variant']}) from "
              f"{self.root} step {self.available[name]}"
              + (f" + {replayed} journal round(s)" if replayed else ""))
        return tid

    def save(self, rounds):
        # periodic cadence: overlap snapshot IO with the serving rounds
        # (bounded: one in-flight write per tenant, stragglers skipped).
        # Quarantined tenants are excluded — their state is suspect, and
        # persisting it would poison the very snapshot the guard's
        # auto-restore falls back to.
        for tid in self.mgr.tenants:
            if self.mgr.is_quarantined(tid):
                continue
            self.writer.submit(self.mgr, tid,
                               step=self.base_step.get(tid, 0) + rounds,
                               extra_meta=self._meta(tid),
                               keep_floor=self.floor.get(tid))

    def save_final(self, rounds):
        # steps continue from each restored trajectory's snapshot, so a
        # resumed run's saves never sort below (and lose the latest-step
        # race against) the history they extend. The writer is drained
        # FIRST (no concurrent writes into a tenant dir its gc could
        # tear), but a failed background write must not abort the exit
        # save — that is the moment durability matters most.
        try:
            self.writer.close()
        except Exception as e:
            print(f"snapshot writer: {e}; writing the exit snapshots "
                  "synchronously anyway")
        for tid in self.mgr.tenants:
            self.cluster.snapshot_tenant(
                self.mgr, tid, self.root,
                step=self.base_step.get(tid, 0) + rounds,
                extra_meta=self._meta(tid),
                keep_floor=self.floor.get(tid))
            if self.journal is not None:
                # exit truncation: drop WAL segments no retained
                # snapshot needs; the anchor step pins future GC
                anchor = self.cluster.truncate_journal(
                    self.journal, self.root, tid)
                if anchor is not None:
                    self.floor[tid] = anchor
        if self.writer.skipped:
            print(f"snapshot writer: {self.writer.skipped} periodic "
                  "save(s) skipped while a previous write was in flight")


def _tgn_setup(args):
    """Shared --mode tgn setup: dataset + config + params + features."""
    from repro.core import tgn
    from repro.core.pipeline import variant_config
    from repro.data import temporal_graph as tgd

    g = tgd.DATASETS[args.dataset](n_edges=args.edges)
    cfg = variant_config(
        args.variant,
        n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=g.cfg.f_edge,
        f_feat=g.cfg.f_feat, f_mem=args.f_mem, f_time=args.f_mem,
        f_emb=args.f_mem, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    node_feats = g.node_feats
    edge_feats = (jnp.asarray(g.edge_feats) if g.edge_feats.shape[1] else
                  jnp.zeros((g.n_edges, cfg.f_edge), jnp.float32))
    return g, cfg, params, edge_feats, node_feats


def _tenant_variants(args) -> list:
    return ([v for v in args.tenant_variants.split(",") if v]
            if args.tenant_variants else [args.variant] * args.tenants)


def _tenant_params(args, n: int) -> list:
    """--tenant-params names aligned with the tenant list, padded with
    the session default set (empty entries mean "default" too)."""
    names = ([p.strip() for p in args.tenant_params.split(",")]
             if args.tenant_params else [])
    if len(names) > n:
        raise SystemExit(f"--tenant-params lists {len(names)} sets for "
                         f"{n} tenants")
    names += [""] * (n - len(names))
    return [p or "default" for p in names]


def _ensure_param_sets(mgr, variants, pnames) -> None:
    """Register every named (non-default) set the fleet asks for.

    The CLI has no weight files to load, so a name maps to a
    deterministic name-seeded init for that tenant's variant config —
    the same name always yields the same weights (and so the same
    snapshot digest across runs). A real deployment would register
    trained checkpoints here instead.
    """
    import zlib

    from repro.core import tgn

    for v, pname in zip(variants, pnames):
        if pname == "default" or pname in mgr.param_store:
            continue
        cfg = mgr._tenant_cfg(v, None, pname)
        seed = zlib.crc32(pname.encode())
        mgr.register_params(pname,
                            tgn.init_params(jax.random.key(seed), cfg))
        print(f"registered param set {pname!r} "
              f"(digest {mgr.param_store.digest(pname)}, seed {seed})")


def _make_guard(mgr, args, writer=None, journal=None):
    """--guard: arm the FleetGuard supervisor (serving/guard.py) — NaN
    sentinel + SLO-burn quarantine, snapshot auto-restore with capped
    backoff and a --max-restores eviction ceiling, kernel-tier
    degradation on classified launch failures. Returns the guard (or
    None); once constructed, every round routes through it. With a
    journal, auto-restores replay the WAL suffix (lossless)."""
    if not args.guard:
        return None
    from repro.serving.guard import FleetGuard
    return FleetGuard(mgr, snapshot_root=args.snapshot_dir, writer=writer,
                      max_restores=args.max_restores,
                      quarantine_slo_burn=args.quarantine_slo_burn,
                      journal=journal)


def _make_journal(args):
    """--journal-dir: arm the durable write-ahead event journal
    (serving/journal.py). Every accepted ingest is logged BEFORE it
    enqueues, ``(client_id, seq)`` retries dedup server-side, and
    restores replay the WAL suffix for lossless recovery (see
    docs/ROBUSTNESS.md, "Recovery semantics")."""
    if not args.journal_dir:
        return None
    from repro.serving.journal import EventJournal
    return EventJournal(args.journal_dir,
                        fsync_s=args.journal_fsync_ms / 1e3,
                        dedup_window=args.dedup_window)


def _make_tracer(args):
    """--trace-out: build the sampled round tracer (obs/trace.py)."""
    if not args.trace_out:
        return None
    from repro.obs import RoundTracer
    return RoundTracer(sample_every=args.trace_every)


def _export_trace(tracer, args):
    """Write the collected spans at exit: Chrome/Perfetto trace_event
    JSON by default, span-per-line JSONL when the path ends .jsonl."""
    if tracer is None:
        return
    if args.trace_out.endswith(".jsonl"):
        tracer.write_jsonl(args.trace_out)
    else:
        tracer.write_chrome(args.trace_out)
    print(f"trace: {tracer.summary()} -> {args.trace_out}")


def _print_metrics(obs, tag=""):
    import json
    print(f"metrics{tag}:",
          json.dumps(obs.snapshot(), sort_keys=True, default=float),
          flush=True)


def run_frontend(args):
    """--listen: the online serving front-end (serving/frontend.py).

    Boots a reserve-enabled SessionManager (live admission: attach/detach
    over the wire land in the compiled round without a recompile), wraps
    it in the deadline-batching ServingFrontend, and serves the
    newline-delimited-JSON protocol on the requested address. One request
    dict per line, one response per line — see docs/SERVING.md."""
    import asyncio

    from repro.serving.admission import CapacityLadder
    from repro.serving.frontend import (FrontendConfig, ServingFrontend,
                                        serve_jsonl)
    from repro.serving.session import SessionManager

    _g, cfg, params, edge_feats, node_feats = _tgn_setup(args)
    mgr = SessionManager(params, edge_feats, node_feats, model=cfg,
                         use_kernels=args.kernels, reserve=CapacityLadder())
    variants = _tenant_variants(args)
    pnames = _tenant_params(args, len(variants))
    _ensure_param_sets(mgr, variants, pnames)
    for i, (v, p) in enumerate(zip(variants, pnames)):
        mgr.add_tenant(v, name=f"t{i}", params=p)
    fcfg = FrontendConfig(max_wait_s=args.deadline_ms / 1e3,
                          max_rows=args.max_rows,
                          queue_rows=args.queue_rows,
                          pad_quantum=args.pad_quantum)
    tracer = _make_tracer(args)
    journal = _make_journal(args)
    fe = ServingFrontend(mgr, fcfg, tracer=tracer,
                         slo_ms=args.slo_ms or None,
                         slo_objective=args.slo_objective,
                         journal=journal)
    guard = _make_guard(mgr, args, journal=journal)
    host, _, port = args.listen.partition(":")

    async def serve():
        await fe.start()
        server = await serve_jsonl(fe, host or "127.0.0.1", int(port or 0))
        addr = server.sockets[0].getsockname()
        print(f"serving JSON-lines on {addr[0]}:{addr[1]} "
              f"(deadline {fcfg.max_wait_s * 1e3:.1f}ms, "
              f"max-rows {fcfg.max_rows}, tenants {list(mgr.tenants)})",
              flush=True)
        ticker = None
        if args.metrics_every:
            async def tick():
                # online mode has no round counter to key off, so
                # --metrics-every is SECONDS here (rounds offline)
                while True:
                    await asyncio.sleep(args.metrics_every)
                    _print_metrics(fe.obs)
            ticker = asyncio.create_task(tick())
        try:
            if args.serve_seconds > 0:
                await asyncio.sleep(args.serve_seconds)
            else:
                await asyncio.Event().wait()      # forever; Ctrl-C stops
        finally:
            if ticker is not None:
                ticker.cancel()
            server.close()
            await server.wait_closed()
            await fe.stop()

    try:
        asyncio.run(serve())
    except KeyboardInterrupt:
        pass
    if journal is not None:
        journal.close()             # fsync the tail: exit is durable
    print("frontend stats:", fe.stats())
    if args.slo_ms:
        print("slo:", {tid: mgr.slo.tenant(tid) for tid in mgr.tenants})
    if guard is not None:
        print("guard:", guard.snapshot())
    _export_trace(tracer, args)


def run_tgn(args):
    from repro.data import stream
    from repro.serving.engine import EngineConfig, StreamingEngine
    from repro.serving.session import SessionManager

    g, cfg, params, edge_feats, node_feats = _tgn_setup(args)

    tenant_variants = _tenant_variants(args)
    if args.tenant_variants or args.tenants > 1 or args.mesh is not None \
            or args.snapshot_dir or args.slo_ms or args.trace_out \
            or args.guard or args.journal_dir:
        # multi-tenant: split the stream into one contiguous feed per
        # tenant; same-variant tenants share one vmapped launch per round.
        # (--snapshot-dir forces this path too: snapshots are a session
        # feature, and a 1-tenant session serves bitwise like the engine.
        # Likewise --slo-ms/--trace-out/--guard: SLO burn, round tracing
        # and the FleetGuard supervisor live on the session.)
        coalesce = not args.per_cohort
        if args.mesh is not None:
            from repro.serving.cluster import ShardedSessionManager
            mgr = ShardedSessionManager(params, edge_feats, node_feats,
                                        model=cfg, use_kernels=args.kernels,
                                        mesh=args.mesh, coalesce=coalesce)
        else:
            mgr = SessionManager(params, edge_feats, node_feats, model=cfg,
                                 use_kernels=args.kernels, coalesce=coalesce)
        tracer = _make_tracer(args)
        if tracer is not None:
            mgr.set_tracer(tracer)
        if args.slo_ms:
            mgr.set_slo(args.slo_ms, args.slo_objective)
        journal = _make_journal(args)
        snapshots = (_SnapshotHooks(mgr, args, journal=journal)
                     if args.snapshot_dir else None)
        guard = _make_guard(mgr, args,
                            writer=snapshots.writer if snapshots else None,
                            journal=journal)
        pnames = _tenant_params(args, len(tenant_variants))
        _ensure_param_sets(mgr, tenant_variants, pnames)
        tids = []
        for i, (v, p) in enumerate(zip(tenant_variants, pnames)):
            tid = snapshots.restore(v, f"t{i}") if snapshots else None
            tids.append(tid if tid is not None else
                        mgr.add_tenant(v, name=f"t{i}", params=p))
        print("session cohorts:", {v: i["tenants"]
                                   for v, i in mgr.describe().items()
                                   if isinstance(i, dict)
                                   and "tenants" in i})
        if args.mesh is not None:
            print("fabric mesh:", dict(mgr.mesh.shape))
        span = g.n_edges // len(tids)
        streams = {}
        for i, tid in enumerate(tids):
            lo = i * span
            if snapshots:
                # a restored tenant RESUMES its window where the snapshot
                # left off (one round = one --batch of edges; resuming
                # assumes the same --batch) instead of re-ingesting edges
                # its state already contains; a fully-consumed window
                # leaves the tenant idle.
                lo += min(snapshots.base_step.get(tid, 0) * args.batch,
                          span)
            streams[tid] = stream.fixed_count(
                g, args.batch, window=slice(lo, (i + 1) * span))
        if journal is not None:
            # write-ahead for the offline path: each batch journals
            # (rows + flush marker) as the driver PULLS it — before the
            # round that applies it ever launches
            def journaled(tid, it):
                for b in it:
                    journal.append_batch(tid, b)
                    yield b
            streams = {t: journaled(t, s) for t, s in streams.items()}
        rounds = 0
        for _batches, _outs in mgr.run(streams):
            rounds += 1
            if snapshots and args.snapshot_every and \
                    rounds % args.snapshot_every == 0:
                snapshots.save(rounds)
            if args.metrics_every and rounds % args.metrics_every == 0:
                _print_metrics(mgr.obs, tag=f" (round {rounds})")
        if snapshots:
            snapshots.save_final(rounds)
            steps = {t: snapshots.base_step.get(t, 0) + rounds
                     for t in sorted(mgr.tenants)}
            print(f"snapshots: {steps} -> {args.snapshot_dir}")
        if journal is not None:
            jstats = journal.stats()
            journal.close()         # fsync the tail: exit is durable
            print("journal:", jstats, "->", args.journal_dir)
        print("session summary:", mgr.summary())
        if guard is not None:
            print("guard:", guard.snapshot())
        _export_trace(tracer, args)
        return

    engine = StreamingEngine(EngineConfig(model=cfg,
                                          use_kernels=args.kernels),
                             params, edge_feats, node_feats)
    print("engine stages:", engine.describe())
    if args.window_s:
        batches = stream.time_window(g, args.window_s, args.batch)
    else:
        batches = stream.fixed_count(g, args.batch)
    for _batch, _out in engine.run(batches):
        pass
    print("engine summary:", engine.summary())


def run_lm(args):
    from repro import configs
    from repro.models import lm_common
    from repro.serving import lm_serve

    cfg = configs.get(args.arch).smoke_config()
    params = lm_common.init_params(jax.random.key(0), cfg)
    prompts = jnp.asarray(
        np.random.RandomState(0).randint(0, cfg.vocab, size=(args.batch, 8)),
        jnp.int32)
    out = lm_serve.generate(params, cfg, prompts,
                            lm_serve.ServeConfig(
                                max_new_tokens=args.new_tokens,
                                temperature=args.temperature))
    print(f"generated {out['tokens'].shape}; "
          f"prefill {out['prefill_s']*1e3:.1f}ms, "
          f"decode {out['decode_s_per_tok']*1e3:.2f}ms/token")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("tgn", "lm"), default="tgn")
    ap.add_argument("--dataset", default="wikipedia",
                    choices=("wikipedia", "reddit", "gdelt"))
    ap.add_argument("--edges", type=int, default=4000)
    ap.add_argument("--f-mem", type=int, default=32)
    ap.add_argument("--variant", default="sat+lut+np4",
                    help="pipeline-registry variant spec (e.g. teacher, "
                         "+NP(M), sat+lut+np2, sat+lut+np4+reservoir)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="serve N concurrent tenant streams through the "
                         "multi-tenant SessionManager (each gets 1/N of "
                         "the edge stream)")
    ap.add_argument("--tenant-variants", default="",
                    help="comma-separated per-tenant variant specs "
                         "(overrides --tenants; attention+encoder must "
                         "match --variant, sampler/pruning may differ — "
                         "unless the tenant also names its own param set "
                         "via --tenant-params)")
    ap.add_argument("--tenant-params", default="",
                    help="comma-separated per-tenant parameter-set names "
                         "aligned with the tenant list (shorter lists pad "
                         "with the default set). Unknown names are "
                         "registered from a deterministic name-seeded "
                         "init; tenants with different sets serve in "
                         "separate lanes of the SAME coalesced launch")
    ap.add_argument("--kernels", default="staged",
                    choices=("ref", "staged", "fused"),
                    help="kernel tier: jnp references, one Pallas kernel "
                         "per unit, or the fused single-pass step kernel "
                         "(kernels/fused_step.py; SAT+LUT variants — "
                         "others degrade to staged)")
    ap.add_argument("--per-cohort", action="store_true",
                    help="dispatch one compiled launch per cohort per "
                         "round (the pre-coalescing baseline) instead of "
                         "the fused single-launch round")
    ap.add_argument("--mesh", default=None,
                    help="serve on the sharded tenant fabric: a device-"
                         "mesh spec like '8' or 'tenant=4,vertex=2' "
                         "(CPU hosts: set XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N first)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="snapshot every tenant's VertexState here "
                         "(atomic + crc32, via distributed/checkpoint.py)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="also snapshot every N rounds (0: only at exit)")
    ap.add_argument("--restore", action="store_true",
                    help="resume tenants found in --snapshot-dir instead "
                         "of starting them fresh (any mesh shape)")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve the online JSON-lines frontend instead of "
                         "replaying the offline stream (port 0 = "
                         "ephemeral; see docs/SERVING.md for the "
                         "protocol)")
    ap.add_argument("--deadline-ms", type=float, default=10.0,
                    help="frontend flush deadline: a round launches when "
                         "the oldest queued event is this old")
    ap.add_argument("--max-rows", type=int, default=128,
                    help="frontend flush size: a round launches when any "
                         "tenant has this many events queued")
    ap.add_argument("--queue-rows", type=int, default=1024,
                    help="per-tenant ingest bound; beyond it events are "
                         "rejected with retry_after (backpressure)")
    ap.add_argument("--pad-quantum", type=int, default=32,
                    help="pad flushed batches to a multiple of this so "
                         "the compiled round's static widths stay stable "
                         "(0: exact sizes, retraces on new widths)")
    ap.add_argument("--serve-seconds", type=float, default=0.0,
                    help="with --listen: serve this long then exit "
                         "(0: run until interrupted)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-tenant latency SLO target: track burn rate "
                         "against this target (offline: round wall; "
                         "--listen: per-event queue+serve latency). 0 "
                         "disables (see docs/OBSERVABILITY.md)")
    ap.add_argument("--slo-objective", type=float, default=0.99,
                    help="SLO objective quantile, e.g. 0.99 = 'p99 under "
                         "--slo-ms'; burn rate 1.0 means the error budget "
                         "is being consumed exactly on schedule")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="print a metrics-registry snapshot every N rounds "
                         "(offline) or every N seconds (--listen); 0 "
                         "disables")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the sampled round trace at exit: Chrome/"
                         "Perfetto trace_event JSON (open in ui.perfetto."
                         "dev), or one-span-per-line JSONL if PATH ends "
                         ".jsonl")
    ap.add_argument("--trace-every", type=int, default=8,
                    help="trace 1 in N rounds (sampled rounds add device "
                         "fences for span accuracy, so keep this >1 to "
                         "preserve async pipelining on the rest)")
    ap.add_argument("--guard", action="store_true",
                    help="arm the FleetGuard supervisor: per-round finite-"
                         "state health checks, tenant quarantine with auto-"
                         "restore (from --snapshot-dir when set), and "
                         "kernel-tier degradation on launch failure (see "
                         "docs/ROBUSTNESS.md)")
    ap.add_argument("--max-restores", type=int, default=3,
                    help="evict a quarantined tenant after this many failed "
                         "restore attempts (requires --guard)")
    ap.add_argument("--quarantine-slo-burn", type=float, default=0.0,
                    help="quarantine a tenant whose SLO burn rate exceeds "
                         "this threshold (requires --guard and --slo-ms; "
                         "0 disables the SLO trigger)")
    ap.add_argument("--journal-dir", default=None,
                    help="write-ahead event journal root: every accepted "
                         "event is durably logged BEFORE it enqueues, "
                         "(client_id, seq) ingest retries dedup server-"
                         "side, and restores replay the journal suffix "
                         "for lossless recovery (docs/ROBUSTNESS.md)")
    ap.add_argument("--journal-fsync-ms", type=float, default=5.0,
                    help="batch journal fsyncs on this interval (0: fsync "
                         "every append — strongest durability, highest "
                         "ingest latency; see benchmarks/"
                         "frontend_latency.py for the cost curve)")
    ap.add_argument("--dedup-window", type=int, default=1024,
                    help="per-client sliding seq window for exactly-once "
                         "ingest; size it above a client's max in-flight "
                         "retry depth")
    ap.add_argument("--batch", type=int, default=200)
    ap.add_argument("--window-s", type=float, default=0.0)
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    if args.restore and not args.snapshot_dir:
        ap.error("--restore needs --snapshot-dir")
    if args.snapshot_every and not args.snapshot_dir:
        ap.error("--snapshot-every needs --snapshot-dir")
    if args.listen is not None and args.mode != "tgn":
        ap.error("--listen is a --mode tgn feature")
    if (args.slo_ms or args.trace_out or args.metrics_every) \
            and args.mode != "tgn":
        ap.error("--slo-ms/--trace-out/--metrics-every are --mode tgn "
                 "features")
    if args.slo_ms < 0:
        ap.error("--slo-ms must be >= 0")
    if not 0.0 < args.slo_objective < 1.0:
        ap.error("--slo-objective must be in (0, 1)")
    if args.trace_every < 1:
        ap.error("--trace-every must be >= 1")
    if args.metrics_every < 0:
        ap.error("--metrics-every must be >= 0")
    if args.guard and args.mode != "tgn":
        ap.error("--guard is a --mode tgn feature")
    if args.max_restores < 1:
        ap.error("--max-restores must be >= 1")
    if args.quarantine_slo_burn < 0:
        ap.error("--quarantine-slo-burn must be >= 0")
    if args.quarantine_slo_burn and not args.slo_ms:
        ap.error("--quarantine-slo-burn needs --slo-ms")
    if args.journal_dir and args.mode != "tgn":
        ap.error("--journal-dir is a --mode tgn feature")
    if args.journal_fsync_ms < 0:
        ap.error("--journal-fsync-ms must be >= 0")
    if args.dedup_window < 1:
        ap.error("--dedup-window must be >= 1")
    if args.listen is not None:
        run_frontend(args)
    else:
        (run_tgn if args.mode == "tgn" else run_lm)(args)


if __name__ == "__main__":
    main()
