import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower+compile named variants of a cell and print
the roofline deltas (hypothesis -> change -> before -> after).

    PYTHONPATH=src python -m repro.launch.hillclimb qwen3_8b train_4k \
        baseline H1 H1+H2

Variants (composable with '+'):
    baseline   paper-faithful execution (naive autodiff attention, SP carry)
    H1         flash-style rematted attention backward (attn_remat=True)
    H2         Megatron-SP block schedule (gather once per block)
    H3         no sequence parallelism (replicated carry — control arm)
    H4         bf16 optimizer moments
    H5         loss in bf16 logits (chunked CE matmul in bf16)
"""

import json
import sys

from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import dryrun


def variant_spec(cell_arch: str, names: str):
    spec = configs.get(cell_arch)
    cfg = spec.config()
    rules = {}
    kwargs = {}
    parts = set(names.split("+")) - {"baseline"}
    if "H1" in parts:
        cfg = cfg.replace(attn_remat=True)
    if "H2" in parts:
        rules["block_in"] = P(None, None, None)
    if "H3" in parts:
        rules["carry"] = P(None, None, None)  # overrides the default
    if "O1" in parts:
        kwargs["params_bf16"] = True
    if "O2" in parts:
        cfg = cfg.replace(kv_prune_keep=4096)
    if "O4" in parts:
        cfg = cfg.replace(decode_upcast=False)
    if "O5" in parts:
        cfg = cfg.replace(decode_unroll=True)
    return cfg, rules, kwargs


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = sys.argv[3:] or ["baseline"]
    out_dir = os.path.join("results", "hillclimb")
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for name in variants:
        cache = os.path.join(out_dir, f"{arch}__{shape}__{name}.json")
        if os.path.exists(cache):
            with open(cache) as f:
                r = json.load(f)
            print(f"[cached] {name}")
        else:
            cfg, rules, kwargs = variant_spec(arch, name)
            hlo_path = os.path.join(out_dir,
                                    f"{arch}__{shape}__{name}.hlo.gz")
            print(f"[compile] {name} ...", flush=True)
            r = dryrun.run_cell(arch, shape, override_cfg=cfg,
                                extra_rules=rules, save_hlo=hlo_path,
                                **kwargs)
            with open(cache, "w") as f:
                json.dump(r, f, indent=2)
        rows.append((name, r))

    print(f"\n=== {arch} x {shape}: roofline terms (per-device seconds) ===")
    print(f"{'variant':14s}{'compute':>10s}{'memory':>10s}"
          f"{'collective':>11s}  {'bound':10s}{'step_opt':>9s}")
    for name, r in rows:
        if r["status"] != "ok":
            print(f"{name:14s} {r['status']}")
            continue
        rl = r["roofline"]
        step = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        print(f"{name:14s}{rl['compute_s']:10.3f}{rl['memory_s']:10.3f}"
              f"{rl['collective_s']:11.3f}  {rl['bound']:10s}{step:9.3f}")


if __name__ == "__main__":
    main()
