"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.

Topology: TPU v5e, 256 chips per pod arranged (data=16, model=16); the
multi-pod mesh adds a leading ``pod`` axis (2 pods = 512 chips). The `model`
axis maps to the pod's fast ICI dimension (TP/EP/SP traffic); `data` carries
DP gradient reduction; `pod` crosses DCN (gradient all-reduce only — which
is why gradient compression in distributed/compression.py targets it).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            "under launch/dryrun.py (sets xla_force_host_platform_device_"
            "count) or on real hardware")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(dev_array, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (same axis names as single-pod)."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
