"""Trip-count-aware static analysis of compiled (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits while-loop bodies ONCE —
a scanned 48-layer model reports ~1/48th of its real FLOPs (verified
empirically, see EXPERIMENTS.md §Dry-run notes). Since every model here
scans over layers (and attention scans over q/k blocks), all roofline terms
must be scaled by loop trip counts. XLA conveniently records
``backend_config={"known_trip_count":{"n":...}}`` on while ops.

The analyzer parses the HLO module into computations, builds a call graph
(while bodies/conds weighted by trip count, fusions/calls by 1), and
accumulates per-device totals:

  * flops        — dot (2*M*N*K), elementwise, reduce
  * bytes        — operands + result of every top-level op (fusion internals
                   excluded: they never touch HBM), the cost_analysis
                   convention
  * collectives  — ring-weighted per-device traffic: all-gather ~ result,
                   reduce-scatter/all-to-all ~ operand, all-reduce ~
                   2 x operand, collective-permute ~ operand
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

# compiled HLO prints computation headers with a full signature
# ("name (args) -> result {"); unoptimized HLO (cross-platform lowering,
# compiler_ir(dialect="hlo")) prints the short form ("name {").
_COMP_HDR = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\)\s*->.*)?{\s*$")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_REF_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"}')

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "log-plus-one", "rsqrt", "sqrt", "negate",
    "abs", "sign", "floor", "ceil", "cosine", "sine", "logistic",
    "and", "or", "xor", "not", "compare", "select", "clamp", "convert",
    "round-nearest-afz", "round-nearest-even", "expm1",
}
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "iota", "partition-id", "replica-id",
}

# Ops that touch only a REGION of their big operand: counting the full
# operand would inflate scan-over-stacked-weights by the trip count.
#   dynamic-slice: traffic = slice read + result write = 2 x result
#   dynamic-update-slice: read-modify-write of the update region = 2 x update
#   gather: 2 x result; scatter: 2 x updates operand (approx)
_REGION_OPS = {"dynamic-slice", "gather"}          # 2 x result bytes
_REGION_UPDATE_OPS = {"dynamic-update-slice", "scatter"}  # 2 x update op

COLLECTIVE_FACTORS = {
    "all-gather": ("result", 1.0), "all-gather-start": ("result", 1.0),
    "all-reduce": ("operand", 2.0), "all-reduce-start": ("operand", 2.0),
    "reduce-scatter": ("operand", 1.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
    "collective-permute-start": ("operand", 1.0),
    "ragged-all-to-all": ("operand", 1.0),
}
_SKIP_DONE = {"all-gather-done", "all-reduce-done", "collective-permute-done"}


def _shape_info(type_str: str):
    """-> (bytes, [per-shape dims list])."""
    total, shapes = 0, []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dd = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dd:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append(dd)
    return total, shapes


@dataclass
class _Op:
    name: str
    kind: str
    bytes_: int
    shapes: list
    line: str


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)
    table: dict = field(default_factory=dict)   # name -> _Op


def _parse(hlo_text: str) -> dict:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = _Computation(name=hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, kind = m.groups()
        b, shapes = _shape_info(type_str)
        op = _Op(name=name, kind=kind, bytes_=b, shapes=shapes, line=line)
        cur.ops.append(op)
        cur.table[name] = op
    return comps


def _operand_refs(op: _Op) -> list[str]:
    paren = op.line[op.line.find("("):]
    # cut control metadata to avoid counting calls=%x etc.
    for key in (", calls=", ", condition=", ", to_apply=", ", metadata=",
                ", backend_config=", ", sharding=", ", replica_groups=",
                ", dimensions=", ", source_target_pairs="):
        i = paren.find(key)
        if i >= 0:
            paren = paren[:i]
    return _REF_RE.findall(paren)


_PARAM_KINDS = {"parameter", "constant"}


def _operand_bytes(op: _Op, comp: _Computation,
                   skip_params: bool = False) -> int:
    total = 0
    for r in _operand_refs(op):
        o = comp.table.get(r)
        if o is None or (skip_params and o.kind in _PARAM_KINDS):
            continue
        total += o.bytes_
    return total


def _op_traffic(op: _Op, comp: _Computation,
                fusion_param_bytes: dict | None = None,
                skip_params: bool = False) -> int:
    """HBM bytes touched by one op (region-aware). ``skip_params``
    excludes reads of entry parameters/constants — the *materialized
    intermediates* view: resident state tables, parameter sets and feature
    stores are standing storage, so only traffic through freshly
    materialized buffers is charged (region ops already charge the slice,
    not the table)."""
    k = op.kind
    if k in _REGION_OPS:
        return 2 * op.bytes_
    if k in _REGION_UPDATE_OPS:
        refs = _operand_refs(op)
        upd = comp.table[refs[1]].bytes_ if len(refs) > 1 and \
            refs[1] in comp.table else op.bytes_
        return 2 * upd
    if k == "fusion" and fusion_param_bytes is not None:
        return op.bytes_ + fusion_param_bytes.get(
            op.name, _operand_bytes(op, comp, skip_params))
    return op.bytes_ + _operand_bytes(op, comp, skip_params)


_TRANSPARENT_KINDS = {"convert", "bitcast", "copy", "reshape", "transpose",
                      "parameter", "constant", "tuple", "get-tuple-element"}


def _pure_transparent_bytes(op: _Op, comp: _Computation,
                            comps: dict) -> int | None:
    """Pure dtype/layout-conversion fusions (e.g. the CPU backend's
    bf16<->f32 emulation converts, which do not exist on TPU's native-bf16
    datapath) count once at the NARROW side — reading the data, no wide
    replica. Returns None when the fusion does real work."""
    if op.kind == "convert":
        return min(op.bytes_, _operand_bytes(op, comp))
    if op.kind != "fusion":
        return None
    mc = _CALLS_RE.search(op.line)
    if not mc or mc.group(1) not in comps:
        return None
    fused = comps[mc.group(1)]
    if all(o.kind in _TRANSPARENT_KINDS for o in fused.ops):
        return min(op.bytes_, _operand_bytes(op, comp))
    return None


def _fusion_traffic(op: _Op, comp: _Computation, comps: dict,
                    skip_params: bool = False) -> int:
    """HBM traffic of a fusion op, region-aware:

      * an operand whose only fused users are dynamic-slice ops counts at
        the slice sizes (scan bodies slice one block of a stacked buffer
        per iteration — the stack itself is not re-read);
      * an operand that is only the DESTINATION of dynamic-update-slice
        ops counts at the update size (in-place region write, aliased);
      * the fusion RESULT counts at the update size when the root is a
        dynamic-update-slice (possibly through bitcasts) — the rest of the
        output buffer is aliased, not written.
    """
    mc = _CALLS_RE.search(op.line)
    refs = _operand_refs(op)
    if not mc or mc.group(1) not in comps:
        return op.bytes_ + sum(comp.table[r].bytes_ for r in refs
                               if r in comp.table)
    fused = comps[mc.group(1)]

    def resolve(name, depth=0):
        """Follow dtype/layout-only chains to the defining op."""
        o = fused.table.get(name)
        while o is not None and depth < 8 and \
                o.kind in ("bitcast", "copy", "convert", "reshape",
                           "transpose"):
            rs = _operand_refs(o)
            if not rs or rs[0] not in fused.table:
                break
            o = fused.table[rs[0]]
            depth += 1
        return o

    _TRANSPARENT = ("convert", "bitcast", "copy", "reshape", "transpose")

    def terminal_users(name, depth=0):
        """Users of ``name``, looking through dtype/layout-only ops (a
        convert wrapping a DUS must still classify as a region write)."""
        out = []
        for o in fused.ops:
            if o.name == name or name not in _operand_refs(o):
                continue
            if o.kind in _TRANSPARENT and depth < 6:
                out.extend(terminal_users(o.name, depth + 1))
            else:
                out.append((o, name))
        return out

    # effective bytes per parameter index
    param_eff: dict[int, int] = {}
    for fop in fused.ops:
        if fop.kind != "parameter":
            continue
        midx = re.search(r"parameter\((\d+)\)", fop.line)
        if not midx:
            continue
        idx = int(midx.group(1))
        users = terminal_users(fop.name)
        if not users:
            param_eff[idx] = 0
            continue

        def region_bytes(u, via):
            if u.kind == "dynamic-slice":
                return 2 * u.bytes_
            if u.kind == "gather":
                return 2 * u.bytes_
            if u.kind == "dynamic-update-slice" \
                    and _operand_refs(u)[:1] == [via]:
                urefs = _operand_refs(u)
                if len(urefs) > 1 and urefs[1] in fused.table:
                    return 2 * fused.table[urefs[1]].bytes_
                return 2 * u.bytes_
            return None

        rbs = [region_bytes(u, via) for u, via in users]
        if all(r is not None for r in rbs):
            param_eff[idx] = sum(rbs)

    total = 0
    for i, r in enumerate(refs):
        if r not in comp.table:
            continue
        if skip_params and comp.table[r].kind in _PARAM_KINDS:
            continue
        total += param_eff.get(i, comp.table[r].bytes_)

    # result side
    root = fused.ops[-1] if fused.ops else None
    root = resolve(root.name) if root is not None else None
    if root is not None and root.kind == "dynamic-update-slice":
        urefs = _operand_refs(root)
        upd = fused.table[urefs[1]].bytes_ if len(urefs) > 1 and \
            urefs[1] in fused.table else op.bytes_
        total += upd
    else:
        total += op.bytes_
    return total


def _dot_flops(op: _Op, comp: _Computation) -> float:
    # result elements x 2 x contraction size (from lhs shape + dims)
    mc = re.search(r"lhs_contracting_dims={([\d,]*)}", op.line)
    if not mc:
        return 0.0
    cdims = [int(d) for d in mc.group(1).split(",") if d]
    paren = op.line[op.line.find("("):]
    refs = _REF_RE.findall(paren)
    lhs = comp.table.get(refs[0]) if refs else None
    if lhs is None or not lhs.shapes:
        return 0.0
    k = 1
    for d in cdims:
        if d < len(lhs.shapes[0]):
            k *= lhs.shapes[0][d]
    out_elems = 1
    for d in (op.shapes[0] if op.shapes else []):
        out_elems *= d
    return 2.0 * out_elems * k


def _trip_count(op: _Op, comps: dict) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    # fallback: constant in the condition computation's compare
    mw = _WHILE_RE.search(op.line)
    if mw:
        cond = comps.get(mw.group(1))
        if cond:
            for o in cond.ops:
                if o.kind == "constant":
                    mc = re.search(r"constant\((\d+)\)", o.line)
                    if mc:
                        return int(mc.group(1))
    return 1


def analyze(hlo_text: str, intermediates_only: bool = False) -> dict:
    """Per-device totals with loop multipliers applied.

    ``intermediates_only`` switches the byte accounting to the
    *materialized-intermediates* view: operand reads straight from entry
    parameters/constants (resident state tables, parameter sets, feature
    stores) are excluded, so ``bytes`` counts only traffic through buffers
    the program itself materializes — the quantity a kernel-fusion change
    moves. Region ops (gather/scatter/dynamic-slice) already charge the
    touched slice rather than the standing table in both modes.
    """
    skip = intermediates_only
    comps = _parse(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # fusion-target computations contribute flops at their call site but no
    # bytes (internal values stay in registers/VMEM)
    fusion_targets = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                mc = _CALLS_RE.search(op.line)
                if mc:
                    fusion_targets.add(mc.group(1))

    totals = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
              "collective_bytes": 0.0}
    by_coll: dict[str, float] = defaultdict(float)
    n_coll: dict[str, int] = defaultdict(int)
    bytes_by_kind: dict[str, float] = defaultdict(float)
    top_ops: list[tuple[float, str]] = []
    visited_stack = []

    def visit(comp_name: str, mult: float, in_fusion: bool):
        comp = comps.get(comp_name)
        if comp is None or comp_name in visited_stack:
            return
        visited_stack.append(comp_name)
        for op in comp.ops:
            k = op.kind
            out_elems = 1
            for d in (op.shapes[0] if op.shapes else []):
                out_elems *= d
            # ---- flops ----
            if k == "dot":
                totals["flops"] += mult * _dot_flops(op, comp)
            elif k in _ELEMENTWISE:
                totals["flops"] += mult * out_elems
                if k in ("tanh", "exponential", "log", "rsqrt", "sqrt",
                         "logistic", "cosine", "sine", "power", "expm1",
                         "log-plus-one"):
                    totals["transcendentals"] += mult * out_elems
            elif k == "reduce":
                totals["flops"] += mult * _operand_bytes(op, comp) / 4.0
            # ---- bytes (skip fusion internals; region-aware slices) ----
            if not in_fusion and k not in _NO_BYTES:
                pure = _pure_transparent_bytes(op, comp, comps)
                if pure is not None:
                    b = pure
                elif k == "fusion":
                    b = _fusion_traffic(op, comp, comps, skip_params=skip)
                else:
                    b = _op_traffic(op, comp, skip_params=skip)
                totals["bytes"] += mult * b
                bytes_by_kind[k] += mult * b
                if mult * b > 1e9:
                    top_ops.append((mult * b, f"{comp_name}/{op.name} "
                                    f"[{k}] x{mult:g}"))
            # ---- collectives ----
            if k in COLLECTIVE_FACTORS and not in_fusion:
                kind, factor = COLLECTIVE_FACTORS[k]
                raw = op.bytes_ if kind == "result" \
                    else _operand_bytes(op, comp)
                totals["collective_bytes"] += mult * factor * raw
                by_coll[k] += mult * raw
                n_coll[k] += int(mult)
            # ---- recursion ----
            if k == "fusion":
                mc = _CALLS_RE.search(op.line)
                if mc:
                    visit(mc.group(1), mult, True)
            elif k == "while":
                trips = _trip_count(op, comps)
                mw = _WHILE_RE.search(op.line)
                if mw:
                    visit(mw.group(1), mult * trips, in_fusion)  # cond
                    visit(mw.group(2), mult * trips, in_fusion)  # body
            elif k in ("call", "conditional", "custom-call", "reduce",
                       "sort", "scatter", "map", "reduce-window",
                       "select-and-scatter", "reduce-scatter", "all-reduce"):
                mt = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if mt:
                    visit(mt.group(1), mult, in_fusion)
        visited_stack.pop()

    visit(entry.name, 1.0, False)
    totals["collectives_by_op"] = dict(by_coll)
    totals["collectives_count"] = dict(n_coll)
    totals["bytes_by_kind"] = dict(bytes_by_kind)
    totals["top_bytes_ops"] = [f"{b/1e9:.1f}GB {s}" for b, s in
                               sorted(top_ops, reverse=True)[:20]]
    return totals


def summarize(hlo_text: str) -> str:
    return json.dumps(analyze(hlo_text), indent=2)


# ---------------------------------------------------------------------------
# Cross-platform lowering + jaxpr-level fallback accounting
# ---------------------------------------------------------------------------


def lowered_hlo_text(fn, *args, platform: str | None = "tpu") -> str:
    """Lower ``fn(*args)`` (optionally cross-platform — Mosaic lowers
    Pallas kernels to opaque custom-calls without TPU hardware) and return
    the unoptimized HLO text for ``analyze``. Raises whatever the lowering
    raises; callers fall back to ``jaxpr_traffic``."""
    import jax  # local: keep this module importable without jax

    traced = jax.jit(fn).trace(*args)
    if platform is None:
        lowered = traced.lower()
    else:
        lowered = traced.lower(lowering_platforms=(platform,))
    return lowered.compiler_ir(dialect="hlo").as_hlo_text()


#: jaxpr primitives whose big operand is only touched in a region —
#: mirrors _REGION_OPS/_REGION_UPDATE_OPS above.
_JAXPR_REGION = {"gather", "dynamic_slice"}
_JAXPR_REGION_UPDATE = {"scatter", "scatter-add", "scatter_add",
                        "dynamic_update_slice"}
_JAXPR_CALLS = {"pjit": "jaxpr", "closed_call": "call_jaxpr",
                "custom_jvp_call": "call_jaxpr",
                "custom_vjp_call": "call_jaxpr",
                "remat": "jaxpr", "checkpoint": "jaxpr"}


def _aval_bytes(v) -> int:
    aval = v.aval
    if not hasattr(aval, "dtype"):
        return 0
    size = 1
    for d in aval.shape:
        size *= int(d)
    return size * aval.dtype.itemsize


def _is_var(v) -> bool:
    import jax

    return isinstance(v, jax.core.Var)


def jaxpr_traffic(fn, *args, intermediates_only: bool = True) -> dict:
    """Backend-independent traffic accounting over the closed jaxpr.

    Every equation charges operand + result bytes; ``pallas_call`` stays
    ONE opaque equation (its internals are VMEM-resident by construction),
    so the count matches the launch-boundary HBM-traffic semantics of the
    HLO accounting, pre-fusion. ``intermediates_only`` skips operands that
    are the jaxpr's own invars/constvars (resident tables and parameters),
    and region ops charge the touched slice. Also reports
    ``pallas_launches`` — the per-trace kernel-launch count.
    """
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    totals = {"bytes": 0.0, "pallas_launches": 0}
    by_prim: dict[str, float] = defaultdict(float)

    def visit(jaxpr, params_set, mult):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            sub = _JAXPR_CALLS.get(name)
            if sub is not None and sub in eqn.params:
                inner = eqn.params[sub]
                inner_jaxpr = getattr(inner, "jaxpr", inner)
                inner_params = set()
                for iv, ov in zip(inner_jaxpr.invars, eqn.invars):
                    if not _is_var(ov) or ov in params_set:
                        inner_params.add(iv)
                visit(inner_jaxpr, inner_params, mult)
                continue
            if name == "scan":
                inner = eqn.params["jaxpr"].jaxpr
                visit(inner, set(), mult * eqn.params["length"])
                continue
            if name == "while":
                visit(eqn.params["body_jaxpr"].jaxpr, set(), mult)
                continue
            if name == "pallas_call":
                totals["pallas_launches"] += int(mult)
            out_b = sum(_aval_bytes(v) for v in eqn.outvars)
            if name in _JAXPR_REGION:
                b = 2 * out_b
            elif name in _JAXPR_REGION_UPDATE:
                upd = (eqn.invars[1] if len(eqn.invars) > 1
                       else eqn.invars[-1])
                b = 2 * _aval_bytes(upd)
            else:
                in_b = 0
                for v in eqn.invars:
                    if not _is_var(v):
                        continue        # literal
                    if intermediates_only and v in params_set:
                        continue
                    in_b += _aval_bytes(v)
                b = out_b + in_b
            totals["bytes"] += mult * b
            by_prim[name] += mult * b
        return

    top_params = set(closed.jaxpr.invars) | set(closed.jaxpr.constvars)
    visit(closed.jaxpr, top_params if intermediates_only else set(), 1.0)
    totals["bytes_by_primitive"] = {
        k: v for k, v in sorted(by_prim.items(), key=lambda kv: -kv[1])}
    return totals


def step_traffic(fn, *args) -> dict:
    """Materialized-intermediate bytes of one compiled step, preferring
    HLO-level accounting over a cross-lowered TPU module (Pallas kernels
    opaque custom-calls) and falling back to the jaxpr view when the
    host toolchain cannot cross-lower. Returns
    ``{"bytes", "accounting", ...}``."""
    try:
        txt = lowered_hlo_text(fn, *args, platform="tpu")
        out = analyze(txt, intermediates_only=True)
        return {"bytes": out["bytes"], "accounting": "hlo-tpu",
                "bytes_by_kind": out["bytes_by_kind"]}
    except Exception as e:             # pragma: no cover - toolchain gaps
        out = jaxpr_traffic(fn, *args, intermediates_only=True)
        return {"bytes": out["bytes"], "accounting": f"jaxpr ({e!r:.60})",
                "bytes_by_kind": out["bytes_by_primitive"]}
