"""LUT time-encoder Pallas kernel (§III-C on TPU).

The paper's BRAM LUT emits one (possibly weight-folded) row per clock. The
TPU analogue: bucket each dt by counting quantile boundaries <= dt (a fully
vectorized VPU compare-reduce over the 128 boundary lanes), then fetch the
row as ``one_hot(bucket) @ table`` — a (B,128)x(128,D) MXU matmul instead of
a scalar gather. With the projection folded into the table (§III-C), this
kernel IS the whole encode-then-project path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def lut_rows(dt_col, bounds_ref, table_ref, n_entries: int):
    """The in-kernel LUT row fetch, shared by EVERY kernel body that
    consumes a folded table (this module, sat_aggregate, fused_step —
    one definition so the bucketing can never drift between tiers):
    bucket by boundary count (fp32 accumulate of the 0/1 compares — exact
    for E <= 2^24 and, unlike an integer reduce, Mosaic-lowerable without
    a TPU attached), then fetch via one-hot matmul (MXU).
    ``dt_col`` (rows, 1); bounds (1, E); table (E, D) -> (rows, D)."""
    rows = dt_col.shape[0]
    bucket = jnp.sum((dt_col >= bounds_ref[...]).astype(jnp.float32),
                     axis=1, keepdims=True).astype(jnp.int32)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (rows, n_entries), 1)
    one_hot = (lanes == bucket).astype(jnp.float32)
    return jnp.dot(one_hot, table_ref[...],
                   preferred_element_type=jnp.float32)


def _lut_kernel(dt_ref, bounds_ref, table_ref, out_ref, *, n_entries: int):
    """dt (Bb, 1), bounds (1, E), table (E, D) -> out (Bb, D)."""
    out_ref[...] = lut_rows(dt_ref[...], bounds_ref, table_ref, n_entries)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def lut_encode_pallas(dt: jax.Array, bounds: jax.Array, table: jax.Array,
                      *, block_b: int = 256,
                      interpret: bool = False) -> jax.Array:
    """dt (B,) float32; bounds (1, E); table (E, D). B multiple of block_b,
    D LANE-aligned. Returns (B, D) float32."""
    B = dt.shape[0]
    E, D = table.shape
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_lut_kernel, n_entries=E),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
            pl.BlockSpec((E, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(dt.reshape(B, 1), bounds, table)
