"""Public jit'd entry points for the Pallas kernels.

These wrappers own all the padding/unpadding between the paper's native dims
(f_mem=100, f_edge=172, ...) and the LANE(128)-aligned shapes the kernels
require, pick interpret mode automatically off-TPU, and repack the core/
parameter layout (gate blocks at f_mem strides) into the lane-aligned kernel
layout (gate blocks at m_p strides).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import LANE, round_up
from repro.kernels.gru_cell import gru_cell_pallas
from repro.kernels.sat_aggregate import sat_aggregate_pallas
from repro.kernels.lut_time_encode import lut_encode_pallas


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


# ---------------------------------------------------------------------------
# GRU memory update
# ---------------------------------------------------------------------------


def pad_gru_params(params: dict, f_mail: int, f_mem: int) -> dict:
    """Repack core-layout GRU params into lane-aligned kernel layout.

    core layout: w_i (f_mail, 3*f_mem) with gates at f_mem strides.
    kernel layout: (f_mail_p, 3*m_p) with gates at m_p strides.
    Precompute once per model; reuse across calls.
    """
    f_p, m_p = round_up(f_mail), round_up(f_mem)

    def repack_w(w, in_dim, in_p):
        gates = [w[:, g * f_mem:(g + 1) * f_mem] for g in range(3)]
        return jnp.concatenate(
            [_pad2(g, in_p, m_p) for g in gates], axis=1)

    def repack_b(b):
        gates = [b[g * f_mem:(g + 1) * f_mem] for g in range(3)]
        return jnp.concatenate(
            [jnp.pad(g, (0, m_p - f_mem)) for g in gates])[None, :]

    return {
        "w_i": repack_w(params["w_i"], f_mail, f_p),
        "w_h": repack_w(params["w_h"], f_mem, m_p),
        "b_i": repack_b(params["b_i"]),
        "b_h": repack_b(params["b_h"]),
    }


def repack_gate_rows(x: jax.Array, f_mem: int, m_p: int) -> jax.Array:
    """Per-row gate vectors (B, 3*f_mem) [r|z|n at f_mem strides] ->
    lane-aligned (B, 3*m_p)."""
    gates = [x[:, g * f_mem:(g + 1) * f_mem] for g in range(3)]
    return jnp.concatenate(
        [jnp.pad(g, ((0, 0), (0, m_p - f_mem))) for g in gates], axis=1)


def gru_cell(mail: jax.Array, s: jax.Array, packed: dict,
             extra: jax.Array | None = None, *,
             block_b: int = 128) -> jax.Array:
    """Fused GRU cell on native dims. mail (B, f_mail), s (B, f_mem);
    ``packed`` from pad_gru_params; ``extra`` optional (B, 3*f_mem) additive
    input-gate rows in core layout (LUT-folded time rows, §III-C).
    Returns (B, f_mem)."""
    B, f_mail = mail.shape
    f_mem = s.shape[-1]
    f_p = packed["w_i"].shape[0]
    m_p = packed["w_h"].shape[0]
    bb = min(block_b, round_up(B, 8))
    B_p = round_up(B, bb)
    mail_p = _pad2(mail.astype(jnp.float32), B_p, f_p)
    s_p = _pad2(s.astype(jnp.float32), B_p, m_p)
    if extra is None:
        extra_p = jnp.zeros((B_p, 3 * m_p), jnp.float32)
    else:
        extra_p = _pad2(repack_gate_rows(extra.astype(jnp.float32),
                                         f_mem, m_p), B_p, 3 * m_p)
    out = gru_cell_pallas(mail_p, s_p, extra_p, packed["w_i"], packed["w_h"],
                          packed["b_i"], packed["b_h"], block_b=bb,
                          interpret=_use_interpret())
    return out[:B, :f_mem]


# ---------------------------------------------------------------------------
# LUT time encode
# ---------------------------------------------------------------------------


def pad_lut_params(boundaries: jax.Array, table: jax.Array) -> dict:
    """bounds (E-1,) -> (1, E) with +inf sentinel; table (E, D) -> (E, D_p)."""
    E, D = table.shape
    bounds = jnp.concatenate(
        [boundaries.astype(jnp.float32),
         jnp.full((E - boundaries.shape[0],), np.inf, jnp.float32)])[None, :]
    return {"bounds": bounds,
            "table": _pad2(table.astype(jnp.float32), E, round_up(D)),
            "d": D}


def lut_encode(dt: jax.Array, packed: dict) -> jax.Array:
    """dt (...,) -> (..., D) via the LUT kernel."""
    shape = dt.shape
    flat = dt.reshape(-1).astype(jnp.float32)
    B = flat.shape[0]
    bb = min(256, round_up(B, 8))
    B_p = round_up(B, bb)
    flat = jnp.pad(flat, (0, B_p - B))
    out = lut_encode_pallas(flat, packed["bounds"], packed["table"],
                            block_b=bb, interpret=_use_interpret())
    return out[:B, :packed["d"]].reshape(*shape, packed["d"])


# ---------------------------------------------------------------------------
# SAT aggregation
# ---------------------------------------------------------------------------


def pad_sat_params(w_v: jax.Array, b_v: jax.Array, boundaries: jax.Array,
                   folded_table: jax.Array) -> dict:
    """w_v (Dkv, D) [memory||edge rows only], b_v (D,), folded LUT table
    (E, D) already = table @ W_v[time rows]."""
    dkv, d = w_v.shape
    dkv_p, d_p = round_up(dkv), round_up(d)
    E = folded_table.shape[0]
    bounds = jnp.concatenate(
        [boundaries.astype(jnp.float32),
         jnp.full((E - boundaries.shape[0],), np.inf, jnp.float32)])[None, :]
    return {
        "w_v": _pad2(w_v.astype(jnp.float32), dkv_p, d_p),
        "b_v": jnp.pad(b_v.astype(jnp.float32), (0, d_p - d))[None, :],
        "bounds": bounds,
        "table": _pad2(folded_table.astype(jnp.float32), E, d_p),
        "dkv": dkv, "d": d,
    }


def sat_aggregate(kv: jax.Array, dt: jax.Array, logits: jax.Array,
                  valid: jax.Array, packed: dict,
                  *, block_b: int = 128) -> jax.Array:
    """Fused student EU tail. kv (B, k, dkv); dt/logits (B, k);
    valid (B, k) bool. Returns (B, d)."""
    B, k, dkv = kv.shape
    dkv_p = packed["w_v"].shape[0]
    bb = min(block_b, round_up(B, 8))
    B_p = round_up(B, bb)
    kv_p = jnp.pad(kv.astype(jnp.float32),
                   ((0, B_p - B), (0, 0), (0, dkv_p - dkv)))
    pad_rows = ((0, B_p - B), (0, 0))
    out = sat_aggregate_pallas(
        kv_p, jnp.pad(dt.astype(jnp.float32), pad_rows),
        jnp.pad(logits.astype(jnp.float32), pad_rows),
        jnp.pad(valid.astype(jnp.float32), pad_rows),
        packed["w_v"], packed["b_v"], packed["bounds"], packed["table"],
        block_b=bb, interpret=_use_interpret())
    return out[:B, :packed["d"]]
