"""Public jit'd entry points for the Pallas kernels.

These wrappers own all the padding/unpadding between the paper's native dims
(f_mem=100, f_edge=172, ...) and the LANE(128)-aligned shapes the kernels
require, pick interpret mode automatically off-TPU, and repack the core/
parameter layout (gate blocks at f_mem strides) into the lane-aligned kernel
layout (gate blocks at m_p strides).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import LANE, round_up
from repro.kernels.gru_cell import gru_cell_pallas
from repro.kernels.sat_aggregate import sat_aggregate_pallas
from repro.kernels.lut_time_encode import lut_encode_pallas
from repro.kernels.fused_step import fused_step_pallas

#: interpret-mode override: None = auto (interpret off-TPU); True/False
#: force it. The HLO byte-accounting benchmark traces with interpret
#: forced OFF so the kernels lower to opaque Mosaic custom-calls whose
#: operand/result bytes ARE the launch's HBM traffic.
_INTERPRET = {"override": None}


@contextlib.contextmanager
def force_interpret(mode: bool | None):
    """Force (or restore auto) interpret-mode selection for every kernel
    entry point while the context is active (trace-time switch)."""
    prev = _INTERPRET["override"]
    _INTERPRET["override"] = mode
    try:
        yield
    finally:
        _INTERPRET["override"] = prev


def _use_interpret() -> bool:
    if _INTERPRET["override"] is not None:
        return bool(_INTERPRET["override"])
    return jax.default_backend() != "tpu"


#: Trace-time kernel-launch counter: every public entry point below bumps
#: it when its pallas_call is staged into a trace, so
#: ``reset_launch_count(); jax.jit(step).lower(...); launch_count()``
#: counts the compiled step's kernel launches (the benchmark's
#: one-launch-per-step guard). Interpret/compiled mode agnostic.
_LAUNCHES = {"count": 0}


def reset_launch_count() -> None:
    _LAUNCHES["count"] = 0


def launch_count() -> int:
    return _LAUNCHES["count"]


def _count_launch() -> None:
    _LAUNCHES["count"] += 1


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


# ---------------------------------------------------------------------------
# GRU memory update
# ---------------------------------------------------------------------------


def pad_gru_params(params: dict, f_mail: int, f_mem: int) -> dict:
    """Repack core-layout GRU params into lane-aligned kernel layout.

    core layout: w_i (f_mail, 3*f_mem) with gates at f_mem strides.
    kernel layout: (f_mail_p, 3*m_p) with gates at m_p strides.
    Precompute once per model; reuse across calls.
    """
    f_p, m_p = round_up(f_mail), round_up(f_mem)

    def repack_w(w, in_dim, in_p):
        gates = [w[:, g * f_mem:(g + 1) * f_mem] for g in range(3)]
        return jnp.concatenate(
            [_pad2(g, in_p, m_p) for g in gates], axis=1)

    def repack_b(b):
        gates = [b[g * f_mem:(g + 1) * f_mem] for g in range(3)]
        return jnp.concatenate(
            [jnp.pad(g, (0, m_p - f_mem)) for g in gates])[None, :]

    return {
        "w_i": repack_w(params["w_i"], f_mail, f_p),
        "w_h": repack_w(params["w_h"], f_mem, m_p),
        "b_i": repack_b(params["b_i"]),
        "b_h": repack_b(params["b_h"]),
    }


def repack_gate_rows(x: jax.Array, f_mem: int, m_p: int) -> jax.Array:
    """Per-row gate vectors (B, 3*f_mem) [r|z|n at f_mem strides] ->
    lane-aligned (B, 3*m_p)."""
    gates = [x[:, g * f_mem:(g + 1) * f_mem] for g in range(3)]
    return jnp.concatenate(
        [jnp.pad(g, ((0, 0), (0, m_p - f_mem))) for g in gates], axis=1)


def gru_cell(mail: jax.Array, s: jax.Array, packed: dict,
             extra: jax.Array | None = None, *,
             block_b: int = 128) -> jax.Array:
    """Fused GRU cell on native dims. mail (B, f_mail), s (B, f_mem);
    ``packed`` from pad_gru_params; ``extra`` optional (B, 3*f_mem) additive
    input-gate rows in core layout (LUT-folded time rows, §III-C).
    Returns (B, f_mem)."""
    B, f_mail = mail.shape
    f_mem = s.shape[-1]
    f_p = packed["w_i"].shape[0]
    m_p = packed["w_h"].shape[0]
    _count_launch()
    bb = min(block_b, round_up(B, 8))
    B_p = round_up(B, bb)
    mail_p = _pad2(mail.astype(jnp.float32), B_p, f_p)
    s_p = _pad2(s.astype(jnp.float32), B_p, m_p)
    if extra is None:
        extra_p = jnp.zeros((B_p, 3 * m_p), jnp.float32)
    else:
        extra_p = _pad2(repack_gate_rows(extra.astype(jnp.float32),
                                         f_mem, m_p), B_p, 3 * m_p)
    out = gru_cell_pallas(mail_p, s_p, extra_p, packed["w_i"], packed["w_h"],
                          packed["b_i"], packed["b_h"], block_b=bb,
                          interpret=_use_interpret())
    return out[:B, :f_mem]


# ---------------------------------------------------------------------------
# LUT time encode
# ---------------------------------------------------------------------------


def _sentinel_bounds(boundaries: jax.Array, E: int) -> jax.Array:
    """bounds (E-1,) -> (1, E) with the +inf sentinel — the ONE definition
    of the kernel-side boundary layout (pad_lut_params, pad_sat_params and
    pad_fused_params all feed the same in-kernel bucketing,
    lut_time_encode.lut_rows; a drift here would desynchronize tiers)."""
    return jnp.concatenate(
        [boundaries.astype(jnp.float32),
         jnp.full((E - boundaries.shape[0],), np.inf,
                  jnp.float32)])[None, :]


def pad_lut_params(boundaries: jax.Array, table: jax.Array) -> dict:
    """bounds (E-1,) -> (1, E) with +inf sentinel; table (E, D) -> (E, D_p)."""
    E, D = table.shape
    return {"bounds": _sentinel_bounds(boundaries, E),
            "table": _pad2(table.astype(jnp.float32), E, round_up(D)),
            "d": D}


def lut_encode(dt: jax.Array, packed: dict) -> jax.Array:
    """dt (...,) -> (..., D) via the LUT kernel."""
    _count_launch()
    shape = dt.shape
    flat = dt.reshape(-1).astype(jnp.float32)
    B = flat.shape[0]
    bb = min(256, round_up(B, 8))
    B_p = round_up(B, bb)
    flat = jnp.pad(flat, (0, B_p - B))
    out = lut_encode_pallas(flat, packed["bounds"], packed["table"],
                            block_b=bb, interpret=_use_interpret())
    return out[:B, :packed["d"]].reshape(*shape, packed["d"])


# ---------------------------------------------------------------------------
# SAT aggregation
# ---------------------------------------------------------------------------


def pad_sat_params(w_v: jax.Array, b_v: jax.Array, boundaries: jax.Array,
                   folded_table: jax.Array) -> dict:
    """w_v (Dkv, D) [memory||edge rows only], b_v (D,), folded LUT table
    (E, D) already = table @ W_v[time rows]."""
    dkv, d = w_v.shape
    dkv_p, d_p = round_up(dkv), round_up(d)
    E = folded_table.shape[0]
    return {
        "w_v": _pad2(w_v.astype(jnp.float32), dkv_p, d_p),
        "b_v": jnp.pad(b_v.astype(jnp.float32), (0, d_p - d))[None, :],
        "bounds": _sentinel_bounds(boundaries, E),
        "table": _pad2(folded_table.astype(jnp.float32), E, d_p),
        "dkv": dkv, "d": d,
    }


def sat_aggregate(kv: jax.Array, dt: jax.Array, logits: jax.Array,
                  valid: jax.Array, packed: dict,
                  *, block_b: int = 128) -> jax.Array:
    """Fused student EU tail. kv (B, k, dkv); dt/logits (B, k);
    valid (B, k) bool. Returns (B, d)."""
    _count_launch()
    B, k, dkv = kv.shape
    dkv_p = packed["w_v"].shape[0]
    bb = min(block_b, round_up(B, 8))
    B_p = round_up(B, bb)
    kv_p = jnp.pad(kv.astype(jnp.float32),
                   ((0, B_p - B), (0, 0), (0, dkv_p - dkv)))
    pad_rows = ((0, B_p - B), (0, 0))
    out = sat_aggregate_pallas(
        kv_p, jnp.pad(dt.astype(jnp.float32), pad_rows),
        jnp.pad(logits.astype(jnp.float32), pad_rows),
        jnp.pad(valid.astype(jnp.float32), pad_rows),
        packed["w_v"], packed["b_v"], packed["bounds"], packed["table"],
        block_b=bb, interpret=_use_interpret())
    return out[:B, :packed["d"]]


# ---------------------------------------------------------------------------
# Fused single-pass step (scalar-prefetch gather + one-launch MUU/EU)
# ---------------------------------------------------------------------------


def pad_fused_params(gru_params: dict, attn_params: dict, folded_gru: dict,
                     folded_attn: dict, f_mail_raw: int, f_mem: int,
                     f_edge: int) -> dict:
    """Kernel-layout parameter pack for the fused single-pass step.

    Everything the one-launch datapath consumes, padded on OUT dims only
    (IN rows are DMA'd at native table widths into zero-padded VMEM
    scratch, so zero-padding weight ROWS keeps the math exact):

      * the raw-mail GRU weights at m_p gate strides (pad_gru_params) plus
        the GRU-folded LUT table gate-repacked to (E, 3*m_p);
      * W_v split at the memory/edge boundary — the kernel computes the kv
        projection as TWO matmuls, so the ``(B, k, Dkv)`` concat never
        exists — plus the attention-folded LUT table (E, d_p);
      * the output transform split the same way (self rows || aggregate).
    """
    m_p = round_up(f_mem)
    e_p = round_up(max(f_edge, 1))
    d = attn_params["w_v"].shape[1]
    d_p = round_up(d)
    f_emb = attn_params["w_out"].shape[1]
    emb_p = round_up(f_emb)
    E = folded_gru["table"].shape[0]

    gru = pad_gru_params(
        {"w_i": gru_params["w_i"][:f_mail_raw], "w_h": gru_params["w_h"],
         "b_i": gru_params["b_i"], "b_h": gru_params["b_h"]},
        f_mail_raw, f_mem)
    w_v = attn_params["w_v"]
    wv_edge = (w_v[f_mem:f_mem + f_edge] if f_edge
               else jnp.zeros((1, d), jnp.float32))
    w_out = attn_params["w_out"]
    return {
        "w_i": gru["w_i"], "w_h": gru["w_h"],
        "b_i": gru["b_i"], "b_h": gru["b_h"],
        "g_bounds": _sentinel_bounds(folded_gru["boundaries"], E),
        "g_table": _pad2(repack_gate_rows(
            folded_gru["table"].astype(jnp.float32), f_mem, m_p), E,
            3 * m_p),
        "wv_mem": _pad2(w_v[:f_mem].astype(jnp.float32), m_p, d_p),
        "wv_edge": _pad2(wv_edge.astype(jnp.float32), e_p, d_p),
        "b_v": jnp.pad(attn_params["b_v"].astype(jnp.float32),
                       (0, d_p - d))[None, :],
        "s_bounds": _sentinel_bounds(folded_attn["boundaries"], E),
        "s_table": _pad2(folded_attn["table"].astype(jnp.float32), E, d_p),
        "w_self": _pad2(w_out[:f_mem].astype(jnp.float32), m_p, emb_p),
        "w_agg": _pad2(w_out[f_mem:].astype(jnp.float32), d_p, emb_p),
        "b_out": jnp.pad(attn_params["b_out"].astype(jnp.float32),
                         (0, emb_p - f_emb))[None, :],
        "f_mem": f_mem, "f_edge": f_edge, "f_mail": f_mail_raw,
        "f_emb": f_emb,
    }


def fused_step(vids: jax.Array, sel_ids: jax.Array, sel_eid: jax.Array,
               hit: jax.Array, dt_mail: jax.Array, mail_ok: jax.Array,
               sel_dt: jax.Array, sel_logits: jax.Array,
               sel_valid: jax.Array, memory: jax.Array, mail: jax.Array,
               edge_feats: jax.Array | None, packed: dict,
               *, block_b: int = 128):
    """ONE launch for the post-prune datapath: winner-row gather + kv
    projection + folded-LUT rows + masked softmax + FAM + output transform
    + GRU memory update.

    ``vids`` (R,) int; ``sel_ids``/``sel_eid``/``hit`` (R, k) int —
    ``hit[r, j] >= 0`` marks a winner whose vertex is updated by THIS
    batch and names the batch row holding its updated memory (the
    committed view); ``dt_mail``/``mail_ok`` (R,); ``sel_dt``/
    ``sel_logits``/``sel_valid`` (R, k). ``memory``/``mail``/
    ``edge_feats`` are the HBM-resident tables — the kernel fetches only
    the addressed rows. Returns ``(h (R, f_emb), s_upd (R, f_mem))``.
    """
    _count_launch()
    R, k = sel_ids.shape
    bb = min(block_b, round_up(R, 8))
    R_p = round_up(R, bb)
    pad = R_p - R
    p1, p2 = ((0, pad),), ((0, pad), (0, 0))

    def i32(x, padder=p1, fill=0):
        return jnp.pad(x.astype(jnp.int32), padder, constant_values=fill)

    def f32(x, padder=p1):
        return jnp.pad(x.astype(jnp.float32), padder)

    ef = (edge_feats.astype(jnp.float32) if packed["f_edge"]
          else jnp.zeros((1, 1), jnp.float32))
    h, s_upd = fused_step_pallas(
        i32(vids), i32(sel_ids, p2).reshape(-1),
        i32(sel_eid, p2).reshape(-1),
        i32(hit, p2, fill=-1).reshape(-1),
        f32(dt_mail)[:, None], f32(mail_ok)[:, None],
        f32(sel_dt, p2), f32(sel_logits, p2), f32(sel_valid, p2),
        memory.astype(jnp.float32), mail.astype(jnp.float32), ef,
        packed["w_i"], packed["w_h"], packed["b_i"], packed["b_h"],
        packed["g_bounds"], packed["g_table"], packed["wv_mem"],
        packed["wv_edge"], packed["b_v"], packed["s_bounds"],
        packed["s_table"], packed["w_self"], packed["w_agg"],
        packed["b_out"],
        k=k, f_mem=packed["f_mem"], f_mail=packed["f_mail"],
        f_edge=packed["f_edge"], block_b=bb, interpret=_use_interpret())
    return h[:R, :packed["f_emb"]], s_upd[:R, :packed["f_mem"]]
