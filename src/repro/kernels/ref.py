"""Pure-jnp oracles for every Pallas kernel (same padded shapes, no Pallas).

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; the oracles
themselves are cross-checked against the algorithmic definitions in
``repro.core`` (memory.gru_cell, attention.sat_attention, time_encode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import NEG_INF  # single source of truth (see utils.py)


def gru_ref(mail: jax.Array, s: jax.Array, w_i: jax.Array, w_h: jax.Array,
            b_i: jax.Array, b_h: jax.Array,
            extra: jax.Array | None = None) -> jax.Array:
    m_p = s.shape[-1]
    gi = mail @ w_i + b_i
    if extra is not None:
        gi = gi + extra
    gh = s @ w_h + b_h
    r = jax.nn.sigmoid(gi[:, :m_p] + gh[:, :m_p])
    z = jax.nn.sigmoid(gi[:, m_p:2 * m_p] + gh[:, m_p:2 * m_p])
    n = jnp.tanh(gi[:, 2 * m_p:] + r * gh[:, 2 * m_p:])
    return (1.0 - z) * n + z * s


def lut_encode_ref(dt: jax.Array, bounds: jax.Array,
                   table: jax.Array) -> jax.Array:
    bucket = jnp.sum(dt[:, None] >= bounds[0], axis=1).astype(jnp.int32)
    return jnp.take(table, bucket, axis=0)


def sat_aggregate_ref(kv: jax.Array, dt: jax.Array, logits: jax.Array,
                      valid: jax.Array, w_v: jax.Array, b_v: jax.Array,
                      bounds: jax.Array, table: jax.Array) -> jax.Array:
    B, k, dkv = kv.shape
    v = kv.reshape(B * k, dkv) @ w_v
    v = v + lut_encode_ref(dt.reshape(B * k), bounds, table)
    v = (v + b_v).reshape(B, k, -1)
    masked = jnp.where(valid > 0, logits, NEG_INF)
    mx = jnp.max(masked, axis=1, keepdims=True)
    e = jnp.exp(masked - mx) * valid
    z = jnp.sum(e, axis=1, keepdims=True)
    attn = jnp.where(z > 0, e / jnp.maximum(z, 1e-30), 0.0)
    return jnp.sum(attn[:, :, None] * v, axis=1)
