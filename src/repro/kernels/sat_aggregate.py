"""Fused SAT aggregation Pallas kernel — the Embedding Unit (§IV-B) on TPU.

Covers the FLOP-heavy tail of the student model's embedding step, AFTER the
prune-then-fetch gather (top-k selection over (B, m_r) logits is metadata
work left to XLA; the gather itself is the HBM saving the paper is after and
happens before this kernel — only k rows per vertex ever reach it):

  v      = kv_sel @ W_v  +  LUT_folded[bucket(dt_sel)]  +  b_v     (Eq. 14,
           with the time-encoding rows pre-folded through W_v, §III-C)
  attn   = masked_softmax(sel_logits)                              (Eq. 16)
  h_agg  = sum_k attn_k * v_k                                      (FAM)

The LUT row fetch is realised as one_hot(bucket) @ table so it runs on the
MXU (TPU has no cheap scalar gather from VMEM; a (Bk,128)x(128,D) matmul is
fully pipelined) — see DESIGN.md §2.

Per grid step the working set is one batch tile of neighbors
(block_b * k, Dkv) plus the weights (Dkv, D) and the folded table (128, D) —
for paper dims (k<=10, Dkv=384, D=128) well under 2 MiB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils import NEG_INF  # single source of truth (see utils.py)
from repro.kernels.lut_time_encode import lut_rows


def _sat_kernel(kv_ref, dt_ref, logits_ref, valid_ref, w_v_ref, b_v_ref,
                bounds_ref, table_ref, out_ref, *, k: int, n_entries: int):
    """One batch tile.  Shapes (VMEM):
    kv (Bb, k*Dkv) — k pre-gathered neighbor rows, flattened;
    dt (Bb, k), logits (Bb, k), valid (Bb, k) float {0,1};
    w_v (Dkv, D), b_v (1, D), bounds (1, n_entries), table (n_entries, D);
    out (Bb, D).
    """
    bb = kv_ref.shape[0]
    dkv = kv_ref.shape[1] // k
    d = w_v_ref.shape[1]

    kv = kv_ref[...].reshape(bb * k, dkv)
    v = jnp.dot(kv, w_v_ref[...], preferred_element_type=jnp.float32)

    # LUT time rows (lut_time_encode.lut_rows: the one shared bucketing
    # definition across every kernel tier)
    dt = dt_ref[...].reshape(bb * k, 1)
    v = v + lut_rows(dt, bounds_ref, table_ref, n_entries)
    v = v + b_v_ref[...]
    v = v.reshape(bb, k, d)

    # masked softmax over the k surviving neighbors
    valid = valid_ref[...]
    logits = jnp.where(valid > 0, logits_ref[...], NEG_INF)
    mx = jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits - mx) * valid
    z = jnp.sum(e, axis=1, keepdims=True)
    attn = jnp.where(z > 0, e / jnp.maximum(z, 1e-30), 0.0)  # (Bb, k)

    out_ref[...] = jnp.sum(attn[:, :, None] * v, axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sat_aggregate_pallas(kv: jax.Array, dt: jax.Array, logits: jax.Array,
                         valid: jax.Array, w_v: jax.Array, b_v: jax.Array,
                         bounds: jax.Array, table: jax.Array,
                         *, block_b: int = 128,
                         interpret: bool = False) -> jax.Array:
    """Fused V-projection + LUT + masked-softmax aggregation.

    kv (B, k, Dkv) float32 — pruned, pre-gathered neighbor features (memory
    || edge feature), zero where invalid; dt/logits (B, k); valid (B, k)
    float {0,1}; w_v (Dkv, D); b_v (1, D); bounds (1, E); table (E, D).
    B multiple of block_b; Dkv and D LANE-aligned. Returns (B, D).
    """
    B, k, dkv = kv.shape
    d = w_v.shape[1]
    E = table.shape[0]
    assert B % block_b == 0, (B, block_b)
    assert bounds.shape == (1, E)
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_sat_kernel, k=k, n_entries=E),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, k * dkv), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((dkv, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
            pl.BlockSpec((E, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(kv.reshape(B, k * dkv), dt, logits, valid, w_v, b_v, bounds, table)
