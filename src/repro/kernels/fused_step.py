"""Fused single-pass step kernel — the paper's §IV pipelined datapath on TPU.

The paper's hardware contribution (Fig. 4) is a *single-pass* datapath: a
prefetcher pulls ONLY the pruned winners' rows out of Graph Storage, and
sampler -> time-LUT -> attention (EU) -> memory update (MUU) stream through
on-chip buffers without ever round-tripping to off-chip memory. The staged
Pallas tier reproduces each unit as its own kernel, but every stage boundary
(the ``(B, k, Dkv)`` neighbor tensor, the kv concat, the LUT rows, the GRU
inputs) is a full HBM materialization XLA schedules between launches.

This kernel is the whole post-prune datapath in ONE ``pallas_call``:

  * the pruned winner indices (``sel_ids``/``sel_eid``) plus the involved
    vertex ids arrive as **scalar-prefetched** operands (SMEM) — metadata
    computed from timestamps/ids only, upstream, preserving the
    prune-then-fetch contract of §III-B;
  * the vertex memory / mailbox / edge-feature tables stay in HBM
    (``memory_space=ANY``); per batch tile the kernel DMAs exactly the k
    winner rows (plus the tile's own mail/memory rows) into VMEM — the jax
    analogue of the paper's prefetcher;
  * phase 0 (MUU): mail rows through the fused LUT+GRU -> updated memory
    rows, written both to the ``s_upd`` output and to a persistent VMEM
    scratch that spans the whole batch;
  * phase 1 (EU): winner-row gather (neighbors updated by THIS batch are
    read back from the phase-0 scratch, not from stale HBM — the
    chronological-commit view the staged path gets from its scatter),
    split-matmul kv projection (no concat), folded-LUT time rows, masked
    softmax, FAM reduction and the output transform.

The TPU grid is sequential, so ``grid=(2, T)`` runs every phase-0 tile
before any phase-1 tile — exactly the MUU->commit->EU ordering of
Algorithm 1 — and the scratch carries the updated rows across grid steps.

VMEM working set per tile (fp32 words): the persistent updated-row buffer
``R_p x m_p`` plus gather buffers ``block_b x f_p`` (mail) and
``block_b*k x (m_p + e_p)`` (neighbors) plus the weights
(``f_p x 3m_p + m_p x 3m_p + m_p x d_p + e_p x d_p + 2 E x (3m_p|d_p)``).
For paper dims (B=256 -> R=512, k=4, f_mem=100, f_edge=172, E=128) that is
~2.1 MiB — comfortably inside one core's 16 MiB.

Per-row copies are issued through one DMA semaphore with an immediate
wait; a production kernel would rotate a semaphore array to keep several
row fetches in flight, which changes no numerics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import NEG_INF
from repro.kernels.lut_time_encode import lut_rows


def _fused_kernel(  # scalar prefetch (SMEM)
                  vids_ref, sel_ids_ref, sel_eid_ref, hit_ref,
                  # grid-blocked VMEM operands
                  dt_mail_ref, mail_ok_ref, sel_dt_ref, logits_ref,
                  valid_ref,
                  # HBM-resident tables (manual DMA)
                  mem_hbm, mail_hbm, ef_hbm,
                  # weights (VMEM, whole-array blocks)
                  w_i_ref, w_h_ref, b_i_ref, b_h_ref, gb_ref, gt_ref,
                  wv_mem_ref, wv_edge_ref, b_v_ref, sb_ref, st_ref,
                  w_self_ref, w_agg_ref, b_out_ref,
                  # outputs
                  h_ref, supd_ref,
                  # scratch
                  supd_all, mail_scr, self_scr, nbr_s, nbr_e, sem,
                  *, k: int, f_mem: int, f_mail: int, f_edge: int,
                  n_entries: int, block_b: int):
    """One grid step of the two-phase single-pass datapath (see module
    docstring for the shapes)."""
    ph = pl.program_id(0)
    t = pl.program_id(1)
    bb = block_b
    m_p = supd_all.shape[1]

    @pl.when(ph == 0)
    def _muu():
        # --- prefetch: this tile's mail + pre-update memory rows ----------
        mail_scr[...] = jnp.zeros_like(mail_scr)
        self_scr[...] = jnp.zeros_like(self_scr)

        def fetch(i, _):
            v = vids_ref[t * bb + i]
            cp = pltpu.make_async_copy(mail_hbm.at[v],
                                       mail_scr.at[i, pl.ds(0, f_mail)], sem)
            cp.start()
            cp.wait()
            cp = pltpu.make_async_copy(mem_hbm.at[v],
                                       self_scr.at[i, pl.ds(0, f_mem)], sem)
            cp.start()
            cp.wait()
            return 0

        jax.lax.fori_loop(0, bb, fetch, 0)

        # --- fused LUT + GRU (gate blocks at m_p strides) -----------------
        gi = jnp.dot(mail_scr[...], w_i_ref[...],
                     preferred_element_type=jnp.float32)
        gi = gi + b_i_ref[...]
        gi = gi + lut_rows(dt_mail_ref[...], gb_ref, gt_ref, n_entries)
        s_prev = self_scr[...]
        gh = jnp.dot(s_prev, w_h_ref[...],
                     preferred_element_type=jnp.float32) + b_h_ref[...]
        r = jax.nn.sigmoid(gi[:, :m_p] + gh[:, :m_p])
        z = jax.nn.sigmoid(gi[:, m_p:2 * m_p] + gh[:, m_p:2 * m_p])
        n = jnp.tanh(gi[:, 2 * m_p:] + r * gh[:, 2 * m_p:])
        s_new = (1.0 - z) * n + z * s_prev
        s_upd = jnp.where(mail_ok_ref[...] > 0, s_new, s_prev)

        # persist for phase 1 (self rows AND same-batch neighbor overrides)
        supd_all[pl.ds(t * bb, bb), :] = s_upd
        supd_ref[...] = s_upd
        h_ref[...] = jnp.zeros_like(h_ref)

    @pl.when(ph == 1)
    def _eu():
        # --- prefetch: ONLY the k winners' memory/edge rows per vertex ----
        # Winners whose vertex was updated by THIS batch (hit >= 0) are
        # read back from the phase-0 scratch — the committed view — so the
        # kernel never needs the scatter/gather round-trip through HBM.
        nbr_s[...] = jnp.zeros_like(nbr_s)
        if f_edge:
            nbr_e[...] = jnp.zeros_like(nbr_e)

        def fetch(j, _):
            f = t * bb * k + j
            hit = hit_ref[f]

            @pl.when(hit >= 0)
            def _():
                cp = pltpu.make_async_copy(supd_all.at[hit], nbr_s.at[j],
                                           sem)
                cp.start()
                cp.wait()

            @pl.when(hit < 0)
            def _():
                cp = pltpu.make_async_copy(
                    mem_hbm.at[sel_ids_ref[f]],
                    nbr_s.at[j, pl.ds(0, f_mem)], sem)
                cp.start()
                cp.wait()

            if f_edge:
                cp = pltpu.make_async_copy(
                    ef_hbm.at[sel_eid_ref[f]],
                    nbr_e.at[j, pl.ds(0, f_edge)], sem)
                cp.start()
                cp.wait()
            return 0

        jax.lax.fori_loop(0, bb * k, fetch, 0)

        # --- kv projection WITHOUT the concat: two split matmuls ----------
        v = jnp.dot(nbr_s[...], wv_mem_ref[...],
                    preferred_element_type=jnp.float32)
        if f_edge:
            v = v + jnp.dot(nbr_e[...], wv_edge_ref[...],
                            preferred_element_type=jnp.float32)
        dt = sel_dt_ref[...].reshape(bb * k, 1)
        v = v + lut_rows(dt, sb_ref, st_ref, n_entries)
        v = v + b_v_ref[...]
        d_p = v.shape[1]
        v = v.reshape(bb, k, d_p)

        # --- masked softmax over the k winners (Eq. 16) -------------------
        valid = valid_ref[...]
        logits = jnp.where(valid > 0, logits_ref[...], NEG_INF)
        mx = jnp.max(logits, axis=1, keepdims=True)
        e = jnp.exp(logits - mx) * valid
        zs = jnp.sum(e, axis=1, keepdims=True)
        attn = jnp.where(zs > 0, e / jnp.maximum(zs, 1e-30), 0.0)

        # --- FAM reduction + output transform (split, no concat) ---------
        agg = jnp.sum(attn[:, :, None] * v, axis=1)
        fp = supd_all[pl.ds(t * bb, bb), :]
        h = jnp.dot(fp, w_self_ref[...],
                    preferred_element_type=jnp.float32)
        h = h + jnp.dot(agg, w_agg_ref[...],
                        preferred_element_type=jnp.float32)
        h_ref[...] = h + b_out_ref[...]
        supd_ref[...] = fp


@functools.partial(jax.jit, static_argnames=("k", "f_mem", "f_mail",
                                             "f_edge", "block_b",
                                             "interpret"))
def fused_step_pallas(vids, sel_ids, sel_eid, hit, dt_mail, mail_ok,
                      sel_dt, sel_logits, sel_valid,
                      memory, mail, edge_feats,
                      w_i, w_h, b_i, b_h, g_bounds, g_table,
                      wv_mem, wv_edge, b_v, s_bounds, s_table,
                      w_self, w_agg, b_out,
                      *, k: int, f_mem: int, f_mail: int, f_edge: int,
                      block_b: int, interpret: bool = False):
    """One launch for the post-prune datapath of one batch.

    Scalar prefetch (int32): ``vids`` (R,), flat ``sel_ids``/``sel_eid``/
    ``hit`` (R*k,) — ``hit[f] >= 0`` redirects winner ``f`` to the phase-0
    updated row (its vertex was committed by this batch). Blocked operands:
    ``dt_mail``/``mail_ok`` (R, 1), ``sel_dt``/``sel_logits``/``sel_valid``
    (R, k). HBM tables: ``memory`` (V, f_mem), ``mail`` (V, f_mail),
    ``edge_feats`` (E_rows, f_edge). Weights are kernel-layout (lane-padded
    OUT dims, gate blocks at m_p strides; see ops.pad_fused_params).
    R must be a multiple of ``block_b``. Returns ``(h, s_upd)`` —
    (R, emb_p) embeddings and (R, m_p) updated memory rows.
    """
    R = vids.shape[0]
    assert R % block_b == 0, (R, block_b)
    m_p = w_h.shape[0]
    d_p = wv_mem.shape[1]
    e_p = wv_edge.shape[0]
    emb_p = w_self.shape[1]
    E = g_table.shape[0]
    f_p = w_i.shape[0]
    T = R // block_b
    nk = block_b * k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(2, T),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda ph, t, *_: (t, 0)),
            pl.BlockSpec((block_b, 1), lambda ph, t, *_: (t, 0)),
            pl.BlockSpec((block_b, k), lambda ph, t, *_: (t, 0)),
            pl.BlockSpec((block_b, k), lambda ph, t, *_: (t, 0)),
            pl.BlockSpec((block_b, k), lambda ph, t, *_: (t, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),       # memory table
            pl.BlockSpec(memory_space=pltpu.ANY),       # mailbox table
            pl.BlockSpec(memory_space=pltpu.ANY),       # edge features
            pl.BlockSpec((f_p, 3 * m_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((m_p, 3 * m_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((1, 3 * m_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((1, 3 * m_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((1, E), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((E, 3 * m_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((m_p, d_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((e_p, d_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((1, d_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((1, E), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((E, d_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((m_p, emb_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((d_p, emb_p), lambda ph, t, *_: (0, 0)),
            pl.BlockSpec((1, emb_p), lambda ph, t, *_: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, emb_p), lambda ph, t, *_: (t, 0)),
            pl.BlockSpec((block_b, m_p), lambda ph, t, *_: (t, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((R, m_p), jnp.float32),          # updated rows
            pltpu.VMEM((block_b, f_p), jnp.float32),    # mail tile
            pltpu.VMEM((block_b, m_p), jnp.float32),    # pre-update memory
            pltpu.VMEM((nk, m_p), jnp.float32),         # winner memory rows
            pltpu.VMEM((nk, e_p), jnp.float32),         # winner edge rows
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, k=k, f_mem=f_mem, f_mail=f_mail,
                          f_edge=f_edge, n_entries=E, block_b=block_b),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((R, emb_p), jnp.float32),
                   jax.ShapeDtypeStruct((R, m_p), jnp.float32)],
        interpret=interpret,
    )(vids, sel_ids, sel_eid, hit, dt_mail, mail_ok, sel_dt, sel_logits,
      sel_valid, memory, mail, edge_feats, w_i, w_h, b_i, b_h, g_bounds,
      g_table, wv_mem, wv_edge, b_v, s_bounds, s_table, w_self, w_agg,
      b_out)
