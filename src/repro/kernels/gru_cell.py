"""Fused GRU memory-update Pallas kernel — the MUU (§IV-B) on TPU.

The paper maps each GRU gate to an S_g x S_g DSP multiply-accumulate array and
pipelines the gates through FIFOs. On TPU the analogous design is ONE kernel
invocation per batch tile that:

  1. computes the packed input projection  gi = mail @ W_i   (one MXU matmul
     covering all three gates: W_i is (f_mail, 3*m) with gate blocks at
     lane-aligned m strides),
  2. computes the packed hidden projection gh = s @ W_h,
  3. fuses the gate nonlinearities and the convex memory merge in VREGs —
     no HBM round-trip between the matmuls and the elementwise tail.

Block layout: the batch axis is tiled (block_b rows per grid step); weights
are small enough (f_mail_p x 3*m_p fp32 < 1 MiB for the paper dims) to pin
fully in VMEM for every grid step, the TPU analogue of the paper keeping
"learnable parameters on-chip".

All feature dims must be pre-padded to LANE (=128) multiples by the caller
(see ops.pad_gru_params); zero padding is a fixed point of the GRU tail, so
padded columns stay exactly zero (asserted in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(mail_ref, s_ref, extra_ref, w_i_ref, w_h_ref, b_i_ref,
                b_h_ref, out_ref, *, m_p: int):
    """One batch tile: out = GRU(mail, s). Shapes (VMEM):
    mail (Bb, F), s (Bb, M), extra (Bb, 3M) — per-row additive input-gate
    contribution (the LUT-folded time rows, §III-C; zeros when unused),
    w_i (F, 3M), w_h (M, 3M), b_* (1, 3M), out (Bb, M).
    """
    mail = mail_ref[...]
    s = s_ref[...]
    gi = jnp.dot(mail, w_i_ref[...], preferred_element_type=jnp.float32)
    gi = gi + b_i_ref[...] + extra_ref[...]
    gh = jnp.dot(s, w_h_ref[...], preferred_element_type=jnp.float32)
    gh = gh + b_h_ref[...]
    # gate blocks live at lane-aligned strides [r | z | n]
    i_r, i_z, i_n = gi[:, :m_p], gi[:, m_p:2 * m_p], gi[:, 2 * m_p:]
    h_r, h_z, h_n = gh[:, :m_p], gh[:, m_p:2 * m_p], gh[:, 2 * m_p:]
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    out_ref[...] = (1.0 - z) * n + z * s


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def gru_cell_pallas(mail: jax.Array, s: jax.Array, extra: jax.Array,
                    w_i: jax.Array, w_h: jax.Array, b_i: jax.Array,
                    b_h: jax.Array, *, block_b: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Fused GRU cell. All dims must already be LANE-aligned:
    mail (B, F), s (B, M), extra (B, 3M), w_i (F, 3M), w_h (M, 3M),
    b_i/b_h (1, 3M). B must be a multiple of block_b. Returns (B, M) fp32.
    """
    B, F = mail.shape
    M = s.shape[-1]
    assert B % block_b == 0, (B, block_b)
    assert w_i.shape == (F, 3 * M) and w_h.shape == (M, 3 * M)
    assert extra.shape == (B, 3 * M)
    grid = (B // block_b,)
    return pl.pallas_call(
        functools.partial(_gru_kernel, m_p=M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, F), lambda i: (i, 0)),
            pl.BlockSpec((block_b, M), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 3 * M), lambda i: (i, 0)),
            pl.BlockSpec((F, 3 * M), lambda i: (0, 0)),
            pl.BlockSpec((M, 3 * M), lambda i: (0, 0)),
            pl.BlockSpec((1, 3 * M), lambda i: (0, 0)),
            pl.BlockSpec((1, 3 * M), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(mail, s, extra, w_i, w_h, b_i, b_h)
