"""Temporal attention aggregators.

Teacher — vanilla transformer-style temporal attention (Eq. 11-15):
    f'_i = s_i + W_s f_i + b_s
    q    = W_q [f'_i || Phi(0)] + b_q
    K    = W_k [f'_j || e_ij || Phi(dt_j)] + b_k
    V    = W_v [f'_j || e_ij || Phi(dt_j)] + b_v
    h_i  = softmax(q K^T / sqrt(d)) V            (multi-head generalisation)

Student — Simplified temporal Attention (SAT, Eq. 16):
    alpha'(u) = softmax(a + W_t dt^u)            logits from timestamps ONLY
followed by top-k neighbor pruning (§III-B) and a V-projection of just the
surviving neighbors. The output transform (FTM analogue) is shared:
    h_i = W_out [f'_i || h~_i] + b_out

Both return their pre-softmax logits so the distillation loss (Eq. 17) can
align student and teacher score distributions.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, dense_init
from repro.core import time_encode as te
from repro.core import pruning

NEG_INF = pruning.NEG_INF


@dataclasses.dataclass(frozen=True)
class AttnConfig(FrozenConfig):
    f_mem: int = 100
    f_feat: int = 0          # static node feature dim (0 on Wikipedia/Reddit)
    f_edge: int = 172
    f_time: int = 100
    f_emb: int = 100
    n_heads: int = 2         # teacher heads (TGN default)
    m_r: int = 10            # neighbor buffer width
    prune_k: int | None = None   # SAT pruning budget; None = keep all m_r

    @property
    def d_kv_in(self) -> int:
        return self.f_mem + self.f_edge + self.f_time

    @property
    def d_q_in(self) -> int:
        return self.f_mem + self.f_time


# ---------------------------------------------------------------------------
# Shared input transform
# ---------------------------------------------------------------------------


def init_feat_proj(key: jax.Array, cfg: AttnConfig) -> dict:
    p = {}
    if cfg.f_feat > 0:
        p["w_s"] = dense_init(key, (cfg.f_feat, cfg.f_mem))
        p["b_s"] = jnp.zeros((cfg.f_mem,), jnp.float32)
    return p


def feat_proj(params: dict, s: jax.Array, f: jax.Array | None) -> jax.Array:
    """f'_i = s_i + W_s f_i + b_s   (Eq. 11; identity when f_feat == 0)."""
    if "w_s" in params and f is not None:
        return s + f @ params["w_s"] + params["b_s"]
    return s


# ---------------------------------------------------------------------------
# Teacher: vanilla temporal attention
# ---------------------------------------------------------------------------


def init_vanilla(key: jax.Array, cfg: AttnConfig) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.f_emb
    return {
        "feat": init_feat_proj(ks[0], cfg),
        "w_q": dense_init(ks[1], (cfg.d_q_in, d)),
        "b_q": jnp.zeros((d,), jnp.float32),
        "w_k": dense_init(ks[2], (cfg.d_kv_in, d)),
        "b_k": jnp.zeros((d,), jnp.float32),
        "w_v": dense_init(ks[3], (cfg.d_kv_in, d)),
        "b_v": jnp.zeros((d,), jnp.float32),
        "w_out": dense_init(ks[4], (cfg.f_mem + d, cfg.f_emb)),
        "b_out": jnp.zeros((cfg.f_emb,), jnp.float32),
    }


def vanilla_attention(params: dict, cfg: AttnConfig, time_params: dict,
                      s_self: jax.Array, f_self: jax.Array | None,
                      s_nbr: jax.Array, e_nbr: jax.Array, dt_nbr: jax.Array,
                      valid: jax.Array):
    """Teacher aggregator.

    s_self (B, f_mem); s_nbr (B, m_r, f_mem); e_nbr (B, m_r, f_edge);
    dt_nbr (B, m_r) time deltas (t_query - t_interaction); valid (B, m_r).
    Returns (h (B, f_emb), logits (B, m_r) head-mean pre-softmax scores).
    """
    B, m_r = dt_nbr.shape
    H = cfg.n_heads
    fp = feat_proj(params["feat"], s_self, f_self)

    phi0 = te.cosine_encode(time_params, jnp.zeros((B,), jnp.float32))
    q_in = jnp.concatenate([fp, phi0], axis=-1)
    q = (q_in @ params["w_q"] + params["b_q"]).reshape(B, H, -1)

    phi = te.cosine_encode(time_params, dt_nbr)
    kv_in = jnp.concatenate([s_nbr, e_nbr, phi], axis=-1)
    k = (kv_in @ params["w_k"] + params["b_k"]).reshape(B, m_r, H, -1)
    v = (kv_in @ params["w_v"] + params["b_v"]).reshape(B, m_r, H, -1)

    d_h = q.shape[-1]
    scores = jnp.einsum("bhd,bnhd->bhn", q, k) / math.sqrt(d_h)
    attn = pruning.masked_softmax(scores, valid[:, None, :])
    agg = jnp.einsum("bhn,bnhd->bhd", attn, v).reshape(B, -1)

    h = jnp.concatenate([fp, agg], axis=-1) @ params["w_out"] + params["b_out"]
    logits = jnp.mean(scores, axis=1)  # (B, m_r) for distillation
    return h, logits


# ---------------------------------------------------------------------------
# Student: SAT (+ optional pruning)
# ---------------------------------------------------------------------------


def init_sat(key: jax.Array, cfg: AttnConfig) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.f_emb
    return {
        "feat": init_feat_proj(ks[0], cfg),
        "a": jnp.zeros((cfg.m_r,), jnp.float32),          # shared logit vector
        "w_t": dense_init(ks[1], (cfg.m_r, cfg.m_r), scale=0.01),
        "w_v": dense_init(ks[2], (cfg.d_kv_in, d)),
        "b_v": jnp.zeros((d,), jnp.float32),
        "w_out": dense_init(ks[3], (cfg.f_mem + d, cfg.f_emb)),
        "b_out": jnp.zeros((cfg.f_emb,), jnp.float32),
    }


def sat_logits(params: dict, dt_nbr: jax.Array) -> jax.Array:
    """alpha-bar' = a + W_t dt  (Eq. 16). dt is log1p-compressed for numeric
    stability (time spans decades; raw dt saturates the linear map — a
    numerics adaptation recorded in DESIGN.md)."""
    dtf = jnp.log1p(jnp.maximum(dt_nbr, 0.0))
    return params["a"] + dtf @ params["w_t"].T


def sat_attention(params: dict, cfg: AttnConfig, time_params: dict,
                  s_self: jax.Array, f_self: jax.Array | None,
                  s_nbr: jax.Array, e_nbr: jax.Array, dt_nbr: jax.Array,
                  valid: jax.Array, *, encoder: str = "cosine",
                  lut_folded: dict | None = None):
    """Student aggregator with prune-then-fetch.

    NOTE on dataflow: in the streaming engine the top-k indices are computed
    BEFORE s_nbr/e_nbr are gathered from the sharded tables (that is the whole
    point — see serving/engine.py); this function also accepts pre-gathered
    full buffers for the training path, pruning them internally so both paths
    share one definition. Returns (h, full logits (B, m_r)).
    """
    B, m_r = dt_nbr.shape
    fp = feat_proj(params["feat"], s_self, f_self)
    logits = sat_logits(params, dt_nbr)

    if cfg.prune_k is not None and cfg.prune_k < m_r:
        idx, sel_logits, sel_valid = pruning.topk_select(logits, valid, cfg.prune_k)
        s_sel = pruning.gather_rows(s_nbr, idx)
        e_sel = pruning.gather_rows(e_nbr, idx)
        dt_sel = jnp.take_along_axis(dt_nbr, idx, axis=1)
        attn = pruning.masked_softmax(sel_logits, sel_valid)
    else:
        s_sel, e_sel, dt_sel, sel_valid = s_nbr, e_nbr, dt_nbr, valid
        attn = pruning.masked_softmax(logits, valid)

    if encoder == "lut":
        folded = lut_folded
        if folded is None:
            folded = te.fold_projection(
                time_params, params["w_v"][cfg.f_mem + cfg.f_edge:])
        v = (jnp.concatenate([s_sel, e_sel], axis=-1)
             @ params["w_v"][:cfg.f_mem + cfg.f_edge]
             + te.lut_encode(folded, dt_sel) + params["b_v"])
    else:
        phi = te.cosine_encode(time_params, dt_sel)
        kv_in = jnp.concatenate([s_sel, e_sel, phi], axis=-1)
        v = kv_in @ params["w_v"] + params["b_v"]

    agg = jnp.einsum("bn,bnd->bd", attn, v)
    h = jnp.concatenate([fp, agg], axis=-1) @ params["w_out"] + params["b_out"]
    return h, logits
