"""Analytic MAC / memory-access model of TGN-attn inference (Tables I & II).

Counting conventions (documented because the paper's own convention is not
fully specified; we reproduce the paper's RELATIVE reductions — the headline
"84% computation / 67% memory-access reduction" — under these conventions and
report both absolute and relative numbers side by side in
``benchmarks/table2_model_opts.py``):

  * one MAC = one multiply-accumulate; a dense (n_in -> n_out) layer applied
    to one vector costs n_in * n_out MACs (biases and activations free);
  * one MEM = one scalar element read from / written to EXTERNAL memory
    (vertex mailbox, memory table, neighbor table, edge/node feature stores);
    learnable parameters are assumed resident on-chip, per the paper;
  * everything is counted per *dynamic node embedding*, i.e. per vertex
    instance of an edge batch (each edge contributes 2 instances), matching
    Table I's "per dynamic node embedding" unit.

Stage split follows the paper: sample / memory / GNN / update.
"""
from __future__ import annotations

import dataclasses

from repro.utils import FrozenConfig


@dataclasses.dataclass(frozen=True)
class ComplexityConfig(FrozenConfig):
    f_mem: int = 100
    f_feat: int = 0          # static node feature dim (GDELT: 200)
    f_edge: int = 172        # edge feature dim (Wikipedia/Reddit: 172)
    f_time: int = 100
    f_emb: int = 100
    m_r: int = 10            # neighbor buffer width
    attention: str = "vanilla"   # "vanilla" | "sat"
    encoder: str = "cosine"      # "cosine" | "lut"
    prune_k: int | None = None   # neighbors aggregated (None = m_r)
    lut_entries: int = 128

    @property
    def k_eff(self) -> int:
        return self.prune_k if self.prune_k is not None else self.m_r

    @property
    def f_mail(self) -> int:
        return 2 * self.f_mem + self.f_edge + self.f_time


def stage_macs(cfg: ComplexityConfig) -> dict:
    """MACs per dynamic node embedding, by stage."""
    m, t, e, d = cfg.f_mem, cfg.f_time, cfg.f_edge, cfg.f_emb
    k = cfg.k_eff

    # ---- sample: index manipulation only ---------------------------------
    sample = 0

    # ---- memory: time encode + GRU ----------------------------------------
    # time encoding of the cached message's dt
    if cfg.encoder == "cosine":
        te_mem = t                       # omega*dt (cos is free like activations)
        gru_in = cfg.f_mail              # message includes the Phi(dt) slice
        gru = 3 * gru_in * m + 3 * m * m
    else:
        te_mem = 0                       # LUT row fetch, zero MACs
        gru_in = cfg.f_mail - t          # time rows pre-folded into the table
        gru = 3 * gru_in * m + 3 * m * m
    memory = te_mem + gru

    # ---- GNN: attention aggregation ---------------------------------------
    w_s = cfg.f_feat * m if cfg.f_feat else 0          # f' = s + W_s f
    if cfg.attention == "vanilla":
        te_gnn = t * (1 + cfg.m_r) if cfg.encoder == "cosine" else 0
        q = (m + t) * d
        kk = cfg.m_r * (m + e + t) * d
        v = cfg.m_r * (m + e + t) * d
        scores = cfg.m_r * d             # q . k per neighbor
        agg = cfg.m_r * d                # alpha * v
        out = (m + d) * d
        gnn = w_s + te_gnn + q + kk + v + scores + agg + out
    else:
        # SAT: logits from dt only (a + W_t dt), no q/K; V only for the k
        # surviving neighbors; with LUT the time slice of W_v is pre-folded.
        sat_logits = cfg.m_r * cfg.m_r   # W_t is (m_r, m_r)
        if cfg.encoder == "cosine":
            te_gnn = t * k
            v = k * (m + e + t) * d
        else:
            te_gnn = 0
            v = k * (m + e) * d
        agg = k * d
        out = (m + d) * d
        gnn = w_s + sat_logits + te_gnn + v + agg + out

    # ---- update: writes only ----------------------------------------------
    update = 0

    return {"sample": sample, "memory": memory, "GNN": gnn, "update": update,
            "total": sample + memory + gnn + update}


def stage_mems(cfg: ComplexityConfig) -> dict:
    """External-memory element accesses per dynamic node embedding, by stage.

    Convention (reproduces Table I/II MEM columns on Wikipedia/Reddit exactly,
    including the 0.3% / 91.4% / 8.3% stage split): TGN refreshes the memory
    of every node in the computation graph — self AND sampled neighbors — so
    the memory stage fetches, per node, its cached mail (raw part + ts) and
    its memory vector (+ last_update): (2*f_mem + f_edge + 1) + (f_mem + 1)
    elements. With pruning, only the k surviving neighbors are fetched
    (prune-then-fetch). Static node features are fetched per node where the
    dataset has them (GDELT).
    """
    m = cfg.f_mem
    k = cfg.k_eff

    # sample: read neighbor-table row (ids + timestamps)
    sample = 2 * cfg.m_r

    # memory: (self + k neighbors) x (mail + memory [+ node feature])
    per_node = (2 * m + cfg.f_edge + 1) + (m + 1) + cfg.f_feat
    memory = (1 + k) * per_node

    # GNN: compute only (operands already on-chip once the memory stage
    # staged them)
    gnn = 0

    # update: write back memory + last_update, the new mail (+ts+valid), and
    # the neighbor ring-buffer row (id, ts, eid)
    update = (m + 1) + (2 * m + cfg.f_edge + 2) + 3

    return {"sample": sample, "memory": memory, "GNN": gnn, "update": update,
            "total": sample + memory + gnn + update}


# ---------------------------------------------------------------------------
# Table II variant ladder
# ---------------------------------------------------------------------------

VARIANT_LADDER = (
    ("Baseline", dict(attention="vanilla", encoder="cosine", prune_k=None)),
    ("+SAT", dict(attention="sat", encoder="cosine", prune_k=None)),
    ("+LUT", dict(attention="sat", encoder="lut", prune_k=None)),
    ("+NP(L)", dict(attention="sat", encoder="lut", prune_k=6)),
    ("+NP(M)", dict(attention="sat", encoder="lut", prune_k=4)),
    ("+NP(S)", dict(attention="sat", encoder="lut", prune_k=2)),
)

DATASETS = {
    # name: (f_feat, f_edge) — dims per the paper's Table II header
    "Wikipedia": (0, 172),
    "Reddit": (0, 172),
    "GDELT": (200, 0),
}

# The paper's own relative totals (% of baseline kMAC) for validation.
PAPER_MAC_PERCENT = {
    "Baseline": 100.0, "+SAT": 53.1, "+LUT": 37.0,
    "+NP(L)": 25.9, "+NP(M)": 20.3, "+NP(S)": 14.8,
}
PAPER_MEM_PERCENT = {   # derived from Table II kMEM columns (Wikipedia)
    "Baseline": 100.0, "+SAT": 100.0, "+LUT": 100.0,
    "+NP(L)": 66.7, "+NP(M)": 50.9, "+NP(S)": 33.3,
}


def table2(dataset: str = "Wikipedia", base: ComplexityConfig | None = None):
    """The accumulated-optimization ladder (Table II): returns a list of rows
    ``(name, macs_by_stage, mems_by_stage, mac_pct, mem_pct)``."""
    f_feat, f_edge = DATASETS[dataset]
    base = base or ComplexityConfig(f_feat=f_feat, f_edge=f_edge)
    base = base.replace(f_feat=f_feat, f_edge=f_edge)
    rows = []
    base_mac = base_mem = None
    for name, kw in VARIANT_LADDER:
        cfg = base.replace(**kw)
        macs, mems = stage_macs(cfg), stage_mems(cfg)
        if base_mac is None:
            base_mac, base_mem = macs["total"], mems["total"]
        rows.append((name, macs, mems,
                     100.0 * macs["total"] / base_mac,
                     100.0 * mems["total"] / base_mem))
    return rows


def headline_reductions(dataset: str = "Wikipedia") -> dict:
    """The paper's headline claim: computation/memory-access reduction of the
    fully-optimized model (NP(S)) vs baseline."""
    rows = table2(dataset)
    _, m0, e0, _, _ = rows[0]
    _, m1, e1, _, _ = rows[-1]
    return {
        "mac_reduction": 1.0 - m1["total"] / m0["total"],
        "mem_reduction": 1.0 - e1["total"] / e0["total"],
    }
