"""Temporal neighbor pruning (§III-B): score-then-fetch.

Because SAT logits depend only on timestamps, the top-k neighbor subset is
known *before* any feature/memory gather — computation and HBM traffic then
scale with the pruning budget k instead of the buffer width m_r. NP(L/M/S)
in the paper are k = 6/4/2 with m_r = 10.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import NEG_INF  # single source of truth (see utils.py)

__all__ = ["NEG_INF", "topk_select", "masked_softmax", "gather_rows"]


def topk_select(logits: jax.Array, valid: jax.Array, k: int):
    """Select the k highest-logit valid neighbors.

    logits, valid: (B, m_r). Returns (idx, sel_logits, sel_valid):
      idx        (B, k) int32 — positions into the m_r axis
      sel_logits (B, k) — logits of the selected slots (NEG_INF where invalid)
      sel_valid  (B, k) bool — whether the selected slot was a valid neighbor
    """
    masked = jnp.where(valid, logits, NEG_INF)
    sel_logits, idx = jax.lax.top_k(masked, k)
    sel_valid = jnp.take_along_axis(valid, idx, axis=1)
    return idx.astype(jnp.int32), sel_logits, sel_valid


def masked_softmax(logits: jax.Array, valid: jax.Array) -> jax.Array:
    """Softmax over valid entries; rows with zero valid entries return zeros."""
    masked = jnp.where(valid, logits, NEG_INF)
    m = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.exp(masked - jax.lax.stop_gradient(m)) * valid
    z = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.where(z > 0, e / jnp.maximum(z, 1e-30), 0.0)


def gather_rows(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather (B, m_r, d) -> (B, k, d) rows by per-row indices (B, k)."""
    return jnp.take_along_axis(x, idx[..., None], axis=1)
