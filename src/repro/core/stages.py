"""Algorithm 1 decomposed into pluggable stage interfaces.

Every Table-II variant (teacher included) is one composition of five stages;
``core/pipeline.py`` holds the registry and the composing ``TGNPipeline``:

  MemoryUpdater  (MUU)    consume cached mail -> updated memory rows.
                          cosine | LUT-reference | LUT-Pallas backends.
  NeighborSampler         read the ring buffer and produce the Neighborhood
                          the aggregator consumes. Two dataflows:
                            * fetch-all        (vanilla attention needs the
                              full m_r rows of memory/edge features)
                            * prune-then-fetch (selection from timestamps/ids
                              ONLY -> top-k -> gather just k rows; the HBM
                              saving the paper measures, §III-B)
                          Prune-then-fetch selection is a pluggable policy
                          (``SAMPLERS``): "recent" (SAT top-k, the paper),
                          "uniform", or time-decayed "reservoir" — both
                          randomized policies use a stateless hash so
                          serving stays deterministic and vmap-batchable.
  Aggregator     (EU)     vanilla attention | SAT reference | SAT-Pallas.
  Committer               chronological last-write-wins commit of memory and
                          cached mail (§IV-B). Winners are computed ONCE per
                          batch and shared by both commits.
  (insert)                neighbor ring-buffer FIFO insertion stays in
                          core/mailbox.py — it is parameter-free and common
                          to every variant.

Stages are pure closures built from a frozen ``TGNConfig``; per-call inputs
are ``(params, aux, ...)`` where ``aux = prepare(params)`` carries every
derived table (folded LUT rows, lane-packed Pallas parameters). Training
paths recompute ``aux`` inside the traced step so gradients flow through the
folds; the serving engine computes it once at session construction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import attention as attn_mod
from repro.core import mailbox, memory, pruning, time_encode as te
from repro.core import updater


#: Kernel-backend tiers. ``use_kernels`` everywhere accepts a tier name or
#: the legacy booleans (False -> "ref", True -> "staged"):
#:   ref     pure-jnp stage references (the numerics oracle)
#:   staged  one Pallas kernel per unit (LUT encode, GRU, SAT aggregate) —
#:           stage boundaries still materialize HBM intermediates
#:   fused   the single-pass step kernel (kernels/fused_step.py): scalar-
#:           prefetched winner gather + EU + MUU in ONE launch, no
#:           inter-kernel intermediates (paper §IV, Fig. 4)
KERNEL_TIERS = ("ref", "staged", "fused")


def kernel_tier(use_kernels) -> str:
    """Normalize a ``use_kernels`` value (bool-like or tier name) to a
    tier: any falsy value is ``"ref"``, any truthy non-string (True, 1,
    np.True_) is ``"staged"``, strings must name a tier."""
    if isinstance(use_kernels, str):
        if use_kernels in KERNEL_TIERS:
            return use_kernels
        raise ValueError(f"unknown kernel tier {use_kernels!r}; pass a "
                         f"bool or one of {KERNEL_TIERS}")
    return "staged" if use_kernels else "ref"


def fused_supported(cfg) -> bool:
    """The fused single-pass kernel covers the co-designed student tail:
    SAT attention + LUT encoder (any prune budget / sampler backend),
    without static node features (the paper's Wikipedia/Reddit setting —
    f_feat > 0 would add a feature projection the kernel does not carry)."""
    return (cfg.attention == "sat" and cfg.encoder == "lut"
            and cfg.f_feat == 0)


def resolved_tier(cfg, use_kernels) -> str:
    """The tier that actually runs for ``cfg``: requesting ``"fused"`` on a
    variant outside the fused kernel's coverage silently degrades to the
    staged tier, mirroring how staged kernels degrade to references."""
    tier = kernel_tier(use_kernels)
    if tier == "fused" and not fused_supported(cfg):
        return "staged"
    return tier


class Neighborhood(NamedTuple):
    """What a sampler hands the aggregator.

    ``s_nbr``/``e_nbr``/``dt``/``valid`` cover the FETCHED slots (k of them
    under prune-then-fetch, m_r otherwise). ``logits`` are the SAT scores of
    the fetched slots (None for the vanilla sampler, which scores inside the
    aggregator). ``full_*`` always span all m_r ring-buffer slots — the
    distillation views (Eq. 17 masking) regardless of pruning.
    """
    s_nbr: jax.Array            # (2B, k, f_mem) masked neighbor memory
    e_nbr: jax.Array            # (2B, k, f_edge) masked edge features
    dt: jax.Array               # (2B, k) time deltas of fetched slots
    valid: jax.Array            # (2B, k) fetched-slot validity
    logits: jax.Array | None    # (2B, k) SAT logits of fetched slots
    full_logits: jax.Array      # (2B, m_r) pre-softmax scores (distill)
    full_valid: jax.Array       # (2B, m_r) ring-buffer validity
    full_dt: jax.Array          # (2B, m_r) time deltas of every slot


class Selection(NamedTuple):
    """Prune-then-fetch METADATA — everything the selection policy decides
    from timestamps/ids alone, before any memory/feature gather. The
    staged sampler turns this into a ``Neighborhood`` by gathering the k
    winners' rows; the fused tier hands it (scalar-prefetched) straight to
    the single-pass kernel, which DMAs the rows itself.
    """
    ids: jax.Array              # (2B, k) int32 winner vertex ids
    eids: jax.Array             # (2B, k) int32 winner edge-feature rows
    dt: jax.Array               # (2B, k) winner time deltas
    logits: jax.Array           # (2B, k) SAT logits (NEG_INF where invalid)
    valid: jax.Array            # (2B, k) bool winner validity
    full_logits: jax.Array      # (2B, m_r) pre-softmax scores (distill)
    full_valid: jax.Array       # (2B, m_r) ring-buffer validity
    full_dt: jax.Array          # (2B, m_r) time deltas of every slot


class StageBundle(NamedTuple):
    """The resolved stage stack for one variant (+ backend choice)."""
    memory_updater: object      # (params, aux, state, vids) -> (s_upd, lu_upd)
    sampler: object             # (params, aux, state, ef, vids, t) -> Neighborhood
    aggregator: object          # (params, aux, nb, s_self, f_self) -> (h, logits)
    committer: object           # LastWriteWinsCommitter
    names: dict                 # stage-name -> backend label (introspection)
    variant_id: int             # lane id of this stage PROGRAM (variant_lane)
    fused: object = None        # fused tier only: the one-launch step body


#: Process-wide lane registry: every distinct resolved stage *program* (the
#: knobs that change which code runs inside ``TGNPipeline.step``, not the
#: table dims) gets a small stable integer id. The coalesced cross-cohort
#: round dispatcher (``pipeline.CoalescedRound``) uses these ids as its
#: static lane table: each row of the fused super-batch carries the
#: variant_id of the stage stack that must advance it.
_VARIANT_LANES: dict[tuple, int] = {}


def variant_lane(cfg, use_kernels=False) -> int:
    """The lane id of ``cfg``'s resolved stage program.

    Two configs share a lane iff ``build_stages`` would resolve them to the
    same stage code path: attention/encoder/pruning/sampler (tau included
    for the reservoir — it is baked into the sampler closure), plus the
    RESOLVED kernel tier (a variant the fused kernel cannot cover resolves
    to its staged lane) and the ring width the prune clamp sees.
    """
    key = (cfg.attention, cfg.encoder, cfg.prune_k, cfg.sampler,
           float(cfg.reservoir_tau) if cfg.sampler == "reservoir" else None,
           resolved_tier(cfg, use_kernels), cfg.m_r)
    return _VARIANT_LANES.setdefault(key, len(_VARIANT_LANES))


# ---------------------------------------------------------------------------
# aux preparation: folded LUT rows + lane-packed kernel parameters (§III-C)
# ---------------------------------------------------------------------------


def make_prepare(cfg, use_kernels=False):
    """Build ``prepare(params) -> aux`` for ``cfg`` (a TGNConfig).

    aux carries every parameter-derived table the resolved stage backends
    need:
      folded_gru / folded_attn   LUT tables pre-multiplied through the time
                                 rows of W_i / W_v (te.fold_projection)
      packed_gru / packed_lut_gru / packed_sat
                                 lane-aligned Pallas parameter layouts
                                 (kernels/ops.py pad_* helpers) — staged and
                                 fused tiers (the fused tier's ``embed``
                                 path still runs the staged backends)
      packed_fused               the single-pass kernel's parameter pack
                                 (kernels/ops.py pad_fused_params) — fused
                                 tier only
    Cheap jnp ops — safe to trace inside a training step (gradients flow
    through the folds) or run once at engine construction.
    """
    tier = resolved_tier(cfg, use_kernels)

    def prepare(params: dict) -> dict:
        aux = {}
        if cfg.encoder != "lut":
            return aux
        gcfg = cfg.gru
        gru_p = params["gru"]
        folded_gru = te.fold_projection(params["time"],
                                        gru_p["w_i"][gcfg.f_mail_raw:])
        aux["folded_gru"] = folded_gru
        folded_attn = None
        if cfg.attention == "sat":
            attn_p = params["attn"]
            dkv = cfg.f_mem + cfg.f_edge
            folded_attn = te.fold_projection(params["time"],
                                             attn_p["w_v"][dkv:])
            aux["folded_attn"] = folded_attn
        if tier == "ref":
            return aux
        from repro.kernels import ops as kops  # local: keep core importable
        aux["packed_gru"] = kops.pad_gru_params(
            {"w_i": gru_p["w_i"][:gcfg.f_mail_raw], "w_h": gru_p["w_h"],
             "b_i": gru_p["b_i"], "b_h": gru_p["b_h"]},
            gcfg.f_mail_raw, cfg.f_mem)
        aux["packed_lut_gru"] = kops.pad_lut_params(
            folded_gru["boundaries"], folded_gru["table"])
        if folded_attn is not None:
            aux["packed_sat"] = kops.pad_sat_params(
                attn_p["w_v"][:dkv], attn_p["b_v"],
                folded_attn["boundaries"], folded_attn["table"])
        if tier == "fused":
            aux["packed_fused"] = kops.pad_fused_params(
                gru_p, attn_p, folded_gru, folded_attn,
                gcfg.f_mail_raw, cfg.f_mem, cfg.f_edge)
        return aux

    return prepare


# ---------------------------------------------------------------------------
# MemoryUpdater (MUU)
# ---------------------------------------------------------------------------


def make_memory_updater(cfg, use_kernels: bool):
    """UPDT: consume cached messages for the involved vertex instances.

    Returns ``(muu, backend_name)``; ``muu(params, aux, state, vids)`` maps
    the cached mail of ``vids`` to updated (memory, last_update) rows.
    Vertices without valid mail keep their previous rows. The Pallas backend
    exists for the LUT encoder only; other combinations fall back to the
    jnp reference.
    """
    gcfg = cfg.gru

    if cfg.encoder == "lut" and use_kernels:
        from repro.kernels import ops as kops

        def muu(params, aux, state, vids):
            mail_raw = state.mail[vids]
            mail_ts = state.mail_ts[vids]
            mail_valid = state.mail_valid[vids]
            s_prev = state.memory[vids]
            lu_prev = state.last_update[vids]
            # LUT row fetch (Pallas) -> fused GRU (Pallas): the folded time
            # rows enter the kernel as an additive input-gate term.
            dt_mail = mail_ts - lu_prev
            time_rows = kops.lut_encode(dt_mail, aux["packed_lut_gru"])
            s_new = kops.gru_cell(mail_raw, s_prev, aux["packed_gru"],
                                  extra=time_rows)
            s_upd = jnp.where(mail_valid[:, None], s_new, s_prev)
            lu_upd = jnp.where(mail_valid, mail_ts, lu_prev)
            return s_upd, lu_upd

        return muu, "gru:lut-pallas"

    def muu(params, aux, state, vids):
        return memory.update_memory(
            params["gru"], params["time"], gcfg,
            state.mail[vids], state.mail_ts[vids], state.mail_valid[vids],
            state.memory[vids], state.last_update[vids],
            encoder=cfg.encoder, lut_folded=aux.get("folded_gru"))

    return muu, f"gru:{cfg.encoder}-ref"


# ---------------------------------------------------------------------------
# NeighborSampler / Pruner
# ---------------------------------------------------------------------------

#: Registered sampler backends (the selection policy of prune-then-fetch).
#:   recent     paper behavior — SAT top-k over the FIFO ring buffer
#:   uniform    k valid slots uniformly at random (stateless hash RNG)
#:   reservoir  time-decayed weighted reservoir (Efraimidis–Spirakis keys
#:              with weight exp(-dt/tau)) — recency-biased but randomized
SAMPLERS = ("recent", "uniform", "reservoir")


def _stateless_uniform(eid: jax.Array, vids: jax.Array,
                       t_query: jax.Array) -> jax.Array:
    """Deterministic pseudo-uniform draws in (0, 1) per (vertex, slot).

    A jit/vmap-safe integer hash of (edge id, queried vertex, query-time
    bits) — no PRNG key threading, so multi-tenant vmapped serving and a
    lone engine sample IDENTICAL neighborhoods for identical inputs (the
    bitwise-equivalence guarantee tests/test_session.py checks).

    eid: (B, m_r) int32; vids: (B,) int; t_query: (B,) float32.
    """
    h = eid.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
    h = h ^ (vids.astype(jnp.uint32)[:, None] * jnp.uint32(0x85EBCA77))
    tb = jax.lax.bitcast_convert_type(t_query.astype(jnp.float32),
                                      jnp.uint32)
    h = h ^ (tb[:, None] * jnp.uint32(0xC2B2AE3D))
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 12)
    h = h * jnp.uint32(0x297A2D39)
    h = h ^ (h >> 15)
    # 24 mantissa-safe bits -> (0, 1); +2^-25 keeps log(u) finite
    return ((h >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
            + jnp.float32(2.0 ** -25))


def make_sampler(cfg):
    """Returns ``(sampler, backend_name)``.

    ``sampler(params, aux, state, edge_feats, vids, t_query) -> Neighborhood``
    reads the ring buffer for ``vids`` at query times ``t_query``. The
    ``cfg.sampler`` backend picks WHICH k slots are fetched (``SAMPLERS``);
    aggregation weights always come from the SAT logits of the fetched
    slots, so the prune-then-fetch HBM saving is preserved: every policy
    decides from timestamps/ids ONLY, before any memory/feature gather.
    """
    if cfg.sampler not in SAMPLERS:
        raise ValueError(f"unknown sampler backend {cfg.sampler!r}; "
                         f"registered backends: {SAMPLERS}")
    if cfg.attention == "vanilla":
        if cfg.sampler != "recent":
            raise ValueError(
                "alternative sampler backends (uniform/reservoir) require "
                "SAT attention: vanilla fetch-all consumes every ring-buffer "
                f"slot, so there is no selection to randomize — got "
                f"sampler={cfg.sampler!r}")
        # fetch-all: vanilla attention scores depend on neighbor memory, so
        # every m_r row must be gathered before scoring.
        def sampler(params, aux, state, edge_feats, vids, t_query):
            nbr_ids, nbr_ts, nbr_eid, valid = mailbox.gather_neighbors(
                state, vids)
            dt = jnp.maximum(t_query[:, None] - nbr_ts, 0.0) * valid
            s_nbr = state.memory[nbr_ids] * valid[..., None]
            e_nbr = edge_feats[nbr_eid] * valid[..., None]
            return Neighborhood(s_nbr=s_nbr, e_nbr=e_nbr, dt=dt, valid=valid,
                                logits=None, full_logits=dt * 0.0,
                                full_valid=valid, full_dt=dt)

        return sampler, "sampler:fetch-all"

    select, name = make_selector(cfg)

    # prune-then-fetch: selection is metadata-only (make_selector); here we
    # fetch ONLY the winners' rows (the point of the co-design).
    def sampler(params, aux, state, edge_feats, vids, t_query):
        sel = select(params, aux, state, vids, t_query)
        s_nbr = state.memory[sel.ids] * sel.valid[..., None]
        e_nbr = edge_feats[sel.eids] * sel.valid[..., None]
        return Neighborhood(s_nbr=s_nbr, e_nbr=e_nbr, dt=sel.dt,
                            valid=sel.valid, logits=sel.logits,
                            full_logits=sel.full_logits,
                            full_valid=sel.full_valid, full_dt=sel.full_dt)

    return sampler, name


def make_selector(cfg):
    """Returns ``(select, backend_name)`` — the metadata half of
    prune-then-fetch for the SAT variants.

    ``select(params, aux, state, vids, t_query) -> Selection`` decides the
    k winners from the ring buffer's timestamps/ids ONLY, so top-k
    selection runs BEFORE any memory/edge-feature gather and HBM traffic
    scales with k, not m_r (the paper's 67% MEM saving). "recent" ranks by
    SAT logit (the paper's pruner); "uniform"/"reservoir" rank by a
    stateless-hash priority instead. The staged sampler gathers the
    winners' rows from this; the fused kernel scalar-prefetches it.
    """
    k = cfg.prune_k if cfg.prune_k is not None else cfg.m_r
    k = min(k, cfg.m_r)
    policy = cfg.sampler
    tau = float(cfg.reservoir_tau)

    def select(params, aux, state, vids, t_query):
        nbr_ids, nbr_ts, nbr_eid, valid = mailbox.gather_neighbors(
            state, vids)
        dt = jnp.maximum(t_query[:, None] - nbr_ts, 0.0) * valid
        logits = attn_mod.sat_logits(params["attn"], dt)      # ts ONLY
        if policy != "recent":
            u = _stateless_uniform(nbr_eid, vids, t_query)
            if policy == "uniform":
                prio = u
            else:
                # Efraimidis–Spirakis weighted reservoir: key = u^(1/w) with
                # w = exp(-dt/tau); rank by log key = log(u) * exp(dt/tau).
                prio = jnp.log(u) * jnp.exp(jnp.minimum(dt / tau, 50.0))
            idx, _, sel_valid = pruning.topk_select(prio, valid, k)
            sel_ids = jnp.take_along_axis(nbr_ids, idx, axis=1)
            sel_eid = jnp.take_along_axis(nbr_eid, idx, axis=1)
            sel_dt = jnp.take_along_axis(dt, idx, axis=1)
            sel_logits = jnp.where(sel_valid,
                                   jnp.take_along_axis(logits, idx, axis=1),
                                   pruning.NEG_INF)
        elif k < cfg.m_r:
            idx, sel_logits, sel_valid = pruning.topk_select(logits, valid, k)
            sel_ids = jnp.take_along_axis(nbr_ids, idx, axis=1)
            sel_eid = jnp.take_along_axis(nbr_eid, idx, axis=1)
            sel_dt = jnp.take_along_axis(dt, idx, axis=1)
        else:
            sel_ids, sel_eid, sel_dt = nbr_ids, nbr_eid, dt
            sel_logits, sel_valid = logits, valid
        return Selection(ids=sel_ids, eids=sel_eid, dt=sel_dt,
                         logits=sel_logits, valid=sel_valid,
                         full_logits=logits, full_valid=valid, full_dt=dt)

    if policy == "uniform":
        name = f"sampler:uniform(k={k})"
    elif policy == "reservoir":
        name = f"sampler:reservoir(k={k},tau={tau:g})"
    else:
        name = (f"sampler:prune-then-fetch(k={k})" if k < cfg.m_r
                else "sampler:score-all")
    return select, name


# ---------------------------------------------------------------------------
# Aggregator (EU)
# ---------------------------------------------------------------------------


def make_aggregator(cfg, use_kernels: bool):
    """Returns ``(aggregator, backend_name)``.

    ``aggregator(params, aux, nb, s_self, f_self) -> (h, distill_logits)``
    consumes a Neighborhood and the self rows. The Pallas backend covers the
    SAT+LUT student tail; everything else runs the jnp reference.
    """
    acfg = cfg.attn

    if cfg.attention == "vanilla":
        def aggregator(params, aux, nb, s_self, f_self):
            return attn_mod.vanilla_attention(
                params["attn"], acfg, params["time"],
                s_self, f_self, nb.s_nbr, nb.e_nbr, nb.dt, nb.valid)

        return aggregator, "attn:vanilla-ref"

    dkv = cfg.f_mem + cfg.f_edge

    if cfg.encoder == "lut" and use_kernels:
        from repro.kernels import ops as kops

        def aggregator(params, aux, nb, s_self, f_self):
            # fused: logits -> masked softmax -> V-projection+LUT -> sum
            kv = jnp.concatenate([nb.s_nbr, nb.e_nbr], axis=-1)
            agg = kops.sat_aggregate(kv, nb.dt, nb.logits, nb.valid,
                                     aux["packed_sat"])
            fp = attn_mod.feat_proj(params["attn"]["feat"], s_self, f_self)
            h = (jnp.concatenate([fp, agg], axis=-1)
                 @ params["attn"]["w_out"] + params["attn"]["b_out"])
            return h, nb.full_logits

        return aggregator, "attn:sat-lut-pallas"

    def aggregator(params, aux, nb, s_self, f_self):
        attn_p = params["attn"]
        attnw = pruning.masked_softmax(nb.logits, nb.valid)
        if cfg.encoder == "lut":
            folded = aux.get("folded_attn")
            if folded is None:
                folded = te.fold_projection(params["time"],
                                            attn_p["w_v"][dkv:])
            v = (jnp.concatenate([nb.s_nbr, nb.e_nbr], axis=-1)
                 @ attn_p["w_v"][:dkv]
                 + te.lut_encode(folded, nb.dt) + attn_p["b_v"])
        else:
            phi = te.cosine_encode(params["time"], nb.dt)
            kv_in = jnp.concatenate([nb.s_nbr, nb.e_nbr, phi], axis=-1)
            v = kv_in @ attn_p["w_v"] + attn_p["b_v"]
        agg = jnp.einsum("bn,bnd->bd", attnw, v)
        fp = attn_mod.feat_proj(attn_p["feat"], s_self, f_self)
        h = (jnp.concatenate([fp, agg], axis=-1)
             @ attn_p["w_out"] + attn_p["b_out"])
        return h, nb.full_logits

    return aggregator, f"attn:sat-{cfg.encoder}-ref"


# ---------------------------------------------------------------------------
# Committer — chronological last-write-wins (§IV-B)
# ---------------------------------------------------------------------------


class LastWriteWinsCommitter:
    """Chronological Updater semantics on SIMD: per batch, exactly the
    chronologically-last valid update of each vertex survives. The winner
    mask is computed ONCE per batch and shared by the memory commit and the
    mail commit (both race over the same (vids, vvalid) layout).
    """

    def winners(self, vids: jax.Array, vvalid: jax.Array,
                B: int) -> jax.Array:
        return updater.last_write_wins(vids, vvalid,
                                       updater.interleave_order(B))

    def commit_memory(self, state, vids, winners, s_upd, lu_upd):
        """Commit updated memory rows; consuming mail invalidates it."""
        mem_t = updater.commit(state.memory, vids, s_upd, winners)
        lu_t = updater.commit_scalar(state.last_update, vids, lu_upd,
                                     winners)
        mv_t = updater.commit_scalar(
            state.mail_valid, vids,
            jnp.zeros(vids.shape, state.mail_valid.dtype), winners)
        return state._replace(memory=mem_t, last_update=lu_t,
                              mail_valid=mv_t)

    def commit_mail(self, state, vids, winners, new_mail, t_inst):
        """Cache new messages (Most-Recent aggregator == LWW commit)."""
        mail_t = updater.commit(state.mail, vids, new_mail, winners)
        mts_t = updater.commit_scalar(state.mail_ts, vids, t_inst, winners)
        mvv_t = updater.commit_scalar(
            state.mail_valid, vids,
            jnp.ones(vids.shape, state.mail_valid.dtype), winners)
        return state._replace(mail=mail_t, mail_ts=mts_t, mail_valid=mvv_t)


# ---------------------------------------------------------------------------
# Fused tier: the single-pass step body (§IV, Fig. 4)
# ---------------------------------------------------------------------------


def make_fused_step(cfg):
    """Build the fused-tier step body: prune metadata -> ONE kernel launch
    (winner gather + EU + MUU) -> state commits.

    The returned closure replaces the staged ``memory_updater -> commit ->
    sampler -> aggregator`` chain inside ``TGNPipeline.step``: selection
    stays a metadata computation (timestamps/ids only, the prune-then-fetch
    contract), the kernel DMAs only the winners' rows, and the committed
    memory view inside the batch is resolved through the kernel's phase-0
    scratch instead of a scatter/gather HBM round-trip. The mail build and
    the state commits — genuine state writes the paper's design also pays —
    stay in XLA after the launch.
    """
    from repro.kernels import ops as kops  # local: keep core importable
    from repro.core import tgn             # local: BatchOut (no cycle)

    select, _ = make_selector(cfg)
    committer = LastWriteWinsCommitter()
    V = cfg.n_nodes

    def datapath(params, aux, state, edge_feats, vids, t_inst, winners):
        """Metadata + the one launch. This function must never materialize
        a neighbor row itself: only ids/timestamps/validity leave XLA
        (tools/session_lint.py AST-guards it against jnp.concatenate and
        memory/mail/edge-feature gathers creeping back in)."""
        sel = select(params, aux, state, vids, t_inst)
        mail_ts = state.mail_ts[vids]
        lu_prev = state.last_update[vids]
        mail_ok = state.mail_valid[vids]
        # winner-row redirect table (ids only): hit[r, j] >= 0 names the
        # batch row whose phase-0 GRU output IS the committed memory of
        # winner (r, j) — the kernel reads it from VMEM scratch, giving the
        # exact post-commit view the staged path gets from its scatter.
        R = vids.shape[0]
        win_rows = jnp.full((V + 1,), -1, jnp.int32).at[
            jnp.where(winners, vids, V)].set(
                jnp.arange(R, dtype=jnp.int32))
        hit = win_rows[sel.ids]
        h, s_upd = kops.fused_step(
            vids, sel.ids, sel.eids, hit, mail_ts - lu_prev, mail_ok,
            sel.dt, sel.logits, sel.valid, state.memory, state.mail,
            edge_feats, aux["packed_fused"])
        lu_upd = jnp.where(mail_ok, mail_ts, lu_prev)
        return sel, h, s_upd, lu_upd

    def fused(params, aux, state, batch, vids, t_inst, vvalid, edge_feats,
              node_feats):
        src, dst, eid, ts, valid = batch
        B = src.shape[0]
        winners = committer.winners(vids, vvalid, B)
        sel, h, s_upd, lu_upd = datapath(params, aux, state, edge_feats,
                                         vids, t_inst, winners)
        state = committer.commit_memory(state, vids, winners, s_upd, lu_upd)
        # mail build: committed memory of a VALID row r is exactly
        # s_upd[r] (duplicates of a vertex compute identical updates and
        # the LWW commit picks one), so the staged path's post-commit
        # memory gather is unnecessary; losers' mail is dropped by the
        # commit anyway.
        fe = edge_feats[eid]
        mail_src = memory.build_mail_raw(s_upd[:B], s_upd[B:], fe)
        mail_dst = memory.build_mail_raw(s_upd[B:], s_upd[:B], fe)
        new_mail = jnp.concatenate([mail_src, mail_dst], axis=0)
        state = committer.commit_mail(state, vids, winners, new_mail,
                                      t_inst)
        state = mailbox.insert_neighbors(state, src, dst, eid, ts, valid)
        return tgn.BatchOut(state=state, emb_src=h[:B], emb_dst=h[B:],
                            attn_logits=sel.full_logits,
                            nbr_valid=sel.full_valid, nbr_dt=sel.full_dt)

    return fused


def build_stages(cfg, use_kernels=False) -> StageBundle:
    """Resolve the stage stack for ``cfg`` (a TGNConfig).

    ``use_kernels`` picks the tier (see ``KERNEL_TIERS``; booleans
    accepted). Pallas kernel backends exist for the LUT encoder paths
    (MUU) and the SAT+LUT aggregation tail; any stage without a kernel
    backend silently uses its jnp reference, so every variant — teacher
    included — builds and runs. The fused tier additionally carries the
    single-pass step body; its per-stage backends are the STAGED ones
    (``embed`` and distillation views still run stage-at-a-time), and
    variants outside ``fused_supported`` resolve to their staged program.
    """
    if cfg.attention == "vanilla" and cfg.encoder != "cosine":
        raise ValueError("vanilla attention requires the cosine encoder "
                         "(its K/Q/V inputs consume the cosine encoding "
                         "directly; LUT is a SAT-path optimization)")
    tier = resolved_tier(cfg, use_kernels)
    staged = tier != "ref"
    muu, muu_name = make_memory_updater(cfg, staged)
    sampler, sampler_name = make_sampler(cfg)
    aggregator, agg_name = make_aggregator(cfg, staged)
    names = {"memory_updater": muu_name, "sampler": sampler_name,
             "aggregator": agg_name, "committer": "lww-chronological"}
    fused = None
    if tier == "fused":
        fused = make_fused_step(cfg)
        names["fused_step"] = "step:single-pass-pallas"
    return StageBundle(
        memory_updater=muu, sampler=sampler, aggregator=aggregator,
        committer=LastWriteWinsCommitter(), names=names,
        variant_id=variant_lane(cfg, use_kernels), fused=fused)
