"""Chronological Updater (§IV-B) — TPU adaptation.

The paper's Updater is a fully-associative cache with rotating write pointers:
CUs emit updated vertex state round-robin; a commit pointer drains lines in
chronological order; a newer uncommitted update to the same vertex
*invalidates* the older line. Net semantics per processing batch:

    for each vertex touched by the batch, exactly the CHRONOLOGICALLY LAST
    update survives; commits happen in chronological order.

On a SIMD machine we realise identical semantics with a vectorized
last-write-wins reduction (DESIGN.md §2): compute, for every batch row, whether
it is the final occurrence of its vertex id, then scatter only the winners.
Because winners have unique vertex ids the scatter is collision-free and
order-independent — chronology is preserved by construction. Property tests
(tests/test_updater.py) check equivalence against a serial replay oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def last_write_wins(ids: jax.Array, valid: jax.Array | None = None,
                    order: jax.Array | None = None) -> jax.Array:
    """Winner mask: True where row i is the chronologically-last valid
    occurrence of ids[i].

    ``ids``: (B,) int — vertex ids. ``order``: optional (B,) int giving each
    row's chronological position (defaults to array order). Needed because
    process_batch lays rows out as concat([src, dst]): edge e's dst row sits
    B rows after its src row, so array order is NOT chronological —
    callers pass order = concat([2*arange(B), 2*arange(B)+1]).
    ``valid``: optional (B,) bool — rows excluded from the race entirely.

    O(B^2) masked reduce; B is a processing micro-batch. A sort-based
    O(B log B) variant is ``last_write_wins_sorted`` for large batches.
    """
    n = ids.shape[0]
    if order is None:
        order = jnp.arange(n)
    if valid is None:
        valid = jnp.ones((n,), bool)
    same = (ids[None, :] == ids[:, None]) & valid[None, :]
    eff = jnp.where(same, order[None, :], -1)
    last = jnp.max(eff, axis=1)            # last valid occurrence of ids[i]
    return (order == last) & valid


def last_write_wins_sorted(ids: jax.Array, valid: jax.Array | None = None,
                           order: jax.Array | None = None) -> jax.Array:
    """O(B log B) winner mask via sort by (id, chronological order)."""
    n = ids.shape[0]
    if order is None:
        order = jnp.arange(n)
    if valid is None:
        valid = jnp.ones((n,), bool)
    # Invalid rows get a sentinel id so they never win their group.
    sent = jnp.where(valid, ids, jnp.iinfo(jnp.int32).max)
    perm = jnp.lexsort((order, sent))               # group ids, chron inside
    sorted_ids = sent[perm]
    # winner within sorted order: last element of each id-group
    next_differs = jnp.concatenate(
        [sorted_ids[1:] != sorted_ids[:-1], jnp.ones((1,), bool)])
    winner_sorted = next_differs & (sorted_ids != jnp.iinfo(jnp.int32).max)
    return jnp.zeros((n,), bool).at[perm].set(winner_sorted)


def interleave_order(B: int) -> jax.Array:
    """Chronological positions for concat([src, dst]) row layout: edge e's
    src row precedes its dst row, edges in batch order."""
    return jnp.concatenate([2 * jnp.arange(B), 2 * jnp.arange(B) + 1])


def commit(table: jax.Array, ids: jax.Array, values: jax.Array,
           winners: jax.Array) -> jax.Array:
    """Scatter winner rows into ``table`` (V, ...). Losers' ids are redirected
    to row ``drop`` trick-free: we use where-masked ids pointing at their own
    current value (id kept, value kept) — simpler: scatter with winner values,
    losers write the row's existing value back (no-op write).

    To stay O(B) and avoid a gather of existing rows, losers are instead
    redirected to a scratch row appended at index V; callers never see it
    because we slice it off. This keeps the scatter collision-free AND
    side-effect-free for losers.
    """
    V = table.shape[0]
    safe_ids = jnp.where(winners, ids, V)  # losers -> scratch row
    scratch = jnp.zeros((1,) + table.shape[1:], table.dtype)
    ext = jnp.concatenate([table, scratch], axis=0)
    ext = ext.at[safe_ids].set(values.astype(table.dtype))
    return ext[:V]


def commit_scalar(table: jax.Array, ids: jax.Array, values: jax.Array,
                  winners: jax.Array) -> jax.Array:
    """commit() for (V,)-shaped tables."""
    V = table.shape[0]
    safe_ids = jnp.where(winners, ids, V)
    ext = jnp.concatenate([table, jnp.zeros((1,), table.dtype)])
    ext = ext.at[safe_ids].set(values.astype(table.dtype))
    return ext[:V]
