"""TGN-attn: the full memory-based TGNN (teacher) and the co-designed student.

``process_batch`` implements Algorithm 1 for one chronological batch of edges:

  1. UPDT: consume cached messages -> updated memory for involved vertices
  2. commit memory + last_update chronologically (Updater semantics)
  3. GNN: gather ring-buffer neighbors, attend (vanilla or SAT+prune),
     emit dynamic embeddings for every involved vertex instance
  4. cache new messages (Most-Recent aggregator == last-write-wins commit)
  5. insert edges into the neighbor ring buffers

Variant axes (the paper's ablation rows in Table II, plus the sampler
backend axis the serving layer exposes):
  attention: "vanilla" (teacher/baseline) | "sat" (+SAT)
  encoder:   "cosine" | "lut"             (+LUT)
  prune_k:   None | 6 | 4 | 2             (+NP(L/M/S))
  sampler:   "recent" (paper FIFO/SAT top-k) | "uniform" | "reservoir"

Since the TGNPipeline redesign the Algorithm-1 body lives in
``core/pipeline.py`` as a composition of the stage interfaces in
``core/stages.py``; ``process_batch`` here is exactly the registry's
reference composition (``build_pipeline(cfg, use_kernels=False)``), kept as
the stable entry point for training, evaluation, and tests. The streaming
engine (``serving/engine.py``) runs the SAME composition, optionally with
Pallas kernel stage backends.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, fold_path
from repro.core import attention as attn_mod
from repro.core import mailbox, memory, time_encode as te


@dataclasses.dataclass(frozen=True)
class TGNConfig(FrozenConfig):
    n_nodes: int = 10_000
    n_edges: int = 200_000       # edge-feature store capacity
    f_feat: int = 0              # static node features (GDELT: 200)
    f_edge: int = 172            # edge features (Wikipedia/Reddit: 172)
    f_mem: int = 100
    f_time: int = 100
    f_emb: int = 100
    m_r: int = 10
    n_heads: int = 2
    attention: str = "vanilla"   # "vanilla" | "sat"
    encoder: str = "cosine"      # "cosine" | "lut"
    lut_entries: int = 128
    prune_k: int | None = None
    sampler: str = "recent"      # "recent" | "uniform" | "reservoir"
    reservoir_tau: float = 86_400.0  # time-decay scale (s) of the reservoir

    @property
    def gru(self) -> memory.GRUConfig:
        return memory.GRUConfig(f_mem=self.f_mem, f_edge=self.f_edge,
                                f_time=self.f_time)

    @property
    def attn(self) -> attn_mod.AttnConfig:
        return attn_mod.AttnConfig(
            f_mem=self.f_mem, f_feat=self.f_feat, f_edge=self.f_edge,
            f_time=self.f_time, f_emb=self.f_emb, n_heads=self.n_heads,
            m_r=self.m_r, prune_k=self.prune_k)

    @property
    def tables(self) -> mailbox.TableConfig:
        return mailbox.TableConfig(n_nodes=self.n_nodes, f_mem=self.f_mem,
                                   f_edge=self.f_edge, m_r=self.m_r)


class BatchOut(NamedTuple):
    state: mailbox.VertexState
    emb_src: jax.Array       # (B, f_emb) embeddings of edge sources
    emb_dst: jax.Array       # (B, f_emb) embeddings of edge destinations
    attn_logits: jax.Array   # (2B, m_r) pre-softmax scores (for distillation)
    nbr_valid: jax.Array     # (2B, m_r) neighbor validity (distill masking)
    nbr_dt: jax.Array        # (2B, m_r) time deltas (student distill input)


def init_params(key: jax.Array, cfg: TGNConfig,
                dt_samples=None) -> dict:
    tcfg = te.TimeEncoderConfig(dim=cfg.f_time, n_entries=cfg.lut_entries)
    p = {"gru": memory.init_gru(fold_path(key, "gru"), cfg.gru)}
    if cfg.encoder == "cosine":
        p["time"] = te.init_cosine(fold_path(key, "time"), tcfg)
    else:
        p["time"] = te.init_lut(fold_path(key, "time"), tcfg,
                                dt_samples=dt_samples)
    if cfg.attention == "vanilla":
        p["attn"] = attn_mod.init_vanilla(fold_path(key, "attn"), cfg.attn)
    else:
        p["attn"] = attn_mod.init_sat(fold_path(key, "attn"), cfg.attn)
    # downstream link predictor (self-supervision; Section II)
    k1, k2 = jax.random.split(fold_path(key, "link"))
    from repro.utils import dense_init
    p["link"] = {
        "w1": dense_init(k1, (2 * cfg.f_emb, cfg.f_emb)),
        "b1": jnp.zeros((cfg.f_emb,), jnp.float32),
        "w2": dense_init(k2, (cfg.f_emb, 1)),
        "b2": jnp.zeros((1,), jnp.float32),
    }
    return p


def init_state(cfg: TGNConfig) -> mailbox.VertexState:
    return mailbox.init_state(cfg.tables)


# ---------------------------------------------------------------------------
# Embedding (GNN) step — shared by teacher and student
# ---------------------------------------------------------------------------


def _reference_pipeline(cfg: TGNConfig):
    # local import: pipeline imports this module for TGNConfig/BatchOut
    from repro.core import pipeline as pl
    return pl.build_pipeline(cfg, use_kernels=False)


def _embed(params: dict, cfg: TGNConfig, state: mailbox.VertexState,
           node_feats: jax.Array | None, edge_feats: jax.Array,
           vids: jax.Array, t_query: jax.Array):
    """Dynamic embeddings for vertex instances ``vids`` at times ``t_query``.

    Sampler + aggregator stages of the reference pipeline (pruning included
    for SAT variants). Returns (h, logits, valid, dt).
    """
    pipe = _reference_pipeline(cfg)
    return pipe.embed(params, pipe.prepare(params), state, edge_feats,
                      node_feats, vids, t_query)


# ---------------------------------------------------------------------------
# Algorithm 1: one chronological batch
# ---------------------------------------------------------------------------


def process_batch(params: dict, cfg: TGNConfig, state: mailbox.VertexState,
                  node_feats: jax.Array | None, edge_feats: jax.Array,
                  src: jax.Array, dst: jax.Array, eid: jax.Array,
                  ts: jax.Array, valid: jax.Array | None = None) -> BatchOut:
    """Process one batch of chronologically-sorted edges (B,).

    The reference (pure-jnp) composition of the registered Algorithm-1
    stages — see core/pipeline.py for the step body and core/stages.py for
    the stage implementations. ``valid`` masks padding rows: their state
    writes are dropped entirely (their embeddings are still computed but are
    garbage the caller must mask).
    """
    pipe = _reference_pipeline(cfg)
    return pipe.step_fn(params, state, (src, dst, eid, ts, valid),
                        edge_feats, node_feats)


# ---------------------------------------------------------------------------
# Self-supervised temporal link prediction head (Section II)
# ---------------------------------------------------------------------------


def link_score(params: dict, h_u: jax.Array, h_v: jax.Array) -> jax.Array:
    x = jnp.concatenate([h_u, h_v], axis=-1)
    x = jax.nn.relu(x @ params["link"]["w1"] + params["link"]["b1"])
    return (x @ params["link"]["w2"] + params["link"]["b2"])[..., 0]


def link_loss(params: dict, out: BatchOut, neg_dst_emb: jax.Array):
    """BCE on positive (src,dst) vs negative (src, random) pairs."""
    pos = link_score(params, out.emb_src, out.emb_dst)
    neg = link_score(params, out.emb_src, neg_dst_emb)
    loss = (jnp.mean(jax.nn.softplus(-pos)) + jnp.mean(jax.nn.softplus(neg))) / 2
    return loss, (pos, neg)
