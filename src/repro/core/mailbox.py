"""Vertex state tables: Mailbox, Memory Table, Neighbor (ring-buffer) Table.

These are the on-device analogues of the paper's Graph Storage (§IV-A):

  - Vertex Memory Table   {s_v}      (V, f_mem)  float32
  - Vertex Mailbox        {m_v}      raw message components + timestamp; the
    time-encoding of dt is applied lazily at UPDT time (so the stored mail is
    ``s_src || s_dst || f_e`` plus ``mail_ts``), matching the paper's cached
    messages whose dt is measured when consumed.
  - Vertex Neighbor Table {N_mr(v)}  ring buffer of the m_r most-recent
    neighbors: ids, timestamps and edge-feature pointers. This is the FIFO
    hardware sampler (§IV, DESIGN.md §2): insertion is O(1) via a rotating
    cursor, and "sample most recent m_r" is just "read the buffer".

All tables are dense jnp arrays so the whole structure shards over the
(`pod`,`data`) mesh axes by vertex id and updates are scatters.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig


class VertexState(NamedTuple):
    """The complete per-vertex dynamic state (a pytree; shardable)."""
    memory: jax.Array        # (V, f_mem) float32
    last_update: jax.Array   # (V,) float32 — timestamp of last memory update
    mail: jax.Array          # (V, f_mail_raw) float32 — s_src||s_dst||f_e
    mail_ts: jax.Array       # (V,) float32 — timestamp of cached message
    mail_valid: jax.Array    # (V,) bool — has this vertex any cached message
    nbr_ids: jax.Array       # (V, m_r) int32 — ring buffer of neighbor ids
    nbr_ts: jax.Array        # (V, m_r) float32 — interaction timestamps
    nbr_eid: jax.Array       # (V, m_r) int32 — edge-feature row pointers
    nbr_cursor: jax.Array    # (V,) int32 — rotating write cursor


@dataclasses.dataclass(frozen=True)
class TableConfig(FrozenConfig):
    n_nodes: int = 10_000
    f_mem: int = 100
    f_edge: int = 172
    m_r: int = 10            # neighbor buffer width (paper samples 10)


def init_state(cfg: TableConfig) -> VertexState:
    V, mr = cfg.n_nodes, cfg.m_r
    f_mail_raw = 2 * cfg.f_mem + cfg.f_edge
    return VertexState(
        memory=jnp.zeros((V, cfg.f_mem), jnp.float32),
        last_update=jnp.zeros((V,), jnp.float32),
        mail=jnp.zeros((V, f_mail_raw), jnp.float32),
        mail_ts=jnp.zeros((V,), jnp.float32),
        mail_valid=jnp.zeros((V,), bool),
        nbr_ids=jnp.zeros((V, mr), jnp.int32),
        nbr_ts=jnp.full((V, mr), -1.0, jnp.float32),
        nbr_eid=jnp.zeros((V, mr), jnp.int32),
        nbr_cursor=jnp.zeros((V,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Neighbor ring buffer (FIFO hardware sampler analogue)
# ---------------------------------------------------------------------------


def insert_neighbors(state: VertexState, src: jax.Array, dst: jax.Array,
                     eid: jax.Array, ts: jax.Array,
                     valid: jax.Array | None = None) -> VertexState:
    """Insert edges (src->dst and dst->src) into the ring buffers.

    ``src, dst, eid, ts``: (B,). Each edge contributes dst to src's buffer and
    src to dst's buffer, at the vertex's rotating cursor. Because a vertex may
    appear several times in one batch, insertion must be *serial in batch
    order* per vertex; we realise that with a cumulative per-vertex occurrence
    count so every insert in the batch lands in a distinct slot — identical
    result to the FIFO pushing edges one by one.

    ``valid``: optional (B,) bool — padding rows are dropped (their scatter
    indices are redirected out of bounds, which jit scatters silently drop).
    """
    V = state.nbr_ids.shape[0]
    B = src.shape[0]
    ids = jnp.concatenate([src, dst])                    # vertex being appended to
    nbrs = jnp.concatenate([dst, src])                   # the neighbor id stored
    eids = jnp.concatenate([eid, eid])
    tss = jnp.concatenate([ts, ts])
    if valid is not None:
        vv = jnp.concatenate([valid, valid])
        ids = jnp.where(vv, ids, V)                      # OOB -> dropped
    n = ids.shape[0]

    # occurrence index of each id within the batch in CHRONOLOGICAL order
    # (edge e's src entry precedes its dst entry; edges in batch order) —
    # the concat layout puts all src rows first, so array order is wrong
    # for vertices hit from both sides.
    occ = _occurrence_index(ids, updater_order(B))
    slot = (state.nbr_cursor[ids] + occ) % state.nbr_ids.shape[1]

    # Scatter: duplicate (id, slot) pairs cannot collide because occ is unique
    # per (id, occurrence).
    nbr_ids = state.nbr_ids.at[ids, slot].set(nbrs.astype(jnp.int32))
    nbr_ts = state.nbr_ts.at[ids, slot].set(tss.astype(jnp.float32))
    nbr_eid = state.nbr_eid.at[ids, slot].set(eids.astype(jnp.int32))

    counts = jnp.zeros_like(state.nbr_cursor).at[ids].add(1)
    cursor = (state.nbr_cursor + counts) % (2 ** 30)
    return state._replace(nbr_ids=nbr_ids, nbr_ts=nbr_ts, nbr_eid=nbr_eid,
                          nbr_cursor=cursor)


def updater_order(B: int) -> jax.Array:
    """Chronological positions for the concat([src, dst]) layout."""
    return jnp.concatenate([2 * jnp.arange(B), 2 * jnp.arange(B) + 1])


def _occurrence_index(ids: jax.Array,
                      order: jax.Array | None = None) -> jax.Array:
    """occ[i] = number of j with ids[j]==ids[i] and order[j] < order[i].
    O(B^2) compare — B is a processing micro-batch (~1e2-1e3), and this
    lowers to one masked reduce."""
    n = ids.shape[0]
    if order is None:
        order = jnp.arange(n)
    same = ids[None, :] == ids[:, None]
    before = order[None, :] < order[:, None]
    return jnp.sum(same & before, axis=1).astype(jnp.int32)


def gather_neighbors(state: VertexState, vids: jax.Array):
    """Read the ring buffer for a batch of vertices.

    Returns (nbr_ids, nbr_ts, nbr_eid, valid_mask), each (B, m_r), ordered by
    buffer slot age: slot (cursor-1) is the most recent. We roll each row so
    output column 0 = most recent, matching the paper's timestamp-sorted
    neighbor lists (descending recency).
    """
    ids = state.nbr_ids[vids]
    ts = state.nbr_ts[vids]
    eid = state.nbr_eid[vids]
    cur = state.nbr_cursor[vids]
    mr = ids.shape[1]
    # roll so that most-recent (cursor-1) comes first, then cursor-2, ...
    col = jnp.arange(mr)
    src_slot = (cur[:, None] - 1 - col) % mr
    ids = jnp.take_along_axis(ids, src_slot, axis=1)
    ts = jnp.take_along_axis(ts, src_slot, axis=1)
    eid = jnp.take_along_axis(eid, src_slot, axis=1)
    valid = ts >= 0.0
    return ids, ts, eid, valid
