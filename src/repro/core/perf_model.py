"""Performance models.

1. The paper's Section-V analytical FPGA model:
       T_p    = max(T_comp_max, T_LS)                      (Eq. 18)
       T_comp ~ Eq. 20 (three dominant MXU/DSP terms)
       T_LS   ~ Eq. 21 (four burst-transfer terms)
       thpt   ~ N_b / T_p ; latency ~ (beta - 1 + ceil(N/N_b)) * T_p  (Eq. 22)
   reproduced verbatim so ``benchmarks/fig6_perf_model.py`` can compare its
   predictions against measured runtimes of our implementation.

2. The TPU v5e roofline used by §Roofline: three terms derived from the
   compiled dry-run artifact
       compute    = HLO_FLOPs       / (chips * PEAK_FLOPS)
       memory     = HLO_bytes       / (chips * HBM_BW)
       collective = collective_bytes / (chips * ICI_BW)
   with the hardware constants fixed by the assignment.
"""
from __future__ import annotations

import dataclasses
import math

from repro.utils import FrozenConfig


# ---------------------------------------------------------------------------
# TPU v5e roofline constants (assignment-fixed)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per ICI link


@dataclasses.dataclass(frozen=True)
class RooflineTerms(FrozenConfig):
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: perfectly-overlapped max of terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant term in the no-overlap sum — how close a
        perfectly-overlapped schedule is to the sequential lower bound."""
        s = self.compute_s + self.memory_s + self.collective_s
        return self.step_time_s / s if s > 0 else 0.0


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             n_chips: int, ici_links: int = 1) -> RooflineTerms:
    """Three-term roofline for a compiled step.

    ``hlo_flops``/``hlo_bytes`` come from ``compiled.cost_analysis()`` and are
    PER-DEVICE on a SPMD module; ``collective_bytes`` is the per-device sum of
    collective operand sizes parsed from the HLO text. ``ici_links`` is the
    number of ICI links per chip usable by the collective schedule (a 2D torus
    axis exposes 2 directed links per axis; we default conservatively to 1 and
    let the perf loop refine it).
    """
    return RooflineTerms(
        compute_s=hlo_flops / PEAK_FLOPS,
        memory_s=hlo_bytes / HBM_BW,
        collective_s=collective_bytes / (ici_links * ICI_BW),
    )


def model_flops(n_params: int, n_tokens: int, *, training: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D for a training step (fwd 2ND + bwd 4ND); 2*N*D for
    a pure forward (prefill/decode). For MoE pass the ACTIVE parameter count."""
    return (6.0 if training else 2.0) * n_params * n_tokens


# ---------------------------------------------------------------------------
# Section V — FPGA analytical model (Eq. 18-22)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FPGAConfig(FrozenConfig):
    """Design configuration (Table IV) + model dims (Section V notation)."""
    f_feat: int = 0
    f_mail: int = 372        # message length fed to the GRU (raw, LUT-folded)
    f_mem: int = 100
    f_emb: int = 100
    m_r: int = 10            # neighbor list width (mr)
    n_cu: int = 2            # number of computation units
    s_g: int = 8             # MUU gate array is S_g x S_g
    s_fam: int = 16          # FAM parallelism
    s_ftm: int = 64          # FTM parallelism (8x8)
    n_b: int = 8             # edges per processing batch
    freq_hz: float = 250e6   # F_freq
    bw_bytes: float = 77e9   # peak external bandwidth (U200 DDR4)
    z_d: int = 4             # bytes per element (fp32)
    beta: int = 9            # pipeline stages (Fig. 4)


def alpha_burst(l_elems: int, z_d: int = 4) -> float:
    """Effective-bandwidth factor alpha(l) for burst length l (elements).

    Modeled after the microbenchmarks of Lu et al. [21]: short bursts waste
    DRAM pages; efficiency saturates near 1 for bursts >= ~4KiB.
    """
    bytes_ = max(l_elems, 1) * z_d
    return min(1.0, 0.1 + 0.9 * bytes_ / (bytes_ + 1024.0))


def t_comp_max(cfg: FPGAConfig) -> float:
    """Eq. 20 — dominant compute-stage latency (seconds)."""
    nb = cfg.n_b
    t_muu = 3.0 * nb * cfg.f_mail * cfg.f_mem / (cfg.s_g * cfg.s_g)
    t_fam = 3.0 * nb * cfg.m_r * (cfg.f_mem + cfg.f_feat) / cfg.s_fam
    t_ftm = 3.0 * nb * (cfg.f_mem + cfg.f_feat) * cfg.f_emb / cfg.s_ftm
    return max(t_muu, t_fam, t_ftm) / cfg.freq_hz


def t_ls(cfg: FPGAConfig) -> float:
    """Eq. 21 — load/store latency per processing batch (seconds)."""
    nb, z = cfg.n_b, cfg.z_d
    bw = cfg.bw_bytes
    t1 = 6.0 * nb * cfg.f_mail * z / (alpha_burst(cfg.f_mail, z) * bw)
    t2 = (3.0 * nb * (2 + cfg.m_r) * cfg.f_mem * z
          / (alpha_burst(cfg.f_mem, z) * bw))
    t3 = (3.0 * nb * cfg.m_r * cfg.f_feat * z
          / (alpha_burst(max(cfg.f_feat, 1), z) * bw)) if cfg.f_feat else 0.0
    t4 = 3.0 * nb * cfg.f_emb * z / (alpha_burst(cfg.f_emb, z) * bw)
    return t1 + t2 + t3 + t4


def predict(cfg: FPGAConfig, batch_size: int) -> dict:
    """Eq. 18 & 22: predicted pipeline period, throughput, latency."""
    tp = max(t_comp_max(cfg), t_ls(cfg))
    thpt = cfg.n_b / tp
    latency = (cfg.beta - 1 + math.ceil(batch_size / cfg.n_b)) * tp
    return {"t_p_s": tp, "throughput_eps": thpt, "latency_s": latency,
            "compute_bound": t_comp_max(cfg) >= t_ls(cfg)}


# Published design points (Table IV) for the two boards.
U200 = FPGAConfig(n_cu=2, s_g=8, s_fam=16, s_ftm=64, n_b=8,
                  freq_hz=250e6, bw_bytes=77e9)
ZCU104 = FPGAConfig(n_cu=1, s_g=4, s_fam=8, s_ftm=16, n_b=4,
                    freq_hz=125e6, bw_bytes=19.2e9)
