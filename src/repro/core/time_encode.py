"""Time encoders: the paper's cosine encoder (Eq. 6) and the LUT encoder (§III-C).

Cosine encoder (teacher / baseline):   Phi(dt) = cos(omega * dt + phi)
LUT encoder  (student / accelerator):  Phi(dt) = table[bucket(dt)]

The LUT buckets are *equal-frequency* (quantile) intervals of the empirical
time-delta distribution — the paper observes dt follows a power law with mass
near zero, so equal-frequency bucketing spends resolution where the data is.

TPU adaptation (see DESIGN.md §2): at inference the LUT row fetch is realised
as ``one_hot(bucket, n_entries) @ table`` so it runs on the MXU instead of a
scalar gather; and the downstream projections are *folded into the table*
(``fold_projection``) exactly as the paper precomputes LUT x W products into
on-chip memory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import FrozenConfig, dense_init


@dataclasses.dataclass(frozen=True)
class TimeEncoderConfig(FrozenConfig):
    dim: int = 100            # f_time: encoding width
    n_entries: int = 128      # LUT entries (paper: 128 intervals)


# ---------------------------------------------------------------------------
# Cosine encoder (Eq. 6)
# ---------------------------------------------------------------------------


def init_cosine(key: jax.Array, cfg: TimeEncoderConfig) -> dict:
    """TGN-style init: omega spans decades so different dims see different scales."""
    omega = 1.0 / (10.0 ** np.linspace(0, 9, cfg.dim))
    return {
        "omega": jnp.asarray(omega, jnp.float32),
        "phi": jnp.zeros((cfg.dim,), jnp.float32),
    }


def cosine_encode(params: dict, dt: jax.Array) -> jax.Array:
    """Phi(dt) = cos(omega*dt + phi). dt: (...,) -> (..., dim)."""
    dt = dt.astype(jnp.float32)
    return jnp.cos(dt[..., None] * params["omega"] + params["phi"])


# ---------------------------------------------------------------------------
# LUT encoder (§III-C)
# ---------------------------------------------------------------------------


def fit_boundaries(dt_samples: np.ndarray, n_entries: int = 128) -> np.ndarray:
    """Equal-frequency interval boundaries from empirical dt samples.

    Returns ``n_entries - 1`` interior boundaries; bucket(dt) = #boundaries <= dt,
    so bucket indices lie in [0, n_entries).
    """
    dt_samples = np.asarray(dt_samples, np.float64)
    qs = np.linspace(0.0, 1.0, n_entries + 1)[1:-1]
    bounds = np.quantile(dt_samples, qs)
    # strictly increasing (duplicate quantiles happen on discrete dt) — nudge.
    bounds = np.maximum.accumulate(bounds)
    eps = 1e-6 * max(1.0, float(bounds[-1]) if len(bounds) else 1.0)
    for i in range(1, len(bounds)):
        if bounds[i] <= bounds[i - 1]:
            bounds[i] = bounds[i - 1] + eps
    return bounds.astype(np.float32)


def init_lut(key: jax.Array, cfg: TimeEncoderConfig,
             boundaries: np.ndarray | None = None,
             cosine_params: dict | None = None,
             dt_samples: np.ndarray | None = None) -> dict:
    """LUT encoder params.

    If ``cosine_params`` (a trained teacher cosine encoder) is given, the table
    is initialised to the cosine encoding of each bucket's center so the student
    starts as a piecewise-constant approximation of the teacher's encoder.
    """
    if boundaries is None:
        if dt_samples is None:
            # power-law-ish default covering [0, 1e7)
            dt_samples = (10.0 ** np.random.RandomState(0).uniform(0, 7, 20000))
        boundaries = fit_boundaries(np.asarray(dt_samples), cfg.n_entries)
    boundaries = jnp.asarray(boundaries, jnp.float32)
    if cosine_params is not None:
        lo = jnp.concatenate([jnp.zeros((1,)), boundaries])
        hi = jnp.concatenate([boundaries, boundaries[-1:] * 2 + 1.0])
        centers = 0.5 * (lo + hi)
        table = cosine_encode(cosine_params, centers)
    else:
        table = dense_init(key, (cfg.n_entries, cfg.dim), scale=1.0)
    return {"boundaries": boundaries, "table": table}


def lut_bucket(boundaries: jax.Array, dt: jax.Array) -> jax.Array:
    """bucket(dt) = number of boundaries <= dt.  Vectorized compares (VPU)."""
    dt = dt.astype(jnp.float32)
    return jnp.sum(dt[..., None] >= boundaries, axis=-1).astype(jnp.int32)


def lut_encode(params: dict, dt: jax.Array, *, one_hot: bool = False) -> jax.Array:
    """Phi(dt) via table lookup. ``one_hot=True`` uses the MXU-friendly
    one-hot x table matmul (the TPU analogue of the BRAM LUT)."""
    b = lut_bucket(params["boundaries"], dt)
    table = params["table"]
    if one_hot:
        oh = jax.nn.one_hot(b, table.shape[0], dtype=table.dtype)
        return oh @ table
    return jnp.take(table, b, axis=0)


def fold_projection(params: dict, w_time: jax.Array,
                    b_contrib: jax.Array | None = None) -> dict:
    """Precompute table @ W (the paper's 'LUT x weight matrices' fold).

    ``w_time`` is the slice of a downstream weight matrix that multiplies the
    time-encoding portion of a concatenated input (shape (dim, out)). The
    returned params encode dt directly to the *projected* space: the whole
    encode-then-project path becomes one table row.
    """
    table = params["table"] @ w_time
    if b_contrib is not None:
        table = table + b_contrib
    return {"boundaries": params["boundaries"], "table": table}
