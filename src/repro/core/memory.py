"""Message construction (Eq. 4-5) and the GRU memory updater (Eq. 7-10).

The GRU maps an aggregated message m̄ (input) and the previous node memory s
(hidden state) to the updated memory:

    r = sigmoid(W_ir m̄ + b_ir + W_hr s + b_hr)
    z = sigmoid(W_iz m̄ + b_iz + W_hz s + b_hz)
    n = tanh  (W_in m̄ + b_in + r * (W_hn s + b_hn))
    s' = (1 - z) * n + z * s

Weights are stored packed: W_i (f_mail, 3*f_mem), W_h (f_mem, 3*f_mem) with
gate order [r | z | n] — one MXU matmul per projection instead of three
(DESIGN.md §2, the Pallas kernel `kernels/gru_cell.py` fuses the rest).

The message is m = s_self || s_other || f_e || Phi(dt)  (Eq. 4-5); the mailbox
stores the raw part (s_self || s_other || f_e) and the timestamp, and Phi(dt)
is appended at consume time. With the LUT encoder the time contribution is
folded: instead of concatenating Phi(dt) and multiplying by the last f_time
rows of W_i, we add ``(table @ W_i[time rows])[bucket(dt)]`` — one row fetch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, dense_init
from repro.core import time_encode as te


@dataclasses.dataclass(frozen=True)
class GRUConfig(FrozenConfig):
    f_mem: int = 100
    f_edge: int = 172
    f_time: int = 100

    @property
    def f_mail_raw(self) -> int:
        return 2 * self.f_mem + self.f_edge

    @property
    def f_mail(self) -> int:
        return self.f_mail_raw + self.f_time


def init_gru(key: jax.Array, cfg: GRUConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_i": dense_init(k1, (cfg.f_mail, 3 * cfg.f_mem)),
        "w_h": dense_init(k2, (cfg.f_mem, 3 * cfg.f_mem)),
        "b_i": jnp.zeros((3 * cfg.f_mem,), jnp.float32),
        "b_h": jnp.zeros((3 * cfg.f_mem,), jnp.float32),
    }


def gru_cell(params: dict, mail: jax.Array, s: jax.Array) -> jax.Array:
    """Plain-JAX GRU cell. mail: (B, f_mail), s: (B, f_mem) -> (B, f_mem).

    The Pallas production path is kernels/ops.gru_cell; this function is the
    algorithmic definition used by tests and the CPU path.
    """
    gi = mail @ params["w_i"] + params["b_i"]
    gh = s @ params["w_h"] + params["b_h"]
    f_mem = s.shape[-1]
    i_r, i_z, i_n = gi[..., :f_mem], gi[..., f_mem:2 * f_mem], gi[..., 2 * f_mem:]
    h_r, h_z, h_n = gh[..., :f_mem], gh[..., f_mem:2 * f_mem], gh[..., 2 * f_mem:]
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    return (1.0 - z) * n + z * s


def gru_cell_lut(params: dict, mail_raw: jax.Array, time_rows: jax.Array,
                 s: jax.Array) -> jax.Array:
    """GRU cell with the time contribution pre-projected (LUT-fused path).

    ``mail_raw``: (B, f_mail_raw) — message without the time encoding.
    ``time_rows``: (B, 3*f_mem) — LUT rows already folded through
    W_i[time slice] (see time_encode.fold_projection); added to the input
    projection directly, eliminating the (B,f_time)x(f_time,3*f_mem) matmul.
    """
    n_raw = mail_raw.shape[-1]
    gi = mail_raw @ params["w_i"][:n_raw] + params["b_i"] + time_rows
    gh = s @ params["w_h"] + params["b_h"]
    f_mem = s.shape[-1]
    i_r, i_z, i_n = gi[..., :f_mem], gi[..., f_mem:2 * f_mem], gi[..., 2 * f_mem:]
    h_r, h_z, h_n = gh[..., :f_mem], gh[..., f_mem:2 * f_mem], gh[..., 2 * f_mem:]
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    return (1.0 - z) * n + z * s


def build_mail_raw(s_self: jax.Array, s_other: jax.Array,
                   f_e: jax.Array) -> jax.Array:
    """Raw cached message (Eq. 4-5 minus the time encoding): (B, f_mail_raw)."""
    return jnp.concatenate([s_self, s_other, f_e], axis=-1)


def update_memory(gru_params: dict, time_params: dict, cfg: GRUConfig,
                  mail_raw: jax.Array, mail_ts: jax.Array,
                  mail_valid: jax.Array, s: jax.Array, last_update: jax.Array,
                  *, encoder: str = "cosine",
                  lut_folded: dict | None = None):
    """Consume cached messages: s' = UPDT(mail, s).  (Alg. 1 lines 3-5.)

    dt = mail_ts - last_update (time between the last memory write and the
    cached message). Vertices without a valid mail keep their memory.
    Returns (s_new, last_update_new).
    """
    dt = mail_ts - last_update
    if encoder == "cosine":
        phi = te.cosine_encode(time_params, dt)
        mail = jnp.concatenate([mail_raw, phi], axis=-1)
        s_new = gru_cell(gru_params, mail, s)
    elif encoder == "lut":
        folded = lut_folded
        if folded is None:
            # fold on the fly (training path; inference precomputes once)
            folded = te.fold_projection(
                time_params, gru_params["w_i"][cfg.f_mail_raw:])
        time_rows = te.lut_encode(folded, dt)
        s_new = gru_cell_lut(gru_params, mail_raw, time_rows, s)
    else:
        raise ValueError(f"unknown encoder {encoder!r}")
    ok = mail_valid[:, None]
    s_out = jnp.where(ok, s_new, s)
    lu_out = jnp.where(mail_valid, mail_ts, last_update)
    return s_out, lu_out
