"""The pluggable TGN pipeline: one Algorithm-1 composition for every variant.

The paper's co-design is a ladder of variants (Table II):

    vanilla+cosine  ->  sat+cosine  ->  sat+lut  ->  sat+lut+np{6,4,2}

Historically the repo implemented Algorithm 1 twice — a reference path in
``tgn.process_batch`` and a hand-fused copy inside the streaming engine that
only ran the SAT+LUT student. This module replaces both with ONE composition
of the stage interfaces in ``core/stages.py``:

    pipe = build_pipeline("sat+lut+np4", n_nodes=..., n_edges=...)
    aux  = pipe.prepare(params)                  # folded/packed tables
    out  = pipe.step(params, aux, state, batch, edge_feats)   # BatchOut

``tgn.process_batch`` is now the registry's reference composition and
``serving.StreamingEngine`` is a thin stateful session over any built
pipeline (kernel or reference backend, any variant, teacher included).

Variant registry: canonical specs are
``"<attention>+<encoder>[+np<k>][+<sampler>]"`` (sampler backends:
``stages.SAMPLERS`` — e.g. ``"sat+lut+np4+reservoir"``); Table-II row names
and a few shorthands are registered as aliases. New variants (samplers,
aggregators, encoders) plug in via ``register_variant`` without forking the
step function. Invalid specs raise with the full token menu
(``spec_menu()``).
"""
from __future__ import annotations

import functools
from typing import Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mailbox, memory, stages, tgn


class VariantSpec(NamedTuple):
    """The three model axes of the paper's ablation ladder, plus the
    serving-layer sampler-backend axis (selection policy of
    prune-then-fetch; see ``stages.SAMPLERS``)."""
    attention: str          # "vanilla" | "sat"
    encoder: str            # "cosine" | "lut"
    prune_k: int | None     # None | 6 | 4 | 2
    sampler: str = "recent"  # "recent" | "uniform" | "reservoir"


_REGISTRY: dict[str, VariantSpec] = {}
_ALIASES: dict[str, str] = {}


def spec_menu() -> str:
    """The full menu of valid variant-spec tokens — every spec-parsing
    error embeds this so an invalid string prints everything legal."""
    return (
        "valid spec grammar: '<attention>+<encoder>[+np<k>][+<sampler>]' "
        "with attention in ('vanilla', 'sat'), encoder in ('cosine', 'lut'), "
        "np<k> an integer pruning budget (SAT only, e.g. np4), and sampler "
        f"in {stages.SAMPLERS} (SAT only; default 'recent'); "
        f"registered variants: {sorted(_REGISTRY)}; "
        f"aliases: {sorted(_ALIASES)}")


def register_variant(name: str, spec: VariantSpec,
                     aliases: tuple[str, ...] = ()) -> None:
    """Register a canonical variant name (and optional aliases)."""
    _REGISTRY[name] = spec
    for a in aliases:
        _ALIASES[a] = name


register_variant("vanilla+cosine", VariantSpec("vanilla", "cosine", None),
                 aliases=("teacher", "baseline", "Baseline", "vanilla"))
register_variant("sat+cosine", VariantSpec("sat", "cosine", None),
                 aliases=("+SAT", "sat"))
register_variant("sat+lut", VariantSpec("sat", "lut", None),
                 aliases=("+LUT",))
register_variant("sat+lut+np6", VariantSpec("sat", "lut", 6),
                 aliases=("+NP(L)", "np6"))
register_variant("sat+lut+np4", VariantSpec("sat", "lut", 4),
                 aliases=("+NP(M)", "np4", "student"))
register_variant("sat+lut+np2", VariantSpec("sat", "lut", 2),
                 aliases=("+NP(S)", "np2"))
# sampler-backend variants: the student ladder with the prune-then-fetch
# selection policy swapped (multi-tenant serving mixes these per tenant)
register_variant("sat+lut+np4+uniform", VariantSpec("sat", "lut", 4,
                                                    "uniform"),
                 aliases=("uniform",))
register_variant("sat+lut+np4+reservoir", VariantSpec("sat", "lut", 4,
                                                      "reservoir"),
                 aliases=("reservoir",))

#: Canonical registry names in ladder order (Table II rows).
VARIANTS = ("vanilla+cosine", "sat+cosine", "sat+lut",
            "sat+lut+np6", "sat+lut+np4", "sat+lut+np2")

#: Sampler-backend specs of the np4 student (registry names).
SAMPLER_VARIANTS = ("sat+lut+np4", "sat+lut+np4+uniform",
                    "sat+lut+np4+reservoir")


def resolve_variant(spec) -> VariantSpec:
    """Accepts a canonical name, an alias, a generic ``attn+enc[+npK]``
    string, a VariantSpec, or a TGNConfig."""
    if isinstance(spec, VariantSpec):
        return spec
    if isinstance(spec, tgn.TGNConfig):
        return VariantSpec(spec.attention, spec.encoder, spec.prune_k,
                           spec.sampler)
    if not isinstance(spec, str):
        raise TypeError(f"cannot resolve variant from {type(spec)!r}")
    name = _ALIASES.get(spec, spec)
    if name in _REGISTRY:
        return _REGISTRY[name]
    return _parse_spec(spec)


def _parse_spec(spec: str) -> VariantSpec:
    """Grammar fallback: ``<attention>+<encoder>[+np<k>][+<sampler>]``."""
    parts = spec.split("+")
    if len(parts) not in (2, 3, 4):
        raise ValueError(f"unknown variant {spec!r}; {spec_menu()}")
    attention, encoder = parts[0], parts[1]
    if attention not in ("vanilla", "sat"):
        raise ValueError(f"unknown attention {attention!r} in {spec!r}; "
                         f"{spec_menu()}")
    if encoder not in ("cosine", "lut"):
        raise ValueError(f"unknown encoder {encoder!r} in {spec!r}; "
                         f"{spec_menu()}")
    if attention == "vanilla" and encoder != "cosine":
        raise ValueError("vanilla attention requires the cosine encoder "
                         f"(its K/Q/V inputs consume the cosine encoding "
                         f"directly; LUT is a SAT-path optimization) — "
                         f"got {spec!r}; {spec_menu()}")
    prune_k = None
    sampler = None
    for clause in parts[2:]:
        if clause.startswith("np") and clause[2:].isdigit():
            if prune_k is not None:
                raise ValueError(f"duplicate prune clause {clause!r} in "
                                 f"{spec!r}; {spec_menu()}")
            prune_k = int(clause[2:])
            if attention != "sat":
                raise ValueError("neighbor pruning requires SAT "
                                 f"(prune-then-fetch) — got {spec!r}; "
                                 f"{spec_menu()}")
        elif clause in stages.SAMPLERS:
            if sampler is not None:
                raise ValueError(f"duplicate sampler clause {clause!r} in "
                                 f"{spec!r}; {spec_menu()}")
            sampler = clause
            if attention != "sat" and clause != "recent":
                raise ValueError(
                    "alternative sampler backends require SAT "
                    f"(prune-then-fetch) — got {spec!r}; {spec_menu()}")
        else:
            raise ValueError(f"bad clause {clause!r} in {spec!r}; "
                             f"{spec_menu()}")
    return VariantSpec(attention, encoder, prune_k,
                       sampler if sampler is not None else "recent")


def variant_name(spec) -> str:
    """Canonical registry string for a spec/config (synthesized via the
    grammar when not pre-registered)."""
    v = resolve_variant(spec)
    for name, s in _REGISTRY.items():
        if s == v:
            return name
    base = f"{v.attention}+{v.encoder}"
    if v.prune_k is not None:
        base += f"+np{v.prune_k}"
    if v.sampler != "recent":
        base += f"+{v.sampler}"
    return base


def variant_config(spec, **dims) -> tgn.TGNConfig:
    """TGNConfig for a variant at the given table/feature dims.

    ``dims`` are TGNConfig fields (n_nodes, n_edges, f_edge, f_mem, ...);
    the three variant axes come from ``spec``.
    """
    v = resolve_variant(spec)
    return tgn.TGNConfig(**dims, attention=v.attention, encoder=v.encoder,
                         prune_k=v.prune_k, sampler=v.sampler)


# ---------------------------------------------------------------------------
# The composed pipeline
# ---------------------------------------------------------------------------


class TGNPipeline:
    """Algorithm 1 as a composition of registered stages.

    Pure-function API (jit/grad friendly):
      prepare(params) -> aux                       derived tables
      step(params, aux, state, batch, edge_feats, node_feats) -> BatchOut
      embed(params, aux, state, edge_feats, node_feats, vids, t) -> (h, ...)

    ``batch`` is ``(src, dst, eid, ts, valid)`` with ``valid`` optionally
    None. Convenience wrappers ``init_params``/``init_state``/``step_fn``
    cover the common cases.
    """

    def __init__(self, cfg: tgn.TGNConfig, use_kernels=False):
        self.cfg = cfg
        self.use_kernels = stages.kernel_tier(use_kernels)
        #: the tier that actually runs (``"fused"`` degrades to
        #: ``"staged"`` outside the fused kernel's coverage)
        self.tier = stages.resolved_tier(cfg, use_kernels)
        self.variant = variant_name(cfg)
        self.stages = stages.build_stages(cfg, use_kernels)
        self.prepare = stages.make_prepare(cfg, use_kernels)

    # -- construction helpers ------------------------------------------
    def init_params(self, key: jax.Array, dt_samples=None) -> dict:
        return tgn.init_params(key, self.cfg, dt_samples=dt_samples)

    def init_state(self) -> mailbox.VertexState:
        return tgn.init_state(self.cfg)

    # -- Algorithm 1 ---------------------------------------------------
    def step(self, params: dict, aux: dict, state: mailbox.VertexState,
             batch, edge_feats: jax.Array,
             node_feats: jax.Array | None = None) -> tgn.BatchOut:
        """Process one chronological batch of edges (B,).

        Intra-batch temporal dependencies between vertices are ignored
        (paper's general setup) but commits are chronological with
        last-write-wins per vertex. ``valid`` masks padding rows: their
        state writes are dropped entirely (their embeddings are still
        computed but are garbage the caller must mask).
        """
        src, dst, eid, ts, valid = batch
        B = src.shape[0]
        vids = jnp.concatenate([src, dst])          # (2B,) involved instances
        t_inst = jnp.concatenate([ts, ts])
        vvalid = (jnp.concatenate([valid, valid]) if valid is not None
                  else jnp.ones((2 * B,), bool))
        st = self.stages

        # --- fused tier: the whole post-prune datapath is ONE launch ------
        # (selection metadata + winner-row DMA + EU + MUU inside the
        # kernel; commits and the ring insert follow — see
        # stages.make_fused_step)
        if st.fused is not None:
            return st.fused(params, aux, state, batch, vids, t_inst,
                            vvalid, edge_feats, node_feats)

        # --- 1. UPDT: consume cached mail for involved vertices ----------
        s_upd, lu_upd = st.memory_updater(params, aux, state, vids)

        # --- 2. chronological commit of memory (winners computed ONCE) ---
        # duplicates of a vertex consume the SAME cached mail -> identical
        # values; last-write-wins picks one winner so the scatter is
        # collision-free. The same winner mask serves the mail commit below.
        winners = st.committer.winners(vids, vvalid, B)
        state = st.committer.commit_memory(state, vids, winners, s_upd,
                                           lu_upd)

        # --- 3. GNN embeddings (sampler + aggregator on updated memory) --
        nb = st.sampler(params, aux, state, edge_feats, vids, t_inst)
        s_self = state.memory[vids]
        f_self = node_feats[vids] if node_feats is not None else None
        h, logits = st.aggregator(params, aux, nb, s_self, f_self)

        # --- 4. cache new messages (Most-Recent aggregator == LWW commit) -
        mem_t = state.memory
        fe = edge_feats[eid]
        mail_src = memory.build_mail_raw(mem_t[src], mem_t[dst], fe)
        mail_dst = memory.build_mail_raw(mem_t[dst], mem_t[src], fe)
        new_mail = jnp.concatenate([mail_src, mail_dst], axis=0)
        state = st.committer.commit_mail(state, vids, winners, new_mail,
                                         t_inst)

        # --- 5. neighbor ring-buffer insertion (FIFO sampler) -------------
        state = mailbox.insert_neighbors(state, src, dst, eid, ts, valid)

        return tgn.BatchOut(state=state, emb_src=h[:B], emb_dst=h[B:],
                            attn_logits=logits, nbr_valid=nb.full_valid,
                            nbr_dt=nb.full_dt)

    def embed(self, params: dict, aux: dict, state: mailbox.VertexState,
              edge_feats: jax.Array, node_feats: jax.Array | None,
              vids: jax.Array, t_query: jax.Array):
        """Dynamic embeddings for vertex instances without a state update
        (negative-destination scoring, ad-hoc queries).

        Returns ``(h, logits, valid, dt)`` like the GNN stage of ``step``.
        """
        nb = self.stages.sampler(params, aux, state, edge_feats, vids,
                                 t_query)
        s_self = state.memory[vids]
        f_self = node_feats[vids] if node_feats is not None else None
        h, logits = self.stages.aggregator(params, aux, nb, s_self, f_self)
        return h, logits, nb.full_valid, nb.full_dt

    def step_fn(self, params: dict, state: mailbox.VertexState, batch,
                edge_feats: jax.Array,
                node_feats: jax.Array | None = None) -> tgn.BatchOut:
        """``step`` with aux derived in-trace (training/reference paths:
        gradients flow through the LUT folds)."""
        return self.step(params, self.prepare(params), state, batch,
                         edge_feats, node_feats)

    def batched_step(self, aux: dict, *, donate_state: bool = False,
                     in_shardings=None, out_shardings=None):
        """The cohort launch: ``jit(vmap(step))`` over a leading tenant axis.

        Signature of the returned callable:
        ``(params, stacked_state, stacked_batch, edge_feats, node_feats)
        -> BatchOut`` with state/batch/output leaves carrying the tenant
        axis and params/features broadcast. ``aux`` (folded/packed tables
        with static metadata) is closed over, not traced.

        ``donate_state`` donates the stacked VertexState buffers to the
        launch — the committed state reuses them, so a resident fleet's
        tables are updated in place instead of double-buffered.
        ``in_shardings``/``out_shardings`` pin the mesh placement of every
        operand (the sharded tenant fabric, serving/cluster.py); left
        ``None`` the launch follows its inputs (single-device serving).
        """
        step = self.step

        def one(params, state, batch, ef, nf):
            return step(params, aux, state, batch, ef, nf)

        vstep = jax.vmap(one, in_axes=(None, 0, 0, None, None))
        kw = {}
        if in_shardings is not None:
            kw["in_shardings"] = in_shardings
        if out_shardings is not None:
            kw["out_shardings"] = out_shardings
        if donate_state:
            kw["donate_argnums"] = (1,)
        return jax.jit(vstep, **kw)

    def describe(self) -> dict:
        """Variant + resolved stage backends (introspection/logging)."""
        return {"variant": self.variant, "use_kernels": self.use_kernels,
                "tier": self.tier, "lane": self.stages.variant_id,
                **self.stages.names}


class CoalescedRound:
    """ONE compiled launch advancing EVERY cohort of a serving round.

    The per-cohort launch (``batched_step``) pays one dispatch per cohort
    per round — the dispatch-bound regime StreamTGN identifies for small
    streaming batches. ``CoalescedRound`` fuses the whole round: the
    cohorts are laid out as contiguous row segments of a common
    **super-batch** (rows = sum of cohort capacities, columns = the shared
    padded batch width) and one ``jax.jit`` compiles every segment's
    vmapped step side by side, so a round costs one XLA execution no
    matter how many variants the fleet mixes.

    Variant-stage selection is POSITIONAL and static: each segment's rows
    are advanced by the step closure of the pipeline that built it, bound
    at trace time. ``lane_ids[row]`` (the ``stages.variant_id`` of the
    program advancing that row) is the introspection/guard view of that
    mapping — tests and ``describe`` read it; the launch itself never
    branches on it. A traced per-row ``lax.switch`` would be the dynamic
    alternative, but under ``vmap`` a batched branch index lowers to
    computing every branch for every row and selecting — cohorts ×
    variants work, the opposite of a fusion win — so rows are instead
    pinned to their lane at build time and a lane change is a relayout
    (recompile), exactly like cohort growth today.

    Cohort states stay resident per cohort (``states`` is a tuple aligned
    with the segments — no per-round concatenation of the big vertex
    tables); the super-batch is the only physically fused operand. Pad
    rows (idle tenants, mesh padding, batch-width padding) are
    all-``valid=False`` lanes: the LWW committer and the OOB-redirected
    ring insert make them bitwise no-ops, so per-tenant trajectories are
    identical to the per-cohort launches.

    **Per-lane parameter sets.** ``params`` is a tuple aligned with the
    segments, exactly like ``states``: each segment's vmapped step
    consumes ITS cohort's resident parameter set as a traced operand —
    the same position ``batched_step`` passes it — so a teacher lane and
    two distilled-student lanes (different weights, even different
    attention/encoder pytrees) advance in the SAME compiled launch while
    every segment program stays shape-identical to its per-cohort
    launch (the bitwise contract). A single mapping broadcasts to every
    lane (the shared-params fleet, the pre-param-store behavior).

    **Reserved lane slots (live admission).** A segment's ``rows`` is a
    *capacity*, not a head-count: the serving session may lay a cohort
    out with spare idle-masked slots (``serving/admission.py`` capacity
    classes). Attaching a tenant into a spare slot — or detaching one and
    leaving its slot idle — changes nothing this class was built from, so
    the SAME compiled program keeps serving: no relayout, no recompile,
    no round stall. Only exhausting a capacity class forces a new
    ``CoalescedRound`` (the slow path, identical to cohort growth).
    ``traces`` counts compilations of this launch (the body traces once
    per new static signature), so serving tests can assert live admission
    never recompiled: a fast attach/detach leaves ``traces`` untouched.

    Calling convention::

        outs, edges = round(params, states, superbatch, edge_feats,
                            node_feats)

    ``params`` is a per-cohort tuple (or one mapping, broadcast);
    ``outs`` is a per-cohort tuple of ``BatchOut`` (tenant axis leading);
    ``edges`` is the round's valid-edge count summed INSIDE the launch —
    a device scalar the caller can keep pending, so steady-state serving
    never blocks on a D2H sync to meter throughput.
    """

    def __init__(self, parts, *, donate_state: bool = False,
                 in_shardings=None, out_shardings=None, obs=None):
        """``parts``: sequence of ``(pipeline, aux, rows)`` — one entry per
        cohort, ``rows`` its stacked-table capacity. ``donate_state``
        donates the per-cohort state tuple (resident tables updated in
        place); shardings pin mesh placements exactly as ``batched_step``.
        ``obs`` (an ``obs.MetricsRegistry``) mirrors ``traces``/``calls``
        into the ``compile.round_traces``/``compile.round_calls`` gauges
        so ``compile_counters`` reads one lock-consistent snapshot; the
        gauges keep the current-launch semantics (they reset with every
        fresh layout).
        """
        self.parts = tuple((p, a, int(r)) for p, a, r in parts)
        segments, lanes, lo = [], [], 0
        for pipe, _aux, rows in self.parts:
            segments.append((lo, lo + rows))
            lanes.extend([pipe.stages.variant_id] * rows)
            lo += rows
        self.segments = tuple(segments)
        self.rows = lo
        #: static per-row lane table of the super-batch (introspection).
        self.lane_ids = np.asarray(lanes, np.int32)
        #: number of compiled executions dispatched through this round
        #: launch (the serving tests' one-launch-per-round guard).
        self.calls = 0
        #: number of TRACES of the round body — one per compiled
        #: executable (jit traces exactly on cache miss), i.e. the
        #: compile counter the live-admission zero-recompile guard reads.
        self.traces = 0
        self._g_traces = self._g_calls = None
        if obs is not None:
            self._g_traces = obs.gauge("compile.round_traces")
            self._g_calls = obs.gauge("compile.round_calls")
            self._g_traces.set(0)        # a fresh layout starts at zero
            self._g_calls.set(0)

        steps = [(pipe.step, aux) for pipe, aux, _rows in self.parts]
        segs = self.segments

        # ``widths`` (static): each segment's padded batch width for this
        # round — the cohort's max submitted batch size, exactly the B the
        # per-cohort launch would compile for. Slicing every segment to
        # its own width (rather than running all at the super-batch's
        # global width) matters for the BITWISE contract: XLA's lowering
        # of the embedding math is shape-dependent, so the same real rows
        # under a different padded width can differ in the last ulp. With
        # per-segment widths the compiled segment programs are
        # shape-identical to the per-cohort launches, and jit caches one
        # executable per widths vector — the same recompile behavior the
        # per-cohort dispatch has per cohort.
        def round_fn(params, states, batch, ef, nf, widths):
            self.traces += 1          # trace time == compile time, not per call
            if self._g_traces is not None:
                self._g_traces.set(self.traces)
            outs = []
            for (lo, hi), (step, aux), p, state, w in zip(segs, steps,
                                                          params, states,
                                                          widths):
                seg = tuple(x[lo:hi, :w] for x in batch)

                def one(pp, s, b, e, n, _step=step, _aux=aux):
                    return _step(pp, _aux, s, b, e, n)

                outs.append(jax.vmap(one, in_axes=(None, 0, 0, None, None))(
                    p, state, seg, ef, nf))
            return tuple(outs), jnp.sum(batch[4])

        kw = {}
        if in_shardings is not None:
            kw["in_shardings"] = in_shardings
        if out_shardings is not None:
            kw["out_shardings"] = out_shardings
        if donate_state:
            kw["donate_argnums"] = (1,)
        self._fn = jax.jit(round_fn, static_argnums=(5,), **kw)

    def __call__(self, params, states: tuple, superbatch: tuple,
                 edge_feats, node_feats=None, *, widths: tuple | None = None):
        if widths is None:
            widths = (superbatch[0].shape[1],) * len(self.parts)
        if isinstance(params, Mapping):      # shared-params fleet: broadcast
            params = (params,) * len(self.parts)
        self.calls += 1
        if self._g_calls is not None:
            self._g_calls.set(self.calls)
        return self._fn(params, states, superbatch, edge_feats, node_feats,
                        tuple(int(w) for w in widths))


@functools.lru_cache(maxsize=64)
def _cached_pipeline(cfg: tgn.TGNConfig, tier: str) -> TGNPipeline:
    return TGNPipeline(cfg, tier)


def build_pipeline(spec, use_kernels=False, **dims) -> TGNPipeline:
    """Build (or fetch the cached) pipeline for a variant.

    ``spec`` may be a TGNConfig (used as-is; ``dims`` must be empty) or any
    string/VariantSpec accepted by ``resolve_variant`` — then ``dims``
    supplies the TGNConfig table/feature fields. ``use_kernels`` selects
    the kernel tier (``stages.KERNEL_TIERS``: ``"ref"``/``"staged"``/
    ``"fused"``; legacy booleans accepted).
    """
    if isinstance(spec, tgn.TGNConfig):
        if dims:
            raise TypeError("dims are only valid with a variant spec, "
                            "not a full TGNConfig")
        cfg = spec
    else:
        cfg = variant_config(spec, **dims)
    # cache on the RESOLVED tier: "fused" on an uncovered variant is the
    # same program as "staged", so both requests share one pipeline
    return _cached_pipeline(cfg, stages.resolved_tier(cfg, use_kernels))
