"""Knowledge distillation (Eq. 17) + self-supervised link-prediction losses.

The student (SAT [+LUT] [+NP]) is trained under two signals:

  1. self-supervision from temporal edges — BCE on positive (src,dst) pairs
     vs negative (src, random-dst) pairs, using the downstream link head;
  2. a soft cross-entropy between the student's simplified attention logits
     alpha-bar' = a + W_t * dt and the teacher's vanilla attention logits
     alpha-bar (Eq. 17), temperature T (paper uses T=1):

         l_a = - sum_v Softmax(abar'(v)/T) . log Softmax(abar(v)/T)

     (The paper writes the product of two softmaxes; the standard KD form is
     teacher-prob . log student-prob — we use the standard form, with the
     teacher distribution as the target, which is what "encourage the student
     to mimic the teacher" requires. Invalid neighbor slots are masked.)

Teacher and student see identical vertex-state trajectories during
distillation: the teacher runs on its OWN state (vanilla model), the student
on its own; logits are aligned per edge instance over the shared neighbor
ring-buffer ordering (most-recent first), which is identical for both because
the neighbor table dynamics do not depend on model parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pruning import NEG_INF


def masked_log_softmax(logits: jax.Array, valid: jax.Array) -> jax.Array:
    masked = jnp.where(valid, logits, NEG_INF)
    m = jnp.max(masked, axis=-1, keepdims=True)
    shifted = masked - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted) * valid, axis=-1, keepdims=True)
                  + 1e-30)
    return shifted - lse


def attn_distill_loss(student_logits: jax.Array, teacher_logits: jax.Array,
                      valid: jax.Array, temperature: float = 1.0) -> jax.Array:
    """Eq. 17: soft cross-entropy between attention score distributions.

    student_logits, teacher_logits, valid: (B, m_r). Rows with no valid
    neighbor contribute zero. Teacher side is stop-gradient (it is a frozen
    teacher during distillation anyway, but this makes the intent explicit).
    """
    t = jnp.asarray(temperature, jnp.float32)
    teacher_p = jnp.where(
        valid,
        jax.nn.softmax(
            jnp.where(valid, jax.lax.stop_gradient(teacher_logits) / t,
                      NEG_INF), axis=-1),
        0.0)
    student_logp = masked_log_softmax(student_logits / t, valid)
    per_row = -jnp.sum(teacher_p * jnp.where(valid, student_logp, 0.0), axis=-1)
    has_valid = jnp.any(valid, axis=-1)
    denom = jnp.maximum(jnp.sum(has_valid), 1)
    # T^2 rescaling keeps gradient magnitude comparable across temperatures
    # (Hinton et al. 2015).
    return (t * t) * jnp.sum(jnp.where(has_valid, per_row, 0.0)) / denom


def bce_link_loss(pos_scores: jax.Array, neg_scores: jax.Array) -> jax.Array:
    """Self-supervised temporal link prediction BCE (Section II)."""
    return 0.5 * (jnp.mean(jax.nn.softplus(-pos_scores))
                  + jnp.mean(jax.nn.softplus(neg_scores)))


def distill_loss(student_logits: jax.Array, teacher_logits: jax.Array,
                 valid: jax.Array, pos_scores: jax.Array,
                 neg_scores: jax.Array, *, temperature: float = 1.0,
                 kd_weight: float = 1.0):
    """Combined student objective: link BCE + kd_weight * l_a.

    Returns (total, dict of components).
    """
    l_link = bce_link_loss(pos_scores, neg_scores)
    l_a = attn_distill_loss(student_logits, teacher_logits, valid,
                            temperature)
    total = l_link + kd_weight * l_a
    return total, {"link": l_link, "kd": l_a, "total": total}


def average_precision(pos_scores: jax.Array, neg_scores: jax.Array) -> jax.Array:
    """AP for balanced pos/neg link prediction (the paper's accuracy metric).

    Pure-jnp implementation (no sklearn): sort all scores descending and
    compute mean precision at each positive hit.
    """
    scores = jnp.concatenate([pos_scores, neg_scores])
    labels = jnp.concatenate([jnp.ones_like(pos_scores),
                              jnp.zeros_like(neg_scores)])
    order = jnp.argsort(-scores)
    lab = labels[order]
    cum_tp = jnp.cumsum(lab)
    ranks = jnp.arange(1, lab.shape[0] + 1, dtype=jnp.float32)
    precision_at = cum_tp / ranks
    n_pos = jnp.maximum(jnp.sum(lab), 1.0)
    return jnp.sum(precision_at * lab) / n_pos
