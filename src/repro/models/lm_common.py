"""Uniform adapter over the model families (transformer / mamba2 / rglru /
whisper / vision_lm): one signature for losses, decode steps, abstract
parameter trees and input specs, so the launcher, dry-run, trainer and tests
never special-case a family.

Batch layouts (all leaves jnp arrays or ShapeDtypeStructs):
  train:   {"tokens": (B,S) i32, "targets": (B,S) i32 [, "frames"|"vision"]}
  decode:  {"token": (B,1) i32, "caches": <family cache tree>}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer, mamba2, rglru, whisper, vision_lm

FAMILIES = {
    "transformer": transformer,
    "mamba2": mamba2,
    "rglru": rglru,
    "whisper": whisper,
    "vision_lm": vision_lm,
}


def family_of(cfg) -> str:
    if isinstance(cfg, transformer.LMConfig):
        return "transformer"
    if isinstance(cfg, mamba2.MambaConfig):
        return "mamba2"
    if isinstance(cfg, rglru.GriffinConfig):
        return "rglru"
    if isinstance(cfg, whisper.WhisperConfig):
        return "whisper"
    if isinstance(cfg, vision_lm.VisionLMConfig):
        return "vision_lm"
    raise TypeError(type(cfg))


def init_params(key: jax.Array, cfg):
    return FAMILIES[family_of(cfg)].init(key, cfg)


def abstract_params(cfg):
    return FAMILIES[family_of(cfg)].init_abstract(cfg)


def loss_fn(params, cfg, batch: dict) -> jax.Array:
    fam = family_of(cfg)
    if fam == "whisper":
        return whisper.loss_fn(params, cfg, batch["frames"], batch["tokens"],
                               batch["targets"])
    if fam == "vision_lm":
        return vision_lm.loss_fn(params, cfg, batch["tokens"],
                                 batch["vision"], batch["targets"])
    return FAMILIES[fam].loss_fn(params, cfg, batch["tokens"],
                                 batch["targets"])


def decode_fn(params, cfg, batch: dict):
    """One serve step: next-token logits + updated caches."""
    return FAMILIES[family_of(cfg)].decode_step(params, cfg, batch["token"],
                                                batch["caches"])


def abstract_caches(cfg, batch: int, seq_len: int):
    fam = family_of(cfg)
    mod = FAMILIES[fam]
    return jax.eval_shape(
        lambda: mod.init_caches(cfg, batch, seq_len))


def train_inputs(cfg, batch: int, seq_len: int, *, abstract: bool = True):
    """ShapeDtypeStruct batch for a training step (dry-run path)."""
    fam = family_of(cfg)
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
    if fam == "whisper":
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if fam == "vision_lm":
        specs["vision"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if not abstract:
        specs = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return specs


def decode_inputs(cfg, batch: int, seq_len: int, *, abstract: bool = True):
    """ShapeDtypeStruct batch for a single-token decode step against a
    seq_len-long cache (dry-run path)."""
    specs = {
        "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "caches": abstract_caches(cfg, batch, seq_len),
    }
    if not abstract:
        specs = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return specs


def supports_long_context(cfg) -> bool:
    """True when decode memory/compute per token is sub-linear in history
    (SSM/hybrid) or dominated by windowed layers (gemma3-style local:global).
    Pure full-attention archs skip ``long_500k`` (DESIGN.md §5)."""
    fam = family_of(cfg)
    if fam in ("mamba2", "rglru"):
        return True
    if fam == "transformer":
        return cfg.window is not None and "local" in cfg.pattern
    return False


def has_decode(cfg) -> bool:
    return True  # all assigned archs are decoder-bearing (whisper: enc-dec)
