"""Llama-3.2-Vision-style backbone: text decoder with gated cross-attention
image layers every 5th layer (vision frontend stubbed).

Per the assignment, only the transformer BACKBONE is modeled: ``input_specs``
provides precomputed patch embeddings (B, n_patches, D) — the ViT frontend is
a stub. Self layers are llama-3.1 GQA + SwiGLU; cross layers attend from text
to image tokens with tanh-gated residuals (zero-initialized gates, as in the
reference model, so the text path is intact at init).

Pattern per scan block: 4 self + 1 cross (40 layers = 8 blocks).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, fold_path
from repro.models import layers as L
from repro.distributed import sharding as shd


@dataclasses.dataclass(frozen=True)
class VisionLMConfig(FrozenConfig):
    arch: str = "llama32-vision"
    n_layers: int = 40
    d_model: int = 4096
    n_heads: int = 32
    n_kv_heads: int = 8
    d_head: int = 128
    d_ff: int = 14_336
    vocab: int = 128_256
    n_patches: int = 1024        # stubbed vision tokens per sample
    rope_theta: float = 500_000.0
    cross_every: int = 5         # every 5th layer is cross-attention
    dtype: str = "bfloat16"
    remat: str = "nothing"
    q_block: int = 512
    k_block: int = 1024
    loss_chunk: int = 512

    @property
    def pattern(self) -> tuple[str, ...]:
        return ("self",) * (self.cross_every - 1) + ("cross",)

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.cross_every == 0
        return self.n_layers // self.cross_every

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                         n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                         rope_theta=self.rope_theta)

    def xattn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                         n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                         use_rope=False, qk_norm=True)

    @property
    def n_params(self) -> int:
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        per_layer = attn + 3 * d * f + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d

    n_active_params = n_params


def _init_layer(key: jax.Array, cfg: VisionLMConfig, kind: str) -> dict:
    ka, km = jax.random.split(key)
    p = {"ln1": L.init_rmsnorm(cfg.d_model),
         "ln2": L.init_rmsnorm(cfg.d_model),
         "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff)}
    if kind == "self":
        p["attn"] = L.init_attention(ka, cfg.attn_cfg())
    else:
        p["xattn"] = L.init_attention(ka, cfg.xattn_cfg())
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_ffn"] = jnp.zeros((), jnp.float32)
    return p


def init(key: jax.Array, cfg: VisionLMConfig) -> dict:
    def init_block(bkey):
        ks = jax.random.split(bkey, len(cfg.pattern))
        return {f"l{i}": _init_layer(ks[i], cfg, kind)
                for i, kind in enumerate(cfg.pattern)}

    bkeys = jax.random.split(fold_path(key, "blocks"), cfg.n_blocks)
    return {
        "embed": L.init_embed(fold_path(key, "embed"), cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(init_block)(bkeys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "head": L.init_unembed(fold_path(key, "head"), cfg.d_model, cfg.vocab),
    }


def init_abstract(cfg: VisionLMConfig):
    return jax.eval_shape(lambda: init(jax.random.key(0), cfg))


def _layer_fwd(lp: dict, cfg: VisionLMConfig, kind: str, x: jax.Array,
               positions: jax.Array, vision: jax.Array) -> jax.Array:
    h = L.rmsnorm(lp["ln1"], x)
    if kind == "self":
        a = L.chunked_attention(lp["attn"], cfg.attn_cfg(), h, positions,
                                q_block=cfg.q_block, k_block=cfg.k_block)
        x = x + a
        h = L.rmsnorm(lp["ln2"], x)
        return x + L.mlp(lp["mlp"], h)
    vis_pos = jnp.arange(vision.shape[1], dtype=jnp.int32)
    a = L.chunked_attention(lp["xattn"], cfg.xattn_cfg(), h, positions,
                            kv_x=vision.astype(h.dtype),
                            kv_positions=vis_pos, causal=False,
                            q_block=cfg.q_block, k_block=cfg.k_block)
    x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * a
    h = L.rmsnorm(lp["ln2"], x)
    return x + jnp.tanh(lp["gate_ffn"]).astype(x.dtype) * L.mlp(lp["mlp"], h)


def backbone(params: dict, cfg: VisionLMConfig, tokens: jax.Array,
             vision: jax.Array) -> jax.Array:
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)

    def body(bp, x):
        for i, kind in enumerate(cfg.pattern):
            x = _layer_fwd(bp[f"l{i}"], cfg, kind, x, positions, vision)
        return x

    if cfg.remat != "none":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(
        lambda c, bp: (shd.constrain(body(bp, c), "carry"), None),
        shd.constrain(x, "carry"), params["blocks"])
    return L.rmsnorm(params["final_norm"], x)


def loss_fn(params: dict, cfg: VisionLMConfig, tokens: jax.Array,
            vision: jax.Array, targets: jax.Array) -> jax.Array:
    h = backbone(params, cfg, tokens, vision)
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    w = params["head"]["unembed"]

    def step(acc, i):
        hi = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        ti = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, 1)
        logits = (hi @ w.astype(hi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            jnp.arange(S // chunk))
    return total / (B * S)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_caches(cfg: VisionLMConfig, batch: int, max_len: int,
                params: dict | None = None,
                vision: jax.Array | None = None,
                dtype=jnp.bfloat16) -> dict:
    """Self-KV caches per block + fixed cross K/V from the vision tokens."""
    nb = cfg.n_blocks
    caches = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "self":
            c = L.init_kv_cache(batch, max_len, cfg.attn_cfg(), dtype)
            caches[f"l{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (nb,) + x.shape), c)
    kv, hd = cfg.n_kv_heads, cfg.d_head
    ci = len(cfg.pattern) - 1  # cross position
    if params is not None and vision is not None:
        S = vision.shape[1]

        def one(bp):
            lp = bp[f"l{ci}"]
            dt = vision.dtype
            k = (vision @ lp["xattn"]["wk"].astype(dt)).reshape(
                batch, S, kv, hd)
            k = L.rmsnorm(lp["xattn"]["k_norm"], k)
            v = (vision @ lp["xattn"]["wv"].astype(dt)).reshape(
                batch, S, kv, hd)
            return k.astype(dtype), v.astype(dtype)

        ck, cv = jax.vmap(one)(params["blocks"])
    else:
        ck = jnp.zeros((nb, batch, cfg.n_patches, kv, hd), dtype)
        cv = jnp.zeros((nb, batch, cfg.n_patches, kv, hd), dtype)
    caches["cross_k"], caches["cross_v"] = ck, cv
    return caches


def decode_step(params: dict, cfg: VisionLMConfig, token: jax.Array,
                caches: dict):
    import math
    B = token.shape[0]
    x = L.embed(params["embed"], token, cfg.compute_dtype)
    ci = len(cfg.pattern) - 1
    self_keys = [f"l{i}" for i, k in enumerate(cfg.pattern) if k == "self"]

    def scan_step(x, inp):
        bp, sc, ck, cv = inp
        new_sc = {}
        for i, kind in enumerate(cfg.pattern):
            lp = bp[f"l{i}"]
            h = L.rmsnorm(lp["ln1"], x)
            if kind == "self":
                a, new_sc[f"l{i}"] = L.decode_attention(
                    lp["attn"], cfg.attn_cfg(), h, sc[f"l{i}"])
                x = x + a
                h = L.rmsnorm(lp["ln2"], x)
                x = x + L.mlp(lp["mlp"], h)
            else:
                dt = h.dtype
                kvh, hd = cfg.n_kv_heads, cfg.d_head
                q = (h @ lp["xattn"]["wq"].astype(dt)).reshape(
                    B, kvh, cfg.n_heads // kvh, hd)
                q = L.rmsnorm(lp["xattn"]["q_norm"], q)
                s = jnp.einsum("bngd,btnd->bngt", q.astype(jnp.float32),
                               ck.astype(jnp.float32)) / math.sqrt(hd)
                attn = jax.nn.softmax(s, axis=-1)
                o = jnp.einsum("bngt,btnd->bngd", attn,
                               cv.astype(jnp.float32))
                o = o.reshape(B, 1, cfg.n_heads * hd).astype(dt)
                a = o @ lp["xattn"]["wo"].astype(dt)
                x = x + jnp.tanh(lp["gate_attn"]).astype(dt) * a
                h = L.rmsnorm(lp["ln2"], x)
                x = x + jnp.tanh(lp["gate_ffn"]).astype(dt) * L.mlp(
                    lp["mlp"], h)
        return x, new_sc

    self_caches = {k: caches[k] for k in self_keys}
    x, new_self = jax.lax.scan(
        scan_step, x,
        (params["blocks"], self_caches, caches["cross_k"],
         caches["cross_v"]))
    h = L.rmsnorm(params["final_norm"], x)
    logits = L.unembed(params["head"], h)[:, 0]
    out = dict(new_self)
    out["cross_k"], out["cross_v"] = caches["cross_k"], caches["cross_v"]
    return logits, out


def prefill(params: dict, cfg: VisionLMConfig, tokens: jax.Array,
            vision: jax.Array):
    h = backbone(params, cfg, tokens, vision)
    logits = L.unembed(params["head"], h[:, -1:])[:, 0]
    return logits, h
