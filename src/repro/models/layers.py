"""Shared transformer building blocks (pure functions + dict params).

Conventions (used by distributed/sharding.py path rules):
  * params are nested dicts; leaf names fix the sharding rule:
      embed (V, D) | wq/wk/wv (D, H*hd) | wo (H*hd, D)
      w_gate/w_up (D, F) | w_down (F, D) | unembed (D, V)
      scale (D,) norms | q_norm/k_norm (hd,)
  * weights are stored fp32; compute casts to ``dtype`` (bf16 on TPU);
    norms/softmax/rope run in fp32.
  * attention supports GQA, causal & sliding-window masks, logit softcap,
    qk-norm, cross-attention, and single-token decode against a KV cache.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.utils import dense_init, embed_init

NEG_INF = -2.3819763e38  # max bf16-representable negative; avoids inf-inf NaNs


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}  # gemma-style (1+scale)


def rmsnorm(p: dict, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10_000.0,
         scaling: float = 1.0) -> jax.Array:
    """x (..., S, H, hd); positions (..., S) int32. fp32 internally."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs / scaling  # (...,S,half)
    cos = jnp.cos(ang)[..., None, :]     # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / MHA, causal / sliding-window / cross, cached decode)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10_000.0
    rope_scaling: float = 1.0
    qk_norm: bool = False
    window: int | None = None        # sliding-window size (None = full)
    softcap: float | None = None     # attention-logit softcap
    use_rope: bool = True
    bias: bool = False               # projection biases (whisper)
    cache_upcast: bool = True        # decode: materialize fp32 cache copy
    # (baseline-faithful). False = §Perf O4: score in the cache dtype with
    # fp32 ACCUMULATION (preferred_element_type) — no fp32 cache replica.


def init_attention(key: jax.Array, cfg: AttnCfg) -> dict:
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kv * hd)),
        "wv": dense_init(ks[2], (d, kv * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bo"] = jnp.zeros((d,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, window: int | None,
               causal: bool) -> jax.Array:
    """(..., S_q, S_k) additive fp32 mask from position vectors."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        ok &= dk <= dq
    if window is not None:
        ok &= dk > dq - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(p: dict, cfg: AttnCfg, x: jax.Array, positions: jax.Array,
              *, kv_x: jax.Array | None = None,
              kv_positions: jax.Array | None = None,
              cache: dict | None = None, causal: bool = True) -> tuple:
    """General attention.

    x (B, S, D). Self-attention by default; pass ``kv_x`` for cross-attention
    (then causal/rope on kv side follow kv_positions and cache is ignored).
    With ``cache`` (dict k/v (B, S_max, kv, hd), pos scalar int32): appends
    this call's kv at [pos, pos+S) and attends over the whole cache (decode /
    chunked prefill). Returns (out (B, S, D), new_cache|None).
    """
    B, S, D = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype

    q = (x @ p["wq"].astype(dt)).reshape(B, S, h, hd)
    if "bq" in p:
        q = q + p["bq"].astype(dt).reshape(h, hd)
    src = kv_x if kv_x is not None else x
    Sk = src.shape[1]
    k = (src @ p["wk"].astype(dt)).reshape(B, Sk, kv, hd)
    v = (src @ p["wv"].astype(dt)).reshape(B, Sk, kv, hd)
    if "bv" in p:
        v = v + p["bv"].astype(dt).reshape(kv, hd)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)

    k_pos = kv_positions if kv_positions is not None else positions
    if cfg.use_rope and kv_x is None:
        q = rope(q, positions, theta=cfg.rope_theta, scaling=cfg.rope_scaling)
        k = rope(k, k_pos, theta=cfg.rope_theta, scaling=cfg.rope_scaling)

    new_cache = None
    if cache is not None:
        # append at cache["pos"] (same for all rows: aligned serving batch)
        pos0 = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos0, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos0 + S}
        k, v = ck.astype(dt), cv.astype(dt)
        Sk = k.shape[1]
        k_pos = jnp.arange(Sk, dtype=jnp.int32)[None, :]
        # entries beyond pos0+S are invalid -> masked below via positions
        k_valid = k_pos < (pos0 + S)
    else:
        k_valid = None
        if k_pos.ndim == 1:
            k_pos = k_pos[None, :]

    if positions.ndim == 1:
        positions = positions[None, :]

    # group query heads over kv heads: (B, S, kv, h/kv, hd)
    g = h // kv
    qg = q.reshape(B, S, kv, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bsngd,btnd->bnstg", qg, kf) / math.sqrt(hd)
    # scores: (B, kv, S_q, S_k=t, g) -> reorder to (B, kv, g, S_q, S_k)
    scores = jnp.moveaxis(scores, -1, 2)
    if cfg.softcap is not None:
        scores = jnp.tanh(scores / cfg.softcap) * cfg.softcap
    bias = _mask_bias(positions, k_pos, cfg.window,
                      causal and kv_x is None)          # (B, S_q, S_k)
    scores = scores + bias[:, None, None, :, :]
    if k_valid is not None:
        scores = jnp.where(k_valid[:, None, None, None, :], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnd->bsngd", attn, v.astype(jnp.float32))
    out = out.reshape(B, S, h * hd).astype(dt)
    y = out @ p["wo"].astype(dt)
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return y, new_cache


def init_kv_cache(batch: int, max_len: int, cfg: AttnCfg,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.d_head), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_ring_cache(batch: int, window: int, cfg: AttnCfg,
                    dtype=jnp.bfloat16) -> dict:
    """Rotating KV cache for sliding-window layers: O(window) memory
    regardless of sequence length (slot = absolute_position % window).
    This is what makes ``long_500k`` feasible for gemma3's local layers."""
    return {
        "k": jnp.zeros((batch, window, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((batch, window, cfg.n_kv_heads, cfg.d_head), dtype),
        "k_pos": jnp.full((window,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_attention(p: dict, cfg: AttnCfg, x: jax.Array, cache: dict) -> tuple:
    """Single-token decode (S=1) against a full or ring KV cache.

    Returns (out (B, 1, D), new_cache). Scores are (B, h, 1, S_cache) —
    linear in cache length, no chunking needed.
    """
    B, S, D = x.shape
    assert S == 1, "decode_attention is single-token; use attention() else"
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    pos0 = cache["pos"]
    positions = pos0[None, None]  # (1, 1)

    q = (x @ p["wq"].astype(dt)).reshape(B, 1, h, hd)
    if "bq" in p:
        q = q + p["bq"].astype(dt).reshape(h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, 1, kv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, 1, kv, hd)
    if "bv" in p:
        v = v + p["bv"].astype(dt).reshape(kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cfg.use_rope:
        q = rope(q, positions, theta=cfg.rope_theta, scaling=cfg.rope_scaling)
        k = rope(k, positions, theta=cfg.rope_theta, scaling=cfg.rope_scaling)

    if "k_pos" in cache:  # ring cache
        W = cache["k"].shape[1]
        slot = pos0 % W
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        k_pos = jax.lax.dynamic_update_slice(cache["k_pos"], pos0[None], (slot,))
        new_cache = {"k": ck, "v": cv, "k_pos": k_pos, "pos": pos0 + 1}
        k_pos_b = k_pos[None, :]
        k_valid = k_pos >= 0
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, pos0, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos0 + 1}
        Sk = ck.shape[1]
        k_pos_b = jnp.arange(Sk, dtype=jnp.int32)[None, :]
        k_valid = k_pos_b[0] <= pos0

    g = h // kv
    if cfg.cache_upcast:
        kf, vf = ck.astype(jnp.float32), cv.astype(jnp.float32)
        qg = q.reshape(B, kv, g, hd).astype(jnp.float32)
    else:
        kf, vf = ck, cv
        qg = q.reshape(B, kv, g, hd).astype(ck.dtype)
    scores = jnp.einsum("bngd,btnd->bngt", qg, kf,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    if cfg.softcap is not None:
        scores = jnp.tanh(scores / cfg.softcap) * cfg.softcap
    bias = _mask_bias(positions, k_pos_b, cfg.window, True)[:, 0]  # (B, S_k)
    scores = scores + bias[:, None, None, :]
    scores = jnp.where(k_valid[None, None, None, :], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngt,btnd->bngd",
                     attn if cfg.cache_upcast else attn.astype(cv.dtype),
                     vf, preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, h * hd)
    y = out.astype(dt) @ p["wo"].astype(dt)
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return y, new_cache


def pruned_decode_attention(p: dict, cfg: AttnCfg, x: jax.Array,
                            cache: dict, keep: int,
                            prune_a: float = 0.0,
                            prune_w: float = -1.0) -> tuple:
    """Single-token decode with SAT-style positional KV pruning — the
    paper's prune-before-fetch at the KV cache (DESIGN.md §5): score every
    cache slot from POSITION metadata only (a + w*log1p(age)), keep the
    top-k, gather and attend over just those k rows. Because scores depend
    only on positions, the index set is shared across the batch and heads —
    one cheap top-k, one k-row gather, exactly the paper's dataflow.

    Full (non-ring) caches only. Returns (out (B,1,D), new_cache).
    """
    B, S, D = x.shape
    assert S == 1
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    pos0 = cache["pos"]
    Smax = cache["k"].shape[1]

    q = (x @ p["wq"].astype(dt)).reshape(B, 1, h, hd)
    knew = (x @ p["wk"].astype(dt)).reshape(B, 1, kv, hd)
    vnew = (x @ p["wv"].astype(dt)).reshape(B, 1, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        knew = rmsnorm(p["k_norm"], knew)
    positions = pos0[None, None]
    if cfg.use_rope:
        q = rope(q, positions, theta=cfg.rope_theta,
                 scaling=cfg.rope_scaling)
        knew = rope(knew, positions, theta=cfg.rope_theta,
                    scaling=cfg.rope_scaling)
    ck = jax.lax.dynamic_update_slice(cache["k"],
                                      knew.astype(cache["k"].dtype),
                                      (0, pos0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"],
                                      vnew.astype(cache["v"].dtype),
                                      (0, pos0, 0, 0))
    new_cache = {"k": ck, "v": cv, "pos": pos0 + 1}

    # metadata-only scores -> top-k index set (shared across batch/heads)
    k_pos = jnp.arange(Smax, dtype=jnp.int32)
    age = jnp.maximum(pos0 - k_pos, 0).astype(jnp.float32)
    meta = prune_a + prune_w * jnp.log1p(age)
    meta = jnp.where(k_pos <= pos0, meta, -jnp.inf)
    _, idx = jax.lax.top_k(meta, keep)

    k_sel = jnp.take(ck, idx, axis=1)
    v_sel = jnp.take(cv, idx, axis=1)
    pos_sel = jnp.take(k_pos, idx)
    g = h // kv
    qg = q.reshape(B, kv, g, hd).astype(k_sel.dtype)
    s = jnp.einsum("bngd,btnd->bngt", qg, k_sel,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    if cfg.softcap is not None:
        s = jnp.tanh(s / cfg.softcap) * cfg.softcap
    valid = pos_sel <= pos0
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngt,btnd->bngd", attn.astype(v_sel.dtype), v_sel,
                     preferred_element_type=jnp.float32)
    y = out.reshape(B, 1, h * hd).astype(dt) @ p["wo"].astype(dt)
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return y, new_cache


def chunked_attention(p: dict, cfg: AttnCfg, x: jax.Array,
                      positions: jax.Array, *, kv_x: jax.Array | None = None,
                      kv_positions: jax.Array | None = None,
                      causal: bool = True, q_block: int = 512,
                      k_block: int = 1024,
                      remat_qblocks: bool = False) -> jax.Array:
    """Flash-style attention: scan over query blocks; online-softmax scan
    over key blocks. Peak live buffer is O(q_block * k_block) instead of
    O(S^2) — required to fit train_4k / prefill_32k activations in HBM.

    ``remat_qblocks`` (§Perf optimization H1): wrap each query block's
    key-scan in jax.checkpoint so the BACKWARD recomputes the scores
    instead of autodiff stacking per-k-step fp32 score residuals to HBM —
    the flash-attention backward realized with JAX remat. Off by default
    (the paper-faithful baseline measures the naive autodiff cost).

    For sliding-window layers the key range per query block is exactly
    ``q_block + window`` wide, fetched with one dynamic_slice — compute
    scales with the window, not the sequence (the same score-then-fetch
    spirit as the paper's neighbor pruning, applied to positions).
    """
    B, S, D = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    g = h // kv

    q = (x @ p["wq"].astype(dt)).reshape(B, S, h, hd)
    if "bq" in p:
        q = q + p["bq"].astype(dt).reshape(h, hd)
    src = kv_x if kv_x is not None else x
    Sk = src.shape[1]
    k = (src @ p["wk"].astype(dt)).reshape(B, Sk, kv, hd)
    v = (src @ p["wv"].astype(dt)).reshape(B, Sk, kv, hd)
    if "bv" in p:
        v = v + p["bv"].astype(dt).reshape(kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    k_pos = kv_positions if kv_positions is not None else positions
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None, :], (B, Sk))
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (B, S))
    if cfg.use_rope and kv_x is None:
        q = rope(q, positions, theta=cfg.rope_theta, scaling=cfg.rope_scaling)
        k = rope(k, k_pos, theta=cfg.rope_theta, scaling=cfg.rope_scaling)

    is_causal = causal and kv_x is None

    # pad S to a q_block multiple and Sk to a k_block multiple; padded key
    # slots carry kv_ok=False and are masked to NEG_INF, padded query rows
    # are sliced off at the end.
    qb = min(q_block, S)
    S_p = -(-S // qb) * qb
    kb = min(k_block, Sk)
    Sk_p = -(-Sk // kb) * kb
    if S_p != S:
        q = jnp.pad(q, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, S_p - S)),
                            mode="edge")
    kv_ok = jnp.arange(Sk_p) < Sk
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, Sk_p - Sk)), mode="edge")
    S_orig, S, Sk = S, S_p, Sk_p
    n_q = S // qb

    def score_block(qi, ki, qpos_i, kpos_i, ok_i):
        """(B,qb,kv,g,hd),(B,kb,kv,hd) -> (B,kv,g,qb,kb) fp32 masked scores."""
        s = jnp.einsum("bsngd,btnd->bngst", qi.astype(jnp.float32),
                       ki.astype(jnp.float32)) / math.sqrt(hd)
        if cfg.softcap is not None:
            s = jnp.tanh(s / cfg.softcap) * cfg.softcap
        bias = _mask_bias(qpos_i, kpos_i, cfg.window, is_causal)
        bias = jnp.where(ok_i[None, None, :], bias, NEG_INF)
        return s + bias[:, None, None, :, :]

    if cfg.window is not None and kv_x is None:
        # windowed path: one K slice of width qb + window per query block
        Wk = min(cfg.window + qb, Sk)

        def q_step(_, i):
            qi = jax.lax.dynamic_slice_in_dim(q, i * qb, qb, 1)
            qi = qi.reshape(B, qb, kv, g, hd)
            qpos_i = jax.lax.dynamic_slice_in_dim(positions, i * qb, qb, 1)
            start = jnp.clip(i * qb + qb - Wk, 0, Sk - Wk)
            ki = jax.lax.dynamic_slice_in_dim(k, start, Wk, 1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, Wk, 1)
            kpos_i = jax.lax.dynamic_slice_in_dim(k_pos, start, Wk, 1)
            ok_i = jax.lax.dynamic_slice_in_dim(kv_ok, start, Wk, 0)
            s = score_block(qi, ki, qpos_i, kpos_i, ok_i)
            a = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bngst,btnd->bsngd", a, vi.astype(jnp.float32))
            return None, o.reshape(B, qb, h, hd)

        _, blocks = jax.lax.scan(q_step, None, jnp.arange(n_q))
    else:
        n_k = Sk // kb

        def q_inner(qi, qpos_i, k_, v_, kpos_, ok_):
            def k_step(carry, j):
                m, l, acc = carry
                ki = jax.lax.dynamic_slice_in_dim(k_, j * kb, kb, 1)
                vi = jax.lax.dynamic_slice_in_dim(v_, j * kb, kb, 1)
                kpos_j = jax.lax.dynamic_slice_in_dim(kpos_, j * kb, kb, 1)
                ok_j = jax.lax.dynamic_slice_in_dim(ok_, j * kb, kb, 0)
                s = score_block(qi, ki, qpos_i, kpos_j, ok_j)  # (B,kv,g,qb,kb)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                alpha = jnp.exp(m - m_new)
                ex = jnp.exp(s - m_new[..., None])
                l_new = l * alpha + jnp.sum(ex, axis=-1)
                acc_new = (acc * alpha[..., None]
                           + jnp.einsum("bngst,btnd->bngsd", ex,
                                        vi.astype(jnp.float32)))
                return (m_new, l_new, acc_new), None

            init = (jnp.full((B, kv, g, qb), -jnp.inf, jnp.float32),
                    jnp.zeros((B, kv, g, qb), jnp.float32),
                    jnp.zeros((B, kv, g, qb, hd), jnp.float32))
            (m, l, acc), _ = jax.lax.scan(k_step, init, jnp.arange(n_k))
            o = acc / jnp.maximum(l, 1e-30)[..., None]    # (B,kv,g,qb,hd)
            return jnp.moveaxis(o, 3, 1).reshape(B, qb, h, hd)

        if remat_qblocks:
            q_inner = jax.checkpoint(
                q_inner, policy=jax.checkpoint_policies.nothing_saveable)

        def q_step(_, i):
            qi = jax.lax.dynamic_slice_in_dim(q, i * qb, qb, 1)
            qi = qi.reshape(B, qb, kv, g, hd)
            qpos_i = jax.lax.dynamic_slice_in_dim(positions, i * qb, qb, 1)
            return None, q_inner(qi, qpos_i, k, v, k_pos, kv_ok)

        _, blocks = jax.lax.scan(q_step, None, jnp.arange(n_q))

    out = jnp.moveaxis(blocks, 0, 1).reshape(B, S, h * hd)
    out = out[:, :S_orig].astype(dt)
    y = out @ p["wo"].astype(dt)
    if "bo" in p:
        y = y + p["bo"].astype(dt)
    return y


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, d: int, f: int, *, gated: bool = True) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f)), "w_down": dense_init(ks[1], (f, d))}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d, f))
    return p


def mlp(p: dict, x: jax.Array, *, act: str = "silu") -> jax.Array:
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    if "w_gate" in p:
        gate = x @ p["w_gate"].astype(dt)
        if act == "silu":
            hidden = jax.nn.silu(gate) * up
        else:
            hidden = jax.nn.gelu(gate, approximate=True) * up
    else:
        hidden = jax.nn.gelu(up, approximate=True)
    return hidden @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key: jax.Array, vocab: int, d: int) -> dict:
    return {"embed": embed_init(key, (vocab, d))}


def embed(p: dict, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return p["embed"].astype(dtype)[tokens]


def init_unembed(key: jax.Array, d: int, vocab: int) -> dict:
    return {"unembed": dense_init(key, (d, vocab))}


def unembed(p: dict, x: jax.Array) -> jax.Array:
    # logits in fp32 for a numerically-stable softmax/cross-entropy
    return (x @ p["unembed"].astype(x.dtype)).astype(jnp.float32)
