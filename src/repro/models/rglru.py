"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern is (rec, rec, attn) repeating (1 attention : 2 recurrent), with
MQA sliding-window attention (window 2048). 38 layers = 12 scanned triples +
a 2-layer recurrent tail.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t + b_a)                  (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                  (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training evaluates the recurrence with ``lax.associative_scan`` (log-depth,
fully parallel across the sequence); decode is the O(1) step — with the
paper-eye view: a learned, input-dependent recency decay, the closest
existing LM mechanism to the paper's SAT time-decay attention (DESIGN.md §5).
Gate matrices are block-diagonal (n_heads blocks), as in the reference model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, fold_path, dense_init
from repro.models import layers as L
from repro.distributed import sharding as shd

C_RGLRU = 8.0


@dataclasses.dataclass(frozen=True)
class GriffinConfig(FrozenConfig):
    arch: str = "recurrentgemma"
    n_layers: int = 38
    d_model: int = 4096
    lru_width: int = 4096
    n_heads: int = 16           # attention heads; also gate blocks
    n_kv_heads: int = 1
    d_head: int = 256
    d_ff: int = 12288
    vocab: int = 256_000
    window: int = 2048
    rope_theta: float = 10_000.0
    pattern: tuple[str, ...] = ("rec", "rec", "attn")
    dtype: str = "bfloat16"
    remat: str = "nothing"
    q_block: int = 512
    k_block: int = 1024
    loss_chunk: int = 512

    @property
    def n_full_blocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> tuple[str, ...]:
        r = self.n_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                         n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                         rope_theta=self.rope_theta, window=self.window)

    @property
    def n_params(self) -> int:
        d, w, f = self.d_model, self.lru_width, self.d_ff
        n_rec = sum(k == "rec" for k in
                    self.pattern * self.n_full_blocks + self.tail)
        n_att = self.n_layers - n_rec
        gate = 2 * self.n_heads * (w // self.n_heads) ** 2
        rec = 2 * d * w + 4 * w + gate + w + w * d
        att = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        mlp = 3 * d * f
        return (self.vocab * d * 2 + n_rec * rec + n_att * att
                + self.n_layers * (mlp + 2 * d) + d)

    n_active_params = n_params


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def init_rglru(key: jax.Array, w: int, n_blocks: int) -> dict:
    k1, k2 = jax.random.split(key)
    bw = w // n_blocks
    return {
        "w_a": dense_init(k1, (n_blocks, bw, bw)),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": dense_init(k2, (n_blocks, bw, bw)),
        "b_x": jnp.zeros((w,), jnp.float32),
        # softplus(lambda) in ~(0.1, 1) -> per-step decay a in (0.45, 0.92)^r
        "lam": jnp.linspace(-2.0, 1.0, w).astype(jnp.float32),
    }


def _block_diag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (..., W) @ block-diagonal weight (H, W/H, W/H)."""
    H, bw, _ = w.shape
    xs = x.reshape(*x.shape[:-1], H, bw)
    return jnp.einsum("...hi,hij->...hj", xs, w.astype(x.dtype)).reshape(
        *x.shape[:-1], H * bw)


def rglru_scan(p: dict, x: jax.Array, h0: jax.Array | None = None):
    """x (B, L, W) -> (y (B, L, W), h_last (B, W)). fp32 recurrence."""
    B, Lx, W = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag(xf, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(_block_diag(xf, p["w_x"]) + p["b_x"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r       # (B,L,W) <= 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)

    if h0 is not None:
        # fold the carried state into the first step's additive term
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p: dict, x: jax.Array, h: jax.Array):
    """Single decode step: x (B, 1, W), h (B, W) -> (y (B,1,W), h_new)."""
    xf = x[:, 0].astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag(xf, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(_block_diag(xf, p["w_x"]) + p["b_x"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    h_new = a * h.astype(jnp.float32) \
        + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return h_new.astype(x.dtype)[:, None], h_new


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: GriffinConfig, kind: str) -> dict:
    ks = jax.random.split(key, 5)
    p = {"ln1": L.init_rmsnorm(cfg.d_model), "ln2": L.init_rmsnorm(cfg.d_model),
         "mlp": L.init_mlp(ks[0], cfg.d_model, cfg.d_ff)}
    if kind == "rec":
        w = cfg.lru_width
        p["rec"] = {
            "w_gate_in": dense_init(ks[1], (cfg.d_model, w)),
            "w_main_in": dense_init(ks[2], (cfg.d_model, w)),
            "conv_w": dense_init(ks[3], (4, w), scale=0.5),
            "conv_b": jnp.zeros((w,), jnp.float32),
            "lru": init_rglru(ks[4], w, cfg.n_heads),
            "w_out": dense_init(ks[1], (w, cfg.d_model)),
        }
    else:
        p["attn"] = L.init_attention(ks[1], cfg.attn_cfg())
    return p


def init(key: jax.Array, cfg: GriffinConfig) -> dict:
    def init_block(bkey):
        ks = jax.random.split(bkey, len(cfg.pattern))
        return {f"l{i}": _init_layer(ks[i], cfg, kind)
                for i, kind in enumerate(cfg.pattern)}

    bkeys = jax.random.split(fold_path(key, "blocks"), cfg.n_full_blocks)
    p = {
        "embed": L.init_embed(fold_path(key, "embed"), cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(init_block)(bkeys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "head": L.init_unembed(fold_path(key, "head"), cfg.d_model, cfg.vocab),
    }
    if cfg.tail:
        tkeys = jax.random.split(fold_path(key, "tail"), len(cfg.tail))
        p["tail"] = {f"l{i}": _init_layer(tkeys[i], cfg, kind)
                     for i, kind in enumerate(cfg.tail)}
    return p


def init_abstract(cfg: GriffinConfig):
    return jax.eval_shape(lambda: init(jax.random.key(0), cfg))


from repro.models.mamba2 import _causal_conv  # depthwise causal conv (shared)


def _layer_fwd(lp: dict, cfg: GriffinConfig, kind: str, x: jax.Array,
               positions: jax.Array) -> jax.Array:
    dt = x.dtype
    h = L.rmsnorm(lp["ln1"], x)
    if kind == "rec":
        rp = lp["rec"]
        gate = jax.nn.gelu(h @ rp["w_gate_in"].astype(dt), approximate=True)
        main = h @ rp["w_main_in"].astype(dt)
        main, _ = _causal_conv(main, rp["conv_w"], rp["conv_b"])
        main, _ = rglru_scan(rp["lru"], main)
        t_out = (gate * main) @ rp["w_out"].astype(dt)
    else:
        t_out = L.chunked_attention(lp["attn"], cfg.attn_cfg(), h, positions,
                                    q_block=cfg.q_block, k_block=cfg.k_block)
    x = x + t_out
    h = L.rmsnorm(lp["ln2"], x)
    return x + L.mlp(lp["mlp"], h, act="gelu")


def backbone(params: dict, cfg: GriffinConfig, tokens: jax.Array) -> jax.Array:
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    def body(bp, x):
        for i, kind in enumerate(cfg.pattern):
            x = _layer_fwd(bp[f"l{i}"], cfg, kind, x, positions)
        return x

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_step(carry, bp):
        return shd.constrain(body(bp, carry), "carry"), None

    x = shd.constrain(x, "carry")
    x, _ = jax.lax.scan(scan_step, x, params["blocks"])
    for i, kind in enumerate(cfg.tail):
        x = _layer_fwd(params["tail"][f"l{i}"], cfg, kind, x, positions)
    return L.rmsnorm(params["final_norm"], x)


def loss_fn(params: dict, cfg: GriffinConfig, tokens: jax.Array,
            targets: jax.Array) -> jax.Array:
    h = backbone(params, cfg, tokens)
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    w = params["head"]["unembed"]

    def step(acc, i):
        hi = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        ti = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, 1)
        logits = (hi @ w.astype(hi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            jnp.arange(S // chunk))
    return total / (B * S)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def _layer_cache(cfg: GriffinConfig, kind: str, batch: int, dtype):
    if kind == "rec":
        return {"conv": jnp.zeros((batch, 3, cfg.lru_width), dtype),
                "h": jnp.zeros((batch, cfg.lru_width), jnp.float32)}
    return L.init_ring_cache(batch, cfg.window, cfg.attn_cfg(), dtype)


def init_caches(cfg: GriffinConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    del max_len  # bounded state: ring window + O(1) recurrences
    def stack(kind):
        c = _layer_cache(cfg, kind, batch, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_full_blocks,) + x.shape), c)

    caches = {f"l{i}": stack(kind) for i, kind in enumerate(cfg.pattern)}
    caches["tail"] = {f"l{i}": _layer_cache(cfg, kind, batch, dtype)
                      for i, kind in enumerate(cfg.tail)}
    return caches


def _layer_decode(lp: dict, cfg: GriffinConfig, kind: str, x: jax.Array,
                  cache: dict):
    dt = x.dtype
    h = L.rmsnorm(lp["ln1"], x)
    if kind == "rec":
        rp = lp["rec"]
        gate = jax.nn.gelu(h @ rp["w_gate_in"].astype(dt), approximate=True)
        main = h @ rp["w_main_in"].astype(dt)
        main, conv_n = _causal_conv(main, rp["conv_w"], rp["conv_b"],
                                    cache["conv"])
        main, h_n = rglru_step(rp["lru"], main, cache["h"])
        t_out = (gate * main) @ rp["w_out"].astype(dt)
        new_cache = {"conv": conv_n, "h": h_n}
    else:
        t_out, new_cache = L.decode_attention(lp["attn"], cfg.attn_cfg(), h,
                                              cache)
    x = x + t_out
    h = L.rmsnorm(lp["ln2"], x)
    return x + L.mlp(lp["mlp"], h, act="gelu"), new_cache


def decode_step(params: dict, cfg: GriffinConfig, token: jax.Array,
                caches: dict):
    x = L.embed(params["embed"], token, cfg.compute_dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    def scan_step(x, inp):
        bp, bc = inp
        nc = {}
        for i, kind in enumerate(cfg.pattern):
            x, nc[f"l{i}"] = _layer_decode(bp[f"l{i}"], cfg, kind, x,
                                           bc[f"l{i}"])
        return x, nc

    block_caches = {k: v for k, v in caches.items() if k != "tail"}
    x, new_caches = jax.lax.scan(scan_step, x,
                                 (params["blocks"], block_caches))
    new_caches["tail"] = {}
    for i, kind in enumerate(cfg.tail):
        x, new_caches["tail"][f"l{i}"] = _layer_decode(
            params["tail"][f"l{i}"], cfg, kind, x, caches["tail"][f"l{i}"])
    h = L.rmsnorm(params["final_norm"], x)
    logits = L.unembed(params["head"], h)[:, 0]
    return logits, new_caches


def prefill(params: dict, cfg: GriffinConfig, tokens: jax.Array):
    h = backbone(params, cfg, tokens)
    logits = L.unembed(params["head"], h[:, -1:])[:, 0]
    return logits, h
