"""Token-choice top-k Mixture-of-Experts with static-capacity dispatch.

TPU-native formulation (all shapes static, pjit-partitionable):

  1. route: top-k over router logits -> (T, k) expert ids + normalized probs
  2. rank each (token, k) assignment within its expert via a stable sort
  3. scatter token indices into a (E, C) dispatch table (capacity-drop:
     assignments ranked beyond C are dropped, standard Switch/Mixtral
     practice; C = ceil(T*k/E * capacity_factor) rounded to 128)
  4. gather tokens -> (E, C, D), run the expert FFNs as one batched einsum
     (experts shard over the `model` mesh axis when |E| divides it — EP;
     otherwise the FFN hidden dim shards — TP-within-expert)
  5. combine: scatter-add expert outputs back weighted by routing probs.

The router's "score-then-fetch" structure is the same insight as the paper's
SAT neighbor pruning: cheap logits decide which heavy computation is worth
running before any expert weights are touched (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import dense_init, round_up


def init_moe(key: jax.Array, d: int, f: int, n_experts: int) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, n_experts)),
        "w_gate": dense_init(ks[1], (n_experts, d, f)),
        "w_up": dense_init(ks[2], (n_experts, d, f)),
        "w_down": dense_init(ks[3], (n_experts, f, d)),
    }


def capacity(n_tokens: int, n_experts: int, top_k: int,
             factor: float = 1.25) -> int:
    return round_up(max(int(n_tokens * top_k / n_experts * factor), 128), 128)


def route(router: jax.Array, x: jax.Array, top_k: int):
    """x (T, D) -> (expert_idx (T,k) int32, probs (T,k) fp32).

    Probs are softmax over the selected logits (Mixtral/DBRX-style
    renormalization)."""
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))
    top_logits, idx = jax.lax.top_k(logits, top_k)
    probs = jax.nn.softmax(top_logits, axis=-1)
    return idx.astype(jnp.int32), probs


def build_dispatch(expert_idx: jax.Array, n_experts: int, cap: int):
    """expert_idx (T, k) -> (dispatch_tok (E, C) int32 with T as the
    out-of-range "empty" sentinel, keep (T, k) bool, slot (T, k) int32)."""
    T, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat_e, stable=True)              # group by expert
    sorted_e = flat_e[order]
    # rank within expert group
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                  # exclusive prefix
    rank_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    # scatter token indices into the dispatch table; dropped -> OOB (ignored)
    tok_of = jnp.arange(T * k, dtype=jnp.int32) // k
    e_safe = jnp.where(keep, flat_e, n_experts)
    dispatch = jnp.full((n_experts + 1, cap), T, jnp.int32)
    dispatch = dispatch.at[e_safe, jnp.where(keep, rank, 0)].set(
        jnp.where(keep, tok_of, T))
    return dispatch[:n_experts], keep.reshape(T, k), rank.reshape(T, k)


def moe_ffn(p: dict, x: jax.Array, top_k: int, *,
            capacity_factor: float = 1.25, act: str = "silu") -> jax.Array:
    """x (T, D) -> (T, D). See module docstring for the dataflow."""
    T, D = x.shape
    E = p["router"].shape[1]
    C = capacity(T, E, top_k, capacity_factor)
    dt = x.dtype

    expert_idx, probs = route(p["router"], x, top_k)
    dispatch, keep, rank = build_dispatch(expert_idx, E, C)

    # gather (E, C, D); OOB sentinel rows read as zeros via explicit pad row
    x_pad = jnp.concatenate([x, jnp.zeros((1, D), dt)], axis=0)
    xd = x_pad[dispatch]                                  # (E, C, D)

    gate = jnp.einsum("ecd,edf->ecf", xd, p["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xd, p["w_up"].astype(dt))
    hidden = (jax.nn.silu(gate) if act == "silu"
              else jax.nn.gelu(gate, approximate=True)) * up
    out = jnp.einsum("ecf,efd->ecd", hidden, p["w_down"].astype(dt))

    # combine: each (token, k) slot reads back its expert row and weights it
    y = jnp.zeros((T + 1, D), jnp.float32)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    flat_e = expert_idx.reshape(-1)
    flat_rank = rank.reshape(-1)
    flat_keep = keep.reshape(-1)
    flat_w = probs.reshape(-1) * flat_keep
    rows = out[flat_e, jnp.where(flat_keep, flat_rank, 0)]  # (T*k, D)
    y = y.at[jnp.where(flat_keep, flat_tok, T)].add(
        rows.astype(jnp.float32) * flat_w[:, None])
    return y[:T].astype(dt)


def moe_ffn_ref(p: dict, x: jax.Array, top_k: int, *,
                act: str = "silu") -> jax.Array:
    """Dense oracle (no capacity drops): every expert runs on every token,
    combined by routing probs. Used by tests (with generous capacity the
    dispatch path must match exactly)."""
    T, D = x.shape
    dt = x.dtype
    expert_idx, probs = route(p["router"], x, top_k)
    gate = jnp.einsum("td,edf->tef", x, p["w_gate"].astype(dt))
    up = jnp.einsum("td,edf->tef", x, p["w_up"].astype(dt))
    hidden = (jax.nn.silu(gate) if act == "silu"
              else jax.nn.gelu(gate, approximate=True)) * up
    out = jnp.einsum("tef,efd->ted", hidden, p["w_down"].astype(dt))
    E = p["router"].shape[1]
    w = jnp.zeros((T, E), jnp.float32)
    w = w.at[jnp.arange(T)[:, None], expert_idx].add(probs)
    return jnp.einsum("te,ted->td", w.astype(dt), out)
