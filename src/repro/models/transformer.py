"""Decoder-only LM family: dense GQA transformers and MoE transformers.

Covers gemma3-12b (5:1 local:global sliding-window pattern, RoPE-scaled
globals), mistral-nemo-12b, granite-3-8b, qwen3-8b (qk-norm), dbrx-132b
(16e top-4) and grok-1-314b (8e top-2).

Layers are grouped into scan blocks of ``len(cfg.pattern)`` layers; the
per-block parameter trees are stacked along a leading axis and the forward
is a single ``jax.lax.scan`` — compile time and HLO size stay O(pattern)
instead of O(n_layers), which is what makes 80 dry-run compiles tractable.
Each block body is wrapped in ``jax.checkpoint`` (policy configurable) so
train_4k activations fit HBM.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, fold_path
from repro.models import layers as L
from repro.models import moe as M
from repro.distributed import sharding as shd


@dataclasses.dataclass(frozen=True)
class LMConfig(FrozenConfig):
    arch: str = "lm"
    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_head: int = 64
    d_ff: int = 2048
    vocab: int = 32_000
    rope_theta: float = 10_000.0
    rope_theta_local: float | None = None   # gemma3 locals use theta=10k
    rope_scaling: float = 1.0               # gemma3 globals: 8x linear scale
    qk_norm: bool = False
    window: int | None = None               # sliding-window width for "local"
    pattern: tuple[str, ...] = ("global",)  # repeating layer kinds
    softcap: float | None = None
    act: str = "silu"
    embed_scale: bool = False               # gemma multiplies embed by sqrt(d)
    # MoE (0 experts = dense)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # execution
    dtype: str = "bfloat16"
    remat: str = "nothing"                  # "nothing" | "dots" | "none"
    attn_remat: bool = False                # §Perf H1: flash-style bwd remat
    decode_upcast: bool = True              # §Perf O4 off = no fp32 cache copy
    kv_prune_keep: int = 0                  # §Perf O2: >0 = positional KV prune
    decode_unroll: bool = False             # §Perf O5: unrolled decode blocks
    # (donated caches alias in place; scan xs/ys would round-trip the whole
    # cache through HBM every token)
    q_block: int = 512
    k_block: int = 1024
    loss_chunk: int = 512

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            (self.arch, self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def attn_cfg(self, kind: str) -> L.AttnCfg:
        local = kind == "local"
        theta = (self.rope_theta_local if (local and self.rope_theta_local)
                 else self.rope_theta)
        return L.AttnCfg(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.d_head,
            rope_theta=theta,
            rope_scaling=1.0 if local else self.rope_scaling,
            qk_norm=self.qk_norm,
            window=self.window if local else None,
            softcap=self.softcap, cache_upcast=self.decode_upcast)

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        if self.n_experts:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.d_head * 2 \
            + d * self.n_kv_heads * self.d_head * 2
        ffn = self.top_k * 3 * d * f + d * self.n_experts
        per_layer = attn + ffn + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: LMConfig, kind: str) -> dict:
    ka, km = jax.random.split(key)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ka, cfg.attn_cfg(kind)),
        "ln2": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.n_experts:
        p["moe"] = M.init_moe(km, cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff)
    return p


def init(key: jax.Array, cfg: LMConfig) -> dict:
    """Stacked params: blocks.l{i}.* leaves have leading dim n_blocks."""
    def init_block(bkey):
        ks = jax.random.split(bkey, len(cfg.pattern))
        return {f"l{i}": _init_layer(ks[i], cfg, kind)
                for i, kind in enumerate(cfg.pattern)}

    bkeys = jax.random.split(fold_path(key, "blocks"), cfg.n_blocks)
    blocks = jax.vmap(init_block)(bkeys)
    return {
        "embed": L.init_embed(fold_path(key, "embed"), cfg.vocab, cfg.d_model),
        "blocks": blocks,
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "head": L.init_unembed(fold_path(key, "head"), cfg.d_model, cfg.vocab),
    }


def init_abstract(cfg: LMConfig):
    """ShapeDtypeStruct tree without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _layer_fwd(lp: dict, cfg: LMConfig, kind: str, x: jax.Array,
               positions: jax.Array) -> jax.Array:
    acfg = cfg.attn_cfg(kind)
    h = L.rmsnorm(lp["ln1"], x)
    attn_out = L.chunked_attention(lp["attn"], acfg, h, positions,
                                   q_block=cfg.q_block, k_block=cfg.k_block,
                                   remat_qblocks=cfg.attn_remat)
    x = x + attn_out
    h = L.rmsnorm(lp["ln2"], x)
    if cfg.n_experts:
        B, S, D = h.shape
        y = M.moe_ffn(lp["moe"], h.reshape(B * S, D), cfg.top_k,
                      capacity_factor=cfg.capacity_factor, act=cfg.act)
        y = y.reshape(B, S, D)
    else:
        y = L.mlp(lp["mlp"], h, act=cfg.act)
    return x + y


def _block_fwd(bp: dict, cfg: LMConfig, x: jax.Array,
               positions: jax.Array) -> jax.Array:
    # §Perf H2: optional Megatron-SP schedule — when the launcher installs a
    # "block_in" rule, the carry is gathered from its sequence-sharded
    # layout ONCE per block here (and returns to sequence-sharded at the
    # scan boundary), instead of XLA re-gathering inside every attention
    # q-block step.
    x = shd.constrain(x, "block_in")
    for i, kind in enumerate(cfg.pattern):
        x = _layer_fwd(bp[f"l{i}"], cfg, kind, x, positions)
    return x


def _remat(fn, cfg: LMConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat == "nothing"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def backbone(params: dict, cfg: LMConfig, tokens: jax.Array,
             positions: jax.Array | None = None) -> jax.Array:
    """tokens (B, S) -> final hidden states (B, S, D)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    body = _remat(functools.partial(_block_fwd, cfg=cfg), cfg)

    def scan_step(carry, bp):
        out = body(bp, x=carry, positions=positions)
        return shd.constrain(out, "carry"), None

    x = shd.constrain(x, "carry")
    x, _ = jax.lax.scan(scan_step, x, params["blocks"])
    return L.rmsnorm(params["final_norm"], x)


def loss_fn(params: dict, cfg: LMConfig, tokens: jax.Array,
            targets: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy, vocab-chunk-safe.

    The (B, S, V) logits tensor never fully materializes: the loss scans
    over sequence chunks, computing logits + logsumexp per chunk (fp32).
    """
    h = backbone(params, cfg, tokens)
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    n_chunks = S // chunk
    assert S % chunk == 0
    w = params["head"]["unembed"]

    def step(acc, i):
        hi = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        ti = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, 1)
        logits = (hi @ w.astype(hi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            jnp.arange(n_chunks))
    return total / (B * S)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------


def init_caches(cfg: LMConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    """Stacked caches: one entry per pattern position, leading dim n_blocks.
    Local layers get O(window) ring caches, globals full-length caches."""
    def one(kind):
        acfg = cfg.attn_cfg(kind)
        if kind == "local" and cfg.window is not None and cfg.window < max_len:
            c = L.init_ring_cache(batch, cfg.window, acfg, dtype)
        else:
            c = L.init_kv_cache(batch, max_len, acfg, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_blocks,) + x.shape), c)

    return {f"l{i}": one(kind) for i, kind in enumerate(cfg.pattern)}


def decode_step(params: dict, cfg: LMConfig, token: jax.Array,
                caches: dict):
    """token (B, 1) int32; caches from init_caches (all at the same pos).
    Returns (logits (B, vocab) fp32, new caches)."""
    x = L.embed(params["embed"], token, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    def scan_step(x, block):
        bp, bc = block
        new_c = {}
        for i, kind in enumerate(cfg.pattern):
            lp = bp[f"l{i}"]
            h = L.rmsnorm(lp["ln1"], x)
            # §Perf O2: positional KV pruning on full (non-ring) caches —
            # the paper's SAT prune-before-fetch at the decode KV cache
            if cfg.kv_prune_keep and "k_pos" not in bc[f"l{i}"] \
                    and bc[f"l{i}"]["k"].shape[1] > cfg.kv_prune_keep:
                a, nc = L.pruned_decode_attention(
                    lp["attn"], cfg.attn_cfg(kind), h, bc[f"l{i}"],
                    cfg.kv_prune_keep)
            else:
                a, nc = L.decode_attention(lp["attn"], cfg.attn_cfg(kind),
                                           h, bc[f"l{i}"])
            new_c[f"l{i}"] = nc
            x = x + a
            h = L.rmsnorm(lp["ln2"], x)
            if cfg.n_experts:
                B, S, D = h.shape
                y = M.moe_ffn(lp["moe"], h.reshape(B * S, D), cfg.top_k,
                              capacity_factor=cfg.capacity_factor,
                              act=cfg.act).reshape(B, S, D)
            else:
                y = L.mlp(lp["mlp"], h, act=cfg.act)
            x = x + y
        return x, new_c

    if cfg.decode_unroll:
        # §Perf O5: straight-line decode — per-block updates write back into
        # the (donated) stacked cache buffers via in-place dynamic-update-
        # slice; nothing round-trips through scan ys stacks.
        new_caches = caches
        for b in range(cfg.n_blocks):
            bp = jax.tree.map(lambda a: a[b], params["blocks"])
            bc = jax.tree.map(lambda a: a[b], new_caches)
            x, nc = scan_step(x, (bp, bc))
            new_caches = jax.tree.map(
                lambda full, new: full.at[b].set(new), new_caches, nc)
    else:
        x, new_caches = jax.lax.scan(scan_step, x,
                                     (params["blocks"], caches))
    h = L.rmsnorm(params["final_norm"], x)
    logits = L.unembed(params["head"], h)[:, 0]
    return logits, new_caches


def prefill(params: dict, cfg: LMConfig, tokens: jax.Array):
    """Prompt pass: returns (last-token logits (B, vocab) fp32, hidden
    states). Cache materialization for subsequent decode is a separate
    concern (decode cells lower decode_step directly, per the assignment)."""
    h = backbone(params, cfg, tokens)
    logits = L.unembed(params["head"], h[:, -1:])[:, 0]
    return logits, h
