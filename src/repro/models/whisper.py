"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed mel-frame embeddings (B, n_frames, D) — the encoder consumes them
directly (adding sinusoidal positions). Pre-LayerNorm blocks with biased
projections and plain-GELU MLPs, per the Whisper architecture; decoder layers
add cross-attention to the encoder output.

Decode shapes exercise the DECODER: single-token step against a self-KV cache
of the assigned length plus fixed cross K/V computed once from the encoder.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, fold_path, embed_init
from repro.models import layers as L
from repro.distributed import sharding as shd


@dataclasses.dataclass(frozen=True)
class WhisperConfig(FrozenConfig):
    arch: str = "whisper"
    n_layers: int = 4           # encoder AND decoder layer count
    d_model: int = 384
    n_heads: int = 6
    n_kv_heads: int = 6
    d_head: int = 64
    d_ff: int = 1536
    vocab: int = 51_865
    n_frames: int = 1500        # encoder positions (30s of audio)
    max_target: int = 448       # decoder learned-position table size (grown
                                # to the serving length when needed)
    dtype: str = "bfloat16"
    remat: str = "nothing"
    q_block: int = 512
    k_block: int = 512
    loss_chunk: int = 512

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def attn_cfg(self) -> L.AttnCfg:
        return L.AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                         n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                         use_rope=False, bias=True)

    @property
    def n_params(self) -> int:
        d, f = self.d_model, self.d_ff
        attn = 4 * d * self.n_heads * self.d_head
        mlp = 2 * d * f
        enc = self.n_layers * (attn + mlp + 4 * d)
        dec = self.n_layers * (2 * attn + mlp + 6 * d)
        return self.vocab * d + self.max_target * d + enc + dec + 4 * d

    n_active_params = n_params


def _sinusoid(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg):
    ka, km = jax.random.split(key)
    return {"ln1": L.init_layernorm(cfg.d_model),
            "attn": L.init_attention(ka, cfg.attn_cfg()),
            "ln2": L.init_layernorm(cfg.d_model),
            "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, gated=False)}


def _init_dec_layer(key, cfg):
    ka, kc, km = jax.random.split(key, 3)
    return {"ln1": L.init_layernorm(cfg.d_model),
            "attn": L.init_attention(ka, cfg.attn_cfg()),
            "ln_x": L.init_layernorm(cfg.d_model),
            "xattn": L.init_attention(kc, cfg.attn_cfg()),
            "ln2": L.init_layernorm(cfg.d_model),
            "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, gated=False)}


def init(key: jax.Array, cfg: WhisperConfig) -> dict:
    ekeys = jax.random.split(fold_path(key, "enc"), cfg.n_layers)
    dkeys = jax.random.split(fold_path(key, "dec"), cfg.n_layers)
    return {
        "embed": L.init_embed(fold_path(key, "embed"), cfg.vocab, cfg.d_model),
        "pos_dec": embed_init(fold_path(key, "pos"),
                              (cfg.max_target, cfg.d_model)),
        "enc": jax.vmap(lambda k: _init_enc_layer(k, cfg))(ekeys),
        "dec": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dkeys),
        "enc_norm": L.init_layernorm(cfg.d_model),
        "dec_norm": L.init_layernorm(cfg.d_model),
    }


def init_abstract(cfg: WhisperConfig):
    return jax.eval_shape(lambda: init(jax.random.key(0), cfg))


def encode(params: dict, cfg: WhisperConfig, frames: jax.Array) -> jax.Array:
    """frames (B, n_frames, D) — precomputed frontend embeddings (stub)."""
    B, S, D = frames.shape
    x = frames.astype(cfg.compute_dtype) + _sinusoid(S, D).astype(
        cfg.compute_dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(lp, x):
        h = L.layernorm(lp["ln1"], x)
        a, _ = L.attention(lp["attn"], cfg.attn_cfg(), h, positions,
                           causal=False)
        x = x + a
        h = L.layernorm(lp["ln2"], x)
        return x + L.mlp(lp["mlp"], h)

    if cfg.remat != "none":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(
        lambda c, lp: (shd.constrain(body(lp, c), "carry"), None),
        shd.constrain(x, "carry"), params["enc"])
    return L.layernorm(params["enc_norm"], x)


def _dec_layer(lp, cfg, x, positions, enc_out, enc_pos):
    h = L.layernorm(lp["ln1"], x)
    a = L.chunked_attention(lp["attn"], cfg.attn_cfg(), h, positions,
                            q_block=cfg.q_block, k_block=cfg.k_block)
    x = x + a
    h = L.layernorm(lp["ln_x"], x)
    a = L.chunked_attention(lp["xattn"], cfg.attn_cfg(), h, positions,
                            kv_x=enc_out, kv_positions=enc_pos, causal=False,
                            q_block=cfg.q_block, k_block=cfg.k_block)
    x = x + a
    h = L.layernorm(lp["ln2"], x)
    return x + L.mlp(lp["mlp"], h)


def _dec_positions(params, cfg, positions):
    """Learned decoder positions, tiled when serving beyond max_target."""
    return params["pos_dec"][positions % cfg.max_target]


def decode_train(params: dict, cfg: WhisperConfig, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)
    x = x + _dec_positions(params, cfg, positions).astype(x.dtype)
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(lp, x):
        return _dec_layer(lp, cfg, x, positions, enc_out, enc_pos)

    if cfg.remat != "none":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(
        lambda c, lp: (shd.constrain(body(lp, c), "carry"), None),
        shd.constrain(x, "carry"), params["dec"])
    return L.layernorm(params["dec_norm"], x)


def loss_fn(params: dict, cfg: WhisperConfig, frames: jax.Array,
            tokens: jax.Array, targets: jax.Array) -> jax.Array:
    enc_out = encode(params, cfg, frames)
    h = decode_train(params, cfg, tokens, enc_out)
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    w = params["embed"]["embed"].T  # tied unembedding, as in Whisper

    def step(acc, i):
        hi = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        ti = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, 1)
        logits = (hi @ w.astype(hi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            jnp.arange(S // chunk))
    return total / (B * S)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_caches(cfg: WhisperConfig, batch: int, max_len: int,
                params: dict | None = None,
                enc_out: jax.Array | None = None,
                dtype=jnp.bfloat16) -> dict:
    """Self caches for every decoder layer + cross K/V (precomputed once from
    the encoder output when ``params``+``enc_out`` are given, else zeros —
    the dry-run path treats the filled caches as inputs)."""
    nl = cfg.n_layers
    self_c = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (nl,) + x.shape),
        L.init_kv_cache(batch, max_len, cfg.attn_cfg(), dtype))
    kv, hd = cfg.n_kv_heads, cfg.d_head
    if params is not None and enc_out is not None:
        S = enc_out.shape[1]

        def one(lp):  # one decoder layer's cross K/V from the encoder output
            dt = enc_out.dtype
            k = (enc_out @ lp["xattn"]["wk"].astype(dt))
            v = (enc_out @ lp["xattn"]["wv"].astype(dt)
                 + lp["xattn"]["bv"].astype(dt))
            return (k.reshape(batch, S, kv, hd).astype(dtype),
                    v.reshape(batch, S, kv, hd).astype(dtype))

        ck, cv = jax.vmap(one)(params["dec"])
    else:
        ck = jnp.zeros((nl, batch, cfg.n_frames, kv, hd), dtype)
        cv = jnp.zeros((nl, batch, cfg.n_frames, kv, hd), dtype)
    return {"self": self_c, "cross_k": ck, "cross_v": cv}


def decode_step(params: dict, cfg: WhisperConfig, token: jax.Array,
                caches: dict):
    B = token.shape[0]
    pos0 = caches["self"]["pos"][0]
    x = L.embed(params["embed"], token, cfg.compute_dtype)
    x = x + _dec_positions(params, cfg, pos0[None]).astype(x.dtype)[None]

    def scan_step(x, inp):
        lp, sc, ck, cv = inp
        h = L.layernorm(lp["ln1"], x)
        a, nsc = L.decode_attention(lp["attn"], cfg.attn_cfg(), h, sc)
        x = x + a
        # cross-attention: q for 1 token over fixed enc K/V
        h = L.layernorm(lp["ln_x"], x)
        dt = h.dtype
        hd_, kvh = cfg.d_head, cfg.n_kv_heads
        q = (h @ lp["xattn"]["wq"].astype(dt)
             + lp["xattn"]["bq"].astype(dt)).reshape(B, kvh,
                                                     cfg.n_heads // kvh, hd_)
        s = jnp.einsum("bngd,btnd->bngt", q.astype(jnp.float32),
                       ck.astype(jnp.float32)) / math.sqrt(hd_)
        attn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bngt,btnd->bngd", attn, cv.astype(jnp.float32))
        o = o.reshape(B, 1, cfg.n_heads * hd_).astype(dt)
        a = o @ lp["xattn"]["wo"].astype(dt) + lp["xattn"]["bo"].astype(dt)
        x = x + a
        h = L.layernorm(lp["ln2"], x)
        x = x + L.mlp(lp["mlp"], h)
        return x, nsc

    x, new_self = jax.lax.scan(
        scan_step, x,
        (params["dec"], caches["self"], caches["cross_k"], caches["cross_v"]))
    h = L.layernorm(params["dec_norm"], x)
    logits = (h @ params["embed"]["embed"].T.astype(h.dtype))
    return logits.astype(jnp.float32)[:, 0], {
        "self": new_self, "cross_k": caches["cross_k"],
        "cross_v": caches["cross_v"]}


def prefill(params: dict, cfg: WhisperConfig, frames: jax.Array,
            tokens: jax.Array):
    enc_out = encode(params, cfg, frames)
    h = decode_train(params, cfg, tokens, enc_out)
    logits = (h[:, -1:] @ params["embed"]["embed"].T.astype(h.dtype))
    return logits.astype(jnp.float32)[:, 0], enc_out
