"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) language model.

The SSD layer computes, per head h with scalar decay A_h < 0:

    state_t = exp(dt_t A) state_{t-1} + dt_t B_t x_t^T        (P x N outer)
    y_t     = C_t . state_t + D x_t

Training uses the chunked block-decomposition (the "duality"): sequences are
split into chunks of Q tokens; within a chunk the quadratic form
(C_t.B_s) exp(l_t - l_s) dt_s runs on the MXU like attention, across chunks a
``lax.scan`` carries the (B, H, P, N) state. Because A < 0 and dt > 0 every
exponent is <= 0 — all decays live in (0, 1], no overflow anywhere.

Decode is the O(1) recurrence — the reason this arch runs the ``long_500k``
cell that quadratic-attention models skip.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils import FrozenConfig, fold_path, dense_init
from repro.models import layers as L
from repro.distributed import sharding as shd


@dataclasses.dataclass(frozen=True)
class MambaConfig(FrozenConfig):
    arch: str = "mamba2"
    n_layers: int = 24
    d_model: int = 768
    expand: int = 2
    d_head: int = 64            # SSD head dim P
    d_state: int = 128          # N
    n_groups: int = 1           # B/C groups G
    conv_width: int = 4
    vocab: int = 50_280
    chunk: int = 128            # SSD chunk length Q
    dtype: str = "bfloat16"
    remat: str = "nothing"
    loss_chunk: int = 512

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.d_head

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def n_params(self) -> int:
        d, di = self.d_model, self.d_inner
        proj_in = d * (2 * di + 2 * self.n_groups * self.d_state
                       + self.n_heads)
        conv = self.conv_dim * self.conv_width
        per_layer = (proj_in + conv + 3 * self.n_heads + di * d + d + di)
        return self.vocab * d * 2 + self.n_layers * per_layer + d

    n_active_params = n_params


def _init_layer(key: jax.Array, cfg: MambaConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    H = cfg.n_heads
    return {
        "norm": L.init_rmsnorm(cfg.d_model),
        "in_proj": dense_init(k1, (cfg.d_model,
                                   2 * cfg.d_inner
                                   + 2 * cfg.n_groups * cfg.d_state + H)),
        "conv_w": dense_init(k2, (cfg.conv_width, cfg.conv_dim), scale=0.5),
        "conv_b": jnp.zeros((cfg.conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.logspace(-3, -1, H).astype(jnp.float32))),  # softplus^-1
        "gate_norm": L.init_rmsnorm(cfg.d_inner),
        "out_proj": dense_init(k3, (cfg.d_inner, cfg.d_model)),
    }


def init(key: jax.Array, cfg: MambaConfig) -> dict:
    lkeys = jax.random.split(fold_path(key, "layers"), cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(lkeys)
    return {
        "embed": L.init_embed(fold_path(key, "embed"), cfg.vocab, cfg.d_model),
        "layers": layers,
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "head": L.init_unembed(fold_path(key, "head"), cfg.d_model, cfg.vocab),
    }


def init_abstract(cfg: MambaConfig):
    return jax.eval_shape(lambda: init(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _split_proj(cfg: MambaConfig, zxbcdt: jax.Array):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    b = zxbcdt[..., 2 * di:2 * di + g * n]
    c = zxbcdt[..., 2 * di + g * n:2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n:]
    return z, x, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv. x (B, L, C), w (K, C). With ``state``
    (B, K-1, C) — streaming mode: prepend and return the new tail."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    out = jax.nn.silu(out + b.astype(x.dtype))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return out, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int, h0: jax.Array | None = None):
    """SSD scan. x (B,L,H,P) fp32; dt (B,L,H) >0; a (H,) <0;
    b,c (B,L,G,N). Returns (y (B,L,H,P), h_final (B,H,P,N))."""
    B, Lx, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    Q = min(chunk, Lx)
    assert Lx % Q == 0, (Lx, Q)
    nc = Lx // Q
    rep = H // G

    def resh(t):  # (B, L, ...) -> (nc, B, Q, ...)
        return jnp.moveaxis(t.reshape(B, nc, Q, *t.shape[2:]), 1, 0)

    xc, dtc, bc, cc = resh(x), resh(dt), resh(b), resh(c)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_c, dt_c, b_c, c_c = inp                    # (B,Q,H,P) etc.
        la = dt_c * a                                # (B,Q,H) log-decays <0
        l = jnp.cumsum(la, axis=1)                   # inclusive
        l_last = l[:, -1]                            # (B,H)
        bh = jnp.repeat(b_c, rep, axis=2)            # (B,Q,H,N)
        ch = jnp.repeat(c_c, rep, axis=2)

        # inter-chunk: y_t += exp(l_t) C_t . h_in
        y_inter = jnp.exp(l)[..., None] * jnp.einsum(
            "bqhn,bhpn->bqhp", ch, h)

        # intra-chunk quadratic form
        scores = jnp.einsum("bqhn,bshn->bhqs", ch, bh)
        lt = l.transpose(0, 2, 1)                    # (B,H,Q)
        decay = jnp.exp(lt[:, :, :, None] - lt[:, :, None, :])
        qi = jnp.arange(Q)
        causal = (qi[:, None] >= qi[None, :])
        w = scores * jnp.where(causal, decay, 0.0) \
            * dtc_s(dt_c)                            # (B,H,Q,Q)
        y_intra = jnp.einsum("bhqs,bshp->bqhp", w, x_c)

        # state carry
        carry_dec = jnp.exp(l_last)                  # (B,H)
        w_state = (dt_c * jnp.exp(l_last[:, None] - l))  # (B,Q,H)
        h_new = h * carry_dec[..., None, None] + jnp.einsum(
            "bqhn,bqhp,bqh->bhpn", bh, x_c, w_state)
        return h_new, y_inter + y_intra

    def dtc_s(dt_c):                                 # (B,H,1,Q) dt at s
        return dt_c.transpose(0, 2, 1)[:, :, None, :]

    h_f, yc = jax.lax.scan(step, h0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, Lx, H, P)
    return y, h_f


def ssd_ref(x, dt, a, b, c):
    """Naive per-step recurrence oracle (tests)."""
    B, Lx, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)

    def step(h, t):
        xt, dtt, bt, ct = x[:, t], dt[:, t], bh[:, t], ch[:, t]
        dec = jnp.exp(dtt * a)                       # (B,H)
        h = h * dec[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", bt, xt, dtt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(Lx))
    return jnp.moveaxis(ys, 0, 1)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def _layer_fwd(lp: dict, cfg: MambaConfig, x: jax.Array,
               conv_state=None, ssm_state=None, streaming: bool = False):
    dt_c = x.dtype
    B, Lx, D = x.shape
    h = L.rmsnorm(lp["norm"], x)
    zxbcdt = h @ lp["in_proj"].astype(dt_c)
    z, xs, b, c, dt = _split_proj(cfg, zxbcdt)

    conv_in = jnp.concatenate([xs, b, c], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, lp["conv_w"], lp["conv_b"],
                                      conv_state)
    di, g, n = cfg.d_inner, cfg.n_groups, cfg.d_state
    xs = conv_out[..., :di]
    b = conv_out[..., di:di + g * n]
    c = conv_out[..., di + g * n:]

    H, P = cfg.n_heads, cfg.d_head
    xh = xs.reshape(B, Lx, H, P).astype(jnp.float32)
    bg = b.reshape(B, Lx, g, n).astype(jnp.float32)
    cg = c.reshape(B, Lx, g, n).astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])

    if streaming and Lx == 1:
        # O(1) recurrence
        rep = H // g
        bh = jnp.repeat(bg[:, 0], rep, axis=1)       # (B,H,N)
        ch = jnp.repeat(cg[:, 0], rep, axis=1)
        dec = jnp.exp(dtp[:, 0] * a)
        h_new = ssm_state * dec[..., None, None] + jnp.einsum(
            "bhn,bhp,bh->bhpn", bh, xh[:, 0], dtp[:, 0])
        y = jnp.einsum("bhn,bhpn->bhp", ch, h_new)[:, None]
        new_ssm = h_new
    else:
        y, new_ssm = ssd_chunked(xh, dtp, a, bg, cg, cfg.chunk, ssm_state)

    y = y + lp["d_skip"][:, None] * xh               # D skip
    y = y.reshape(B, Lx, di).astype(dt_c)
    y = L.rmsnorm(lp["gate_norm"], y * jax.nn.silu(z))
    out = y @ lp["out_proj"].astype(dt_c)
    return x + out, new_conv, new_ssm


def backbone(params: dict, cfg: MambaConfig, tokens: jax.Array) -> jax.Array:
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)

    def body(lp, x):
        y, _, _ = _layer_fwd(lp, cfg, x)
        return y

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_step(carry, lp):
        return shd.constrain(body(lp, carry), "carry"), None

    x = shd.constrain(x, "carry")
    x, _ = jax.lax.scan(scan_step, x, params["layers"])
    return L.rmsnorm(params["final_norm"], x)


def loss_fn(params: dict, cfg: MambaConfig, tokens: jax.Array,
            targets: jax.Array) -> jax.Array:
    h = backbone(params, cfg, tokens)
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    w = params["head"]["unembed"]

    def step(acc, i):
        hi = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        ti = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, 1)
        logits = (hi @ w.astype(hi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.zeros((), jnp.float32),
                            jnp.arange(S // chunk))
    return total / (B * S)


def init_caches(cfg: MambaConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    del max_len  # O(1) state — the whole point
    nl = cfg.n_layers
    return {
        "conv": jnp.zeros((nl, batch, cfg.conv_width - 1, cfg.conv_dim),
                          dtype),
        "ssm": jnp.zeros((nl, batch, cfg.n_heads, cfg.d_head, cfg.d_state),
                         jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: dict, cfg: MambaConfig, token: jax.Array,
                caches: dict):
    x = L.embed(params["embed"], token, cfg.compute_dtype)

    def scan_step(x, inp):
        lp, conv_s, ssm_s = inp
        y, nc, ns = _layer_fwd(lp, cfg, x, conv_s, ssm_s, streaming=True)
        return y, (nc, ns)

    x, (conv_n, ssm_n) = jax.lax.scan(
        scan_step, x, (params["layers"], caches["conv"], caches["ssm"]))
    h = L.rmsnorm(params["final_norm"], x)
    logits = L.unembed(params["head"], h)[:, 0]
    return logits, {"conv": conv_n, "ssm": ssm_n, "pos": caches["pos"] + 1}


def prefill(params: dict, cfg: MambaConfig, tokens: jax.Array):
    h = backbone(params, cfg, tokens)
    logits = L.unembed(params["head"], h[:, -1:])[:, 0]
    return logits, h
