"""llama-3.2-vision-11b: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attention image layers every 5th layer; vision frontend
STUB (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.models.vision_lm import VisionLMConfig

ARCH_ID = "llama32_vision_11b"
SHARD_MODE = "tp"
GRAD_ACCUM = 1


def config() -> VisionLMConfig:
    return VisionLMConfig(
        arch=ARCH_ID, n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=14336, vocab=128_256, n_patches=1024,
        rope_theta=500_000.0, cross_every=5)


def smoke_config() -> VisionLMConfig:
    return VisionLMConfig(
        arch=ARCH_ID + "_smoke", n_layers=10, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, n_patches=16,
        cross_every=5, dtype="float32", q_block=16, k_block=16,
        loss_chunk=32)
