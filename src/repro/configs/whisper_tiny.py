"""whisper-tiny: 4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865,
enc-dec with conv frontend STUB (input_specs provides precomputed frame
embeddings). [arXiv:2212.04356; unverified]
"""
from repro.models.whisper import WhisperConfig

ARCH_ID = "whisper_tiny"
SHARD_MODE = "tp"
GRAD_ACCUM = 1


def config() -> WhisperConfig:
    return WhisperConfig(
        arch=ARCH_ID, n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_head=64, d_ff=1536, vocab=51_865, n_frames=1500, max_target=448)


def smoke_config() -> WhisperConfig:
    return WhisperConfig(
        arch=ARCH_ID + "_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=512, n_frames=32,
        max_target=64, dtype="float32", q_block=16, k_block=16,
        loss_chunk=32)
