"""granite-3-8b: 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base family; hf]
"""
from repro.models.transformer import LMConfig

ARCH_ID = "granite_3_8b"
SHARD_MODE = "tp"
GRAD_ACCUM = 1


def config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID, n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=12800, vocab=49_155, rope_theta=10_000.0)


def smoke_config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID + "_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, dtype="float32",
        q_block=16, k_block=16, loss_chunk=32)
