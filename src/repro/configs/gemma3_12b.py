"""gemma3-12b: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local:global layer pattern (sliding window 1024 on locals), qk-norm,
RoPE theta 1M on globals (8x linear scaling) / 10k on locals, d_head 256.
[hf:google/gemma-3-1b-pt family; unverified]
"""
from repro.models.transformer import LMConfig

ARCH_ID = "gemma3_12b"
SHARD_MODE = "tp"
GRAD_ACCUM = 1

_PATTERN = ("local",) * 5 + ("global",)


def config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID, n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        d_head=256, d_ff=15360, vocab=262_144,
        pattern=_PATTERN, window=1024,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0, rope_scaling=8.0,
        qk_norm=True, embed_scale=True)


def smoke_config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID + "_smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
        pattern=_PATTERN, window=16,
        rope_theta=1_000_000.0, rope_theta_local=10_000.0, rope_scaling=8.0,
        qk_norm=True, embed_scale=True, dtype="float32",
        q_block=16, k_block=16, loss_chunk=32)
