"""mamba2-130m: 24L d_model=768, attention-free SSD (state-space duality),
ssm_state=128, headdim=64, expand=2, vocab=50280. [arXiv:2405.21060;
unverified]

Runs long_500k: decode state is O(1) in history (the point of SSMs).
"""
from repro.models.mamba2 import MambaConfig

ARCH_ID = "mamba2_130m"
SHARD_MODE = "tp"
GRAD_ACCUM = 1


def config() -> MambaConfig:
    return MambaConfig(
        arch=ARCH_ID, n_layers=24, d_model=768, expand=2, d_head=64,
        d_state=128, n_groups=1, conv_width=4, vocab=50_280, chunk=256)


def smoke_config() -> MambaConfig:
    return MambaConfig(
        arch=ARCH_ID + "_smoke", n_layers=2, d_model=64, expand=2, d_head=16,
        d_state=32, n_groups=1, conv_width=4, vocab=512, chunk=16,
        dtype="float32", loss_chunk=32)
