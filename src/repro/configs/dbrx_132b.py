"""dbrx-132b: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4 (fine-grained). [hf:databricks/dbrx-base; unverified]

fsdp2d sharding: 132B fp32 params cannot be DP-replicated. Experts shard
over the model axis (16 experts / 16-way = pure expert parallelism).
"""
from repro.models.transformer import LMConfig

ARCH_ID = "dbrx_132b"
SHARD_MODE = "fsdp2d"
GRAD_ACCUM = 2
MOMENT_DTYPE = "float32"


def config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID, n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_head=128, d_ff=10752, vocab=100_352, rope_theta=500_000.0,
        n_experts=16, top_k=4)


def smoke_config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID + "_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=96, vocab=512, n_experts=4, top_k=2,
        dtype="float32", q_block=16, k_block=16, loss_chunk=32)
