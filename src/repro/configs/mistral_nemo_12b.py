"""mistral-nemo-12b: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, head_dim=128, 128k ctx (RoPE theta 1M), full attention.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.models.transformer import LMConfig

ARCH_ID = "mistral_nemo_12b"
SHARD_MODE = "tp"
GRAD_ACCUM = 1


def config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID, n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=14336, vocab=131_072, rope_theta=1_000_000.0)


def smoke_config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID + "_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
        rope_theta=1_000_000.0, dtype="float32",
        q_block=16, k_block=16, loss_chunk=32)
