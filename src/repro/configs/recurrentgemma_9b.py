"""recurrentgemma-9b: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention (window 2048), 1 attn : 2 recurrent.
[arXiv:2402.19427; unverified]

Runs long_500k: recurrent state + ring window caches are O(1) in history.
"""
from repro.models.rglru import GriffinConfig

ARCH_ID = "recurrentgemma_9b"
SHARD_MODE = "tp"
GRAD_ACCUM = 1


def config() -> GriffinConfig:
    return GriffinConfig(
        arch=ARCH_ID, n_layers=38, d_model=4096, lru_width=4096, n_heads=16,
        n_kv_heads=1, d_head=256, d_ff=12288, vocab=256_000, window=2048)


def smoke_config() -> GriffinConfig:
    return GriffinConfig(
        arch=ARCH_ID + "_smoke", n_layers=8, d_model=64, lru_width=64,
        n_heads=4, n_kv_heads=1, d_head=16, d_ff=128, vocab=512, window=16,
        dtype="float32", q_block=16, k_block=16, loss_chunk=32)
