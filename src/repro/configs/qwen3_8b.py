"""qwen3-8b: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936,
qk-norm. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.transformer import LMConfig

ARCH_ID = "qwen3_8b"
SHARD_MODE = "tp"
GRAD_ACCUM = 1


def config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID, n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=12288, vocab=151_936, rope_theta=1_000_000.0,
        qk_norm=True)


def smoke_config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID + "_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, qk_norm=True,
        dtype="float32", q_block=16, k_block=16, loss_chunk=32)
