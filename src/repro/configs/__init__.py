"""Architecture registry: ``--arch <id>`` resolves here.

Each config module exposes:
    ARCH_ID      str
    SHARD_MODE   "tp" | "fsdp2d"   (see distributed/sharding.py)
    config()     full assigned-size config
    smoke_config()  reduced same-family config for CPU smoke tests
Optional:
    MOMENT_DTYPE    optimizer moment storage ("float32"|"bfloat16"|"int8")
    GRAD_ACCUM      micro-batches per train step at the assigned shapes
"""
from __future__ import annotations

import dataclasses
import importlib

_ARCH_IDS = (
    "gemma3_12b", "mistral_nemo_12b", "granite_3_8b", "qwen3_8b",
    "dbrx_132b", "grok_1_314b", "mamba2_130m", "whisper_tiny",
    "recurrentgemma_9b", "llama32_vision_11b",
)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    module: object

    @property
    def shard_mode(self) -> str:
        return self.module.SHARD_MODE

    @property
    def moment_dtype(self) -> str:
        return getattr(self.module, "MOMENT_DTYPE", "float32")

    @property
    def grad_accum(self) -> int:
        return getattr(self.module, "GRAD_ACCUM", 1)

    def config(self):
        return self.module.config()

    def smoke_config(self):
        return self.module.smoke_config()


def _norm(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "")


def get(arch_id: str) -> ArchSpec:
    name = _norm(arch_id)
    if name not in _ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return ArchSpec(arch_id=name, module=mod)


def all_archs() -> list[str]:
    return list(_ARCH_IDS)


# Shape cells (assignment): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}
