"""grok-1-314b: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2, attention-logit softcap 30. [hf:xai-org/grok-1;
unverified]

fsdp2d + int8 optimizer moments: at 314B params, fp32 Adam moments alone
(2.5TB) exceed the pod's HBM — 8-bit moments are load-bearing here, not an
optimization (DESIGN.md §4). 8 experts on a 16-way model axis -> TP inside
each expert (ff shards), not EP.
"""
from repro.models.transformer import LMConfig

ARCH_ID = "grok_1_314b"
SHARD_MODE = "fsdp2d"
GRAD_ACCUM = 4
MOMENT_DTYPE = "int8"


def config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID, n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_head=128, d_ff=32768, vocab=131_072, rope_theta=10_000.0,
        n_experts=8, top_k=2, softcap=30.0)


def smoke_config() -> LMConfig:
    return LMConfig(
        arch=ARCH_ID + "_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, n_experts=4, top_k=2,
        softcap=30.0, dtype="float32", q_block=16, k_block=16, loss_chunk=32)
