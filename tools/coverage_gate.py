"""Coverage floor over the serving stack (``make coverage``).

Gates ``src/repro/serving/`` + ``src/repro/core/pipeline.py`` +
``src/repro/obs/`` — the multi-tenant lane table, admission, frontend,
coalesced round and the observability layer threaded through them — the
code the bitwise serving contract lives in. Two modes, mirroring the
Makefile's pyflakes->compileall fallback idiom:

* **pytest-cov installed** (requirements-dev.txt): delegates to
  ``pytest --cov`` over the full tier-1 suite and enforces ``FLOOR``.
* **fallback** (bare container): an in-process ``sys.settrace`` line
  tracer over a serving-focused test subset, with executable lines
  derived from each module's compiled code objects (``co_lines``), and
  a subset-calibrated ``FALLBACK_FLOOR``. No third-party coverage
  machinery — slower per line but runs anywhere.

Both floors are deliberately a few points under the measured value:
the gate catches a satellite module silently dropping out of the suite
(a deleted test file, an always-skip), not single-line drift.
"""
from __future__ import annotations

import importlib.util
import os
import subprocess
import sys
import threading
import types

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TARGETS = ("src/repro/serving", "src/repro/core/pipeline.py",
           "src/repro/obs")

#: tier-1 pytest-cov floor (percent over the TARGETS).
FLOOR = 80

#: fallback-mode floor: calibrated on FALLBACK_TESTS (measured 86% with
#: the obs layer included — the sharded cluster paths skip on 1 device,
#: lm_serve has no test here).
FALLBACK_FLOOR = 80
FALLBACK_TESTS = (
    "tests/test_admission.py",
    "tests/test_frontend.py",
    "tests/test_checkpoint.py",
    "tests/test_session.py",
    "tests/test_obs.py",
    "tests/test_guard.py",
    "tests/test_journal.py",
)


def _target_files() -> list:
    out = []
    for t in TARGETS:
        p = os.path.join(ROOT, t)
        if os.path.isdir(p):
            out.extend(os.path.join(p, f) for f in sorted(os.listdir(p))
                       if f.endswith(".py"))
        else:
            out.append(p)
    return out


def _executable_lines(path: str) -> set:
    """Line numbers with executable bytecode, from the compiled module's
    code objects walked recursively — the denominator pytest-cov would
    compute, minus its pragma/branch niceties."""
    with open(path) as f:
        code = compile(f.read(), path, "exec")
    lines: set = set()
    stack = [code]
    while stack:
        c = stack.pop()
        lines.update(ln for _s, _e, ln in c.co_lines() if ln is not None)
        stack.extend(k for k in c.co_consts
                     if isinstance(k, types.CodeType))
    lines.discard(0)
    return lines


def run_pytest_cov() -> int:
    pkgs = ["--cov=repro.serving", "--cov=repro.core.pipeline",
            "--cov=repro.obs"]
    cmd = [sys.executable, "-m", "pytest", "-x", "-q", *pkgs,
           f"--cov-fail-under={FLOOR}", "--cov-report=term-missing"]
    print("coverage gate: pytest-cov over tier-1,", f"floor {FLOOR}%")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src") + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    return subprocess.call(cmd, cwd=ROOT, env=env)


def run_fallback() -> int:
    targets = {os.path.abspath(p) for p in _target_files()}
    hits: dict = {}

    def tracer(frame, event, _arg):
        fn = frame.f_code.co_filename
        if event == "call":
            # trace into target frames only: everything else runs at
            # full speed (returning None disables per-line events there)
            return tracer if fn in targets else None
        if event == "line":
            hits.setdefault(fn, set()).add(frame.f_lineno)
        return tracer

    import pytest  # after path setup, before the tracer goes live
    print("coverage gate: pytest-cov not installed; settrace fallback "
          f"over {len(FALLBACK_TESTS)} test files, "
          f"floor {FALLBACK_FLOOR}%")
    os.chdir(ROOT)
    threading.settrace(tracer)
    sys.settrace(tracer)
    try:
        rc = pytest.main(["-x", "-q", "-p", "no:cacheprovider",
                          *FALLBACK_TESTS])
    finally:
        sys.settrace(None)
        threading.settrace(None)
    if rc != 0:
        print(f"coverage gate: test subset FAILED (pytest rc {rc})")
        return int(rc) or 1

    total_exec = total_hit = 0
    print(f"{'file':<44}{'lines':>7}{'hit':>6}{'cover':>8}")
    for path in sorted(targets):
        exe = _executable_lines(path)
        hit = len(exe & hits.get(path, set()))
        total_exec += len(exe)
        total_hit += hit
        pct = 100.0 * hit / len(exe) if exe else 100.0
        rel = os.path.relpath(path, ROOT)
        print(f"{rel:<44}{len(exe):>7}{hit:>6}{pct:>7.1f}%")
    pct = 100.0 * total_hit / max(total_exec, 1)
    print(f"{'TOTAL':<44}{total_exec:>7}{total_hit:>6}{pct:>7.1f}%")
    if pct < FALLBACK_FLOOR:
        print(f"coverage gate: {pct:.1f}% < floor {FALLBACK_FLOOR}%")
        return 1
    print(f"coverage gate: OK ({pct:.1f}% >= {FALLBACK_FLOOR}%)")
    return 0


def main() -> int:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    if importlib.util.find_spec("pytest_cov") is not None:
        return run_pytest_cov()
    return run_fallback()


if __name__ == "__main__":
    sys.exit(main())
