#!/usr/bin/env python
"""Zero-recompile serving smoke (make serve-smoke, wired into make lint).

Boots the online front-end in-process over a reserve-enabled
SessionManager, attaches 3 tenants across 2 cohorts, streams a few
hundred edges through deadline-batched rounds, live-attaches AND
live-detaches a 4th tenant mid-stream, and asserts the hard serving
invariants:

- the whole run compiles the coalesced round exactly once
  (``round_traces == 1``) and never relays out after the warmup
  (``relayouts`` frozen) — live admission landed in reserved slots;
- every round is ONE compiled launch (``launches == 1`` in the round
  metrics, ``round_calls`` == rounds);
- no event was rejected or silently dropped.

The run also serves with the OBSERVABILITY layer armed — a 1/8-sampled
``RoundTracer`` on the same fake clock plus a per-event SLO — and
asserts it changes nothing about those invariants while delivering the
goods: the exported Chrome trace carries distinct
ingest/flush/stage/launch/h2d/drain spans on sampled rounds only, and
``summary()["per_tenant"]`` reports SLO burn for every tenant.

A fake clock drives the deadline batcher so the smoke is deterministic;
``pad_quantum`` keeps every flushed width identical, which is exactly the
production recipe for a stable compiled executable.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import jax
import jax.numpy as jnp


def main() -> int:
    from repro.core import pipeline as pl, tgn
    from repro.data import temporal_graph as tgd
    from repro.obs import RoundTracer
    from repro.serving.frontend import FrontendConfig, ServingFrontend
    from repro.serving.session import SessionManager

    g = tgd.wikipedia_like(n_edges=500)
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=16,
                            f_time=16, f_emb=16, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg,
                         reserve=True)
    # 3 tenants across 2 cohorts (np4 + np4-with-reservoir-sampler)
    t0 = mgr.add_tenant()
    t1 = mgr.add_tenant()
    t2 = mgr.add_tenant("sat+lut+np4+reservoir")

    clock = [0.0]
    tracer = RoundTracer(clock=lambda: clock[0], sample_every=8)
    fe = ServingFrontend(
        mgr, FrontendConfig(max_wait_s=0.005, max_rows=8, queue_rows=256,
                            pad_quantum=8),
        clock=lambda: clock[0], tracer=tracer, slo_ms=25.0)

    def feed(tids, i0, rounds):
        nonlocal edges
        for r in range(rounds):
            for i in range(i0 + r * 8, i0 + r * 8 + 8):
                for tid in tids:
                    fe.submit(tid, int(g.src[i]), int(g.dst[i]), i,
                              float(g.ts[i]), int(g.dst[(i + 3) % 500]))
                    edges += 1
            clock[0] += 0.006            # past the 5ms deadline
            assert fe.pump(), "deadline flush did not fire"

    edges = 0
    feed((t0, t1, t2), 0, 2)             # warmup: compile the round once
    mgr.sync()
    c0 = mgr.compile_counters()
    assert c0["round_traces"] == 1, c0

    # mid-stream attach into the reservoir cohort's spare slot (the np4
    # cohort's class is full at 2/2 — attaching there would relayout)
    live = fe.attach("sat+lut+np4+reservoir", name="live")
    assert not mgr.last_admission["relayout"], mgr.last_admission
    feed((t0, t1, t2, live), 16, 5)
    fe.detach(live)                      # mid-stream detach: slot idles
    assert not mgr.last_admission["relayout"], mgr.last_admission
    feed((t0, t1, t2), 56, 5)
    mgr.sync()

    c1 = mgr.compile_counters()
    stats = fe.stats()
    rounds = stats["rounds"]
    launches = {m["launches"] for m in mgr.metrics}
    ok = (c1["relayouts"] == c0["relayouts"]
          and c1["round_traces"] == 1
          and c1["round_calls"] == rounds
          and launches == {1}
          and stats["rejected"] == 0
          and fe.orphaned == 0
          and stats["accepted"] == edges)

    # observability acceptance: sampled spans + trace export + SLO burn
    span_names = {s.name for s in tracer.spans}
    want_spans = {"ingest", "flush", "stage", "launch", "h2d", "drain"}
    fd, trace_path = tempfile.mkstemp(suffix=".json", prefix="smoke-trace-")
    os.close(fd)
    try:
        tracer.write_chrome(trace_path)
        with open(trace_path) as f:
            doc = json.load(f)
        exported = {e["name"] for e in doc["traceEvents"]
                    if e.get("ph") == "X"}
    finally:
        os.unlink(trace_path)
    per_tenant = mgr.summary()["per_tenant"]
    slo_ok = (set(per_tenant) == set(mgr.tenants)
              and all("slo" in st and st["slo"]["events"] > 0
                      and 0.0 <= st["slo"]["budget_remaining"] <= 1.0
                      for st in per_tenant.values()))
    obs_ok = (0 < tracer.rounds_sampled < tracer.rounds_seen
              and want_spans <= span_names
              and want_spans <= exported
              and tracer.dropped == 0
              and slo_ok)

    print(f"serve-smoke: {edges} edges, {rounds} rounds, "
          f"{len(mgr.tenants)} tenants / {len(mgr._cohorts)} cohorts, "
          f"live attach+detach, counters {c1}, "
          f"launches-per-round {sorted(launches)} -> "
          f"{'OK' if ok else 'FAIL'}")
    print(f"serve-smoke: obs {tracer.rounds_sampled}/{tracer.rounds_seen} "
          f"rounds sampled, spans {sorted(span_names)}, SLO burn for "
          f"{len(per_tenant)} tenants -> {'OK' if obs_ok else 'FAIL'}")
    if not (ok and obs_ok):
        print(f"serve-smoke: c0={c0} stats={stats} "
              f"exported={sorted(exported)} per_tenant={per_tenant}",
              file=sys.stderr)
    return 0 if ok and obs_ok else 1


if __name__ == "__main__":
    sys.exit(main())
