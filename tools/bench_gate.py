"""Throughput regression gate (``make bench-gate``).

Runs the three load-bearing benchmark sweeps at toy scale — the
coalesced-vs-per-cohort multitenant round, the fused-vs-staged step, and
the fig5 engine throughput — and compares their edges/s against the
committed baseline (``results/bench_gate.json``). A metric more than
``TOLERANCE`` below its baseline fails the gate: the serving-path
refactors this repo keeps stacking must not quietly give back the
dispatch-cost wins the paper's co-design is about.

The baseline is a best-of-``REPEATS`` measurement on the committing
host, and the gate also takes the best of ``REPEATS`` — so the
comparison tracks the machine's ceiling, not its background-load noise.
``TOLERANCE`` is wide (25%) for the same reason: this catches
regressions of the "accidentally re-enabled per-tenant dispatch" order,
not single-digit drift. Regenerate the baseline after an INTENDED
performance change:

    PYTHONPATH=src python tools/bench_gate.py --update
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "results", "bench_gate.json")

#: fail when current < baseline * (1 - TOLERANCE)
TOLERANCE = 0.25
#: best-of-N runs per case (both for --update and for the gate)
REPEATS = 2


def _case_multitenant() -> dict:
    from benchmarks.multitenant import coalesced_sweep
    row = coalesced_sweep(tenant_counts=(3,), cohort_counts=(3,),
                          batch=16, rounds=4, n_edges=600, f_mem=16)[0]
    return {"coalesced_eps": float(row["coalesced_eps"]),
            "per_cohort_eps": float(row["per_cohort_eps"])}


def _case_fused_step() -> dict:
    from benchmarks.fused_step import sweep
    row = sweep(batch_sizes=(16,), rounds=4, n_edges=600, f_mem=16)[0]
    return {"staged_eps": float(row["staged_eps"]),
            "fused_eps": float(row["fused_eps"])}


def _case_fig5() -> dict:
    from benchmarks.fig5_latency_throughput import sweep
    rows = sweep(batch_sizes=(25,), n_edges=600, f_mem=16)
    return {f"{r['model']}_eps": float(r["throughput_eps"]) for r in rows}


CASES = {
    "multitenant": _case_multitenant,
    "fused_step": _case_fused_step,
    "fig5": _case_fig5,
}


def measure() -> dict:
    """Best-of-REPEATS edges/s for every gated metric, flattened to
    ``case.metric`` keys."""
    best: dict = {}
    for name, fn in CASES.items():
        for i in range(REPEATS):
            print(f"bench gate: {name} run {i + 1}/{REPEATS} ...",
                  flush=True)
            for k, v in fn().items():
                key = f"{name}.{k}"
                best[key] = max(best.get(key, 0.0), v)
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="re-measure and overwrite the committed baseline")
    args = ap.parse_args(argv)

    if not args.update and not os.path.exists(BASELINE):
        print(f"bench gate: no baseline at {BASELINE}; "
              "run with --update first")
        return 1

    current = measure()
    if args.update:
        os.makedirs(os.path.dirname(BASELINE), exist_ok=True)
        with open(BASELINE, "w") as f:
            json.dump({"tolerance": TOLERANCE, "repeats": REPEATS,
                       "metrics": current}, f, indent=2, sort_keys=True)
        print(f"bench gate: baseline written -> {BASELINE}")
        for k, v in sorted(current.items()):
            print(f"  {k:<40}{v:>12.0f} E/s")
        return 0

    with open(BASELINE) as f:
        base = json.load(f)["metrics"]
    failures = []
    print(f"{'metric':<40}{'baseline':>12}{'current':>12}{'ratio':>8}")
    for k in sorted(base):
        b, c = base[k], current.get(k)
        if c is None:
            failures.append(f"{k}: metric disappeared from the sweep")
            continue
        ratio = c / b if b else 1.0
        flag = "" if ratio >= 1.0 - TOLERANCE else "  << FAIL"
        print(f"{k:<40}{b:>12.0f}{c:>12.0f}{ratio:>8.2f}{flag}")
        if ratio < 1.0 - TOLERANCE:
            failures.append(f"{k}: {c:.0f} E/s is {1 - ratio:.0%} below "
                            f"baseline {b:.0f} (tolerance {TOLERANCE:.0%})")
    for k in sorted(set(current) - set(base)):
        print(f"{k:<40}{'(new)':>12}{current[k]:>12.0f}")
    if failures:
        print("bench gate: FAIL")
        for msg in failures:
            print(f"  {msg}")
        print("  (intended change? refresh with: "
              "PYTHONPATH=src python tools/bench_gate.py --update)")
        return 1
    print(f"bench gate: OK ({len(base)} metrics within "
          f"{TOLERANCE:.0%} of baseline)")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(ROOT, "src"))
    sys.path.insert(0, ROOT)
    sys.exit(main())
