#!/usr/bin/env python
"""Staging/fusion-regression guard for the serving hot paths (make lint).

Two invariants, both enforced by walking ASTs (a regression here would be
silent — everything still computes the right numbers, just slower):

1. The coalesced round path in ``src/repro/serving/session.py`` must stay
   allocation-free on the host: batches are written in place into the
   pre-allocated ``_HostStager`` ring buffers and shipped with ONE
   ``device_put`` per round. A ``jnp.pad`` / ``jnp.stack`` /
   ``jnp.asarray`` / ``jnp.concatenate`` creeping back into that path
   reintroduces exactly the per-tenant-per-round device dispatches the
   coalesced design removed.

   The per-cohort baseline (``_percohort_round`` / ``_cohort_round`` /
   ``_as_device_tuple`` / ``_pad_dev`` / ``_idle_dev``) is exempt BY
   DESIGN: it is kept as the measured comparison point for
   ``benchmarks/multitenant.py`` and intentionally stages through device
   ops.

2. The fused single-pass step path must never re-materialize what the one
   launch exists to avoid: in ``stages.make_fused_step``'s ``datapath``
   and in ``kernels/ops.fused_step`` no ``jnp.concatenate``/``jnp.stack``
   (the kv concat) and no subscript gather of ``.memory`` / ``.mail`` /
   ``edge_feats`` (the ``(B, k, Dkv)`` neighbor tensor — winner rows are
   DMA'd inside the kernel, everything XLA-side is ids/timestamps
   metadata); and ``kernels/fused_step.py`` itself must stay concat-free
   (the kernel computes split matmuls).

3. The round hot path must stay ASYNC: no unconditional
   ``block_until_ready`` (a device fence serializes the pipelined
   launches) and no stray ``time.perf_counter`` timing (each one is a
   host sync point temptation) outside the SAMPLED-trace gate. The
   observability layer (src/repro/obs) fences only on rounds the
   ``RoundTracer`` samples, inside an ``if trace ...:`` / ``if ...
   sampled ...:`` conditional — this rule pins that shape, so span
   accuracy can never quietly become an every-round drain.
   (``SessionManager.step`` keeps its by-design round-wall
   ``perf_counter`` pair — only its fences are guarded.)

4. Fault-injection hooks must stay NO-OP gated: every call to a
   ``FaultInjector`` hook (``on_round`` / ``before_launch`` /
   ``on_ingest`` / ``on_snapshot_write``) in the serving hot paths must
   sit inside an ``if`` whose test references the injector (``if faults
   is not None:``, ...). An ungated hook call puts a Python attribute
   lookup + dispatch on every production round/event even when no fault
   plan is armed — the injection layer's contract is strictly zero cost
   when disarmed (see docs/ROBUSTNESS.md).

5. Journal hooks on the ingest hot path must stay armed-gated the same
   way: every ``EventJournal`` call (append / flush-marker / dedup
   query) in ``ServingFrontend.submit``/``pump`` must sit inside an
   ``if`` whose test references the journal (``if self.journal is not
   None:``, ...). A fleet that never arms a journal pays one attribute
   test per event and NO disk IO (docs/ROBUSTNESS.md, "Recovery
   semantics").

Exits non-zero listing every violation; also fails if a guarded function
disappears (a rename must update this guard, not silently skip it).
"""
from __future__ import annotations

import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: jnp attributes that mean per-batch device staging is back (rule 1).
STAGING = {"pad", "stack", "asarray", "concatenate"}
#: jnp attributes that mean the fused datapath re-materializes (rule 2).
FUSING = {"concatenate", "stack"}
#: subscripted names/attributes that mean a neighbor-row gather is back.
GATHERS = {"memory", "mail", "edge_feats"}

#: file -> ((scope, function, banned jnp attrs, ban gathers?), ...)
#: ``scope`` is a class name, "*" for any nesting (module / closure), or
#: None for module level.
GUARDED = {
    os.path.join("src", "repro", "serving", "session.py"): (
        (None, "_as_host_tuple", STAGING, False),
        ("_HostStager", "stage", STAGING, False),
        ("SessionManager", "step", STAGING, False),
        ("SessionManager", "_coalesced_round", STAGING, False),
        ("SessionManager", "_ensure_layout", STAGING, False),
    ),
    os.path.join("src", "repro", "core", "stages.py"): (
        ("*", "datapath", FUSING, True),
    ),
    os.path.join("src", "repro", "kernels", "ops.py"): (
        (None, "fused_step", FUSING, True),
    ),
    os.path.join("src", "repro", "kernels", "fused_step.py"): (
        ("*", "_fused_kernel", FUSING, False),
        ("*", "fused_step_pallas", FUSING, False),
    ),
}

#: names whose call is a host sync point / timing probe (rule 3).
FENCES = {"block_until_ready", "perf_counter"}

#: file -> ((scope, function, banned fence names), ...). Same scope
#: conventions as GUARDED. ``_HostStager.stage``'s transfer wait and
#: ``SessionManager.sync()`` are exempt by design (staging IS the
#: transfer; sync is the explicit drain the callers opt into).
FENCE_GUARDED = {
    os.path.join("src", "repro", "serving", "session.py"): (
        # step()'s round-wall perf_counter pair is the metrics contract;
        # only fences are banned there
        ("SessionManager", "step", {"block_until_ready"}),
        ("SessionManager", "_coalesced_round", FENCES),
        ("SessionManager", "_percohort_round", FENCES),
    ),
    os.path.join("src", "repro", "core", "pipeline.py"): (
        ("CoalescedRound", "__call__", FENCES),
        ("*", "round_fn", FENCES),
    ),
}

#: FaultInjector hook methods whose call must be fault-gated (rule 4).
FAULT_HOOKS = {"on_round", "before_launch", "on_ingest",
               "on_snapshot_write", "on_journal_append"}

#: file -> ((scope, function), ...): hot-path functions that are allowed
#: to call FAULT_HOOKS, but only under an ``if ... fault ...:`` gate.
FAULT_GUARDED = {
    os.path.join("src", "repro", "serving", "session.py"): (
        ("SessionManager", "step"),
    ),
    os.path.join("src", "repro", "serving", "frontend.py"): (
        ("ServingFrontend", "submit"),
        ("ServingFrontend", "pump"),
    ),
    os.path.join("src", "repro", "serving", "cluster.py"): (
        ("*", "work"),
    ),
}

#: EventJournal methods whose ingest-hot-path call must be journal-gated
#: (rule 5). ``append_event`` and ``note_flush`` are the disk writes;
#: ``is_duplicate``/``last_seq`` are the per-event dedup queries.
JOURNAL_HOOKS = {"append_event", "note_flush", "is_duplicate",
                 "last_seq"}

#: file -> ((scope, function), ...): hot-path functions allowed to call
#: JOURNAL_HOOKS, but only under an ``if ... journal ...:`` gate.
JOURNAL_GUARDED = {
    os.path.join("src", "repro", "serving", "frontend.py"): (
        ("ServingFrontend", "submit"),
        ("ServingFrontend", "pump"),
    ),
}


def _functions(tree: ast.Module) -> dict:
    """(scope, name) -> FunctionDef; scope is the enclosing class for
    methods, None for module level, and every function is ALSO indexed
    under the wildcard scope "*" (closures inside factories)."""
    found = {}

    def visit(node, cls):
        for sub in ast.iter_child_nodes(node):
            if isinstance(sub, ast.FunctionDef):
                found.setdefault(("*", sub.name), sub)
                found[(cls, sub.name)] = found.get((cls, sub.name), sub)
                visit(sub, cls)
            elif isinstance(sub, ast.ClassDef):
                for fn in sub.body:
                    if isinstance(fn, ast.FunctionDef):
                        found[(sub.name, fn.name)] = fn
                        found.setdefault(("*", fn.name), fn)
                        visit(fn, sub.name)
            else:
                visit(sub, cls)

    visit(tree, None)
    return found


def _violations(fn: ast.FunctionDef, banned: set, gathers: bool) -> list:
    out = []
    for node in ast.walk(fn):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "jnp" and node.attr in banned):
            out.append((node.lineno, f"jnp.{node.attr}"))
        if gathers and isinstance(node, ast.Subscript):
            v = node.value
            name = (v.attr if isinstance(v, ast.Attribute)
                    else v.id if isinstance(v, ast.Name) else None)
            if name in GATHERS:
                out.append((node.lineno, f"subscript gather of {name!r}"))
    return out


def _is_trace_gate(test: ast.expr) -> bool:
    """True when an ``if`` test references the sampled-trace gate — any
    name/attribute containing "trace" or "sampled" (``if trace is not
    None:``, ``if self.tracer.would_sample():``, ...)."""
    for n in ast.walk(test):
        ident = (n.id if isinstance(n, ast.Name)
                 else n.attr if isinstance(n, ast.Attribute) else "")
        if "trace" in ident or "sampled" in ident:
            return True
    return False


def _fence_violations(fn: ast.FunctionDef, banned: set) -> list:
    """Fence/timing calls reachable UNCONDITIONALLY (i.e. outside every
    sampled-trace-gated ``if`` body) inside ``fn``."""
    out = []

    def visit(node, gated):
        for sub in ast.iter_child_nodes(node):
            if isinstance(sub, ast.If) and _is_trace_gate(sub.test):
                for b in sub.body:
                    visit(b, True)
                for b in sub.orelse:
                    visit(b, gated)
                continue
            ident = (sub.attr if isinstance(sub, ast.Attribute)
                     else sub.id if isinstance(sub, ast.Name) else None)
            if not gated and ident in banned:
                out.append((sub.lineno, ident))
            visit(sub, gated)

    visit(fn, False)
    return out


def _is_fault_gate(test: ast.expr) -> bool:
    """True when an ``if`` test references the fault injector — any
    name/attribute containing "fault" (``if faults is not None:``,
    ``if self._faults:``, ...)."""
    for n in ast.walk(test):
        ident = (n.id if isinstance(n, ast.Name)
                 else n.attr if isinstance(n, ast.Attribute) else "")
        if "fault" in ident.lower():
            return True
    return False


def _fault_violations(fn: ast.FunctionDef) -> list:
    """FAULT_HOOKS calls reachable outside every fault-gated ``if``
    body inside ``fn``."""
    out = []

    def visit(node, gated):
        for sub in ast.iter_child_nodes(node):
            if isinstance(sub, ast.If) and _is_fault_gate(sub.test):
                for b in sub.body:
                    visit(b, True)
                for b in sub.orelse:
                    visit(b, gated)
                continue
            if (not gated and isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in FAULT_HOOKS):
                out.append((sub.lineno, sub.func.attr))
            visit(sub, gated)

    visit(fn, False)
    return out


def _is_journal_gate(test: ast.expr) -> bool:
    """True when an ``if`` test references the journal — any name/
    attribute containing "journal" (``if self.journal is not None:``,
    ``if journal:``, ...)."""
    for n in ast.walk(test):
        ident = (n.id if isinstance(n, ast.Name)
                 else n.attr if isinstance(n, ast.Attribute) else "")
        if "journal" in ident.lower():
            return True
    return False


def _journal_violations(fn: ast.FunctionDef) -> list:
    """JOURNAL_HOOKS calls reachable outside every journal-gated ``if``
    body (and outside ``except`` handlers that re-gate on the journal)
    inside ``fn``."""
    out = []

    def visit(node, gated):
        for sub in ast.iter_child_nodes(node):
            if isinstance(sub, ast.If) and _is_journal_gate(sub.test):
                for b in sub.body:
                    visit(b, True)
                for b in sub.orelse:
                    visit(b, gated)
                continue
            if (not gated and isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in JOURNAL_HOOKS):
                out.append((sub.lineno, sub.func.attr))
            visit(sub, gated)

    visit(fn, False)
    return out


def check_file(relpath: str, guards) -> tuple[int, list]:
    with open(os.path.join(REPO, relpath)) as f:
        tree = ast.parse(f.read(), relpath)
    functions = _functions(tree)
    errors, checked = [], 0
    base = os.path.basename(relpath)
    for scope, name, banned, gathers in guards:
        fn = functions.get((scope, name))
        qual = ".".join(p for p in (None if scope == "*" else scope, name)
                        if p)
        if fn is None:
            errors.append(f"guarded function {qual} not found in {base} — "
                          "update tools/session_lint.py alongside the "
                          "rename")
            continue
        checked += 1
        for lineno, what in _violations(fn, banned, gathers):
            errors.append(
                f"{base}:{lineno}: {what} in {qual} — "
                + ("the coalesced round path must stage through the "
                   "in-place _HostStager ring buffers, not per-batch "
                   "device ops" if banned is STAGING else
                   "the fused step path must leave row fetches to the "
                   "kernel's scalar-prefetch DMA (ids/timestamps metadata "
                   "only outside the launch)"))
    return checked, errors


def check_fences(relpath: str, guards) -> tuple[int, list]:
    with open(os.path.join(REPO, relpath)) as f:
        tree = ast.parse(f.read(), relpath)
    functions = _functions(tree)
    errors, checked = [], 0
    base = os.path.basename(relpath)
    for scope, name, banned in guards:
        fn = functions.get((scope, name))
        qual = ".".join(p for p in (None if scope == "*" else scope, name)
                        if p)
        if fn is None:
            errors.append(f"guarded function {qual} not found in {base} — "
                          "update tools/session_lint.py alongside the "
                          "rename")
            continue
        checked += 1
        for lineno, what in _fence_violations(fn, banned):
            errors.append(
                f"{base}:{lineno}: unconditional {what} in {qual} — the "
                "round hot path only fences/times inside the sampled-"
                "trace gate (if trace ...:); an every-round sync "
                "serializes the async pipeline")
    return checked, errors


def check_faults(relpath: str, guards) -> tuple[int, list]:
    with open(os.path.join(REPO, relpath)) as f:
        tree = ast.parse(f.read(), relpath)
    functions = _functions(tree)
    errors, checked = [], 0
    base = os.path.basename(relpath)
    for scope, name in guards:
        fn = functions.get((scope, name))
        qual = ".".join(p for p in (None if scope == "*" else scope, name)
                        if p)
        if fn is None:
            errors.append(f"guarded function {qual} not found in {base} — "
                          "update tools/session_lint.py alongside the "
                          "rename")
            continue
        checked += 1
        for lineno, what in _fault_violations(fn):
            errors.append(
                f"{base}:{lineno}: ungated fault hook {what}() in {qual} "
                "— injection hooks must sit inside an `if faults ...:` "
                "gate so a disarmed injector costs the hot path nothing")
    return checked, errors


def check_journal(relpath: str, guards) -> tuple[int, list]:
    with open(os.path.join(REPO, relpath)) as f:
        tree = ast.parse(f.read(), relpath)
    functions = _functions(tree)
    errors, checked = [], 0
    base = os.path.basename(relpath)
    for scope, name in guards:
        fn = functions.get((scope, name))
        qual = ".".join(p for p in (None if scope == "*" else scope, name)
                        if p)
        if fn is None:
            errors.append(f"guarded function {qual} not found in {base} — "
                          "update tools/session_lint.py alongside the "
                          "rename")
            continue
        checked += 1
        for lineno, what in _journal_violations(fn):
            errors.append(
                f"{base}:{lineno}: ungated journal hook {what}() in "
                f"{qual} — WAL appends/dedup queries must sit inside an "
                "`if ... journal ...:` gate so a disarmed fleet pays no "
                "disk IO on the ingest hot path")
    return checked, errors


def main() -> int:
    errors, checked = [], 0
    for relpath, guards in GUARDED.items():
        c, errs = check_file(relpath, guards)
        checked += c
        errors.extend(errs)
    for relpath, guards in FENCE_GUARDED.items():
        c, errs = check_fences(relpath, guards)
        checked += c
        errors.extend(errs)
    for relpath, guards in FAULT_GUARDED.items():
        c, errs = check_faults(relpath, guards)
        checked += c
        errors.extend(errs)
    for relpath, guards in JOURNAL_GUARDED.items():
        c, errs = check_journal(relpath, guards)
        checked += c
        errors.extend(errs)
    for e in errors:
        print(f"session-lint: {e}", file=sys.stderr)
    print(f"session-lint: {checked} hot-path functions checked, "
          f"{len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
