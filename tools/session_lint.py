#!/usr/bin/env python
"""Staging-regression guard for the serving hot path (part of make lint).

The coalesced round path in ``src/repro/serving/session.py`` must stay
allocation-free on the host: batches are written in place into the
pre-allocated ``_HostStager`` ring buffers and shipped with ONE
``device_put`` per round. A ``jnp.pad`` / ``jnp.stack`` / ``jnp.asarray``
/ ``jnp.concatenate`` creeping back into that path reintroduces exactly
the per-tenant-per-round device dispatches the coalesced design removed —
so this check walks the AST of the round-path functions and fails on any
such call.

The per-cohort baseline (``_percohort_round`` / ``_cohort_round`` /
``_as_device_tuple`` / ``_pad_dev`` / ``_idle_dev``) is exempt BY DESIGN:
it is kept as the measured comparison point for
``benchmarks/multitenant.py`` and intentionally stages through device ops.

Exits non-zero listing every violation; also fails if a guarded function
disappears (a rename must update this guard, not silently skip it).
"""
from __future__ import annotations

import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SESSION = os.path.join(REPO, "src", "repro", "serving", "session.py")

#: (class name or None, function name) -> the round-path functions that
#: must stay free of host-side jnp staging.
GUARDED = (
    (None, "_as_host_tuple"),
    ("_HostStager", "stage"),
    ("SessionManager", "step"),
    ("SessionManager", "_coalesced_round"),
    ("SessionManager", "_ensure_layout"),
)

#: jnp attributes that mean per-batch device staging is back.
BANNED = {"pad", "stack", "asarray", "concatenate"}


def _functions(tree: ast.Module) -> dict:
    found = {}
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            found[(None, node.name)] = node
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, ast.FunctionDef):
                    found[(node.name, sub.name)] = sub
    return found


def _violations(fn: ast.FunctionDef) -> list:
    out = []
    for node in ast.walk(fn):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "jnp" and node.attr in BANNED):
            out.append((node.lineno, f"jnp.{node.attr}"))
    return out


def main() -> int:
    with open(SESSION) as f:
        tree = ast.parse(f.read(), SESSION)
    functions = _functions(tree)
    errors = []
    checked = 0
    for key in GUARDED:
        fn = functions.get(key)
        qual = ".".join(p for p in key if p)
        if fn is None:
            errors.append(f"guarded function {qual} not found in "
                          "session.py — update tools/session_lint.py "
                          "alongside the rename")
            continue
        checked += 1
        for lineno, what in _violations(fn):
            errors.append(f"session.py:{lineno}: {what} in {qual} — the "
                          "coalesced round path must stage through the "
                          "in-place _HostStager ring buffers, not "
                          "per-batch device ops")
    for e in errors:
        print(f"session-lint: {e}", file=sys.stderr)
    print(f"session-lint: {checked} round-path functions checked, "
          f"{len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
