#!/usr/bin/env python
"""Doc hygiene checks (make docs-check; part of make lint).

Over every tracked markdown file (repo root + docs/):

  1. intra-repo links resolve: each ``[text](target)`` whose target is not
     external (http/https/mailto/#anchor) must point at an existing file,
     relative to the doc that contains it;
  2. variant strings exist: every backtick code span that *looks like* a
     pipeline variant spec (the ``attn+enc[+np<k>][+sampler]`` grammar or a
     ``+ROW``-style Table-II alias) must resolve in the live registry —
     docs cannot advertise specs ``build_pipeline`` would reject.

Exits non-zero listing every violation.
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core import pipeline as pl  # noqa: E402

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_RE = re.compile(r"`([^`\n]+)`")
# a span is a variant-spec candidate if it is pure grammar tokens with at
# least one '+', or a Table-II-style "+ROW" alias
GRAMMAR_RE = re.compile(r"^(vanilla|sat)\+[a-z0-9+]+$")
ALIAS_RE = re.compile(r"^\+[A-Za-z]{2,}(\([A-Za-z]\))?$")
EXTERNAL = ("http://", "https://", "mailto:", "#")


def doc_files() -> list:
    return sorted(glob.glob(os.path.join(REPO, "*.md"))
                  + glob.glob(os.path.join(REPO, "docs", "*.md")))


def check_links(path: str, text: str) -> list:
    errors = []
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link "
                          f"-> {m.group(1)}")
    return errors


def check_variants(path: str, text: str) -> list:
    errors = []
    for m in CODE_RE.finditer(text):
        span = m.group(1).strip()
        if not (GRAMMAR_RE.match(span) or ALIAS_RE.match(span)):
            continue
        try:
            pl.resolve_variant(span)
        except ValueError:
            errors.append(f"{os.path.relpath(path, REPO)}: variant spec "
                          f"`{span}` does not resolve in the pipeline "
                          "registry")
    return errors


def main() -> int:
    errors = []
    files = doc_files()
    n_links = n_specs = 0
    for path in files:
        with open(path) as f:
            text = f.read()
        n_links += len(LINK_RE.findall(text))
        n_specs += sum(1 for m in CODE_RE.finditer(text)
                       if GRAMMAR_RE.match(m.group(1).strip())
                       or ALIAS_RE.match(m.group(1).strip()))
        errors += check_links(path, text)
        errors += check_variants(path, text)
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    print(f"docs-check: {len(files)} files, {n_links} links, "
          f"{n_specs} variant specs, {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
