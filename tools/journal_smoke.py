#!/usr/bin/env python
"""Durable-journal smoke (make journal-smoke, wired into make lint).

Boots a journaled frontend, streams client-stamped events through it,
KILLS the process mid-stream (the journal fd is simply abandoned, the
last appends unfsynced), then recovers into a fresh fleet and asserts
the lossless-recovery contract end to end:

- recovery = snapshot + replay: ``cluster.restore_tenant(journal=...)``
  reloads the newest snapshot and re-applies the journal suffix through
  the normal batcher -> step pipeline, so the recovered tenant is
  BITWISE identical to the state at the kill point;
- the recovered run, continued to completion, is BITWISE identical to
  an uninterrupted twin that never crashed;
- retried ingests are idempotent: a duplicate-fuzz leg submits EVERY
  event twice (same ``client_id``/``seq``) and lands on the same
  trajectory as a send-once run, with every duplicate acked
  ``dedup: true`` and never re-enqueued;
- recovery is quiet: after the restore round the recovered fleet's
  ``relayouts`` counter is FROZEN, and every completed round is still
  ONE compiled launch (``launches_per_round == {1}``).

Everything runs on one shared fake clock, which is what makes the kill
point and the replay deterministic.
"""
from __future__ import annotations

import sys
import tempfile
import os

import jax
import jax.numpy as jnp
import numpy as np


def _bitwise(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def main() -> int:
    from repro.core import pipeline as pl, tgn
    from repro.data import temporal_graph as tgd
    from repro.serving import cluster
    from repro.serving.faults import FakeClock
    from repro.serving.frontend import (DuplicateEvent, FrontendConfig,
                                        ServingFrontend)
    from repro.serving.journal import EventJournal
    from repro.serving.session import SessionManager

    g = tgd.wikipedia_like(n_edges=500)
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=16,
                            f_time=16, f_emb=16, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)

    def make_fleet():
        return SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)

    def make_frontend(mgr, journal, clock):
        return ServingFrontend(
            mgr, FrontendConfig(max_wait_s=0.005, max_rows=8,
                                queue_rows=256, pad_quantum=8),
            clock=clock, journal=journal)

    ROWS, ROUNDS, KILL_AT, SNAP_AT = 8, 10, 6, 4
    EV = [(int(g.src[i]), int(g.dst[i]), i, float(g.ts[i]),
           int(g.dst[(i + 3) % 500])) for i in range(ROWS * ROUNDS)]
    root = tempfile.mkdtemp(prefix="journal-smoke-")
    jroot, sroot = os.path.join(root, "wal"), os.path.join(root, "snaps")

    # ---- leg 1: ingest, snapshot, KILL mid-stream ----------------------
    clock = FakeClock()
    journal = EventJournal(jroot, fsync_s=0.05, clock=clock)
    mgr = make_fleet()
    t0 = mgr.add_tenant(name="t0")
    fe = make_frontend(mgr, journal, clock)
    for r in range(KILL_AT):
        for i in range(r * ROWS, (r + 1) * ROWS):
            fe.submit(t0, *EV[i], client_id="c0", seq=i)
        clock.advance(0.006)
        assert fe.pump(), "deadline flush did not fire"
        if r + 1 == SNAP_AT:
            mgr.sync()
            cluster.snapshot_tenant(mgr, t0, sroot, step=SNAP_AT,
                                    extra_meta={"journal":
                                                journal.cursor(t0)})
    mgr.sync()
    at_kill = mgr.state_of(t0)
    del fe, mgr  # the process dies here: no close(), no final fsync

    # ---- leg 2: recover = snapshot + replay, then run to completion ----
    j2 = EventJournal(jroot, fsync_s=0.05, clock=clock)
    mgr2 = make_fleet()
    new = cluster.restore_tenant(mgr2, sroot, "t0", journal=j2)
    res = j2.last_replay
    mgr2.sync()
    recover_ok = (res is not None and not res.corrupt
                  and res.rounds == KILL_AT - SNAP_AT
                  and _bitwise(mgr2.state_of(new), at_kill))

    fe2 = make_frontend(mgr2, j2, clock)
    c0 = mgr2.compile_counters()           # post-replay layout baseline
    for r in range(KILL_AT, ROUNDS):
        for i in range(r * ROWS, (r + 1) * ROWS):
            fe2.submit(new, *EV[i], client_id="c0", seq=i)
        clock.advance(0.006)
        assert fe2.pump(), "deadline flush did not fire"
    mgr2.sync()
    c = mgr2.compile_counters()
    launches = {m["launches"] for m in mgr2.metrics}
    quiet_ok = (c["relayouts"] == c0["relayouts"] and launches == {1})

    # ---- leg 3: uninterrupted twin -------------------------------------
    twin_clock = FakeClock()
    twin = make_fleet()
    tw = twin.add_tenant(name="tw")
    few = make_frontend(twin, None, twin_clock)
    for r in range(ROUNDS):
        for i in range(r * ROWS, (r + 1) * ROWS):
            few.submit(tw, *EV[i])
        twin_clock.advance(0.006)
        few.pump()
    twin.sync()
    bitwise_ok = _bitwise(mgr2.state_of(new), twin.state_of(tw))

    # ---- leg 4: duplicate-ingest fuzz (every event sent twice) ---------
    fuzz_clock = FakeClock()
    jf = EventJournal(os.path.join(root, "wal-fuzz"), clock=fuzz_clock)
    fz = make_fleet()
    tf = fz.add_tenant(name="t0")
    fef = make_frontend(fz, jf, fuzz_clock)
    dedups = 0
    for r in range(ROUNDS):
        for i in range(r * ROWS, (r + 1) * ROWS):
            fef.submit(tf, *EV[i], client_id="c0", seq=i)
            try:
                fef.submit(tf, *EV[i], client_id="c0", seq=i)
            except DuplicateEvent:
                dedups += 1
        fuzz_clock.advance(0.006)
        fef.pump()
    fz.sync()
    fuzz_ok = (dedups == ROWS * ROUNDS and fef.dedups == dedups
               and _bitwise(fz.state_of(tf), twin.state_of(tw)))

    ok = recover_ok and quiet_ok and bitwise_ok and fuzz_ok
    print(f"journal-smoke: killed after round {KILL_AT}/{ROUNDS}, "
          f"snapshot at {SNAP_AT}, replayed {res.rounds} round(s) "
          f"({res.events} events) -> {'OK' if recover_ok else 'FAIL'}")
    print(f"journal-smoke: recovered run vs uninterrupted twin bitwise "
          f"-> {'OK' if bitwise_ok else 'FAIL'}; relayouts frozen, "
          f"launches {sorted(launches)} -> {'OK' if quiet_ok else 'FAIL'}")
    print(f"journal-smoke: duplicate fuzz ({dedups} dedups, every event "
          f"sent twice) bitwise vs send-once -> "
          f"{'OK' if fuzz_ok else 'FAIL'}")
    if not ok:
        print(f"journal-smoke: replay={res} compile={c} vs {c0} "
              f"stats={fef.stats().get('journal')}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
