#!/usr/bin/env python
"""Fault-injection chaos smoke (make chaos-smoke, wired into make lint).

Boots a 3-cohort fleet behind the online frontend with the FleetGuard
supervisor armed, runs a DETERMINISTIC fault plan against it on a fake
clock — a NaN-poisoned resident state, a failed snapshot write, a
classified kernel-launch failure, and a round stall — and asserts the
recovery contract end to end:

- every planned fault fires and is DETECTED (``injector.pending() ==
  []``; quarantine / snapshot retry / tier degradation / watchdog trip
  each observed exactly once in the guard counters and the fleet
  metrics registry);
- the poisoned tenant is quarantined (ingest rejected with a
  ``quarantined`` RetryAfter), auto-restored from its newest valid
  snapshot after the backoff, and finishes the run healthy;
- the kernel-failing cohort degrades fused -> staged as a lane MOVE:
  exactly ONE extra relayout across the whole run, and the retried
  round still completes;
- SURVIVORS ARE BITWISE: the healthy tenant's final state equals a
  replay of its recorded batches through a fresh solo fleet that never
  had the sick tenants attached;
- every completed round is still ONE compiled launch
  (``launches_per_round == {1}``), and the recovery story is visible in
  ``metrics_snapshot()["guard"]`` and as ``cat="guard"`` spans in the
  round tracer.

A final kill-and-recover leg runs a JOURNALED tenant on the same fake
clock, abandons the process mid-stream (no close, no final fsync), and
recovers snapshot + journal-replay into a fresh fleet: the continued
run must be bitwise identical to an uninterrupted twin (the fault-plan
leg stays journal-free — replay advances the injector's round cursor,
so round-indexed fault plans and journal replay don't mix).

Everything — deadline batcher, guard backoff, fault plan, tracer — runs
on ONE shared fake clock, which is what makes the chaos run replayable.
"""
from __future__ import annotations

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    from repro.core import pipeline as pl, tgn
    from repro.data import temporal_graph as tgd
    from repro.obs import RoundTracer
    from repro.serving.cluster import TenantSnapshotWriter
    from repro.serving.faults import FakeClock, Fault, FaultInjector
    from repro.serving.frontend import (FrontendConfig, RetryAfter,
                                        ServingFrontend)
    from repro.serving.guard import FleetGuard
    from repro.serving.session import SessionManager

    g = tgd.wikipedia_like(n_edges=500)
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=16,
                            f_time=16, f_emb=16, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)

    def make_fleet():
        return SessionManager(params, jnp.asarray(g.edge_feats), model=cfg,
                              reserve=True)

    mgr = make_fleet()
    t0 = mgr.add_tenant()                          # np4 @ staged: survivor
    t1 = mgr.add_tenant("sat+lut+np4+reservoir")   # sick: NaN + snapshot IO
    t2 = mgr.add_tenant(use_kernels="fused")       # np4 @ fused: degrades

    clock = FakeClock()
    tracer = RoundTracer(clock=clock, sample_every=4)
    fe = ServingFrontend(
        mgr, FrontendConfig(max_wait_s=0.005, max_rows=8, queue_rows=256,
                            pad_quantum=8),
        clock=clock, tracer=tracer, slo_ms=25.0, record_rounds=True)

    snap_dir = tempfile.mkdtemp(prefix="chaos-snap-")
    writer = TenantSnapshotWriter(snap_dir, keep=3, retries=2,
                                  obs=mgr.obs, sleep=lambda s: None)
    guard = FleetGuard(mgr, snapshot_root=snap_dir, writer=writer,
                       clock=clock, max_restores=3, backoff_s=0.02,
                       watchdog_s=0.5)

    # the deterministic fault plan: logical positions on the fake clock
    injector = FaultInjector([
        Fault(kind="snapshot_io", tenant=t1, at=0),   # 1st write attempt
        Fault(kind="nan_state", tenant=t1, at=3),     # round 3 poison
        Fault(kind="kernel_fail", tenant=t2, at=5),   # round 5 launch
        Fault(kind="stall", at=7, delay_s=1.0),       # round 7 wall
    ], clock=clock)
    mgr.set_faults(injector)

    ROUNDS, ROWS = 12, 8
    accepted, quarantine_rejects = 0, []
    c0 = None
    for r in range(ROUNDS):
        for i in range(r * ROWS, (r + 1) * ROWS):
            for tid in (t0, t1, t2):
                try:
                    fe.submit(tid, int(g.src[i]), int(g.dst[i]), i,
                              float(g.ts[i]), int(g.dst[(i + 3) % 500]))
                    accepted += 1
                except RetryAfter as e:       # quarantined-tenant ingest
                    quarantine_rejects.append((r, e.tid, e.reason))
        clock.advance(0.006)                  # past the 5ms deadline
        assert fe.pump(), "deadline flush did not fire"
        if c0 is None:                        # post-warmup baseline: the
            c0 = mgr.compile_counters()       # fleet layout is now built
        if r % 2 == 0:                        # snapshot cadence; never
            for tid in mgr.tenants:           # persist a quarantined
                if not mgr.is_quarantined(tid):   # (possibly sick) lane
                    writer.submit(mgr, tid, step=r)
    mgr.sync()
    writer.close()

    gs = guard.snapshot()
    fired = sorted(f["kind"] for f in injector.fired)
    counters = mgr.obs.snapshot(prefix="guard.")
    detect_ok = (injector.pending() == []
                 and fired == ["kernel_fail", "nan_state", "snapshot_io",
                               "stall"]
                 and gs["quarantines"] == 1 and gs["restores"] == 1
                 and gs["degradations"] == 1 and gs["evictions"] == 0
                 and gs["watchdog_trips"] == 1
                 and gs["quarantined_now"] == [] and gs["evicted"] == []
                 and counters["guard.quarantines"] == 1
                 and counters["guard.restores"] == 1
                 and mgr.obs.counter("snapshot.retries").value >= 1
                 and mgr.obs.counter("snapshot.failures").value == 0)

    # the sick tenant came back healthy; its ingest was refused (with a
    # quarantined RetryAfter) only while it sat in quarantine
    view = guard.tenant_view(t1)
    sick_ok = (not view["quarantined"] and view["restores"] == 1
               and not view["evicted"]
               and view["last_reason"] == "nonfinite_state"
               and quarantine_rejects != []
               and {x[1:] for x in quarantine_rejects}
               == {(t1, "quarantined")}
               and bool(np.all(np.isfinite(
                   np.asarray(mgr.state_of(t1).memory)))))

    # fused -> staged was a lane move: tier changed, ONE extra relayout,
    # every completed round one launch
    c = mgr.compile_counters()
    launches = {m["launches"] for m in mgr.metrics}
    degrade_ok = (mgr.cohort_of(t2).tier == "staged"
                  and c["relayouts"] == c0["relayouts"] + 1
                  and launches == {1}
                  and fe.stats()["rounds"] == ROUNDS)

    # survivors are bitwise: replay t0's recorded rounds through a solo
    # fleet that never had t1/t2 attached
    solo = make_fleet()
    t0_ref = solo.add_tenant()
    for batches in fe.round_log:
        if t0 in batches:
            solo.step({t0_ref: batches[t0]})
    solo.sync()
    a, b = mgr.state_of(t0), solo.state_of(t0_ref)
    bitwise_ok = all(np.array_equal(np.asarray(x), np.asarray(y))
                     for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    # the recovery story is visible: metrics snapshot + guard spans
    ms = fe.metrics_snapshot()
    guard_spans = {s.name for s in tracer.spans if s.cat == "guard"}
    obs_ok = (ms.get("guard") == gs
              and {"quarantine", "restore", "degrade",
                   "watchdog"} <= guard_spans
              and fe.stats()["guard"] == gs)

    # kill-and-recover: a journaled tenant on the SAME fake clock dies
    # mid-stream and comes back bitwise through snapshot + replay
    from repro.serving import cluster
    from repro.serving.journal import EventJournal

    kroot = tempfile.mkdtemp(prefix="chaos-wal-")
    jdir, sdir = f"{kroot}/wal", f"{kroot}/snaps"
    KR, KILL, SNAP = 8, 5, 3
    journal = EventJournal(jdir, fsync_s=0.05, clock=clock)
    km = make_fleet()
    kt = km.add_tenant(name="kt")
    kfe = ServingFrontend(
        mgr=km, cfg=FrontendConfig(max_wait_s=0.005, max_rows=8,
                                   queue_rows=256, pad_quantum=8),
        clock=clock, journal=journal)
    ev = [(int(g.src[i]), int(g.dst[i]), i, float(g.ts[i]),
           int(g.dst[(i + 3) % 500])) for i in range(KR * ROWS)]
    for r in range(KILL):
        for i in range(r * ROWS, (r + 1) * ROWS):
            kfe.submit(kt, *ev[i], client_id="c0", seq=i)
        clock.advance(0.006)
        kfe.pump()
        if r + 1 == SNAP:
            km.sync()
            cluster.snapshot_tenant(km, kt, sdir, step=SNAP,
                                    extra_meta={"journal":
                                                journal.cursor(kt)})
    km.sync()
    del kfe, km                                 # killed: fd abandoned

    j2 = EventJournal(jdir, fsync_s=0.05, clock=clock)
    km2 = make_fleet()
    knew = cluster.restore_tenant(km2, sdir, "kt", journal=j2)
    kfe2 = ServingFrontend(
        mgr=km2, cfg=FrontendConfig(max_wait_s=0.005, max_rows=8,
                                    queue_rows=256, pad_quantum=8),
        clock=clock, journal=j2)
    for r in range(KILL, KR):
        for i in range(r * ROWS, (r + 1) * ROWS):
            kfe2.submit(knew, *ev[i], client_id="c0", seq=i)
        clock.advance(0.006)
        kfe2.pump()
    km2.sync()

    twin = make_fleet()
    tw = twin.add_tenant()
    tfe = ServingFrontend(
        mgr=twin, cfg=FrontendConfig(max_wait_s=0.005, max_rows=8,
                                     queue_rows=256, pad_quantum=8),
        clock=clock, journal=None)
    for r in range(KR):
        for i in range(r * ROWS, (r + 1) * ROWS):
            tfe.submit(tw, *ev[i])
        clock.advance(0.006)
        tfe.pump()
    twin.sync()
    ka, kb = km2.state_of(knew), twin.state_of(tw)
    recover_ok = (j2.last_replay.rounds == KILL - SNAP
                  and not j2.last_replay.corrupt
                  and all(np.array_equal(np.asarray(x), np.asarray(y))
                          for x, y in zip(jax.tree.leaves(ka),
                                          jax.tree.leaves(kb))))

    ok = (detect_ok and sick_ok and degrade_ok and bitwise_ok and obs_ok
          and recover_ok)
    print(f"chaos-smoke: {ROUNDS} rounds, faults fired {fired}, "
          f"guard {gs} -> {'OK' if detect_ok else 'FAIL'}")
    print(f"chaos-smoke: sick tenant restored "
          f"({len(quarantine_rejects)} quarantined-ingest rejects) -> "
          f"{'OK' if sick_ok else 'FAIL'}; degrade fused->staged, "
          f"relayouts +{c['relayouts'] - c0['relayouts']}, "
          f"launches {sorted(launches)} -> "
          f"{'OK' if degrade_ok else 'FAIL'}")
    print(f"chaos-smoke: survivor bitwise vs solo replay -> "
          f"{'OK' if bitwise_ok else 'FAIL'}; guard spans "
          f"{sorted(guard_spans)} -> {'OK' if obs_ok else 'FAIL'}")
    print(f"chaos-smoke: kill@{KILL}/{KR} + journal recover "
          f"(replayed {j2.last_replay.rounds}) bitwise vs twin -> "
          f"{'OK' if recover_ok else 'FAIL'}")
    if not ok:
        print(f"chaos-smoke: view={view} counters={counters} "
              f"compile={c} fired={injector.fired}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
