"""SSD (mamba2) and RG-LRU recurrence equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import mamba2 as MM, rglru as G


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_equals_recurrence(chunk):
    rng = np.random.RandomState(chunk)
    B, Lx, H, P, Gn, N = 2, 64, 4, 8, 2, 16
    x = jnp.asarray(rng.randn(B, Lx, H, P), jnp.float32)
    dt = jnp.asarray(rng.rand(B, Lx, H) * 0.5 + 0.01, jnp.float32)
    a = -jnp.asarray(rng.rand(H) * 2 + 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(B, Lx, Gn, N), jnp.float32)
    c = jnp.asarray(rng.randn(B, Lx, Gn, N), jnp.float32)
    want = MM.ssd_ref(x, dt, a, b, c)
    got, _ = MM.ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssd_carry_state_across_calls():
    """Running two halves with carried state == one full run."""
    rng = np.random.RandomState(9)
    B, Lx, H, P, Gn, N = 1, 32, 2, 4, 1, 8
    x = jnp.asarray(rng.randn(B, Lx, H, P), jnp.float32)
    dt = jnp.asarray(rng.rand(B, Lx, H) * 0.3 + 0.01, jnp.float32)
    a = -jnp.asarray(rng.rand(H) + 0.1, jnp.float32)
    b = jnp.asarray(rng.randn(B, Lx, Gn, N), jnp.float32)
    c = jnp.asarray(rng.randn(B, Lx, Gn, N), jnp.float32)
    full, hf = MM.ssd_chunked(x, dt, a, b, c, chunk=8)
    y1, h1 = MM.ssd_chunked(x[:, :16], dt[:, :16], a, b[:, :16], c[:, :16],
                            chunk=8)
    y2, h2 = MM.ssd_chunked(x[:, 16:], dt[:, 16:], a, b[:, 16:], c[:, 16:],
                            chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hf), rtol=1e-4,
                               atol=1e-4)


def test_mamba_decode_equals_prefill():
    cfg = MM.MambaConfig(n_layers=2, d_model=32, d_head=8, d_state=16,
                         vocab=64, chunk=8, dtype="float32", loss_chunk=16)
    params = MM.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, 64)
    caches = MM.init_caches(cfg, 2, 32, dtype=jnp.float32)
    lg = None
    for t in range(16):
        lg, caches = MM.decode_step(params, cfg, toks[:, t:t + 1], caches)
    lp, _ = MM.prefill(params, cfg, toks[:, :16])
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lp), rtol=1e-3,
                               atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 100))
def test_rglru_scan_equals_step(seed):
    p = G.init_rglru(jax.random.key(seed), 16, 2)
    x = jax.random.normal(jax.random.key(seed + 1), (1, 12, 16), jnp.float32)
    y_scan, h_last = G.rglru_scan(p, x)
    h = jnp.zeros((1, 16), jnp.float32)
    ys = []
    for t in range(12):
        y, h = G.rglru_step(p, x[:, t:t + 1], h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_scan), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_last), rtol=1e-4,
                               atol=1e-5)


def test_rglru_carry_h0():
    p = G.init_rglru(jax.random.key(3), 8, 2)
    x = jax.random.normal(jax.random.key(4), (1, 16, 8), jnp.float32)
    full, hf = G.rglru_scan(p, x)
    y1, h1 = G.rglru_scan(p, x[:, :8])
    y2, h2 = G.rglru_scan(p, x[:, 8:], h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-5)


def test_rglru_decay_bounded():
    """a_t in (0, 1): the recurrence can never blow up."""
    p = G.init_rglru(jax.random.key(5), 8, 2)
    x = 100.0 * jax.random.normal(jax.random.key(6), (1, 64, 8), jnp.float32)
    y, h = G.rglru_scan(p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # with zero input the state decays monotonically
    y0, h0 = G.rglru_scan(p, jnp.zeros((1, 8, 8), jnp.float32),
                          h0=jnp.ones((1, 8), jnp.float32) * 5)
    mags = np.abs(np.asarray(y0[0, :, 0]))
    assert np.all(np.diff(mags) <= 1e-6)
