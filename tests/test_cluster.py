"""Sharded tenant fabric (serving/cluster.py) on a forced 8-device host
mesh: trajectories through ShardedSessionManager must be BITWISE-identical
to the unsharded SessionManager, snapshots must restore across mesh shapes
and continue identically, and cohort slots must be released eagerly.

Needs >= 8 devices — run via ``make test-sharded`` (which sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); under the plain
tier-1 suite (1 CPU device, no XLA_FLAGS by design — see conftest.py) the
whole module skips.
"""
import os

import jax
import pytest

if jax.device_count() < 8:
    pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "(make test-sharded)", allow_module_level=True)

import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pl, tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.distributed import checkpoint as ckpt
from repro.serving import cluster as cl
from repro.serving.session import SessionManager


@pytest.fixture(scope="module")
def small_graph():
    return tgd.wikipedia_like(n_edges=500)


def _dims(g, f=8):
    return dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f, f_time=f, f_emb=f, m_r=10)


def _setup(g, variant="sat+lut+np4", key=0, f=8):
    cfg = pl.variant_config(variant, **_dims(g, f))
    params = tgn.init_params(jax.random.key(key), cfg)
    return cfg, params, jnp.asarray(g.edge_feats)


def _feeds(g, tids, rounds=3, batch=30):
    return {t: list(stream_mod.fixed_count(
        g, batch, window=slice(50 * i, 50 * i + batch * rounds), seed=i))
        for i, t in enumerate(tids)}


def _assert_state_equal(a, b, msg=""):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}:{f}")


# ---------------------------------------------------------------------------
# acceptance: sharded == unsharded, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mesh", ["tenant=8", "tenant=4,vertex=2"])
def test_sharded_bitwise_matches_unsharded(small_graph, mesh):
    """Five tenants (a non-multiple of the tenant axis: mesh padding slots
    stay idle-masked) on a sharded mesh reproduce the unsharded session's
    per-round embeddings AND final states bitwise."""
    g = small_graph
    cfg, params, ef = _setup(g)
    ref = SessionManager(params, ef, model=cfg)
    sh = cl.ShardedSessionManager(params, ef, model=cfg, mesh=mesh)
    rt = [ref.add_tenant() for _ in range(5)]
    st = [sh.add_tenant() for _ in range(5)]
    assert sh.cohort_of(st[0]).capacity == 8
    spec = sh.cohort_of(st[0]).state.memory.sharding.spec
    assert spec[0] == "tenant"
    fr, fs = _feeds(g, rt), _feeds(g, st)
    for r in range(3):
        o1 = ref.step({t: fr[t][r] for t in rt})
        o2 = sh.step({t: fs[t][r] for t in st})
        for t1, t2 in zip(rt, st):
            np.testing.assert_array_equal(
                np.asarray(o1[t1].emb_src), np.asarray(o2[t2].emb_src),
                err_msg=f"round {r} {t2} src")
            np.testing.assert_array_equal(
                np.asarray(o1[t1].emb_dst), np.asarray(o2[t2].emb_dst),
                err_msg=f"round {r} {t2} dst")
    for t1, t2 in zip(rt, st):
        _assert_state_equal(ref.state_of(t1), sh.state_of(t2), msg=t2)


def test_sharded_idle_and_ragged_rounds(small_graph):
    """Idle tenants and ragged per-tenant batch sizes behave identically
    to the unsharded session on the mesh (masking composes with mesh
    padding)."""
    g = small_graph
    cfg, params, ef = _setup(g, key=1)
    ref = SessionManager(params, ef, model=cfg)
    sh = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=8")
    rt = [ref.add_tenant() for _ in range(3)]
    st = [sh.add_tenant() for _ in range(3)]
    small = next(iter(stream_mod.fixed_count(g, 16, window=slice(0, 16))))
    big = next(iter(stream_mod.fixed_count(g, 40, window=slice(80, 120),
                                           seed=7)))
    o1 = ref.step({rt[0]: small, rt[2]: big})   # rt[1] idles; ragged B
    o2 = sh.step({st[0]: small, st[2]: big})
    assert set(o2) == {st[0], st[2]}
    np.testing.assert_array_equal(np.asarray(o1[rt[0]].emb_src),
                                  np.asarray(o2[st[0]].emb_src))
    np.testing.assert_array_equal(np.asarray(o1[rt[2]].emb_src),
                                  np.asarray(o2[st[2]].emb_src))
    for t1, t2 in zip(rt, st):
        _assert_state_equal(ref.state_of(t1), sh.state_of(t2), msg=t2)


def test_mixed_sampler_cohorts_on_mesh(small_graph):
    """Cohorts of different sampler backends each get their own sharded
    stacked tables; one launch per cohort per round, bitwise equal to the
    unsharded fleet."""
    g = small_graph
    cfg, params, ef = _setup(g, key=2)
    variants = ("sat+lut+np4", "sat+lut+np4+uniform",
                "sat+lut+np4+reservoir")
    ref = SessionManager(params, ef, model=cfg)
    sh = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=2")
    rt = [ref.add_tenant(v) for v in variants]
    st = [sh.add_tenant(v) for v in variants]
    fr, fs = _feeds(g, rt, rounds=2), _feeds(g, st, rounds=2)
    for r in range(2):
        ref.step({t: fr[t][r] for t in rt})
        sh.step({t: fs[t][r] for t in st})
    # coalesced (default): the whole 3-cohort round is ONE compiled launch
    assert sh.metrics[-1]["launches"] == 1
    for t1, t2 in zip(rt, st):
        _assert_state_equal(ref.state_of(t1), sh.state_of(t2), msg=t2)


def test_mixed_kernel_tier_fleet_on_mesh(small_graph):
    """A fleet mixing the FUSED single-pass lane with a STAGED lane (same
    variant, two kernel tiers, plus a fused reservoir cohort) on the
    sharded fabric replays bitwise-identically to the unsharded mixed-tier
    session — the fused kernel runs inside the one coalesced mesh launch."""
    g = small_graph
    cfg, params, ef = _setup(g, key=5)
    lanes = ((None, "fused"), (None, "staged"),
             ("sat+lut+np4+reservoir", "fused"))
    ref = SessionManager(params, ef, model=cfg, use_kernels="staged")
    sh = cl.ShardedSessionManager(params, ef, model=cfg,
                                  use_kernels="staged", mesh="tenant=2")
    rt = [ref.add_tenant(v, use_kernels=t) for v, t in lanes]
    st = [sh.add_tenant(v, use_kernels=t) for v, t in lanes]
    assert {c.tier for c in sh._cohorts.values()} == {"fused", "staged"}
    fr, fs = _feeds(g, rt, rounds=3), _feeds(g, st, rounds=3)
    for r in range(3):
        o1 = ref.step({t: fr[t][r] for t in rt})
        o2 = sh.step({t: fs[t][r] for t in st})
        assert sh.metrics[-1]["launches"] == 1
        for t1, t2 in zip(rt, st):
            np.testing.assert_array_equal(
                np.asarray(o1[t1].emb_src), np.asarray(o2[t2].emb_src),
                err_msg=f"round {r} {t2} src")
    for t1, t2 in zip(rt, st):
        _assert_state_equal(ref.state_of(t1), sh.state_of(t2), msg=t2)


# ---------------------------------------------------------------------------
# coalesced cross-cohort rounds on the mesh
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mesh", ["tenant=8", "tenant=4,vertex=2"])
def test_sharded_coalesced_matches_percohort_bitwise(small_graph, mesh):
    """A mixed 3-cohort fleet (8 tenants) on the mesh replays
    BITWISE-identically under the coalesced single-launch round (states
    donated, mesh placements pinned) and the per-cohort sharded baseline,
    through ragged widths and idle tenants — with exactly ONE compiled
    execution per coalesced round."""
    g = small_graph
    cfg, params, ef = _setup(g, key=6)
    variants = ("sat+lut+np4", "sat+lut+np2", "sat+lut+np4+reservoir")
    m1 = cl.ShardedSessionManager(params, ef, model=cfg, mesh=mesh)
    m2 = cl.ShardedSessionManager(params, ef, model=cfg, mesh=mesh,
                                  coalesce=False)
    t1 = [m1.add_tenant(variants[i % 3]) for i in range(8)]
    t2 = [m2.add_tenant(variants[i % 3]) for i in range(8)]
    for r, w in enumerate((30, 18, 30)):
        bs = {}
        for i in range(8):
            if r == 1 and i % 4 == 1:        # some tenants idle round 1
                # (i=1 and i=5 — every cohort keeps at least one active
                # member, so the per-cohort baseline still launches 3x)
                continue
            lo = 40 * i + r * w
            bs[i] = next(iter(stream_mod.fixed_count(
                g, w, window=slice(lo, lo + w), seed=i)))
        before = m1._coalesced.calls if m1._coalesced is not None else 0
        o1 = m1.step({t1[i]: b for i, b in bs.items()})
        o2 = m2.step({t2[i]: b for i, b in bs.items()})
        assert m1._coalesced.calls == before + 1
        assert m1.metrics[-1]["launches"] == 1
        assert m2.metrics[-1]["launches"] == 3
        for i in bs:
            np.testing.assert_array_equal(
                np.asarray(o1[t1[i]].emb_src), np.asarray(o2[t2[i]].emb_src),
                err_msg=f"round {r} tenant {i}")
    for a, b in zip(t1, t2):
        _assert_state_equal(m1.state_of(a), m2.state_of(b), msg=a)
    # the super-batch row space covers every cohort's mesh capacity
    n_tenant_shards = dict(m1.mesh.shape).get("tenant", 1)
    assert m1._coalesced.rows % n_tenant_shards == 0


def test_sharded_coalesced_matches_unsharded_session(small_graph):
    """Coalesced rounds on the mesh reproduce the UNSHARDED coalesced
    session bitwise (the fabric contract composed with the fused round)."""
    g = small_graph
    cfg, params, ef = _setup(g, key=7)
    variants = ("sat+lut+np4", "sat+lut+np4+uniform")
    flat = SessionManager(params, ef, model=cfg)
    sh = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=4")
    ft = [flat.add_tenant(v) for v in variants for _ in range(2)]
    st = [sh.add_tenant(v) for v in variants for _ in range(2)]
    fr, fs = _feeds(g, ft), _feeds(g, st)
    for r in range(3):
        o1 = flat.step({t: fr[t][r] for t in ft})
        o2 = sh.step({t: fs[t][r] for t in st})
        for a, b in zip(ft, st):
            np.testing.assert_array_equal(np.asarray(o1[a].emb_src),
                                          np.asarray(o2[b].emb_src),
                                          err_msg=f"round {r} {b}")
    assert flat.metrics[-1]["launches"] == sh.metrics[-1]["launches"] == 1
    for a, b in zip(ft, st):
        _assert_state_equal(flat.state_of(a), sh.state_of(b), msg=b)


@pytest.mark.parametrize("coalesce", [True, False])
def test_mixed_model_fleet_on_mesh_matches_unsharded(small_graph,
                                                     coalesce):
    """The per-lane parameter dimension on the 8-device mesh: a teacher
    lane + two student weight sets in one sharded session replay
    BITWISE-identically to the unsharded mixed-model session, coalesced
    and per-cohort, with the launch counters pinned — every registered
    set rides the mesh replicated."""
    g = small_graph
    cfg, params, ef = _setup(g, key=20)
    tcfg = pl.variant_config("teacher", **_dims(g))
    tparams = tgn.init_params(jax.random.key(21), tcfg)
    sparams = tgn.init_params(jax.random.key(22), cfg)
    lanes = (("sat+lut+np4", None), ("teacher", "teacher-v1"),
             ("sat+lut+np4", "student-B"))

    def fleet(mk):
        mgr = mk()
        mgr.register_params("teacher-v1", tparams)
        mgr.register_params("student-B", sparams)
        return mgr, [mgr.add_tenant(v, params=p) for v, p in lanes]

    flat, ft = fleet(lambda: SessionManager(
        params, ef, model=cfg, coalesce=coalesce))
    sh, st = fleet(lambda: cl.ShardedSessionManager(
        params, ef, model=cfg, mesh="tenant=2", coalesce=coalesce))
    assert sum(1 for v in sh.describe().values()
               if isinstance(v, dict) and "tenants" in v) == 3
    # registered sets are mesh-replicated (same placement as the default)
    mem = jax.tree.leaves(sh.param_store.get("teacher-v1"))[0]
    assert mem.sharding.mesh.shape == sh.mesh.shape
    fr, fs = _feeds(g, ft), _feeds(g, st)
    for r in range(3):
        o1 = flat.step({t: fr[t][r] for t in ft})
        o2 = sh.step({t: fs[t][r] for t in st})
        assert sh.metrics[-1]["launches"] == (1 if coalesce else 3)
        for a, b in zip(ft, st):
            np.testing.assert_array_equal(np.asarray(o1[a].emb_src),
                                          np.asarray(o2[b].emb_src),
                                          err_msg=f"round {r} {b}")
    if coalesce:
        assert sh._coalesced.traces == 1
        assert sh.summary()["launches_per_round"] == 1
    for a, b in zip(ft, st):
        _assert_state_equal(flat.state_of(a), sh.state_of(b), msg=b)


# ---------------------------------------------------------------------------
# snapshot / restore / migration across mesh shapes
# ---------------------------------------------------------------------------


def test_snapshot_restores_across_mesh_shapes_and_continues(small_graph,
                                                            tmp_path):
    """The elastic acceptance path: snapshot a tenant mid-stream on an
    8-way mesh, restore onto a 2x2 tenant x vertex mesh AND onto the
    unsharded session, and continue all three identically (bitwise)."""
    g = small_graph
    cfg, params, ef = _setup(g, key=3)
    root = str(tmp_path)
    ref = SessionManager(params, ef, model=cfg)
    sh = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=8")
    a_ref, a_sh = ref.add_tenant(), sh.add_tenant()
    feed = list(stream_mod.fixed_count(g, 30, window=slice(0, 150)))
    for b in feed[:3]:                       # mid-stream
        ref.step({a_ref: b})
        sh.step({a_sh: b})
    cl.snapshot_tenant(sh, a_sh, root, step=3)
    assert cl.list_snapshots(root) == {a_sh: 3}
    assert cl.snapshot_meta(root, a_sh)["variant"] == "sat+lut+np4"

    sh2 = cl.ShardedSessionManager(params, ef, model=cfg,
                                   mesh="tenant=2,vertex=2")
    flat = SessionManager(params, ef, model=cfg)
    b_sh = cl.restore_tenant(sh2, root, a_sh)
    b_flat = cl.restore_tenant(flat, root, a_sh, name="revived")
    assert b_flat == "revived"
    _assert_state_equal(sh.state_of(a_sh), sh2.state_of(b_sh), "restored")
    for b in feed[3:]:                       # continue on every topology
        o_ref = ref.step({a_ref: b})[a_ref]
        o_sh2 = sh2.step({b_sh: b})[b_sh]
        o_flat = flat.step({b_flat: b})[b_flat]
        np.testing.assert_array_equal(np.asarray(o_ref.emb_src),
                                      np.asarray(o_sh2.emb_src))
        np.testing.assert_array_equal(np.asarray(o_ref.emb_src),
                                      np.asarray(o_flat.emb_src))
    _assert_state_equal(ref.state_of(a_ref), sh2.state_of(b_sh), "sh2")
    _assert_state_equal(ref.state_of(a_ref), flat.state_of(b_flat), "flat")


def test_migrate_tenant_between_meshes(small_graph, tmp_path):
    """migrate_tenant moves a live tenant to a different mesh shape and
    releases its source slot; the trajectory continues bitwise."""
    g = small_graph
    cfg, params, ef = _setup(g, key=4)
    src = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=8")
    dst = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=4")
    ref = SessionManager(params, ef, model=cfg)
    a_src, a_ref = src.add_tenant(name="hot"), ref.add_tenant()
    feed = list(stream_mod.fixed_count(g, 30, window=slice(0, 120)))
    for b in feed[:2]:
        src.step({a_src: b})
        ref.step({a_ref: b})
    moved = cl.migrate_tenant(src, a_src, dst, str(tmp_path), step=2)
    assert moved == "hot" and src.tenants == ()
    for b in feed[2:]:
        o_ref = ref.step({a_ref: b})[a_ref]
        o_dst = dst.step({moved: b})[moved]
        np.testing.assert_array_equal(np.asarray(o_ref.emb_src),
                                      np.asarray(o_dst.emb_src))
    _assert_state_equal(ref.state_of(a_ref), dst.state_of(moved), "moved")
    # migrating back under the same root auto-continues the step history
    # (never re-writes a step that would lose the latest-step race)
    back = cl.migrate_tenant(dst, moved, src, str(tmp_path))
    assert cl.list_snapshots(str(tmp_path)) == {"hot": 3}
    _assert_state_equal(ref.state_of(a_ref), src.state_of(back), "back")


def test_restore_config_mismatch_is_rejected(small_graph, tmp_path):
    """A snapshot taken at different table dims refuses to restore (clear
    error, no tenant left behind in the target)."""
    g = small_graph
    cfg, params, ef = _setup(g, f=8)
    mgr = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=2")
    tid = mgr.add_tenant()
    cl.snapshot_tenant(mgr, tid, str(tmp_path))

    cfg16 = pl.variant_config("sat+lut+np4", **_dims(g, f=16))
    params16 = tgn.init_params(jax.random.key(0), cfg16)
    other = cl.ShardedSessionManager(params16, ef, model=cfg16,
                                     mesh="tenant=2")
    with pytest.raises(ValueError, match="config fields"):
        cl.restore_tenant(other, str(tmp_path), tid)
    assert other.tenants == ()


def test_sharded_capacity_shrinks_eagerly(small_graph):
    """Cohort slots are released eagerly: stacked rows stay the minimal
    multiple of the tenant axis, and the survivors' states round-trip
    through the shrink untouched."""
    g = small_graph
    cfg, params, ef = _setup(g, key=5)
    mgr = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=2")
    tids = [mgr.add_tenant() for _ in range(3)]
    cohort = mgr.cohort_of(tids[0])
    assert cohort.capacity == 4              # 3 tenants pad to 2x2
    b = next(iter(stream_mod.fixed_count(g, 30)))
    mgr.step({t: b for t in tids})
    keep_states = {t: mgr.state_of(t) for t in tids[1:]}
    mgr.remove_tenant(tids[0])
    assert cohort.capacity == 2              # dead slot + pad released
    assert cohort.state.memory.sharding.spec[0] == "tenant"
    for t in tids[1:]:
        _assert_state_equal(keep_states[t], mgr.state_of(t), msg=t)
    out = mgr.step({t: b for t in tids[1:]})
    assert set(out) == set(tids[1:])


def test_sharded_reserve_live_admission(small_graph):
    """Capacity classes compose with mesh padding: a reserve-enabled
    sharded fleet fast-path attaches/detaches into mesh-aligned spare
    slots (no relayout), stays mesh-sharded, and serves bitwise like the
    exact-size sharded fleet."""
    g = small_graph
    cfg, params, ef = _setup(g, key=5)
    mgr = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=2",
                                   reserve=True)
    a = mgr.add_tenant()
    cohort = mgr.cohort_of(a)
    # ladder says 2, the tenant axis keeps it 2 (already a multiple)
    assert cohort.capacity == 2
    b = mgr.add_tenant()                     # spare slot: fast path
    assert not mgr.last_admission["relayout"]
    assert cohort.capacity == 2
    assert cohort.state.memory.sharding.spec[0] == "tenant"
    feeds = _feeds(g, [a, b], rounds=2)
    for r in range(2):
        mgr.step({t: feeds[t][r] for t in (a, b)})
    mgr.remove_tenant(b)                     # swap-remove: slot idles
    assert not mgr.last_admission["relayout"]
    assert cohort.capacity == 2 and cohort.size == 1
    # survivor bitwise vs the exact-size sharded fleet
    ref = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=2")
    ra, rb = ref.add_tenant(), ref.add_tenant()
    for r in range(2):
        ref.step({ra: feeds[a][r], rb: feeds[b][r]})
    _assert_state_equal(mgr.state_of(a), ref.state_of(ra), msg="survivor")


def test_snapshot_crash_mid_write_recovers(small_graph, tmp_path):
    """A torn write (tmp dir with partial payloads) is invisible to
    restore and garbage-collected by the next snapshot."""
    g = small_graph
    cfg, params, ef = _setup(g)
    mgr = cl.ShardedSessionManager(params, ef, model=cfg, mesh="tenant=2")
    tid = mgr.add_tenant()
    b = next(iter(stream_mod.fixed_count(g, 30)))
    mgr.step({tid: b})
    cl.snapshot_tenant(mgr, tid, str(tmp_path), step=1)
    # simulate a crash mid-snapshot at step 2
    torn = os.path.join(str(tmp_path), tid, "step_00000002.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "arr_00000.npy"), "wb") as f:
        f.write(b"\x93NUMPY garbage")
    assert cl.list_snapshots(str(tmp_path)) == {tid: 1}
    fresh = SessionManager(params, ef, model=cfg)
    revived = cl.restore_tenant(fresh, str(tmp_path), tid, name="r")
    _assert_state_equal(mgr.state_of(tid), fresh.state_of(revived), "torn")
    mgr.step({tid: b})
    cl.snapshot_tenant(mgr, tid, str(tmp_path), step=2)   # gc's the tmp
    assert not os.path.exists(torn)
    assert ckpt.latest_step(os.path.join(str(tmp_path), tid)) == 2
