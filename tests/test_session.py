"""Multi-tenant SessionManager: N concurrent streams through one vmapped
launch must be BITWISE-identical to N sequential single-tenant engines;
sampler backends (uniform / time-decayed reservoir) and the spec-menu
error messages ride along."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline as pl, stages, tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import StreamingEngine
from repro.serving.session import SessionManager


N_TENANTS = 3


@pytest.fixture(scope="module")
def small_graph():
    return tgd.wikipedia_like(n_edges=500)


def _dims(g, f=16):
    return dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f, f_time=f, f_emb=f, m_r=10)


def _tenant_stream(g, i, batch=40, rounds=4):
    """Each tenant replays a different window of the graph (independent
    streams with overlapping vertex populations)."""
    lo = 60 * i
    return stream_mod.fixed_count(g, batch,
                                  window=slice(lo, lo + batch * rounds),
                                  seed=i)


def _assert_state_equal(a, b, msg=""):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}:{f}")


# ---------------------------------------------------------------------------
# the acceptance criterion: N-tenant session == N sequential engines, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["teacher", "sat+lut+np4"])
def test_multitenant_bitwise_matches_sequential_engines(small_graph, variant):
    """One cohort of N same-variant tenants, advanced by one vmapped launch
    per round, reproduces N independent StreamingEngine runs bitwise —
    trajectories (per-round embeddings) AND final vertex state."""
    g = small_graph
    dims = _dims(g)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)

    mgr = SessionManager(params, ef, model=cfg, use_kernels=False)
    tids = [mgr.add_tenant() for _ in range(N_TENANTS)]
    session_embs = {t: [] for t in tids}
    streams = {t: _tenant_stream(g, i) for i, t in enumerate(tids)}
    for _batches, outs in mgr.run(streams):
        for t, o in outs.items():
            session_embs[t].append((np.asarray(o.emb_src),
                                    np.asarray(o.emb_dst)))

    for i, t in enumerate(tids):
        eng = StreamingEngine.from_variant(variant, params, ef,
                                           use_kernels=False, **dims)
        for r, batch in enumerate(_tenant_stream(g, i)):
            hs, hd = eng.process(batch)
            ms, md = session_embs[t][r]
            np.testing.assert_array_equal(ms, np.asarray(hs),
                                          err_msg=f"{t} round {r} src")
            np.testing.assert_array_equal(md, np.asarray(hd),
                                          err_msg=f"{t} round {r} dst")
        _assert_state_equal(mgr.state_of(t), eng.state, msg=t)


def test_mixed_sampler_cohorts_each_match_their_engine(small_graph):
    """Tenants on different sampler backends share the session (and the
    parameter set): one launch per cohort, each tenant still bitwise equal
    to its own sequential engine."""
    g = small_graph
    dims = _dims(g)
    variants = ("sat+lut+np4", "sat+lut+np4+uniform", "sat+lut+np4+reservoir",
                "sat+lut+np4+reservoir")   # two reservoirs: one 2-cohort
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(1), cfg)
    ef = jnp.asarray(g.edge_feats)

    mgr = SessionManager(params, ef, model=cfg, use_kernels=False)
    tids = [mgr.add_tenant(v) for v in variants]
    assert len(mgr.describe()) == 3         # 3 cohorts for 4 tenants
    streams = {t: _tenant_stream(g, i) for i, t in enumerate(tids)}
    for _batches, _outs in mgr.run(streams):
        pass
    # coalesced (default): the whole 3-cohort round is ONE compiled launch
    assert mgr.metrics[-1]["launches"] == 1

    finals = []
    for i, (t, v) in enumerate(zip(tids, variants)):
        eng = StreamingEngine.from_variant(v, params, ef,
                                           use_kernels=False, **dims)
        for batch in _tenant_stream(g, i):
            eng.process(batch)
        _assert_state_equal(mgr.state_of(t), eng.state, msg=v)
        finals.append(np.asarray(mgr.state_of(t).memory))
    # the sampler policy is load-bearing: different backends on the same
    # stream windows land on different memory states
    assert not np.array_equal(finals[0], finals[1])


def test_stager_reuse_gate_includes_the_consuming_launch(small_graph):
    """``device_put`` on CPU zero-copies aligned host buffers, so a staged
    super-batch can ALIAS the stager's NumPy set: reusing the set two
    rounds later must wait for the launch that consumed it, not just the
    transfer, or the (async) executable reads a torn batch. Pins that
    every step joins its launch outputs into the staged set's reuse gate
    — the race only manifests under scheduler-dependent timing, so the
    gate's shape is asserted directly."""
    g = small_graph
    dims = _dims(g)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg,
                         use_kernels=False)
    t0 = mgr.add_tenant()
    rounds = list(_tenant_stream(g, 0, batch=20, rounds=3))
    for k, batch in enumerate(rounds):
        mgr.step({t0: batch})
        st = mgr._stager
        gate = st._inflight[st._last]
        # (transfer, consumer-outputs) pair, arrays of the launch output
        assert isinstance(gate, tuple) and len(gate) == 2
        dev, outputs = gate
        assert all(isinstance(x, jax.Array) for x in dev)
        assert any(isinstance(leaf, jax.Array)
                   for leaf in jax.tree_util.tree_leaves(outputs))
    mgr.sync()


def test_idle_tenants_are_bitwise_frozen(small_graph):
    """A round that only some tenants join must not perturb the others:
    the masked (all-invalid) step is a bitwise no-op on their state."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(2), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    a, b = mgr.add_tenant(), mgr.add_tenant()
    batches = list(_tenant_stream(g, 0, rounds=2))
    mgr.step({a: batches[0], b: batches[0]})
    frozen = mgr.state_of(b)
    out = mgr.step({a: batches[1]})          # b idles this round
    assert set(out) == {a}
    _assert_state_equal(mgr.state_of(b), frozen, msg="idle tenant")
    # and the idle round left a's trajectory on the sequential path
    eng = StreamingEngine.from_variant("sat+lut+np4", params,
                                       jnp.asarray(g.edge_feats),
                                       use_kernels=False, **dims)
    for batch in batches:
        eng.process(batch)
    _assert_state_equal(mgr.state_of(a), eng.state, msg="active tenant")


def test_add_tenant_midstream_and_ragged_batches(small_graph):
    """Tenants added after rounds have run start fresh and still match a
    sequential engine; ragged per-tenant batch sizes are padded with masked
    rows (results on real rows unchanged, outputs cut to the real rows)."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(3), cfg)
    ef = jnp.asarray(g.edge_feats)
    mgr = SessionManager(params, ef, model=cfg)
    a = mgr.add_tenant()
    first = list(_tenant_stream(g, 0, rounds=2))
    for batch in first:
        mgr.step({a: batch})
    b = mgr.add_tenant()                     # cohort grows mid-serving
    small = next(iter(stream_mod.fixed_count(g, 24, window=slice(0, 24))))
    big = next(iter(stream_mod.fixed_count(g, 40,
                                           window=slice(80, 120), seed=7)))
    outs = mgr.step({b: small, a: big})      # ragged round: B=24 vs B=40
    assert outs[b].emb_src.shape[0] == 24
    assert outs[b].attn_logits.shape[0] == 48
    assert outs[a].emb_src.shape[0] == 40

    eng = StreamingEngine.from_variant("sat+lut+np4", params, ef,
                                       use_kernels=False, **dims)
    hs, _hd = eng.process(small)
    np.testing.assert_array_equal(np.asarray(outs[b].emb_src),
                                  np.asarray(hs))
    _assert_state_equal(mgr.state_of(b), eng.state, msg="late tenant")


def test_kernel_backends_serve_multitenant(small_graph):
    """The Pallas stage backends run under the vmapped cohort launch and
    agree with the reference-backend session within kernel tolerance."""
    g = small_graph
    dims = _dims(g)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(4), cfg)
    ef = jnp.asarray(g.edge_feats)
    outs = {}
    for kernels in (True, False):
        mgr = SessionManager(params, ef, model=cfg, use_kernels=kernels)
        tids = [mgr.add_tenant() for _ in range(2)]
        for _b, _o in mgr.run({t: _tenant_stream(g, i, rounds=2)
                               for i, t in enumerate(tids)}):
            pass
        outs[kernels] = [np.asarray(mgr.state_of(t).memory) for t in tids]
    for mk, mr in zip(outs[True], outs[False]):
        np.testing.assert_allclose(mk, mr, atol=2e-5)


def test_remove_tenant_releases_slots_eagerly(small_graph):
    """Removing a tenant shrinks the cohort's stacked tables immediately
    (no dead rows), survivors' states round-trip through the shrink
    bitwise, and a removed tenant's slot is really gone."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(6), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    tids = [mgr.add_tenant() for _ in range(4)]
    cohort = mgr.cohort_of(tids[0])
    batches = list(_tenant_stream(g, 0, rounds=2))
    mgr.step({t: batches[0] for t in tids})
    assert cohort.capacity == 4 == cohort.state.memory.shape[0]
    survivors = {t: mgr.state_of(t) for t in tids if t != tids[1]}
    mgr.remove_tenant(tids[1])               # middle slot: indices shift
    assert cohort.capacity == 3 == cohort.state.memory.shape[0]
    for t, st in survivors.items():
        _assert_state_equal(st, mgr.state_of(t), msg=f"survivor {t}")
    with pytest.raises(KeyError):
        mgr.state_of(tids[1])
    # set_state/state_of round-trip still lands on the right slot
    mgr.set_state(tids[2], survivors[tids[0]])
    _assert_state_equal(mgr.state_of(tids[2]), survivors[tids[0]],
                        msg="set_state after remove")
    out = mgr.step({t: batches[1] for t in survivors})
    assert set(out) == set(survivors)
    # removing the rest tears the cohort down entirely
    for t in survivors:
        mgr.remove_tenant(t)
    assert mgr.tenants == () and cohort.state is None
    assert cohort.capacity == 0


def test_remove_tenant_drains_inflight_rounds(small_graph):
    """Hardening regression: steps are async, so ``remove_tenant`` must
    drain the fleet (``sync``) BEFORE the lane slot is released — a
    dispatched round still reads the stacked tables it launched with.
    Guards both the ordering (drain strictly precedes the slot release)
    and the outcome (survivors of a remove issued right behind
    un-synced steps stay bitwise-correct)."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(7), cfg)
    ef = jnp.asarray(g.edge_feats)
    mgr = SessionManager(params, ef, model=cfg)
    tids = [mgr.add_tenant() for _ in range(3)]
    its = {t: iter(_tenant_stream(g, i, rounds=2))
           for i, t in enumerate(tids)}
    for _ in range(2):       # dispatch rounds, never sync: still in flight
        mgr.step({t: next(it) for t, it in its.items()})
    order = []
    cohort = mgr.cohort_of(tids[1])
    orig_sync, orig_remove = mgr.sync, cohort.remove
    mgr.sync = lambda: (order.append("drain"), orig_sync())[-1]
    cohort.remove = lambda t: (order.append("release"),
                               orig_remove(t))[-1]
    mgr.remove_tenant(tids[1])
    mgr.sync, cohort.remove = orig_sync, orig_remove
    assert order == ["drain", "release"]
    for i, t in ((0, tids[0]), (2, tids[2])):
        eng = StreamingEngine.from_variant("sat+lut+np4", params, ef,
                                           use_kernels=False, **dims)
        for batch in _tenant_stream(g, i, rounds=2):
            eng.process(batch)
        _assert_state_equal(mgr.state_of(t), eng.state,
                            msg=f"survivor {t}")


def test_tenant_lifecycle_and_errors(small_graph):
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(5), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    a = mgr.add_tenant(name="fraud-eu")
    assert mgr.tenants == ("fraud-eu",)
    with pytest.raises(ValueError, match="already exists"):
        mgr.add_tenant(name="fraud-eu")
    # the parameterized axes are shared; samplers/pruning may vary
    with pytest.raises(ValueError, match="shares sat\\+lut parameters"):
        mgr.add_tenant("teacher")
    b = mgr.add_tenant("sat+lut+np4+reservoir", reservoir_tau=3600.0)
    assert "tau=3600" in mgr.cohort_of(b).pipeline.describe()["sampler"]
    # cohorts differing only in tau share a variant name: describe must
    # keep BOTH entries (tau-suffixed), not silently overwrite one
    c = mgr.add_tenant("sat+lut+np4+reservoir", reservoir_tau=60.0)
    taus = {k: v for k, v in mgr.describe().items() if "reservoir" in k}
    assert len(taus) == 2
    assert any(k.endswith("@tau=60") for k in taus)
    assert {t for v in taus.values() for t in v["tenants"]} == {b, c}
    mgr.remove_tenant(c)
    with pytest.raises(KeyError, match="unknown tenants"):
        mgr.step({"nope": next(iter(_tenant_stream(g, 0)))})
    mgr.remove_tenant(a)
    assert mgr.tenants == (b,)
    batch = next(iter(_tenant_stream(g, 0)))
    assert set(mgr.step({b: batch})) == {b}


# ---------------------------------------------------------------------------
# coalesced cross-cohort rounds (one compiled launch per round)
# ---------------------------------------------------------------------------

# the mixed 3-cohort fleet: the prune axis (np4 vs np2) AND a sampler
# cohort, all on the session's DEFAULT parameter set. (A tenant on the
# default set must match its attention+encoder axes; a tenant that brings
# its OWN registered set — register_params + add_tenant(params=...) — may
# vary every axis, the mixed-model tests below.)
MIXED_VARIANTS = ("sat+lut+np4", "sat+lut+np2", "sat+lut+np4+reservoir")


def _mixed_fleet(g, params, cfg, n_tenants, coalesce):
    ef = jnp.asarray(g.edge_feats)
    mgr = SessionManager(params, ef, model=cfg, use_kernels=False,
                         coalesce=coalesce)
    tids = [mgr.add_tenant(MIXED_VARIANTS[i % len(MIXED_VARIANTS)])
            for i in range(n_tenants)]
    return mgr, tids


def test_coalesced_bitwise_matches_percohort_mixed_cohorts(small_graph):
    """A mixed 3-cohort fleet (8 tenants) replays BITWISE-identically
    under the coalesced single-launch round and the per-cohort baseline —
    per-round embeddings, distill views, and final states — through
    ragged batch widths and idle tenants."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(7), cfg)
    m1, t1 = _mixed_fleet(g, params, cfg, 8, coalesce=True)
    m2, t2 = _mixed_fleet(g, params, cfg, 8, coalesce=False)
    assert len(m1.describe()) == 3
    rng_widths = (40, 24, 40, 8)          # ragged rounds: stager width grows
    for r, width in enumerate(rng_widths):
        batches = {}
        for i in range(8):
            if r == 2 and i % 4 == 1:     # some tenants idle round 2
                continue
            lo = 50 * i + r * width
            batches[i] = next(iter(stream_mod.fixed_count(
                g, width, window=slice(lo, lo + width), seed=i)))
        o1 = m1.step({t1[i]: b for i, b in batches.items()})
        o2 = m2.step({t2[i]: b for i, b in batches.items()})
        assert set(o1) == {t1[i] for i in batches}
        for i in batches:
            for field in ("emb_src", "emb_dst", "attn_logits",
                          "nbr_valid", "nbr_dt"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(o1[t1[i]], field)),
                    np.asarray(getattr(o2[t2[i]], field)),
                    err_msg=f"round {r} tenant {i} {field}")
    for a, b in zip(t1, t2):
        _assert_state_equal(m1.state_of(a), m2.state_of(b), msg=a)


def test_coalesced_round_is_exactly_one_compiled_launch(small_graph):
    """The launch-count guard: every coalesced ``step`` dispatches exactly
    ONE compiled round execution regardless of cohort count (the
    per-cohort baseline pays one per cohort), and a fleet change relayouts
    without breaking the guarantee."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(8), cfg)
    m1, t1 = _mixed_fleet(g, params, cfg, 6, coalesce=True)
    m2, t2 = _mixed_fleet(g, params, cfg, 6, coalesce=False)
    feeds = {i: list(_tenant_stream(g, i, rounds=3)) for i in range(6)}
    for r in range(3):
        before = m1._coalesced.calls if m1._coalesced is not None else 0
        m1.step({t1[i]: feeds[i][r] for i in range(6)})
        m2.step({t2[i]: feeds[i][r] for i in range(6)})
        assert m1._coalesced.calls == before + 1   # ONE compiled execution
        assert m1.metrics[-1]["launches"] == 1
        assert m2.metrics[-1]["launches"] == 3     # baseline: per cohort
    # lane table covers every cohort row: 6 tenants over 3 variants
    assert m1._coalesced.rows == 6
    assert len(set(m1._coalesced.lane_ids.tolist())) == 3
    # fleet change: relayout, still one launch, trajectories still equal
    a1 = m1.add_tenant(MIXED_VARIANTS[0])
    a2 = m2.add_tenant(MIXED_VARIANTS[0])
    assert m1._coalesced is None                   # layout invalidated
    b = next(iter(_tenant_stream(g, 6)))
    m1.step({a1: b})
    m2.step({a2: b})
    assert m1.metrics[-1]["launches"] == 1
    assert m1._coalesced.rows == 7
    _assert_state_equal(m1.state_of(a1), m2.state_of(a2), msg="late tenant")


def test_mixed_kernel_tier_fleet_replays_bitwise(small_graph):
    """One session mixing FUSED and STAGED lanes — same variant on two
    kernel tiers plus a fused reservoir cohort — replays bitwise-
    identically coalesced vs per-cohort vs N solo single-tenant sessions,
    through a ragged round and an idle lane. The fused lanes run the
    single-pass kernel INSIDE the one coalesced launch."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(11), cfg)
    ef = jnp.asarray(g.edge_feats)
    lanes = ((None, "fused"), (None, "staged"),
             ("sat+lut+np4+reservoir", "fused"))

    def fleet(coalesce):
        mgr = SessionManager(params, ef, model=cfg, use_kernels="staged",
                             coalesce=coalesce)
        tids = [mgr.add_tenant(v, use_kernels=t) for v, t in lanes]
        return mgr, tids

    m1, t1 = fleet(True)
    m2, t2 = fleet(False)
    # same variant on two tiers = two lanes; reservoir fused = a third
    assert len(m1.describe()) == 3
    tiers = {c.tier for c in m1._cohorts.values()}
    assert tiers == {"fused", "staged"}
    solos = []
    for v, t in lanes:
        m = SessionManager(params, ef, model=cfg, use_kernels="staged")
        solos.append((m, m.add_tenant(v, use_kernels=t)))

    feeds = [list(_tenant_stream(g, i, batch=30, rounds=4))
             for i in range(len(lanes))]
    widths = (30, 18, 30, 30)             # round 1 ragged
    for r, w in enumerate(widths):
        batches = {}
        for i in range(len(lanes)):
            if r == 2 and i == 1:         # staged lane idles round 2
                continue
            b = feeds[i][r]
            batches[i] = stream_mod.EdgeBatch(
                src=b.src[:w], dst=b.dst[:w], eid=b.eid[:w],
                ts=b.ts[:w], valid=b.valid[:w], neg_dst=b.neg_dst[:w])
        o1 = m1.step({t1[i]: b for i, b in batches.items()})
        o2 = m2.step({t2[i]: b for i, b in batches.items()})
        assert m1.metrics[-1]["launches"] == 1
        for i, b in batches.items():
            sm, st = solos[i]
            o3 = sm.step({st: b})[st]
            for field in ("emb_src", "emb_dst", "attn_logits",
                          "nbr_valid", "nbr_dt"):
                a = np.asarray(getattr(o1[t1[i]], field))
                np.testing.assert_array_equal(
                    a, np.asarray(getattr(o2[t2[i]], field)),
                    err_msg=f"round {r} lane {i} {field} (per-cohort)")
                np.testing.assert_array_equal(
                    a, np.asarray(getattr(o3, field)),
                    err_msg=f"round {r} lane {i} {field} (solo)")
    for i in range(len(lanes)):
        sm, st = solos[i]
        _assert_state_equal(m1.state_of(t1[i]), m2.state_of(t2[i]),
                            msg=f"lane {i} coalesced-vs-percohort")
        _assert_state_equal(m1.state_of(t1[i]), sm.state_of(st),
                            msg=f"lane {i} coalesced-vs-solo")


# ---------------------------------------------------------------------------
# per-lane parameter sets: teacher/student A/B serving in one launch
# ---------------------------------------------------------------------------

# the mixed-MODEL fleet: a teacher lane (different attention+encoder AND
# weights) plus two students on different weight sets — the parameter
# dimension of the lane table. (variant, param-set name or None=default)
MODEL_LANES = (("sat+lut+np4", None),
               ("teacher", "teacher-v1"),
               ("sat+lut+np4", "student-B"))


def _model_fleet_params(g, f=8):
    dims = _dims(g, f=f)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    tcfg = pl.variant_config("teacher", **dims)
    return (dims, cfg, tcfg,
            {None: tgn.init_params(jax.random.key(20), cfg),
             "teacher-v1": tgn.init_params(jax.random.key(21), tcfg),
             "student-B": tgn.init_params(jax.random.key(22), cfg)})


@pytest.mark.parametrize("coalesce", [True, False])
def test_mixed_model_fleet_replays_bitwise(small_graph, coalesce):
    """A teacher lane + two distilled-student lanes in ONE session —
    three parameter sets, two architectures — replay BITWISE-identical
    to three separate per-model SessionManagers, under both the
    coalesced single-launch round and the per-cohort baseline, with the
    launch and retrace counters pinned."""
    g = small_graph
    _dims_, cfg, tcfg, psets = _model_fleet_params(g)
    ef = jnp.asarray(g.edge_feats)

    mgr = SessionManager(psets[None], ef, model=cfg, use_kernels=False,
                         coalesce=coalesce)
    mgr.register_params("teacher-v1", psets["teacher-v1"])
    mgr.register_params("student-B", psets["student-B"])
    tids = [mgr.add_tenant(v, params=p) for v, p in MODEL_LANES]
    assert len(mgr.describe()) == 3
    # same-variant lanes on different weights stay distinct in describe
    assert any(k.endswith("@params=student-B") for k in mgr.describe())

    feeds = {t: list(_tenant_stream(g, i)) for i, t in enumerate(tids)}
    traj = {t: [] for t in tids}
    for r in range(4):
        outs = mgr.step({t: feeds[t][r] for t in tids})
        for t in tids:
            traj[t].append((np.asarray(outs[t].emb_src),
                            np.asarray(outs[t].emb_dst)))
    # the acceptance guard: 3 models advance as ONE compiled launch per
    # round (per-cohort baseline: one per lane), retraced exactly once
    assert mgr.summary()["launches_per_round"] == (1 if coalesce else 3)
    assert {m["launches"] for m in mgr.metrics} == ({1} if coalesce
                                                    else {3})
    if coalesce:
        assert mgr._coalesced.traces == 1
        assert mgr.compile_counters()["round_traces"] == 1

    for i, (t, (v, pname)) in enumerate(zip(tids, MODEL_LANES)):
        ref = SessionManager(psets[pname], ef,
                             model=tcfg if v == "teacher" else cfg,
                             use_kernels=False, coalesce=coalesce)
        rt = ref.add_tenant(name="solo")
        for r in range(4):
            o = ref.step({rt: feeds[t][r]})[rt]
            ms, md = traj[t][r]
            np.testing.assert_array_equal(
                ms, np.asarray(o.emb_src),
                err_msg=f"lane {i} ({v}@{pname}) round {r} src")
            np.testing.assert_array_equal(
                md, np.asarray(o.emb_dst),
                err_msg=f"lane {i} ({v}@{pname}) round {r} dst")
        _assert_state_equal(mgr.state_of(t), ref.state_of(rt),
                            msg=f"lane {i} ({v}@{pname})")
    # and the weights are load-bearing: replaying lane 2's stream under
    # the DEFAULT set (same policy, different weights) diverges from the
    # student-B trajectory the session produced
    base = SessionManager(psets[None], ef, model=cfg, use_kernels=False,
                          coalesce=coalesce)
    bt = base.add_tenant()
    for r in range(4):
        ob = base.step({bt: feeds[tids[2]][r]})[bt]
    assert not np.array_equal(traj[tids[2]][-1][0], np.asarray(ob.emb_src))


def test_param_store_lifecycle_and_errors(small_graph):
    """The registry contract: admission never invents weights (unknown
    names rejected before any lane mutation), registered sets are
    immutable, and a set that does not structurally fit the tenant's
    config is rejected with the leaf-level diff."""
    g = small_graph
    _dims_, cfg, tcfg, psets = _model_fleet_params(g)
    mgr = SessionManager(psets[None], jnp.asarray(g.edge_feats), model=cfg)
    a = mgr.add_tenant()
    with pytest.raises(ValueError, match="unknown param set"):
        mgr.add_tenant(params="nope")
    assert mgr.tenants == (a,)               # rejection mutated nothing
    # byte-identical re-register is a no-op; different content is an error
    mgr.register_params("s", psets["student-B"])
    mgr.register_params("s", psets["student-B"])
    assert mgr.param_store.names() == ("default", "s")
    with pytest.raises(ValueError, match="immutable"):
        mgr.register_params("s", psets["teacher-v1"])
    with pytest.raises(ValueError, match="non-empty string"):
        mgr.register_params("", psets["student-B"])
    # a student set cannot drive a teacher lane (structural mismatch)
    with pytest.raises(ValueError, match="does not fit"):
        mgr.add_tenant("teacher", params="s")
    # without its own weights the teacher still can't join (PR-4 rule)
    with pytest.raises(ValueError, match="shares sat\\+lut parameters"):
        mgr.add_tenant("teacher")
    # digests are stable content fingerprints
    assert mgr.param_store.digest("s") == mgr.param_store.digest("s")
    assert (mgr.param_store.digest("s") !=
            mgr.param_store.digest("default"))


def test_snapshot_restore_preserves_tenant_kernel_tier(small_graph,
                                                       tmp_path):
    """A tenant serving on a non-default kernel tier must RESUME on that
    tier after snapshot/restore: the manifest records the cohort's
    resolved tier (not the session default), and the restored trajectory
    continues bitwise-identically to the unsnapshotted one."""
    from repro.serving.cluster import restore_tenant, snapshot_tenant

    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(13), cfg)
    ef = jnp.asarray(g.edge_feats)
    feed = list(_tenant_stream(g, 0, batch=25, rounds=4))

    mgr = SessionManager(params, ef, model=cfg, use_kernels="staged")
    a = mgr.add_tenant(use_kernels="fused")
    mgr.step({a: feed[0]})
    mgr.step({a: feed[1]})
    snapshot_tenant(mgr, a, str(tmp_path), step=2)
    mgr.step({a: feed[2]})
    mgr.step({a: feed[3]})
    mgr.sync()

    other = SessionManager(params, ef, model=cfg, use_kernels="staged")
    b = restore_tenant(other, str(tmp_path), a, name="b")
    assert other.cohort_of(b).tier == "fused"
    other.step({b: feed[2]})
    other.step({b: feed[3]})
    other.sync()
    _assert_state_equal(mgr.state_of(a), other.state_of(b),
                        msg="restored fused lane")


def test_edge_counts_defer_to_summary(small_graph):
    """Steady-state rounds never block on a D2H sync: the per-round edge
    count stays a pending device value in ``metrics`` and is resolved only
    by ``summary()`` (both dispatch modes)."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(9), cfg)
    for coalesce in (True, False):
        mgr, tids = _mixed_fleet(g, params, cfg, 3, coalesce=coalesce)
        feeds = {i: list(_tenant_stream(g, i, batch=20, rounds=3))
                 for i in range(3)}
        for r in range(3):
            mgr.step({tids[i]: feeds[i][r] for i in range(3)})
            assert isinstance(mgr.metrics[-1]["edges"], jax.Array), coalesce
        s = mgr.summary()
        # rounds 1..2 (warmup skipped): 2 rounds x 3 tenants x 20 edges
        resolved = sum(int(np.asarray(m["edges"])) for m in mgr.metrics[1:])
        assert resolved == 2 * 3 * 20
        assert s["rounds"] == 2 and s["launches_per_round"] == (
            1 if coalesce else 3)


def test_background_snapshot_writer_bounded_and_durable(small_graph,
                                                        tmp_path):
    """The bounded per-tenant background writer: a submitted snapshot
    restores bitwise after ``wait()``; while a tenant's write is in
    flight further submissions for it are SKIPPED (never queued), so a
    snapshot cadence can never pile IO behind the serving loop."""
    from repro.serving import cluster as cl
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(11), cfg)
    ef = jnp.asarray(g.edge_feats)
    mgr = SessionManager(params, ef, model=cfg)
    a, b = mgr.add_tenant(), mgr.add_tenant()
    batch = next(iter(_tenant_stream(g, 0)))
    mgr.step({a: batch, b: batch})

    w = cl.TenantSnapshotWriter(str(tmp_path))
    assert w.submit(mgr, a, step=1)
    w.wait()
    fresh = SessionManager(params, ef, model=cfg)
    revived = cl.restore_tenant(fresh, str(tmp_path), a, name="r")
    _assert_state_equal(mgr.state_of(a), fresh.state_of(revived),
                        msg="background snapshot")

    class _Stuck:                        # a write that never finishes
        def done(self):
            return False

    w._inflight[b] = _Stuck()
    assert not w.submit(mgr, b, step=1)  # bounded: skipped, not queued
    assert w.skipped == 1
    del w._inflight[b]
    assert w.submit(mgr, b, step=2)      # free again once drained
    w.close()
    assert cl.list_snapshots(str(tmp_path)) == {a: 1, b: 2}

    class _Failed:                       # a write that blew up
        def done(self):
            return True

        def result(self):
            raise IOError("disk full")

    w2 = cl.TenantSnapshotWriter(str(tmp_path))
    w2._inflight["x"] = _Failed()
    w2._inflight["y"] = _Failed()
    with pytest.raises(RuntimeError, match="background snapshot"):
        w2.wait()                        # raises AFTER joining everything
    assert w2._inflight == {}            # ...so nothing is left unjoined
    w2.close()


def test_coalesced_engine_view_and_peek_unchanged(small_graph):
    """The single-tenant engine view: pre-staged device batches take the
    per-cohort fast path (no host round-trip through the stager — the
    prefetched transfer is consumed as-is), still exactly one launch per
    round, and ``peek``'s non-committing output matches ``process``."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(10), cfg)
    ef = jnp.asarray(g.edge_feats)
    eng = StreamingEngine.from_variant("sat+lut+np4", params, ef,
                                       use_kernels=False, **dims)
    assert eng.session.coalesce
    batches = list(_tenant_stream(g, 0, rounds=2))
    peeked = eng.session.peek(eng.tid, batches[0])
    hs, _ = eng.process(batches[0])
    np.testing.assert_array_equal(np.asarray(peeked.emb_src),
                                  np.asarray(hs))
    assert eng.session.metrics[-1]["launches"] == 1
    # the engine's device_put-staged batch never bounced through host
    # staging: the session's ring-buffer stager was never even built
    assert eng.session._stager is None


# ---------------------------------------------------------------------------
# sampler backends
# ---------------------------------------------------------------------------


def _one_neighborhood(variant, g, params, state, batch, dims):
    pipe = pl.build_pipeline(variant, **dims)
    vids = jnp.concatenate([jnp.asarray(batch.src), jnp.asarray(batch.dst)])
    t = jnp.concatenate([jnp.asarray(batch.ts), jnp.asarray(batch.ts)])
    return pipe.stages.sampler(params, pipe.prepare(params), state,
                               jnp.asarray(g.edge_feats), vids, t)


@pytest.mark.parametrize("variant", ["sat+lut+np4+uniform",
                                     "sat+lut+np4+reservoir"])
def test_randomized_samplers_select_valid_deterministic(small_graph,
                                                        variant):
    """Both hash-randomized policies pick k slots, only ever valid ones
    (when enough exist), and are deterministic — two identical queries
    sample the identical neighborhood (the property the bitwise session
    guarantee rests on)."""
    g = small_graph
    dims = _dims(g)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    state = tgn.init_state(cfg)
    ef = jnp.asarray(g.edge_feats)
    batches = list(stream_mod.fixed_count(g, 50, window=slice(0, 200)))
    for batch in batches[:-1]:
        b = tuple(jnp.asarray(x) for x in
                  (batch.src, batch.dst, batch.eid, batch.ts, batch.valid))
        state = tgn.process_batch(params, cfg, state, None, ef, *b).state
    nb1 = _one_neighborhood(variant, g, params, state, batches[-1], dims)
    nb2 = _one_neighborhood(variant, g, params, state, batches[-1], dims)
    np.testing.assert_array_equal(np.asarray(nb1.dt), np.asarray(nb2.dt))
    np.testing.assert_array_equal(np.asarray(nb1.valid),
                                  np.asarray(nb2.valid))
    assert nb1.dt.shape[1] == 4
    # rows with >= k valid ring slots must select k valid ones
    full = np.asarray(nb1.full_valid).sum(axis=1)
    sel = np.asarray(nb1.valid).sum(axis=1)
    assert np.all(sel[full >= 4] == 4)
    assert np.all(sel[full < 4] == full[full < 4])


def test_reservoir_tau_biases_toward_recency(small_graph):
    """As tau -> 0 the reservoir weight exp(-dt/tau) collapses onto the
    most recent neighbors, so the mean selected dt must not exceed the
    uniform policy's."""
    g = small_graph
    dims = _dims(g)
    dims_tau = dict(dims, reservoir_tau=1e-3)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    state = tgn.init_state(cfg)
    ef = jnp.asarray(g.edge_feats)
    batches = list(stream_mod.fixed_count(g, 50, window=slice(0, 300)))
    for batch in batches[:-1]:
        b = tuple(jnp.asarray(x) for x in
                  (batch.src, batch.dst, batch.eid, batch.ts, batch.valid))
        state = tgn.process_batch(params, cfg, state, None, ef, *b).state
    nb_u = _one_neighborhood("sat+lut+np4+uniform", g, params, state,
                             batches[-1], dims)
    nb_r = _one_neighborhood("sat+lut+np4+reservoir", g, params, state,
                             batches[-1], dims_tau)
    du = np.asarray(nb_u.dt)[np.asarray(nb_u.valid)]
    dr = np.asarray(nb_r.dt)[np.asarray(nb_r.valid)]
    assert dr.mean() <= du.mean()


def test_sampler_variants_run_through_pipeline(small_graph):
    g = small_graph
    dims = _dims(g, f=8)
    for variant in pl.SAMPLER_VARIANTS:
        pipe = pl.build_pipeline(variant, **dims)
        params = pipe.init_params(jax.random.key(0))
        state = pipe.init_state()
        b = next(iter(stream_mod.fixed_count(g, 32)))
        bt = tuple(jnp.asarray(x) for x in
                   (b.src, b.dst, b.eid, b.ts, b.valid))
        out = pipe.step_fn(params, state, bt, jnp.asarray(g.edge_feats))
        assert bool(jnp.all(jnp.isfinite(out.emb_src)))


# ---------------------------------------------------------------------------
# spec menu in error messages
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", ["sat+lut+bogus", "nope+cosine", "sat+fft",
                                 "vanilla+cosine+uniform",
                                 "sat+lut+np4+np2+x"])
def test_invalid_spec_prints_the_full_menu(bad):
    with pytest.raises(ValueError) as ei:
        pl.build_pipeline(bad, n_nodes=10, n_edges=10)
    msg = str(ei.value)
    for token in ("vanilla", "sat", "cosine", "lut", "np<k>", "recent",
                  "uniform", "reservoir", "registered variants",
                  "aliases"):
        assert token in msg, f"{token!r} missing from menu for {bad!r}"


def test_sampler_spec_round_trips():
    assert pl.resolve_variant("sat+lut+np4+reservoir").sampler == "reservoir"
    assert pl.resolve_variant("uniform") == pl.VariantSpec(
        "sat", "lut", 4, "uniform")
    assert pl.variant_name(pl.VariantSpec("sat", "lut", 2, "uniform")) == \
        "sat+lut+np2+uniform"
    assert pl.variant_name(pl.resolve_variant("reservoir")) == \
        "sat+lut+np4+reservoir"
    # default sampler stays out of canonical names
    assert pl.variant_name(pl.VariantSpec("sat", "lut", 4)) == "sat+lut+np4"
    assert stages.SAMPLERS == ("recent", "uniform", "reservoir")
    # an explicit 'recent' clause is the default policy: legal anywhere,
    # and it still arms the duplicate-clause check in BOTH orders
    assert pl.resolve_variant("vanilla+cosine+recent").sampler == "recent"
    for dup in ("sat+lut+recent+uniform", "sat+lut+uniform+recent"):
        with pytest.raises(ValueError, match="duplicate sampler"):
            pl.resolve_variant(dup)


# ---------------------------------------------------------------------------
# observability: registry-backed compile counters under live admission
# ---------------------------------------------------------------------------


def test_reserve_mode_compile_counters_frozen_across_admission(small_graph):
    """The registry-backed compile counters are FROZEN across reserve-mode
    attach-detach-attach cycles that land in spare lane slots (serving
    rounds between each mutation), and a forced relayout — exhausting the
    capacity class — increments ``relayouts`` exactly once."""
    g = small_graph
    dims = _dims(g, f=8)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(21), cfg)
    ef = jnp.asarray(g.edge_feats)
    mgr = SessionManager(params, ef, model=cfg, use_kernels=False,
                         reserve=True)
    tids = [mgr.add_tenant(name=f"t{i}") for i in range(3)]
    feeds = list(_tenant_stream(g, 0, batch=20, rounds=10))

    def step(r):
        mgr.step({t: feeds[r] for t in mgr.tenants})

    step(0)
    step(1)
    c0 = mgr.compile_counters()
    assert c0["round_traces"] == 1         # one compiled round, reused
    assert c0["relayouts"] == mgr.relayouts  # registry mirrors the legacy

    # attach -> step -> detach -> step -> attach -> step: all spare-slot
    # fast paths (3 tenants in a capacity-4 class), counters pinned
    extra = mgr.add_tenant(name="late")
    step(2)
    mgr.remove_tenant(extra)
    step(3)
    extra = mgr.add_tenant(name="later")
    step(4)
    c1 = mgr.compile_counters()
    assert c1["relayouts"] == c0["relayouts"]
    assert c1["round_traces"] == c0["round_traces"]
    assert {m["launches"] for m in mgr.metrics} == {1}

    # force a relayout: a 5th resident tenant exhausts the class of 4
    mgr.add_tenant(name="overflow")
    assert mgr._coalesced is None          # layout invalidated...
    step(5)
    step(6)
    c2 = mgr.compile_counters()
    assert c2["relayouts"] == c1["relayouts"] + 1   # ...rebuilt ONCE
    assert c2["round_traces"] == 1         # fresh launch, one trace
    assert mgr.relayouts == c2["relayouts"]
    assert len(tids) + 2 == len(mgr.tenants)
