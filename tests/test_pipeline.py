"""TGNPipeline API: registry resolution, stage-composition equivalence with
a straight-line Algorithm-1 transcription (the seed implementation), and the
variant-agnostic streaming engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as attn_mod
from repro.core import mailbox, memory, pipeline as pl, tgn, updater
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_aliases_resolve_to_table2_rows():
    assert pl.resolve_variant("teacher") == pl.VariantSpec("vanilla",
                                                           "cosine", None)
    assert pl.resolve_variant("Baseline") == pl.resolve_variant("teacher")
    assert pl.resolve_variant("+SAT") == pl.VariantSpec("sat", "cosine",
                                                        None)
    assert pl.resolve_variant("+NP(M)") == pl.VariantSpec("sat", "lut", 4)
    assert pl.resolve_variant("sat+lut+np2").prune_k == 2


def test_registry_grammar_fallback_and_errors():
    # not pre-registered, parsed via the grammar
    assert pl.resolve_variant("sat+cosine+np3") == pl.VariantSpec(
        "sat", "cosine", 3)
    with pytest.raises(ValueError):
        pl.resolve_variant("nope+cosine")
    with pytest.raises(ValueError):
        pl.resolve_variant("sat+fft")
    with pytest.raises(ValueError):
        pl.resolve_variant("vanilla+cosine+np4")  # pruning needs SAT
    with pytest.raises(ValueError):
        pl.resolve_variant("vanilla+lut")  # LUT fold targets SAT's W_v


def test_variant_name_round_trip():
    for name in pl.VARIANTS:
        cfg = pl.variant_config(name, n_nodes=50, n_edges=50)
        assert pl.variant_name(cfg) == name
    # synthesized canonical string for unregistered specs
    assert pl.variant_name(pl.VariantSpec("sat", "lut", 3)) == "sat+lut+np3"


def test_build_pipeline_describe_backends():
    dims = dict(n_nodes=50, n_edges=50, f_mem=8, f_time=8, f_emb=8)
    d = pl.build_pipeline("sat+lut+np4", use_kernels=True, **dims).describe()
    assert d["memory_updater"] == "gru:lut-pallas"
    assert "prune-then-fetch" in d["sampler"]
    d = pl.build_pipeline("teacher", use_kernels=True, **dims).describe()
    # no kernel backend for the teacher stages: reference fallback
    assert d["memory_updater"] == "gru:cosine-ref"
    assert d["aggregator"] == "attn:vanilla-ref"


# ---------------------------------------------------------------------------
# equivalence with the seed straight-line Algorithm 1
# ---------------------------------------------------------------------------


def _seed_process_batch(params, cfg, state, node_feats, edge_feats,
                        src, dst, eid, ts, valid=None):
    """Straight-line transcription of the pre-pipeline (seed)
    ``tgn.process_batch`` — the oracle the stage composition must match."""
    B = src.shape[0]
    vids = jnp.concatenate([src, dst])
    t_inst = jnp.concatenate([ts, ts])
    vvalid = (jnp.concatenate([valid, valid]) if valid is not None
              else jnp.ones((2 * B,), bool))

    mail_valid = state.mail_valid[vids]
    s_upd, lu_upd = memory.update_memory(
        params["gru"], params["time"], cfg.gru,
        state.mail[vids], state.mail_ts[vids], mail_valid,
        state.memory[vids], state.last_update[vids], encoder=cfg.encoder)

    chron = updater.interleave_order(B)
    winners = updater.last_write_wins(vids, vvalid, chron)
    mem_table = updater.commit(state.memory, vids, s_upd, winners)
    lu_table = updater.commit_scalar(state.last_update, vids, lu_upd,
                                     winners)
    mv_table = updater.commit_scalar(
        state.mail_valid, vids, jnp.zeros_like(mail_valid), winners)
    state = state._replace(memory=mem_table, last_update=lu_table,
                           mail_valid=mv_table)

    nbr_ids, nbr_ts, nbr_eid, nvalid = mailbox.gather_neighbors(state, vids)
    dt = jnp.maximum(t_inst[:, None] - nbr_ts, 0.0) * nvalid
    s_self = state.memory[vids]
    f_self = node_feats[vids] if node_feats is not None else None
    s_nbr = state.memory[nbr_ids] * nvalid[..., None]
    e_nbr = edge_feats[nbr_eid] * nvalid[..., None]
    if cfg.attention == "vanilla":
        h, logits = attn_mod.vanilla_attention(
            params["attn"], cfg.attn, params["time"],
            s_self, f_self, s_nbr, e_nbr, dt, nvalid)
    else:
        h, logits = attn_mod.sat_attention(
            params["attn"], cfg.attn, params["time"],
            s_self, f_self, s_nbr, e_nbr, dt, nvalid, encoder=cfg.encoder)

    fe = edge_feats[eid]
    mail_src = memory.build_mail_raw(mem_table[src], mem_table[dst], fe)
    mail_dst = memory.build_mail_raw(mem_table[dst], mem_table[src], fe)
    new_mail = jnp.concatenate([mail_src, mail_dst], axis=0)
    mail_winners = updater.last_write_wins(vids, vvalid, chron)
    state = state._replace(
        mail=updater.commit(state.mail, vids, new_mail, mail_winners),
        mail_ts=updater.commit_scalar(state.mail_ts, vids, t_inst,
                                      mail_winners),
        mail_valid=updater.commit_scalar(
            state.mail_valid, vids, jnp.ones((2 * B,), bool),
            mail_winners))
    state = mailbox.insert_neighbors(state, src, dst, eid, ts, valid)
    return tgn.BatchOut(state=state, emb_src=h[:B], emb_dst=h[B:],
                        attn_logits=logits, nbr_valid=nvalid, nbr_dt=dt)


@pytest.fixture(scope="module")
def small_graph():
    return tgd.wikipedia_like(n_edges=400)


@pytest.mark.parametrize("variant", ["sat+lut+np4", "vanilla+cosine",
                                     "sat+cosine", "sat+lut+np2"])
def test_pipeline_matches_seed_reference_trajectory(small_graph, variant):
    """build_pipeline(v, use_kernels=False) step == the seed straight-line
    Algorithm 1, bitwise-close, over a multi-batch stream (state AND
    embeddings AND distillation views)."""
    g = small_graph
    cfg = pl.variant_config(variant, n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=16,
                            f_time=16, f_emb=16, m_r=10)
    pipe = pl.build_pipeline(cfg, use_kernels=False)
    params = pipe.init_params(jax.random.key(0))
    s_pipe, s_seed = pipe.init_state(), tgn.init_state(cfg)
    ef = jnp.asarray(g.edge_feats)
    for batch in stream_mod.fixed_count(g, 50, window=slice(0, 250)):
        b = tuple(jnp.asarray(x) for x in
                  (batch.src, batch.dst, batch.eid, batch.ts, batch.valid))
        out_p = pipe.step_fn(params, s_pipe, b, ef)
        out_s = _seed_process_batch(params, cfg, s_seed, None, ef, *b)
        s_pipe, s_seed = out_p.state, out_s.state
        np.testing.assert_allclose(np.asarray(out_p.emb_src),
                                   np.asarray(out_s.emb_src), atol=1e-6)
        np.testing.assert_allclose(np.asarray(out_p.attn_logits),
                                   np.asarray(out_s.attn_logits), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out_p.nbr_valid),
                                      np.asarray(out_s.nbr_valid))
        for field in ("memory", "last_update", "mail", "mail_ts",
                      "mail_valid", "nbr_ids", "nbr_ts", "nbr_cursor"):
            np.testing.assert_allclose(
                np.asarray(getattr(s_pipe, field)),
                np.asarray(getattr(s_seed, field)), atol=1e-6,
                err_msg=f"{variant}:{field}")


def test_process_batch_is_the_reference_composition(small_graph):
    """tgn.process_batch and the pipeline step are the same composition."""
    g = small_graph
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=16,
                            f_time=16, f_emb=16, m_r=10)
    pipe = pl.build_pipeline(cfg)
    params = pipe.init_params(jax.random.key(1))
    state = pipe.init_state()
    ef = jnp.asarray(g.edge_feats)
    b = next(iter(stream_mod.fixed_count(g, 40)))
    bt = tuple(jnp.asarray(x) for x in (b.src, b.dst, b.eid, b.ts, b.valid))
    out_a = tgn.process_batch(params, cfg, state, None, ef, *bt)
    out_b = pipe.step_fn(params, state, bt, ef)
    np.testing.assert_array_equal(np.asarray(out_a.emb_src),
                                  np.asarray(out_b.emb_src))
    np.testing.assert_array_equal(np.asarray(out_a.state.memory),
                                  np.asarray(out_b.state.memory))


def test_engine_reference_backend_matches_process_batch(small_graph):
    """The session with jnp reference backends reproduces the
    process_batch trajectory exactly (fixed stream, sat+lut+np4)."""
    g = small_graph
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=16,
                            f_time=16, f_emb=16, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    ef = jnp.asarray(g.edge_feats)
    eng = StreamingEngine(EngineConfig(model=cfg, use_kernels=False),
                          params, ef)
    state = tgn.init_state(cfg)
    for batch in stream_mod.fixed_count(g, 50, window=slice(0, 250)):
        hs, hd = eng.process(batch)
        b = tuple(jnp.asarray(x) for x in
                  (batch.src, batch.dst, batch.eid, batch.ts, batch.valid))
        out = tgn.process_batch(params, cfg, state, None, ef, *b)
        state = out.state
        m = jnp.asarray(batch.valid)[:, None]
        np.testing.assert_allclose(np.asarray((hs - out.emb_src) * m), 0.0,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray((hd - out.emb_dst) * m), 0.0,
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(eng.state.memory),
                               np.asarray(state.memory), atol=1e-5)


# ---------------------------------------------------------------------------
# variant-agnostic engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", pl.VARIANTS)
def test_engine_serves_every_registry_variant(small_graph, variant):
    """Smoke: the one engine session runs every Table-II variant —
    the vanilla/cosine teacher included — with kernel backends where they
    exist, recording latency AND device-transfer metrics."""
    g = small_graph
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=8, f_time=8, f_emb=8, m_r=10)
    cfg = pl.variant_config(variant, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    eng = StreamingEngine.from_variant(variant, params,
                                       jnp.asarray(g.edge_feats), **dims)
    n = 0
    for batch, (hs, hd) in eng.run(
            stream_mod.fixed_count(g, 64, window=slice(0, 192))):
        assert bool(jnp.all(jnp.isfinite(hs))) and hs.shape == (64, 8)
        n += 1
    assert n == 3
    assert len(eng.metrics) == 3
    for m in eng.metrics:
        assert m["h2d_s"] >= 0.0 and m["latency_s"] > 0.0
    s = eng.summary()
    assert s["batches"] == 2 and "mean_h2d_ms" in s


def test_engine_kernel_and_reference_backends_agree(small_graph):
    g = small_graph
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=16, f_time=16, f_emb=16, m_r=10)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(3), cfg)
    ef = jnp.asarray(g.edge_feats)
    eng_k = StreamingEngine(EngineConfig(model=cfg, use_kernels=True),
                            params, ef)
    eng_r = StreamingEngine(EngineConfig(model=cfg, use_kernels=False),
                            params, ef)
    for batch in stream_mod.fixed_count(g, 50, window=slice(0, 150)):
        hk, _ = eng_k.process(batch)
        hr, _ = eng_r.process(batch)
        m = jnp.asarray(batch.valid)[:, None]
        np.testing.assert_allclose(np.asarray((hk - hr) * m), 0.0,
                                   atol=2e-5)
    np.testing.assert_allclose(np.asarray(eng_k.state.memory),
                               np.asarray(eng_r.state.memory), atol=2e-5)


# ---------------------------------------------------------------------------
# fused kernel tier (the single-pass step launch)
# ---------------------------------------------------------------------------


def test_fused_tier_resolution_and_describe(small_graph):
    """``use_kernels="fused"`` selects the single-pass step for SAT+LUT
    variants and degrades to the staged program — same lane id — for
    variants the fused kernel does not cover (the teacher)."""
    g = small_graph
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=8, f_time=8, f_emb=8, m_r=10)
    d = pl.build_pipeline("sat+lut+np4", use_kernels="fused",
                          **dims).describe()
    assert d["tier"] == "fused"
    assert d["fused_step"] == "step:single-pass-pallas"
    t_fused = pl.build_pipeline("teacher", use_kernels="fused", **dims)
    t_staged = pl.build_pipeline("teacher", use_kernels=True, **dims)
    assert t_fused.tier == "staged"
    assert t_fused.stages.fused is None
    assert t_fused.stages.variant_id == t_staged.stages.variant_id
    # legacy booleans keep resolving to their tiers
    assert pl.build_pipeline("sat+lut+np4", use_kernels=False,
                             **dims).tier == "ref"
    with pytest.raises(ValueError):
        pl.build_pipeline("sat+lut+np4", use_kernels="warp", **dims)


def test_engine_fused_and_staged_backends_agree(small_graph):
    """A fused-tier engine reproduces the staged-tier trajectory within
    the kernel tolerances over a multi-batch stream (embeddings AND the
    committed vertex state)."""
    g = small_graph
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=16, f_time=16, f_emb=16, m_r=10)
    cfg = pl.variant_config("sat+lut+np4", **dims)
    params = tgn.init_params(jax.random.key(5), cfg)
    ef = jnp.asarray(g.edge_feats)
    eng_f = StreamingEngine(EngineConfig(model=cfg, use_kernels="fused"),
                            params, ef)
    eng_s = StreamingEngine(EngineConfig(model=cfg, use_kernels=True),
                            params, ef)
    assert eng_f.describe()["tier"] == "fused"
    for batch in stream_mod.fixed_count(g, 50, window=slice(0, 150)):
        hf, _ = eng_f.process(batch)
        hs, _ = eng_s.process(batch)
        m = jnp.asarray(batch.valid)[:, None]
        np.testing.assert_allclose(np.asarray((hf - hs) * m), 0.0,
                                   atol=2e-5)
    np.testing.assert_allclose(np.asarray(eng_f.state.memory),
                               np.asarray(eng_s.state.memory), atol=2e-5)
