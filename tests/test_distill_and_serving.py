"""KD loss properties, AP metric, LM generation, positional KV pruning."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import distill
from repro.models import layers as L
from repro.serving import lm_serve


def test_kd_loss_zero_when_matched():
    logits = jnp.asarray([[1.0, 2.0, 3.0], [0.0, -1.0, 2.0]])
    valid = jnp.ones((2, 3), bool)
    l_same = distill.attn_distill_loss(logits, logits, valid)
    # CE(p, p) = H(p) > 0; but the GRADIENT wrt student at match is zero
    g = jax.grad(lambda s: distill.attn_distill_loss(s, logits, valid))(
        logits)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-6)
    # moving the student away increases the loss
    l_off = distill.attn_distill_loss(logits + jnp.asarray([[1., 0., -1.]]),
                                      logits, valid)
    assert float(l_off) > float(l_same)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000))
def test_kd_loss_masks_invalid(seed):
    rng = np.random.RandomState(seed)
    s = jnp.asarray(rng.randn(4, 6), jnp.float32)
    t = jnp.asarray(rng.randn(4, 6), jnp.float32)
    valid = jnp.asarray(rng.rand(4, 6) > 0.4)
    base = distill.attn_distill_loss(s, t, valid)
    # perturbing INVALID slots changes nothing
    noise = jnp.where(valid, 0.0, 100.0 * rng.randn(4, 6).astype(np.float32))
    pert = distill.attn_distill_loss(s + noise, t + noise, valid)
    np.testing.assert_allclose(float(base), float(pert), rtol=1e-5)


def test_average_precision_perfect_and_random():
    pos = jnp.asarray([3.0, 2.5, 2.0])
    neg = jnp.asarray([-1.0, -2.0, 0.0])
    assert float(distill.average_precision(pos, neg)) == 1.0
    # fully inverted ordering gives low AP
    ap_bad = float(distill.average_precision(neg, pos))
    assert ap_bad < 0.7


def test_generate_greedy_deterministic():
    from repro import configs
    from repro.models import lm_common
    cfg = configs.get("granite_3_8b").smoke_config()
    params = lm_common.init_params(jax.random.key(0), cfg)
    prompts = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    out1 = lm_serve.generate(params, cfg, prompts,
                             lm_serve.ServeConfig(max_new_tokens=4))
    out2 = lm_serve.generate(params, cfg, prompts,
                             lm_serve.ServeConfig(max_new_tokens=4))
    np.testing.assert_array_equal(np.asarray(out1["tokens"]),
                                  np.asarray(out2["tokens"]))
    assert out1["tokens"].shape == (1, 8)


def test_positional_kv_prune_full_keep_matches_exact():
    """keep == cache length -> identical to unpruned decode attention."""
    cfg = L.AttnCfg(d_model=32, n_heads=4, n_kv_heads=2, d_head=8)
    p = L.init_attention(jax.random.key(0), cfg)
    prune_p = lm_serve.init_kv_prune(cfg.n_kv_heads)
    B, S = 2, 24
    x = jax.random.normal(jax.random.key(1), (B, S, 32), jnp.float32)
    c1 = L.init_kv_cache(B, S, cfg, dtype=jnp.float32)
    c2 = L.init_kv_cache(B, S, cfg, dtype=jnp.float32)
    for t in range(S):
        o1, c1 = L.decode_attention(p, cfg, x[:, t:t + 1], c1)
        o2, c2 = lm_serve.pruned_decode_attention(p, cfg, x[:, t:t + 1], c2,
                                                  prune_p, keep=S)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-5)


def test_positional_kv_prune_selects_recent():
    """With the default decreasing-in-age scores, kept set = most recent k —
    the SAT prune-before-fetch dataflow at decode."""
    prune_p = lm_serve.init_kv_prune(2)
    k_pos = jnp.asarray([0, 1, 2, 3, 4, -1, -1, -1], jnp.int32)
    now = jnp.asarray(4)
    scores = lm_serve.kv_prune_scores(prune_p, k_pos, now, 2)
    _, idx = jax.lax.top_k(scores[0], 3)
    assert set(np.asarray(idx).tolist()) == {2, 3, 4}


def test_compression_ef_residual_property():
    """error feedback: g_hat + r_new == g + r_old exactly."""
    from repro.distributed import compression as CP
    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(40, 7), jnp.float32)}
    r = {"w": jnp.asarray(rng.randn(40, 7), jnp.float32) * 0.1}
    g_hat, r_new = CP.ef_int8_roundtrip(g, r)
    lhs = np.asarray(g_hat["w"]) + np.asarray(r_new["w"])
    rhs = np.asarray(g["w"]) + np.asarray(r["w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-6)
