"""Observability layer unit tests (src/repro/obs).

Pure-host tests: metric math (streaming histogram quantiles vs a sorted
list, merge associativity, the defined empty case), registry semantics
(get-or-create, one-type-per-name, atomic snapshot), tracer sampling +
Chrome/Perfetto export shape, and SLO burn arithmetic.
"""
from __future__ import annotations

import json

import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       RoundTracer, SLOTracker, Span)


# --------------------------------------------------------------- metrics
def test_counter_monotonic():
    c = Counter("x")
    c.inc()
    c.inc(4)
    assert c.snapshot() == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    c.reset()
    assert c.snapshot() == 0


def test_histogram_empty_is_defined():
    h = Histogram("t")
    assert h.count == 0
    assert h.mean() is None
    assert h.quantile(0.5) is None
    assert h.quantile(0.99) is None
    snap = h.snapshot()
    assert snap["count"] == 0 and snap["p99"] is None


def test_histogram_constant_samples_exact():
    # the property the frontend latency test relies on: a histogram of
    # identical values reports that value exactly at every quantile
    # (midpoint clamped to [vmin, vmax])
    h = Histogram("t")
    for _ in range(7):
        h.record(0.011)
    assert h.quantile(0.50) == pytest.approx(0.011)
    assert h.quantile(0.99) == pytest.approx(0.011)
    assert h.mean() == pytest.approx(0.011)
    assert h.count == 7 and h.vmin == h.vmax == 0.011


def test_histogram_quantile_vs_sorted_list():
    # same rank convention as the sorted-list lat[int(q*len)] paths it
    # replaced; value within one bucket ratio (10**(1/32) ~ 7.5%)
    xs = [1e-3 * 1.09 ** i for i in range(120)]
    h = Histogram("t")
    for x in xs:
        h.record(x)
    s = sorted(xs)
    for q in (0.10, 0.50, 0.90, 0.99):
        exact = s[min(len(s) - 1, int(q * len(s)))]
        assert h.quantile(q) == pytest.approx(exact, rel=0.08)


def test_histogram_out_of_range_clamps_to_observed():
    h = Histogram("t")
    h.record(1e-12)                 # below LO -> underflow bucket
    h.record(1e9)                   # above HI -> overflow bucket
    assert h.count == 2
    assert h.quantile(0.0) == pytest.approx(1e-12)    # clamped to vmin
    assert h.quantile(0.99) == pytest.approx(1e9)     # clamped to vmax


def test_histogram_merge_matches_combined():
    a, b, both = Histogram("a"), Histogram("b"), Histogram("ab")
    for i, x in enumerate(0.001 * (1 + i) for i in range(50)):
        (a if i % 2 else b).record(x)
        both.record(x)
    a.merge(b)
    assert a.count == both.count
    assert a.total == pytest.approx(both.total)
    assert a.vmin == both.vmin and a.vmax == both.vmax
    for q in (0.5, 0.9):
        assert a.quantile(q) == pytest.approx(both.quantile(q))


def test_histogram_weighted_record_and_reset():
    h = Histogram("t")
    h.record(0.5, n=10)
    assert h.count == 10 and h.total == pytest.approx(5.0)
    h.reset()
    assert h.count == 0 and h.mean() is None


def test_registry_get_or_create_and_type_binding():
    obs = MetricsRegistry()
    assert obs.counter("a") is obs.counter("a")
    obs.counter("a").inc(3)
    obs.gauge("g").set(7)
    obs.histogram("h").record(0.25)
    with pytest.raises(TypeError):
        obs.gauge("a")              # "a" is bound to Counter
    snap = obs.snapshot()
    assert snap["a"] == 3 and snap["g"] == 7
    assert snap["h"]["count"] == 1
    assert obs.snapshot(prefix="a") == {"a": 3}


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c").inc(2)
    b.counter("c").inc(5)
    b.gauge("g").set(9)
    b.histogram("h").record(1.0)
    a.merge(b)
    assert a.counter("c").value == 7
    assert a.gauge("g").value == 9
    assert a.histogram("h").count == 1


# ---------------------------------------------------------------- tracer
class _FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def test_tracer_sampling_cadence():
    tr = RoundTracer(sample_every=4)
    hits = [tr.sample_round() for _ in range(9)]
    assert hits == [True, False, False, False, True,
                    False, False, False, True]
    assert tr.rounds_seen == 9 and tr.rounds_sampled == 3


def test_tracer_would_sample_peeks_without_advancing():
    tr = RoundTracer(sample_every=2)
    assert tr.would_sample() and tr.would_sample()   # no state change
    assert tr.rounds_seen == 0
    assert tr.sample_round() is True
    assert tr.would_sample() is False


def test_tracer_spans_and_bound():
    clk = _FakeClock()
    tr = RoundTracer(clock=clk, max_spans=2)
    with tr.span("stage", cat="host", rows=3):
        clk.t += 0.5
    tr.add("launch", 100.5, 100.6, cat="host")
    tr.add("overflow", 0, 1)
    assert [s.name for s in tr.spans] == ["stage", "launch"]
    assert tr.spans[0].dur == pytest.approx(0.5)
    assert tr.spans[0].args == {"rows": 3}
    assert tr.dropped == 1
    summ = tr.summary()
    assert summ["spans"] == 2 and summ["dropped"] == 1
    assert summ["by_name"]["stage"]["count"] == 1


def test_tracer_chrome_export(tmp_path):
    tr = RoundTracer(clock=_FakeClock())
    tr.add("ingest", 1.0, 1.01, cat="frontend", events=4)
    tr.add("stage", 1.01, 1.02, cat="host")
    tr.add("drain", 1.02, 1.05, cat="device")
    doc = tr.to_chrome()
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert [e["name"] for e in xs] == ["ingest", "stage", "drain"]
    assert xs[0]["ts"] == pytest.approx(1.0e6)       # microseconds
    assert xs[0]["dur"] == pytest.approx(0.01e6)
    # categories land on distinct named tracks
    assert len({e["tid"] for e in xs}) == 3
    assert {m["args"]["name"] for m in metas} >= {"frontend", "host",
                                                  "device"}
    path = tmp_path / "trace.json"
    tr.write_chrome(str(path))
    assert json.loads(path.read_text())["traceEvents"]
    jl = tmp_path / "trace.jsonl"
    tr.write_jsonl(str(jl))
    lines = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert len(lines) == 3 and lines[0]["name"] == "ingest"
    assert lines[0]["events"] == 4


def test_span_as_dict():
    s = Span("launch", "host", 2.0, 2.5, {"lanes": 2})
    assert s.dur == pytest.approx(0.5)
    assert s.as_dict() == {"name": "launch", "cat": "host", "t0": 2.0,
                           "t1": 2.5, "dur": 0.5, "lanes": 2}


# ------------------------------------------------------------------- slo
def test_slo_burn_math():
    slo = SLOTracker(target_ms=10.0, objective=0.9)
    for _ in range(8):
        slo.observe("t0", 0.005)            # within target
    slo.observe("t0", 0.020, n=2)           # 2 violations
    t = slo.tenant("t0")
    assert t["events"] == 10 and t["violations"] == 2
    assert t["error_rate"] == pytest.approx(0.2)
    # 20% errors against a 10% budget: burning 2x
    assert t["burn_rate"] == pytest.approx(2.0)
    assert t["budget_remaining"] == 0.0
    assert t["observed_p99_ms"] == pytest.approx(20.0, rel=0.08)


def test_slo_zero_observation_tenant_is_full_dict():
    slo = SLOTracker(target_ms=25.0, objective=0.99, source="event")
    t = slo.tenant("never-seen")
    assert t["events"] == 0 and t["violations"] == 0
    assert t["burn_rate"] == 0.0 and t["budget_remaining"] == 1.0
    assert t["observed_p99_ms"] is None
    assert t["source"] == "event"
    assert "never-seen" not in slo.snapshot()   # snapshot = observed only


def test_slo_validation():
    with pytest.raises(ValueError):
        SLOTracker(target_ms=0.0)
    with pytest.raises(ValueError):
        SLOTracker(target_ms=5.0, objective=1.0)
