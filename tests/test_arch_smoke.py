"""Per-architecture smoke tests: every assigned arch instantiates a reduced
config, runs one train step (finite loss + grads, correct shapes) and one
decode step on CPU. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm_common

ARCHS = configs.all_archs()


def _batch_for(cfg, B=2, S=32):
    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
    }
    fam = lm_common.family_of(cfg)
    if fam == "whisper":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.n_frames, cfg.d_model), jnp.float32)
    if fam == "vision_lm":
        batch["vision"] = jnp.asarray(
            rng.randn(B, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    spec = configs.get(arch)
    cfg = spec.smoke_config()
    params = lm_common.init_params(jax.random.key(0), cfg)
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: lm_common.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    # loss near ln(vocab) at init (random tokens)
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.0
    gsq = sum(float(jnp.sum(g * g)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsq) and gsq > 0
    # grads congruent to params
    assert jax.tree.structure(grads) == jax.tree.structure(params)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    spec = configs.get(arch)
    cfg = spec.smoke_config()
    params = lm_common.init_params(jax.random.key(0), cfg)
    B, S = 2, 16
    fam = lm_common.family_of(cfg)
    mod = lm_common.FAMILIES[fam]
    caches = mod.init_caches(cfg, B, S, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_caches = lm_common.decode_fn(
        params, cfg, {"token": tok, "caches": caches})
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full config carries the exact assigned dimensions."""
    cfg = configs.get(arch).config()
    expected = {
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262144),
        "mistral_nemo_12b": (40, 5120, 32, 8, 14336, 131072),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "qwen3_8b": (36, 4096, 32, 8, 12288, 151936),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "mamba2_130m": (24, 768, None, None, 0, 50280),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "llama32_vision_11b": (40, 4096, 32, 8, 14336, 128256),
    }[arch]
    nl, d, h, kv, ff, vocab = expected
    assert cfg.n_layers == nl and cfg.d_model == d and cfg.vocab == vocab
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff:
        assert cfg.d_ff == ff


@pytest.mark.parametrize("arch,lo,hi", [
    ("gemma3_12b", 11e9, 14e9), ("mistral_nemo_12b", 11e9, 13.5e9),
    ("granite_3_8b", 7.5e9, 9e9), ("qwen3_8b", 7.5e9, 9e9),
    ("dbrx_132b", 125e9, 140e9), ("grok_1_314b", 300e9, 330e9),
    ("mamba2_130m", 0.1e9, 0.2e9), ("whisper_tiny", 25e6, 60e6),
    ("recurrentgemma_9b", 8e9, 11e9), ("llama32_vision_11b", 9e9, 12e9),
])
def test_param_counts_match_nameplate(arch, lo, hi):
    cfg = configs.get(arch).config()
    assert lo <= cfg.n_params <= hi, f"{arch}: {cfg.n_params/1e9:.2f}B"


def test_long_context_support_flags():
    assert lm_common.supports_long_context(configs.get("mamba2_130m").config())
    assert lm_common.supports_long_context(
        configs.get("recurrentgemma_9b").config())
    assert lm_common.supports_long_context(configs.get("gemma3_12b").config())
    for a in ("mistral_nemo_12b", "granite_3_8b", "qwen3_8b", "dbrx_132b",
              "grok_1_314b", "whisper_tiny", "llama32_vision_11b"):
        assert not lm_common.supports_long_context(configs.get(a).config())
