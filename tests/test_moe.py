"""MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import moe as M


def test_dispatch_equals_dense_with_ample_capacity():
    p = M.init_moe(jax.random.key(0), 16, 32, 4)
    x = jax.random.normal(jax.random.key(1), (64, 16), jnp.float32)
    got = M.moe_ffn(p, x, 2, capacity_factor=8.0)
    want = M.moe_ffn_ref(p, x, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000))
def test_build_dispatch_invariants(seed):
    rng = np.random.RandomState(seed)
    T, k, E, C = 24, 2, 4, 8
    idx = jnp.asarray(rng.randint(0, E, (T, k)), jnp.int32)
    dispatch, keep, rank = M.build_dispatch(idx, E, C)
    d = np.asarray(dispatch)
    # every kept (token, slot) assignment appears exactly once
    kept = np.asarray(keep)
    rk = np.asarray(rank)
    for t in range(T):
        for j in range(k):
            e = int(idx[t, j])
            if kept[t, j]:
                assert d[e, rk[t, j]] == t
    # ranks within an expert are exactly the arrival order
    flat_e = np.asarray(idx).reshape(-1)
    seen = {e: 0 for e in range(E)}
    for i, e in enumerate(flat_e):
        assert rk.reshape(-1)[i] == seen[e]
        seen[e] += 1


def test_capacity_drop_reduces_contribution():
    """With capacity 0... tokens beyond capacity contribute nothing."""
    p = M.init_moe(jax.random.key(2), 8, 16, 2)
    x = jax.random.normal(jax.random.key(3), (256, 8), jnp.float32)
    # tiny capacity forces drops; output should differ from dense
    tight = M.moe_ffn(p, x, 1, capacity_factor=0.25)
    dense = M.moe_ffn_ref(p, x, 1)
    assert float(jnp.max(jnp.abs(tight - dense))) > 1e-4


def test_route_probs_normalized():
    p = M.init_moe(jax.random.key(4), 8, 16, 4)
    x = jax.random.normal(jax.random.key(5), (32, 8), jnp.float32)
    _, probs = M.route(p["router"], x, 2)
    np.testing.assert_allclose(np.asarray(jnp.sum(probs, -1)), 1.0,
                               rtol=1e-5)
