"""Checkpointing: atomicity, corruption detection, elastic resharding,
restart determinism."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as C
from repro.distributed import sharding as shd


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "n": {"b": jnp.ones((5,), jnp.int32),
                  "c": jnp.zeros((2, 2), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    C.save(d, 3, _tree(), meta={"x": 1})
    out, meta = C.restore(d, _tree())
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta == {"x": 1}


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        C.save(d, s, _tree(), keep=3)
    assert C.latest_step(d) == 5
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 3


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    final = C.save(d, 1, _tree())
    # flip a byte in one payload
    target = os.path.join(final, "arr_00000.npy")
    data = bytearray(open(target, "rb").read())
    data[-1] ^= 0xFF
    open(target, "wb").write(bytes(data))
    with pytest.raises(IOError):
        C.restore(d, _tree())


def test_tmp_dirs_ignored(tmp_path):
    d = str(tmp_path)
    C.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert C.latest_step(d) == 1
    C.save(d, 3, _tree())  # gc removes the stale tmp
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ac = C.AsyncCheckpointer(d)
    ac.save(7, _tree())
    ac.wait()
    out, _ = C.restore(d, _tree())
    assert C.latest_step(d) == 7


def test_restore_with_shardings_host_mesh(tmp_path):
    """Elastic path: restore with explicit NamedShardings (1-device mesh)."""
    from repro.launch.mesh import make_host_mesh
    d = str(tmp_path)
    tree = {"embed": jnp.ones((32, 8)), "scale": jnp.ones((8,))}
    C.save(d, 1, tree)
    mesh = make_host_mesh()
    specs = shd.param_specs(tree, "tp", n_model=1)
    shardings = shd.make_shardings(mesh, specs)
    out, _ = C.restore(d, tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["embed"]),
                                  np.asarray(tree["embed"]))


def test_lm_restart_determinism(tmp_path):
    """Kill-and-resume == uninterrupted run (bitwise on params)."""
    from repro.models import lm_common, transformer as T
    from repro.training import optim as O, train_loop as TL
    from repro.training.lr_schedule import ScheduleConfig

    cfg = T.LMConfig(arch="t", n_layers=2, d_model=32, n_heads=2,
                     n_kv_heads=2, d_head=16, d_ff=64, vocab=64,
                     dtype="float32", q_block=16, k_block=16, loss_chunk=16)
    tcfg = TL.TrainConfig(optim=O.OptimConfig(lr=1e-3),
                          sched=ScheduleConfig(warmup_steps=2,
                                               total_steps=10))
    step_fn = jax.jit(TL.make_train_step(
        lambda p, b: lm_common.loss_fn(p, cfg, b), tcfg))

    def batch_at(i):
        rng = np.random.RandomState(100 + i)
        t = rng.randint(0, 64, (2, 32)).astype(np.int32)
        return {"tokens": jnp.asarray(t),
                "targets": jnp.asarray(np.roll(t, -1, 1))}

    def run(n_steps, params, opt):
        for i in range(10 - n_steps, 10):
            params, opt, _ = step_fn(params, opt, batch_at(i), i)
        return params, opt

    p0 = lm_common.init_params(jax.random.key(0), cfg)
    o0 = TL.init_train_state(tcfg, p0)

    # uninterrupted
    p_full, o_full = p0, o0
    for i in range(10):
        p_full, o_full, _ = step_fn(p_full, o_full, batch_at(i), i)

    # interrupted at step 5 + resumed from checkpoint
    p, o = p0, o0
    for i in range(5):
        p, o, _ = step_fn(p, o, batch_at(i), i)
    C.save(str(tmp_path), 5, {"params": p, "opt": o})
    tree, _ = C.restore(str(tmp_path), {"params": p0, "opt": o0})
    p, o = tree["params"], tree["opt"]
    for i in range(5, 10):
        p, o, _ = step_fn(p, o, batch_at(i), i)

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
