"""Checkpointing: atomicity, corruption detection, elastic resharding,
restart determinism."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as C
from repro.distributed import sharding as shd


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "n": {"b": jnp.ones((5,), jnp.int32),
                  "c": jnp.zeros((2, 2), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    C.save(d, 3, _tree(), meta={"x": 1})
    out, meta = C.restore(d, _tree())
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta == {"x": 1}


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        C.save(d, s, _tree(), keep=3)
    assert C.latest_step(d) == 5
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 3


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    final = C.save(d, 1, _tree())
    # flip a byte in one payload
    target = os.path.join(final, "arr_00000.npy")
    data = bytearray(open(target, "rb").read())
    data[-1] ^= 0xFF
    open(target, "wb").write(bytes(data))
    with pytest.raises(IOError):
        C.restore(d, _tree())


def test_gc_never_collects_the_step_just_written(tmp_path):
    """A writer whose step counter lags the directory's history (e.g. a
    restarted serving process) must not have its fresh checkpoint GC'd the
    instant it commits."""
    d = str(tmp_path)
    for s in (3, 4, 5):
        C.save(d, s, _tree(), keep=3)
    final = C.save(d, 2, _tree(), keep=3)   # sorts below the keep window
    assert os.path.isdir(final)
    out, _ = C.restore(d, _tree(), step=2)  # and is restorable
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(_tree()["a"]))
    assert C.latest_step(d) == 5            # history still wins "latest"


def test_tmp_dirs_ignored(tmp_path):
    d = str(tmp_path)
    C.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert C.latest_step(d) == 1
    C.save(d, 3, _tree())  # gc removes the stale tmp
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ac = C.AsyncCheckpointer(d)
    ac.save(7, _tree())
    ac.wait()
    out, _ = C.restore(d, _tree())
    assert C.latest_step(d) == 7


def test_restore_with_shardings_host_mesh(tmp_path):
    """Elastic path: restore with explicit NamedShardings (1-device mesh)."""
    from repro.launch.mesh import make_host_mesh
    d = str(tmp_path)
    tree = {"embed": jnp.ones((32, 8)), "scale": jnp.ones((8,))}
    C.save(d, 1, tree)
    mesh = make_host_mesh()
    specs = shd.param_specs(tree, "tp", n_model=1)
    shardings = shd.make_shardings(mesh, specs)
    out, _ = C.restore(d, tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["embed"]),
                                  np.asarray(tree["embed"]))


# ---------------------------------------------------------------------------
# VertexState (tenant snapshot) round-trips — serving/cluster.py over this
# module; the multi-device restore paths are in tests/test_cluster.py
# ---------------------------------------------------------------------------


def _live_tenant(f_mem=8, n_edges=300):
    from repro.core import pipeline as pl, tgn
    from repro.data import stream as stream_mod, temporal_graph as tgd
    from repro.serving.session import SessionManager
    g = tgd.wikipedia_like(n_edges=n_edges)
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=f_mem,
                            f_time=f_mem, f_emb=f_mem, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    tid = mgr.add_tenant()
    for b in list(stream_mod.fixed_count(g, 50))[:3]:
        mgr.step({tid: b})
    return mgr, tid, cfg, params, g


def test_vertex_state_snapshot_roundtrip_crc(tmp_path):
    """A live tenant's VertexState survives snapshot_tenant/restore_tenant
    bitwise; every leaf is crc32-verified and a flipped byte is caught."""
    from repro.serving import cluster as cl
    mgr, tid, cfg, params, g = _live_tenant()
    root = str(tmp_path)
    final = cl.snapshot_tenant(mgr, tid, root, step=3)
    meta = cl.snapshot_meta(root, tid)
    assert meta["variant"] == "sat+lut+np4" and meta["tenant"] == tid
    fresh = cl.SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    revived = cl.restore_tenant(fresh, root, tid, name="revived")
    a, b = mgr.state_of(tid), fresh.state_of(revived)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)
    # silent corruption of one payload -> IOError at restore
    target = os.path.join(final, "arr_00000.npy")
    data = bytearray(open(target, "rb").read())
    data[-1] ^= 0xFF
    open(target, "wb").write(bytes(data))
    broke = cl.SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    with pytest.raises(IOError):
        cl.restore_tenant(broke, root, tid)


def test_vertex_state_crash_mid_write_recovery(tmp_path):
    """A crash mid-snapshot leaves only a .tmp dir: the previous snapshot
    stays the restorable latest, and the next save garbage-collects the
    torn one."""
    from repro.serving import cluster as cl
    mgr, tid, _cfg, _params, _g = _live_tenant()
    root = str(tmp_path)
    cl.snapshot_tenant(mgr, tid, root, step=1)
    torn = os.path.join(root, tid, "step_00000002.tmp")
    os.makedirs(torn)
    open(os.path.join(torn, "arr_00000.npy"), "wb").write(b"partial")
    assert C.latest_step(os.path.join(root, tid)) == 1
    assert cl.list_snapshots(root) == {tid: 1}
    cl.snapshot_tenant(mgr, tid, root, step=2)
    assert not os.path.exists(torn)
    assert C.latest_step(os.path.join(root, tid)) == 2


def test_vertex_state_restore_with_mesh_shardings(tmp_path):
    """The elastic path at the checkpoint layer: a snapshot holds full
    logical arrays, so a restore may place them with whatever
    NamedShardings a (differently shaped) target mesh prescribes."""
    from repro.core import mailbox
    from repro.distributed import tgn_sharding as tsh
    from repro.serving import cluster as cl
    mgr, tid, _cfg, _params, _g = _live_tenant()
    root = str(tmp_path)
    cl.snapshot_tenant(mgr, tid, root, step=1)
    st = mgr.state_of(tid)
    mesh = tsh.make_tenant_mesh("tenant=1,vertex=1")
    shardings = tsh.make_shardings(
        mesh, tsh.state_specs(mesh, st, stacked=False))
    out, meta = C.restore(os.path.join(root, tid), st._asdict(),
                          shardings=shardings._asdict())
    restored = mailbox.VertexState(**out)
    for f in st._fields:
        np.testing.assert_array_equal(np.asarray(getattr(st, f)),
                                      np.asarray(getattr(restored, f)),
                                      err_msg=f)
    assert restored.memory.sharding.mesh.axis_names == ("tenant", "vertex")


def test_lm_restart_determinism(tmp_path):
    """Kill-and-resume == uninterrupted run (bitwise on params)."""
    from repro.models import lm_common, transformer as T
    from repro.training import optim as O, train_loop as TL
    from repro.training.lr_schedule import ScheduleConfig

    cfg = T.LMConfig(arch="t", n_layers=2, d_model=32, n_heads=2,
                     n_kv_heads=2, d_head=16, d_ff=64, vocab=64,
                     dtype="float32", q_block=16, k_block=16, loss_chunk=16)
    tcfg = TL.TrainConfig(optim=O.OptimConfig(lr=1e-3),
                          sched=ScheduleConfig(warmup_steps=2,
                                               total_steps=10))
    step_fn = jax.jit(TL.make_train_step(
        lambda p, b: lm_common.loss_fn(p, cfg, b), tcfg))

    def batch_at(i):
        rng = np.random.RandomState(100 + i)
        t = rng.randint(0, 64, (2, 32)).astype(np.int32)
        return {"tokens": jnp.asarray(t),
                "targets": jnp.asarray(np.roll(t, -1, 1))}

    def run(n_steps, params, opt):
        for i in range(10 - n_steps, 10):
            params, opt, _ = step_fn(params, opt, batch_at(i), i)
        return params, opt

    p0 = lm_common.init_params(jax.random.key(0), cfg)
    o0 = TL.init_train_state(tcfg, p0)

    # uninterrupted
    p_full, o_full = p0, o0
    for i in range(10):
        p_full, o_full, _ = step_fn(p_full, o_full, batch_at(i), i)

    # interrupted at step 5 + resumed from checkpoint
    p, o = p0, o0
    for i in range(5):
        p, o, _ = step_fn(p, o, batch_at(i), i)
    C.save(str(tmp_path), 5, {"params": p, "opt": o})
    tree, _ = C.restore(str(tmp_path), {"params": p0, "opt": o0})
    p, o = tree["params"], tree["opt"]
    for i in range(5, 10):
        p, o, _ = step_fn(p, o, batch_at(i), i)

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
