"""Checkpointing: atomicity, corruption detection, elastic resharding,
restart determinism."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as C
from repro.distributed import sharding as shd


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "n": {"b": jnp.ones((5,), jnp.int32),
                  "c": jnp.zeros((2, 2), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    C.save(d, 3, _tree(), meta={"x": 1})
    out, meta = C.restore(d, _tree())
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta == {"x": 1}


def test_latest_and_gc(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        C.save(d, s, _tree(), keep=3)
    assert C.latest_step(d) == 5
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(kept) == 3


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    final = C.save(d, 1, _tree())
    # flip a byte in one payload
    target = os.path.join(final, "arr_00000.npy")
    data = bytearray(open(target, "rb").read())
    data[-1] ^= 0xFF
    open(target, "wb").write(bytes(data))
    with pytest.raises(IOError):
        C.restore(d, _tree())


def _corrupt_payload(step_dir):
    target = os.path.join(step_dir, "arr_00000.npy")
    data = bytearray(open(target, "rb").read())
    data[-1] ^= 0xFF
    open(target, "wb").write(bytes(data))


def test_restore_valid_falls_back_past_corrupt_latest(tmp_path):
    """``restore_valid`` skips a corrupt newest step (with a warning) and
    returns the newest PRIOR valid one — a torn final snapshot costs one
    step of history, never the restore."""
    d = str(tmp_path)
    C.save(d, 1, _tree(), meta={"x": 1})
    _corrupt_payload(C.save(d, 2, _tree(), meta={"x": 2}))
    with pytest.warns(UserWarning, match="step 2 is corrupt"):
        out, meta, step = C.restore_valid(d, _tree())
    assert step == 1 and meta == {"x": 1}
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # a mangled manifest is also just a skipped step, not a crash
    m = os.path.join(d, "step_00000001", "manifest.json")
    open(m, "w").write("{truncated")
    C.save(d, 0, _tree(), meta={"x": 0})
    with pytest.warns(UserWarning):
        _, meta, step = C.restore_valid(d, _tree())
    assert step == 0 and meta == {"x": 0}


def test_restore_valid_raises_when_every_step_is_corrupt(tmp_path):
    """A fallback never invents a restorable state: all-corrupt history
    re-raises the NEWEST step's error; an empty root is FileNotFound."""
    d = str(tmp_path)
    with pytest.raises(FileNotFoundError):
        C.restore_valid(d, _tree())
    for s in (1, 2):
        _corrupt_payload(C.save(d, s, _tree()))
    with pytest.warns(UserWarning), pytest.raises(C.CORRUPTION_ERRORS):
        C.restore_valid(d, _tree())


def test_gc_never_collects_the_step_just_written(tmp_path):
    """A writer whose step counter lags the directory's history (e.g. a
    restarted serving process) must not have its fresh checkpoint GC'd the
    instant it commits."""
    d = str(tmp_path)
    for s in (3, 4, 5):
        C.save(d, s, _tree(), keep=3)
    final = C.save(d, 2, _tree(), keep=3)   # sorts below the keep window
    assert os.path.isdir(final)
    out, _ = C.restore(d, _tree(), step=2)  # and is restorable
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(_tree()["a"]))
    assert C.latest_step(d) == 5            # history still wins "latest"


def test_tmp_dirs_ignored(tmp_path):
    d = str(tmp_path)
    C.save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert C.latest_step(d) == 1
    C.save(d, 3, _tree())  # gc removes the stale tmp
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ac = C.AsyncCheckpointer(d)
    ac.save(7, _tree())
    ac.wait()
    out, _ = C.restore(d, _tree())
    assert C.latest_step(d) == 7


def test_restore_with_shardings_host_mesh(tmp_path):
    """Elastic path: restore with explicit NamedShardings (1-device mesh)."""
    from repro.launch.mesh import make_host_mesh
    d = str(tmp_path)
    tree = {"embed": jnp.ones((32, 8)), "scale": jnp.ones((8,))}
    C.save(d, 1, tree)
    mesh = make_host_mesh()
    specs = shd.param_specs(tree, "tp", n_model=1)
    shardings = shd.make_shardings(mesh, specs)
    out, _ = C.restore(d, tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["embed"]),
                                  np.asarray(tree["embed"]))


# ---------------------------------------------------------------------------
# VertexState (tenant snapshot) round-trips — serving/cluster.py over this
# module; the multi-device restore paths are in tests/test_cluster.py
# ---------------------------------------------------------------------------


def _live_tenant(f_mem=8, n_edges=300):
    from repro.core import pipeline as pl, tgn
    from repro.data import stream as stream_mod, temporal_graph as tgd
    from repro.serving.session import SessionManager
    g = tgd.wikipedia_like(n_edges=n_edges)
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=f_mem,
                            f_time=f_mem, f_emb=f_mem, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    tid = mgr.add_tenant()
    for b in list(stream_mod.fixed_count(g, 50))[:3]:
        mgr.step({tid: b})
    return mgr, tid, cfg, params, g


def test_vertex_state_snapshot_roundtrip_crc(tmp_path):
    """A live tenant's VertexState survives snapshot_tenant/restore_tenant
    bitwise; every leaf is crc32-verified and a flipped byte is caught."""
    from repro.serving import cluster as cl
    mgr, tid, cfg, params, g = _live_tenant()
    root = str(tmp_path)
    final = cl.snapshot_tenant(mgr, tid, root, step=3)
    meta = cl.snapshot_meta(root, tid)
    assert meta["variant"] == "sat+lut+np4" and meta["tenant"] == tid
    fresh = cl.SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    revived = cl.restore_tenant(fresh, root, tid, name="revived")
    a, b = mgr.state_of(tid), fresh.state_of(revived)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)
    # silent corruption of one payload -> IOError at restore
    target = os.path.join(final, "arr_00000.npy")
    data = bytearray(open(target, "rb").read())
    data[-1] ^= 0xFF
    open(target, "wb").write(bytes(data))
    broke = cl.SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
    with pytest.raises(IOError):
        cl.restore_tenant(broke, root, tid)


def test_vertex_state_crash_mid_write_recovery(tmp_path):
    """A crash mid-snapshot leaves only a .tmp dir: the previous snapshot
    stays the restorable latest, and the next save garbage-collects the
    torn one."""
    from repro.serving import cluster as cl
    mgr, tid, _cfg, _params, _g = _live_tenant()
    root = str(tmp_path)
    cl.snapshot_tenant(mgr, tid, root, step=1)
    torn = os.path.join(root, tid, "step_00000002.tmp")
    os.makedirs(torn)
    open(os.path.join(torn, "arr_00000.npy"), "wb").write(b"partial")
    assert C.latest_step(os.path.join(root, tid)) == 1
    assert cl.list_snapshots(root) == {tid: 1}
    cl.snapshot_tenant(mgr, tid, root, step=2)
    assert not os.path.exists(torn)
    assert C.latest_step(os.path.join(root, tid)) == 2


def test_vertex_state_restore_with_mesh_shardings(tmp_path):
    """The elastic path at the checkpoint layer: a snapshot holds full
    logical arrays, so a restore may place them with whatever
    NamedShardings a (differently shaped) target mesh prescribes."""
    from repro.core import mailbox
    from repro.distributed import tgn_sharding as tsh
    from repro.serving import cluster as cl
    mgr, tid, _cfg, _params, _g = _live_tenant()
    root = str(tmp_path)
    cl.snapshot_tenant(mgr, tid, root, step=1)
    st = mgr.state_of(tid)
    mesh = tsh.make_tenant_mesh("tenant=1,vertex=1")
    shardings = tsh.make_shardings(
        mesh, tsh.state_specs(mesh, st, stacked=False))
    out, meta = C.restore(os.path.join(root, tid), st._asdict(),
                          shardings=shardings._asdict())
    restored = mailbox.VertexState(**out)
    for f in st._fields:
        np.testing.assert_array_equal(np.asarray(getattr(st, f)),
                                      np.asarray(getattr(restored, f)),
                                      err_msg=f)
    assert restored.memory.sharding.mesh.axis_names == ("tenant", "vertex")


def test_tree_digest_is_content_and_path_sensitive():
    """tree_digest — the identity snapshot manifests record for a param
    set — is stable across calls, and changes when any leaf's bytes OR
    any leaf's path change."""
    t = _tree()
    assert C.tree_digest(t) == C.tree_digest(t)
    assert len(C.tree_digest(t)) == 8            # crc32 hex
    bumped = {"a": t["a"].at[0, 0].add(1.0), "n": t["n"]}
    assert C.tree_digest(bumped) != C.tree_digest(t)
    # identical bytes under a different leaf path digest differently
    assert C.tree_digest({"x": t["a"]}) != C.tree_digest({"a": t["a"]})


def _student_lane(tmp_path, f_mem=8, n_edges=300):
    """A session whose DEFAULT weights differ from the student set one
    tenant serves on, stepped twice and snapshotted at step 2."""
    from repro.core import pipeline as pl, tgn
    from repro.data import stream as stream_mod, temporal_graph as tgd
    from repro.serving import cluster as cl
    from repro.serving.session import SessionManager
    g = tgd.wikipedia_like(n_edges=n_edges)
    cfg = pl.variant_config("sat+lut+np4", n_nodes=g.cfg.n_nodes,
                            n_edges=g.n_edges, f_edge=172, f_mem=f_mem,
                            f_time=f_mem, f_emb=f_mem, m_r=10)
    params = tgn.init_params(jax.random.key(0), cfg)
    student = tgn.init_params(jax.random.key(5), cfg)
    ef = jnp.asarray(g.edge_feats)
    feed = list(stream_mod.fixed_count(g, 40))[:4]
    mgr = SessionManager(params, ef, model=cfg)
    mgr.register_params("student-B", student)
    tid = mgr.add_tenant(params="student-B")
    for b in feed[:2]:
        mgr.step({tid: b})
    cl.snapshot_tenant(mgr, tid, str(tmp_path), step=2)
    return cl, mgr, tid, feed, dict(cfg=cfg, params=params,
                                    student=student, ef=ef)


def test_snapshot_binds_param_set_and_resumes_on_it(tmp_path):
    """The manifest records the param-set name + digest; a restore into a
    session whose default weights DIFFER refuses until the set is
    registered, then resumes on the recorded set and continues bitwise
    with the unsnapshotted original."""
    cl, mgr, tid, feed, env = _student_lane(tmp_path)
    root = str(tmp_path)
    meta = cl.snapshot_meta(root, tid)
    assert meta["param_set"] == "student-B"
    assert meta["params_digest"] == mgr.param_store.digest("student-B")

    fresh = cl.SessionManager(env["params"], env["ef"], model=env["cfg"])
    with pytest.raises(ValueError, match="has not registered"):
        cl.restore_tenant(fresh, root, tid)
    assert fresh.tenants == ()               # loud failure, nothing added
    fresh.register_params("student-B", env["student"])
    revived = cl.restore_tenant(fresh, root, tid, name="revived")
    assert fresh.cohort_of(revived).param_set == "student-B"
    for r, b in enumerate(feed[2:]):
        o1 = mgr.step({tid: b})[tid]
        o2 = fresh.step({revived: b})[revived]
        np.testing.assert_array_equal(np.asarray(o1.emb_src),
                                      np.asarray(o2.emb_src),
                                      err_msg=f"resumed round {r}")
    a, b = mgr.state_of(tid), fresh.state_of(revived)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)


def test_restore_rejects_digest_mismatch_unless_rebound(tmp_path):
    """Same param-set NAME, different bytes: the digest check fails loudly
    (the trajectory would silently continue under different weights);
    passing params= rebinds explicitly and skips the check."""
    import jax as _jax
    from repro.core import tgn
    cl, mgr, tid, _feed, env = _student_lane(tmp_path)
    root = str(tmp_path)
    fresh = cl.SessionManager(env["params"], env["ef"], model=env["cfg"])
    impostor = tgn.init_params(_jax.random.key(99), env["cfg"])
    fresh.register_params("student-B", impostor)   # same name, new bytes
    with pytest.raises(ValueError, match="digest"):
        cl.restore_tenant(fresh, root, tid)
    assert fresh.tenants == ()
    # explicit rebind: the operator takes responsibility for the weights
    revived = cl.restore_tenant(fresh, root, tid, params="default")
    assert fresh.cohort_of(revived).param_set == "default"


def test_restore_rejects_corrupted_manifest_digest(tmp_path):
    """A tampered/corrupted params_digest in the manifest is caught even
    when the registered weights are the right ones."""
    cl, mgr, tid, _feed, env = _student_lane(tmp_path)
    root = str(tmp_path)
    mpath = os.path.join(root, tid, "step_00000002", "manifest.json")
    manifest = json.load(open(mpath))
    manifest["meta"]["params_digest"] = "deadbeef"
    json.dump(manifest, open(mpath, "w"))
    fresh = cl.SessionManager(env["params"], env["ef"], model=env["cfg"])
    fresh.register_params("student-B", env["student"])
    with pytest.raises(ValueError, match="digest"):
        cl.restore_tenant(fresh, root, tid)
    assert fresh.tenants == ()


def test_lm_restart_determinism(tmp_path):
    """Kill-and-resume == uninterrupted run (bitwise on params)."""
    from repro.models import lm_common, transformer as T
    from repro.training import optim as O, train_loop as TL
    from repro.training.lr_schedule import ScheduleConfig

    cfg = T.LMConfig(arch="t", n_layers=2, d_model=32, n_heads=2,
                     n_kv_heads=2, d_head=16, d_ff=64, vocab=64,
                     dtype="float32", q_block=16, k_block=16, loss_chunk=16)
    tcfg = TL.TrainConfig(optim=O.OptimConfig(lr=1e-3),
                          sched=ScheduleConfig(warmup_steps=2,
                                               total_steps=10))
    step_fn = jax.jit(TL.make_train_step(
        lambda p, b: lm_common.loss_fn(p, cfg, b), tcfg))

    def batch_at(i):
        rng = np.random.RandomState(100 + i)
        t = rng.randint(0, 64, (2, 32)).astype(np.int32)
        return {"tokens": jnp.asarray(t),
                "targets": jnp.asarray(np.roll(t, -1, 1))}

    def run(n_steps, params, opt):
        for i in range(10 - n_steps, 10):
            params, opt, _ = step_fn(params, opt, batch_at(i), i)
        return params, opt

    p0 = lm_common.init_params(jax.random.key(0), cfg)
    o0 = TL.init_train_state(tcfg, p0)

    # uninterrupted
    p_full, o_full = p0, o0
    for i in range(10):
        p_full, o_full, _ = step_fn(p_full, o_full, batch_at(i), i)

    # interrupted at step 5 + resumed from checkpoint
    p, o = p0, o0
    for i in range(5):
        p, o, _ = step_fn(p, o, batch_at(i), i)
    C.save(str(tmp_path), 5, {"params": p, "opt": o})
    tree, _ = C.restore(str(tmp_path), {"params": p0, "opt": o0})
    p, o = tree["params"], tree["opt"]
    for i in range(5, 10):
        p, o, _ = step_fn(p, o, batch_at(i), i)

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
