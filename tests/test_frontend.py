"""Online serving front-end: deadline batching, backpressure, and LIVE
tenant admission. The acceptance criterion: attach AND detach a tenant
mid-stream and (a) the coalesced launch is never recompiled (the
relayout/trace counters hold) while (b) the surviving tenants'
trajectories stay bitwise-identical to the offline ``SessionManager``
driver replaying the same flushed batches."""
import asyncio
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline as pl, tgn
from repro.data import temporal_graph as tgd
from repro.serving.admission import AdmissionController, CapacityLadder
from repro.serving.frontend import (DeadlineBatcher, FrontendConfig,
                                    RetryAfter, ServingFrontend,
                                    serve_jsonl)
from repro.serving.session import SessionManager

BASE = "sat+lut+np4"
OTHER = "sat+lut+np4+reservoir"    # second cohort, same shared params


@pytest.fixture(scope="module")
def small_graph():
    return tgd.wikipedia_like(n_edges=500)


@pytest.fixture(scope="module")
def setup(small_graph):
    g = small_graph
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=16, f_time=16, f_emb=16, m_r=10)
    cfg = pl.variant_config(BASE, **dims)
    params = tgn.init_params(jax.random.key(0), cfg)
    return g, cfg, params, jnp.asarray(g.edge_feats)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _feed(fe, g, tids, i0, n):
    """Submit n consecutive graph edges to every tenant in tids."""
    for i in range(i0, i0 + n):
        for tid in tids:
            fe.submit(tid, int(g.src[i]), int(g.dst[i]), i,
                      float(g.ts[i]), int(g.dst[(i + 7) % g.n_edges]))


def _assert_state_equal(a, b, msg=""):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}:{f}")


# ---------------------------------------------------------------------------
# capacity ladder (pure policy)
# ---------------------------------------------------------------------------


def test_capacity_ladder_headroom():
    lad = CapacityLadder()
    assert lad.capacity_for(0) == 2       # prewarm still reserves a class
    assert lad.capacity_for(1) == 2
    assert lad.capacity_for(2) == 4       # 2 tenants + headroom 1 -> 4
    assert lad.capacity_for(4) == 8
    assert lad.capacity_for(64) == 128    # geometric past the ladder top
    # the headroom invariant: after laying out for n there is ALWAYS a
    # spare slot, so the next attach is fast-path
    for n in range(0, 100):
        assert lad.capacity_for(n) > n


def test_capacity_ladder_validation():
    with pytest.raises(ValueError):
        CapacityLadder(classes=(4, 2))
    with pytest.raises(ValueError):
        CapacityLadder(headroom=0)


# ---------------------------------------------------------------------------
# deadline batching + backpressure (pure host, fake clock)
# ---------------------------------------------------------------------------


def test_flush_on_deadline():
    clk = FakeClock()
    b = DeadlineBatcher(FrontendConfig(max_wait_s=0.010, max_rows=100),
                        clock=clk)
    b.add_tenant("a")
    b.submit("a", 1, 2, 0, 0.0)
    assert not b.due()                    # fresh event: not due yet
    clk.advance(0.009)
    assert not b.due()
    clk.advance(0.002)                    # oldest now 11ms old
    assert b.due()
    batches, arrivals = b.take()
    assert set(batches) == {"a"} and len(arrivals) == 1
    assert batches["a"].src.shape == (1,)
    assert not b.due()                    # drained


def test_flush_on_full_with_leftovers():
    clk = FakeClock()
    b = DeadlineBatcher(FrontendConfig(max_wait_s=10.0, max_rows=4),
                        clock=clk)
    b.add_tenant("a")
    for i in range(6):
        b.submit("a", i, i + 10, i, float(i))
    assert b.due()                        # size trigger, no time passed
    batches, _ = b.take()
    np.testing.assert_array_equal(batches["a"].src, [0, 1, 2, 3])
    assert b.depths() == {"a": 2}         # FIFO leftovers stay queued
    batches, _ = b.take()
    np.testing.assert_array_equal(batches["a"].src, [4, 5])


def test_reject_when_queue_full():
    clk = FakeClock()
    b = DeadlineBatcher(FrontendConfig(max_wait_s=10.0, max_rows=100,
                                       queue_rows=3, retry_after_s=0.25),
                        clock=clk)
    b.add_tenant("a")
    for i in range(3):
        b.submit("a", i, i, i, float(i))
    with pytest.raises(RetryAfter) as e:
        b.submit("a", 9, 9, 9, 9.0)
    assert e.value.seconds == 0.25 and e.value.depth == 3
    assert b.rejected == 1 and b.accepted == 3
    b.take()                              # drain frees the queue
    assert b.submit("a", 9, 9, 9, 9.0) == 1


def test_pad_quantum_masks_padding():
    clk = FakeClock()
    b = DeadlineBatcher(FrontendConfig(max_wait_s=0.0, max_rows=8,
                                       pad_quantum=8), clock=clk)
    b.add_tenant("a")
    for i in range(3):
        b.submit("a", i, i, i, float(i))
    batches, _ = b.take()
    eb = batches["a"]
    assert eb.src.shape == (8,)           # padded to the quantum
    np.testing.assert_array_equal(eb.valid,
                                  [True] * 3 + [False] * 5)
    np.testing.assert_array_equal(eb.src[3:], [2] * 5)  # repeat-last


# ---------------------------------------------------------------------------
# live admission over the reserve ladder (device, no frontend yet)
# ---------------------------------------------------------------------------


def test_reserve_attach_detach_is_fast_path(setup):
    """After the first relayout of a cohort, attaches landing in spare
    slots and EVERY detach leave the compiled layout untouched."""
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    adm = AdmissionController(mgr)
    a = adm.attach()                      # new cohort: relayout
    assert not adm.log[-1].fast
    b = adm.attach()                      # lands in the spare slot
    assert adm.log[-1].fast and adm.log[-1].capacity == 2
    c = adm.attach()                      # class exhausted: relayout to 4
    assert not adm.log[-1].fast and adm.log[-1].capacity == 4
    for tid in (c, b):
        adm.detach(tid)                   # reserve detach NEVER relays out
        assert adm.log[-1].fast
    cohort = mgr.cohort_of(a)
    assert cohort.size == 1 and cohort.capacity == 4
    s = adm.stats()
    assert s["fast"] == 3 and s["relayouts"] == 2


def test_reserve_detach_swaps_last_slot(setup):
    """Swap-remove keeps surviving rows aligned with their tids."""
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    tids = [mgr.add_tenant() for _ in range(3)]
    marks = {}
    for k, tid in enumerate(tids):
        st = mgr.state_of(tid)
        marks[tid] = st._replace(memory=st.memory + (k + 1.0))
        mgr.set_state(tid, marks[tid])
    mgr.remove_tenant(tids[0])            # last tenant swaps into slot 0
    for tid in tids[1:]:
        _assert_state_equal(mgr.state_of(tid), marks[tid], msg=tid)


def test_empty_reserved_cohort_stays_resident(setup):
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    a = mgr.add_tenant()
    cohort = mgr.cohort_of(a)
    mgr.remove_tenant(a)
    assert not mgr.last_admission["relayout"]
    assert cohort.capacity == 2 and cohort.size == 0
    b = mgr.add_tenant()                  # re-attach: fast path again
    assert not mgr.last_admission["relayout"]
    assert mgr.cohort_of(b) is cohort


def test_prewarm_makes_first_attach_fast(setup):
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    mgr.prewarm_cohort(OTHER)
    tid = mgr.add_tenant(OTHER)
    assert not mgr.last_admission["relayout"]
    assert mgr.cohort_of(tid).capacity == 2
    # without a reserve, prewarm is meaningless and refuses
    legacy = SessionManager(params, ef, model=cfg)
    with pytest.raises(ValueError):
        legacy.prewarm_cohort(OTHER)


def test_reserve_spares_are_bitwise_noops(setup):
    """A reserve-mode fleet (idle spare slots in every cohort) serves the
    SAME trajectories as the exact-size legacy session, bitwise."""
    g, cfg, params, ef = setup
    mgr_r = SessionManager(params, ef, model=cfg, reserve=True)
    mgr_l = SessionManager(params, ef, model=cfg)
    tids = {}
    for v in (None, OTHER):
        tr = mgr_r.add_tenant(v)
        tl = mgr_l.add_tenant(v)
        tids[tr] = tl
    from repro.data import stream as stream_mod
    streams = {t: stream_mod.fixed_count(g, 20, window=slice(0, 100),
                                         seed=i)
               for i, t in enumerate(tids)}
    for batches in zip(*[[(t, b) for b in s] for t, s in streams.items()]):
        round_r = dict(batches)
        outs_r = mgr_r.step(round_r)
        outs_l = mgr_l.step({tids[t]: b for t, b in round_r.items()})
        for t in round_r:
            np.testing.assert_array_equal(
                np.asarray(outs_r[t].emb_src),
                np.asarray(outs_l[tids[t]].emb_src), err_msg=t)
    for tr, tl in tids.items():
        _assert_state_equal(mgr_r.state_of(tr), mgr_l.state_of(tl),
                            msg=tr)


# ---------------------------------------------------------------------------
# THE acceptance test: live attach + detach mid-stream, zero recompiles,
# survivors bitwise-identical to the offline driver
# ---------------------------------------------------------------------------


def test_live_admission_zero_recompile_bitwise(setup):
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    a = mgr.add_tenant()          # cohort 1
    b = mgr.add_tenant(OTHER)     # cohort 2
    clk = FakeClock()
    fe = ServingFrontend(
        mgr, FrontendConfig(max_wait_s=0.010, max_rows=8, queue_rows=64,
                            pad_quantum=8),
        clock=clk, record_rounds=True)

    # warm up: both cohorts active, the round compiles once
    for r in range(3):
        _feed(fe, g, (a, b), r * 8, 8)
        assert fe.pump(force=True)
    mgr.sync()
    c0 = mgr.compile_counters()
    assert c0["round_traces"] == 1 and c0["round_calls"] == 3

    # live attach into cohort 1's spare slot (fast path)
    c = fe.attach(name="live")
    assert not mgr.last_admission["relayout"]
    for r in range(3, 6):
        _feed(fe, g, (a, b, c), r * 8, 8)
        assert fe.pump(force=True)

    # live detach mid-stream (swap-remove; slot idles, no relayout)
    fe.detach(c)
    assert not mgr.last_admission["relayout"]
    for r in range(6, 9):
        _feed(fe, g, (a, b), r * 8, 8)
        assert fe.pump(force=True)
    mgr.sync()

    # (a) ZERO recompiles across attach + detach: same layout, same
    # compiled executable, only the call count moved
    c1 = mgr.compile_counters()
    assert c1["relayouts"] == c0["relayouts"]
    assert c1["round_traces"] == c0["round_traces"]
    assert c1["round_calls"] == 9
    assert mgr.summary()["per_tenant"][a]["rounds"] == 9

    # (b) survivors bitwise-identical to the OFFLINE driver (legacy
    # exact-size SessionManager) replaying the same flushed batches
    offline = SessionManager(params, ef, model=cfg)
    names = {}
    variants = {a: None, b: OTHER, c: None}
    for round_batches in fe.round_log:
        for tid in round_batches:
            if tid not in names:
                names[tid] = offline.add_tenant(variants[tid])
        offline.step({names[tid]: eb for tid, eb in round_batches.items()})
    for tid in (a, b):
        _assert_state_equal(mgr.state_of(tid),
                            offline.state_of(names[tid]), msg=tid)


def test_live_params_attach_rejected_unknown_fast_when_prewarmed(setup):
    """The per-lane params dimension at the frontend: attaching a tenant
    on a NOT-registered param set mid-stream is rejected with a clear
    ``invalid_request`` and leaves the compile counters (and the fleet)
    frozen; attaching onto a prewarmed param lane is relayout-free, and
    once the lane's widths have been absorbed further attaches into its
    spare slots are fully zero-recompile."""
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    mgr.register_params("student-B",
                        tgn.init_params(jax.random.key(5), cfg))
    a = mgr.add_tenant()
    mgr.prewarm_cohort(params="student-B")   # student lane laid out early
    clk = FakeClock()
    fe = ServingFrontend(
        mgr, FrontendConfig(max_wait_s=0.010, max_rows=8, queue_rows=64,
                            pad_quantum=8), clock=clk)
    for r in range(2):                       # warm the compiled round
        _feed(fe, g, (a,), r * 8, 8)
        assert fe.pump(force=True)
    mgr.sync()
    c0 = mgr.compile_counters()

    # unknown set: clear rejection BEFORE any lane mutation
    resp = fe.handle({"op": "attach", "params": "nope", "name": "bad"})
    assert not resp["ok"] and resp["error"] == "invalid_request"
    assert "unknown param set" in resp["detail"]
    assert "student-B" in resp["detail"]     # the menu names what exists
    assert mgr.tenants == (a,)
    mgr.sync()
    assert mgr.compile_counters() == c0      # counters frozen

    # prewarmed param lane: live attach is relayout-free
    resp = fe.handle({"op": "attach", "params": "student-B", "name": "s1"})
    assert resp["ok"] and resp["tid"] == "s1"
    assert not resp["admission"]["relayout"]
    _feed(fe, g, (a, "s1"), 16, 8)
    assert fe.pump(force=True)               # absorbs the lane's widths
    mgr.sync()
    c1 = mgr.compile_counters()
    assert c1["relayouts"] == c0["relayouts"]

    # second attach into the lane's spare slot: fully zero-recompile
    resp = fe.handle({"op": "attach", "params": "student-B", "name": "s2"})
    assert resp["ok"] and not resp["admission"]["relayout"]
    _feed(fe, g, (a, "s1", "s2"), 24, 8)
    assert fe.pump(force=True)
    mgr.sync()
    c2 = mgr.compile_counters()
    assert c2["relayouts"] == c1["relayouts"]
    assert c2["round_traces"] == c1["round_traces"]
    assert c2["round_calls"] == c1["round_calls"] + 1


# ---------------------------------------------------------------------------
# frontend serving loop details
# ---------------------------------------------------------------------------


def test_frontend_deadline_pump_and_stats(setup):
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    a = mgr.add_tenant()
    clk = FakeClock()
    fe = ServingFrontend(mgr, FrontendConfig(max_wait_s=0.010, max_rows=64),
                         clock=clk)
    _feed(fe, g, (a,), 0, 4)
    assert fe.pump() == {}                # deadline not reached
    clk.advance(0.011)
    outs = fe.pump()                      # deadline flush
    assert set(outs) == {a}
    st = fe.stats()
    assert st["rounds"] == 1 and st["events"] == 4
    assert st["latency_p50_s"] == pytest.approx(0.011)
    per = mgr.tenant_stats()              # satellite: one source of truth
    assert per[a]["rows"] == 4 and per[a]["rounds"] == 1
    assert per[a]["queue_depth"] == 0
    assert per[a]["last_flush_t"] is not None


def test_frontend_detach_flushes_pending(setup):
    """No accepted event is dropped: detach flushes the tenant's queue
    into one last round before the slot is released."""
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    a = mgr.add_tenant()
    b = mgr.add_tenant()
    clk = FakeClock()
    fe = ServingFrontend(mgr, FrontendConfig(max_wait_s=10.0, max_rows=64),
                         clock=clk)
    _feed(fe, g, (a, b), 0, 5)
    fe.detach(b)
    assert b not in mgr.tenants
    assert mgr.tenant_stats()[a]["rows"] == 5   # flushed alongside b
    assert fe.rounds == 1


def test_journaled_ingest_wire_contract(setup, tmp_path):
    """The exactly-once wire contract (docs/SERVING.md): a retried
    ingest with the same ``(client_id, seq)`` acks ``dedup: true``
    without re-enqueueing, ``retry_after`` carries ``last_seq`` so an
    at-least-once client knows where to resume, and ``stats`` exposes
    the journal block."""
    from repro.serving.journal import EventJournal

    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    a = mgr.add_tenant()
    clk = FakeClock()
    journal = EventJournal(str(tmp_path), clock=clk)
    fe = ServingFrontend(mgr, FrontendConfig(max_wait_s=10.0, max_rows=64,
                                             queue_rows=8),
                         clock=clk, journal=journal)

    req = {"op": "ingest", "tid": a, "src": int(g.src[0]),
           "dst": int(g.dst[0]), "eid": 0, "ts": float(g.ts[0]),
           "client_id": "c0", "seq": 0}
    assert fe.handle(dict(req))["ok"] is True
    dup = fe.handle(dict(req))
    assert dup == {"ok": True, "dedup": True, "tid": a,
                   "client_id": "c0", "seq": 0}
    assert fe.batcher.depths()[a] == 1          # not re-enqueued
    assert fe.dedups == 1

    for i in range(1, 8):                       # fill the queue
        fe.handle({**req, "eid": i, "seq": i})
    r = fe.handle({**req, "eid": 8, "seq": 8})
    assert r["error"] == "retry_after"
    assert r["last_seq"] == 7                   # seq 8 was NOT accepted
    assert not journal.is_duplicate(a, "c0", 8)

    st = fe.stats()
    assert st["journal"]["dedups"] == 1
    assert st["journal"]["appends"] == 8


def test_jsonl_server_roundtrip(setup):
    """The wire transport: ingest / stats / backpressure / live attach
    over newline-delimited JSON on an ephemeral port."""
    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    mgr.add_tenant(name="t0")
    fe = ServingFrontend(mgr, FrontendConfig(max_wait_s=0.002, max_rows=16,
                                             queue_rows=8))

    async def scenario():
        await fe.start()
        server = await serve_jsonl(fe, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)

        async def rpc(req):
            writer.write(json.dumps(req).encode() + b"\n")
            await writer.drain()
            return json.loads(await reader.readline())

        for i in range(4):
            r = await rpc({"op": "ingest", "tid": "t0",
                           "src": int(g.src[i]), "dst": int(g.dst[i]),
                           "eid": i, "ts": float(g.ts[i])})
            assert r["ok"], r
        r = await rpc({"op": "attach", "name": "live"})
        assert r["ok"] and r["tid"] == "live"
        assert not r["admission"]["relayout"]     # spare slot absorbed it
        r = await rpc({"op": "ingest", "tid": "nope", "src": 1, "dst": 2,
                       "ts": 0.0})
        assert r["error"] == "unknown_tenant"
        r = await rpc({"op": "flush"})
        assert r["ok"]
        r = await rpc({"op": "stats"})
        assert r["stats"]["rounds"] >= 1
        assert "t0" in r["stats"]["queue_depths"]
        r = await rpc({"op": "detach", "tid": "live"})
        assert r["ok"]
        writer.write(b"{not json\n")
        await writer.drain()
        assert json.loads(await reader.readline())["error"] == "bad_json"

        writer.close()
        server.close()
        await server.wait_closed()
        await fe.stop()

    asyncio.run(scenario())
    assert fe.stats()["tenants"] == ["t0"]


def test_jsonl_server_survives_hostile_wire_input(setup):
    """Wire hardening: malformed JSON, non-dict payloads, unknown ops,
    missing/invalid fields, quarantined-tenant ingest, and an oversized
    line each produce a STRUCTURED error (with a transient/permanent
    classification) — and the server keeps serving new connections."""
    from repro.serving.guard import FleetGuard

    g, cfg, params, ef = setup
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    mgr.add_tenant(name="t0")
    mgr.add_tenant(name="sick")
    guard = FleetGuard(mgr, clock=lambda: 0.0, backoff_s=9.0)
    guard.quarantine("sick", reason="manual")
    fe = ServingFrontend(mgr, FrontendConfig(max_wait_s=0.002, max_rows=16,
                                             queue_rows=8))

    async def scenario():
        await fe.start()
        server = await serve_jsonl(fe, "127.0.0.1", 0, max_line=4096)
        port = server.sockets[0].getsockname()[1]

        async def connect():
            return await asyncio.open_connection("127.0.0.1", port)

        reader, writer = await connect()

        async def rpc(payload):
            writer.write(payload if isinstance(payload, bytes)
                         else json.dumps(payload).encode() + b"\n")
            await writer.drain()
            return json.loads(await reader.readline())

        r = await rpc(b"}{ definitely not json\n")
        assert r["error"] == "bad_json" and not r["ok"]
        r = await rpc([1, 2, 3])                   # valid JSON, not a dict
        assert r["error"] == "invalid_request" and r["transient"] is False
        r = await rpc({"op": "self_destruct"})
        assert r["error"] == "unknown_op" and r["transient"] is False
        r = await rpc({"op": "ingest", "tid": "t0", "src": 1})
        assert r["error"] == "invalid_request" and "dst" in r["detail"]
        r = await rpc({"op": "ingest", "tid": "t0", "src": 1, "dst": 2,
                       "ts": float("inf")})
        assert r["error"] == "invalid_request" and r["transient"] is False
        r = await rpc({"op": "ingest", "tid": "t0", "src": -5, "dst": 2,
                       "ts": 0.0})
        assert r["error"] == "invalid_request"
        r = await rpc({"op": "ingest", "tid": "ghost", "src": 1, "dst": 2,
                       "ts": 0.0})
        assert r["error"] == "unknown_tenant" and r["transient"] is False
        # a quarantined tenant's ingest is refused TRANSIENTLY with the
        # guard's retry hint, never enqueued
        r = await rpc({"op": "ingest", "tid": "sick", "src": 1, "dst": 2,
                       "ts": 0.0})
        assert r["error"] == "retry_after" and r["transient"] is True
        assert r["reason"] == "quarantined"
        assert r["retry_after_s"] == pytest.approx(9.0)
        # the connection survived every bad request above
        r = await rpc({"op": "ingest", "tid": "t0", "src": int(g.src[0]),
                       "dst": int(g.dst[0]), "eid": 0,
                       "ts": float(g.ts[0])})
        assert r["ok"]

        # an oversized line: one structured error, then the connection
        # is dropped (the bounded read cannot resync mid-line)
        r = await rpc(b'{"op": "ingest", "pad": "' + b"x" * 8192 + b'"}\n')
        assert r["error"] == "invalid_request" and "exceeds" in r["detail"]
        assert await reader.read(1) == b""         # server closed it
        writer.close()

        # ...but the SERVER is alive: a fresh connection serves fine
        reader, writer = await connect()
        r = await rpc({"op": "stats"})
        assert r["ok"] and "t0" in r["stats"]["tenants"]
        writer.close()
        server.close()
        await server.wait_closed()
        await fe.stop()

    asyncio.run(scenario())
    assert fe.stats()["accepted"] == 1


# ---------------------------------------------------------------------------
# observability: sampled tracing + SLO burn over the online round path
# ---------------------------------------------------------------------------


def test_sampled_tracing_keeps_zero_recompile_single_launch(setup):
    """Serving a mixed-cohort reserve-mode fleet with sampled tracing
    armed changes NOTHING about the serving contract — compile counters
    frozen, one coalesced launch per round — while the sampled rounds
    produce the full span taxonomy and per-tenant SLO burn."""
    from repro.obs import RoundTracer

    g, cfg, params, ef = setup
    clk = FakeClock()
    mgr = SessionManager(params, ef, model=cfg, reserve=True)
    for i, v in enumerate((BASE, BASE, OTHER)):
        mgr.add_tenant(v, name=f"t{i}")
    tracer = RoundTracer(clock=clk, sample_every=2)
    fe = ServingFrontend(mgr, FrontendConfig(max_wait_s=0.005, max_rows=8,
                                             pad_quantum=8),
                         clock=clk, tracer=tracer, slo_ms=50.0)
    tids = list(mgr.tenants)

    _feed(fe, g, tids, 0, 8)               # warmup: compile both widths
    fe.pump(force=True)
    c0 = mgr.compile_counters()

    for r in range(6):
        _feed(fe, g, tids, 8 * (r + 1), 8)
        clk.advance(0.006)                 # past the deadline
        assert fe.pump()                   # a round launched

    # the serving contract is untouched by tracing
    c1 = mgr.compile_counters()
    assert c1["relayouts"] == c0["relayouts"]
    assert c1["round_traces"] == c0["round_traces"]
    assert {m["launches"] for m in mgr.metrics} == {1}

    # sampling is a strict subset of rounds; spans cover the taxonomy
    assert 0 < tracer.rounds_sampled < tracer.rounds_seen
    names = {s.name for s in tracer.spans}
    assert {"ingest", "flush", "stage", "launch", "h2d", "drain"} <= names

    # SLO burn reported for EVERY tenant in the summary
    per_tenant = mgr.summary()["per_tenant"]
    assert set(per_tenant) == set(tids)
    for st in per_tenant.values():
        slo = st["slo"]
        assert slo["target_ms"] == 50.0 and slo["source"] == "event"
        assert slo["events"] > 0
        assert 0.0 <= slo["budget_remaining"] <= 1.0

    # the wire op exposes the same atomic view
    out = fe.metrics_snapshot()
    assert out["compile"] == mgr.compile_counters()
    assert out["trace"]["rounds_sampled"] == tracer.rounds_sampled
    assert set(out["slo"]) == set(tids)
