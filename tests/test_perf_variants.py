"""§Perf optimization flags preserve semantics (H1/O2/O4/O5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import transformer as T


def _cfg(**kw):
    base = dict(arch="t", n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
                d_head=16, d_ff=64, vocab=64, pattern=("local", "global"),
                window=8, dtype="float32", q_block=16, k_block=16,
                loss_chunk=16)
    base.update(kw)
    return T.LMConfig(**base)


def test_h1_attn_remat_bit_exact():
    cfg = _cfg()
    p = T.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, 64)
    l0, g0 = jax.value_and_grad(lambda pp: T.loss_fn(pp, cfg, toks, toks))(p)
    cfg1 = cfg.replace(attn_remat=True)
    l1, g1 = jax.value_and_grad(lambda pp: T.loss_fn(pp, cfg1, toks, toks))(p)
    assert float(l0) == float(l1)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _decode_all(cfg, p, toks, steps=16):
    caches = T.init_caches(cfg, 2, steps, dtype=jnp.float32)
    lg = None
    for t in range(steps):
        lg, caches = T.decode_step(p, cfg, toks[:, t:t + 1], caches)
    return lg


def test_o5_decode_unroll_matches_scan():
    cfg = _cfg()
    p = T.init(jax.random.key(2), cfg)
    toks = jax.random.randint(jax.random.key(3), (2, 16), 0, 64)
    l0 = _decode_all(cfg, p, toks)
    l1 = _decode_all(cfg.replace(decode_unroll=True), p, toks)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-4,
                               atol=1e-5)


def test_o4_no_upcast_fp32_caches_exact():
    # with fp32 caches the no-upcast path is numerically identical
    cfg = _cfg()
    p = T.init(jax.random.key(4), cfg)
    toks = jax.random.randint(jax.random.key(5), (2, 16), 0, 64)
    l0 = _decode_all(cfg, p, toks)
    l1 = _decode_all(cfg.replace(decode_upcast=False), p, toks)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-5,
                               atol=1e-6)


def test_o2_layers_prune_full_keep_exact():
    cfg = L.AttnCfg(d_model=32, n_heads=4, n_kv_heads=2, d_head=8)
    p = L.init_attention(jax.random.key(6), cfg)
    B, S = 2, 20
    x = jax.random.normal(jax.random.key(7), (B, S, 32), jnp.float32)
    c1 = L.init_kv_cache(B, S, cfg, dtype=jnp.float32)
    c2 = L.init_kv_cache(B, S, cfg, dtype=jnp.float32)
    for t in range(S):
        o1, c1 = L.decode_attention(p, cfg, x[:, t:t + 1], c1)
        o2, c2 = L.pruned_decode_attention(p, cfg, x[:, t:t + 1], c2,
                                           keep=S)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=1e-4, atol=1e-5)


def test_o2_prune_keeps_most_recent():
    """With the default decaying score, pruning keeps the most recent keep
    positions -> for a recency-only query the output matches a window."""
    cfg_w = L.AttnCfg(d_model=16, n_heads=2, n_kv_heads=2, d_head=8,
                      use_rope=False, window=4)
    cfg_p = L.AttnCfg(d_model=16, n_heads=2, n_kv_heads=2, d_head=8,
                      use_rope=False)
    p = L.init_attention(jax.random.key(8), cfg_p)
    B, S = 1, 12
    x = jax.random.normal(jax.random.key(9), (B, S, 16), jnp.float32)
    cw = L.init_kv_cache(B, S, cfg_w, dtype=jnp.float32)
    cp = L.init_kv_cache(B, S, cfg_p, dtype=jnp.float32)
    for t in range(S):
        ow, cw = L.decode_attention(p, cfg_w, x[:, t:t + 1], cw)
        op, cp = L.pruned_decode_attention(p, cfg_p, x[:, t:t + 1], cp,
                                           keep=4)
        np.testing.assert_allclose(np.asarray(ow), np.asarray(op),
                                   rtol=1e-4, atol=1e-5)
