"""Chronological Updater: last-write-wins == serial replay (property)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import updater


ids_valid = st.integers(1, 40).flatmap(
    lambda n: st.tuples(
        st.lists(st.integers(0, 9), min_size=n, max_size=n),
        st.lists(st.booleans(), min_size=n, max_size=n)))


@settings(max_examples=50, deadline=None)
@given(ids_valid)
def test_lww_equals_serial_replay(iv):
    ids, valid = iv
    ids_j = jnp.asarray(ids, jnp.int32)
    valid_j = jnp.asarray(valid)
    values = jnp.arange(len(ids), dtype=jnp.float32)[:, None] + 100.0
    winners = updater.last_write_wins(ids_j, valid_j)
    table = updater.commit(jnp.zeros((10, 1)), ids_j, values, winners)

    # oracle: serial replay in batch order
    ref = np.zeros((10, 1), np.float32)
    for i, (v, ok) in enumerate(zip(ids, valid)):
        if ok:
            ref[v] = i + 100.0
    np.testing.assert_allclose(np.asarray(table), ref)


@settings(max_examples=50, deadline=None)
@given(ids_valid)
def test_lww_sorted_equals_quadratic(iv):
    ids, valid = iv
    ids_j = jnp.asarray(ids, jnp.int32)
    valid_j = jnp.asarray(valid)
    a = updater.last_write_wins(ids_j, valid_j)
    b = updater.last_write_wins_sorted(ids_j, valid_j)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_winners_unique_per_vertex():
    ids = jnp.asarray([3, 3, 3, 1, 1, 2], jnp.int32)
    w = updater.last_write_wins(ids)
    np.testing.assert_array_equal(np.asarray(w),
                                  [False, False, True, False, True, True])


def test_commit_scalar_losers_untouched():
    table = jnp.asarray([1.0, 2.0, 3.0])
    ids = jnp.asarray([0, 0], jnp.int32)
    vals = jnp.asarray([10.0, 20.0])
    w = updater.last_write_wins(ids)
    out = updater.commit_scalar(table, ids, vals, w)
    np.testing.assert_allclose(np.asarray(out), [20.0, 2.0, 3.0])
