"""FleetGuard recovery semantics: quarantine on poisoned state, bitwise
auto-restore from snapshots, deterministic backoff + eviction on an
injected clock, kernel-tier degradation as a single lane move, and SLO
burn accounting over the outage window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline as pl, tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.cluster import snapshot_tenant
from repro.serving.faults import FakeClock, Fault, FaultInjector
from repro.serving.guard import FleetGuard
from repro.serving.session import SessionManager


@pytest.fixture(scope="module")
def small_graph():
    return tgd.wikipedia_like(n_edges=500)


def _dims(g, f=16):
    return dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f, f_time=f, f_emb=f, m_r=10)


def _make_mgr(g, use_kernels=False):
    cfg = pl.variant_config("sat+lut+np4", **_dims(g))
    params = tgn.init_params(jax.random.key(0), cfg)
    return SessionManager(params, jnp.asarray(g.edge_feats), model=cfg,
                          use_kernels=use_kernels)


def _rounds(g, i, batch=20, n=5):
    lo = 60 * i
    return list(stream_mod.fixed_count(g, batch,
                                       window=slice(lo, lo + batch * n),
                                       seed=i))


def _poison(mgr, tid):
    st = mgr.state_of(tid)
    mgr.set_state(tid, st._replace(memory=jnp.full_like(st.memory,
                                                        jnp.nan)))


def _assert_state_equal(a, b, msg=""):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}:{f}")


def test_injected_nan_quarantines_and_survivor_is_bitwise(small_graph):
    """An injected NaN state is caught by the finite sentinel the same
    round; the cohort-mate's trajectory is bitwise identical to a solo
    fleet that never had the sick tenant attached."""
    g = small_graph
    mgr = _make_mgr(g)
    t0, t1 = mgr.add_tenant(), mgr.add_tenant()
    clock = FakeClock()
    injector = FaultInjector([Fault(kind="nan_state", tenant=t1, at=1)])
    mgr.set_faults(injector)
    # backoff far beyond the run on a never-advancing clock: no restore
    # attempts fire, this test pins detection + isolation only
    guard = FleetGuard(mgr, clock=clock, backoff_s=100.0, backoff_cap_s=100.0)

    r0, r1 = _rounds(g, 0), _rounds(g, 1)
    for k in range(4):
        guard.step({t0: r0[k], t1: r1[k]})
    mgr.sync()

    assert injector.pending() == []
    assert mgr.is_quarantined(t1)
    assert guard.quarantines == 1 and guard.restores == 0
    view = guard.tenant_view(t1)
    assert view["quarantined"] and view["last_reason"] == "nonfinite_state"
    assert view["next_attempt_in_s"] == pytest.approx(100.0)
    assert mgr.obs.counter("guard.quarantines").value == 1

    solo = _make_mgr(g)
    ts = solo.add_tenant()
    for k in range(4):
        solo.step({ts: r0[k]})
    solo.sync()
    _assert_state_equal(mgr.state_of(t0), solo.state_of(ts), "survivor")


def test_auto_restore_resumes_bitwise_from_snapshot(small_graph, tmp_path):
    """After the backoff, the guard reloads the quarantined tenant's
    newest snapshot IN PLACE: the restored state is bitwise the
    snapshotted one, and the tenant's next round replays bitwise like a
    solo fleet stepped straight off that snapshot."""
    g = small_graph
    root = str(tmp_path / "snaps")
    mgr = _make_mgr(g)
    t0, t1 = mgr.add_tenant(), mgr.add_tenant()
    clock = FakeClock()
    guard = FleetGuard(mgr, snapshot_root=root, clock=clock, backoff_s=1.0)

    r0, r1 = _rounds(g, 0), _rounds(g, 1)
    for k in range(2):
        guard.step({t0: r0[k], t1: r1[k]})
    mgr.sync()
    snapshot_tenant(mgr, t1, root, step=2)
    good = mgr.state_of(t1)

    _poison(mgr, t1)
    guard.step({t0: r0[2], t1: r1[2]})          # detect + quarantine
    assert mgr.is_quarantined(t1)
    clock.advance(1.0)
    guard.step({t0: r0[3], t1: r1[3]})          # backoff due: restore
    mgr.sync()
    assert not mgr.is_quarantined(t1)
    assert guard.restores == 1
    assert guard.tenant_view(t1)["restores"] == 1
    _assert_state_equal(mgr.state_of(t1), good, "restored")

    # next round continues bitwise from the snapshot state
    guard.step({t0: r0[4], t1: r1[4]})
    mgr.sync()
    solo = _make_mgr(g)
    ts = solo.add_tenant()
    solo.set_state(ts, good)
    solo.step({ts: r1[4]})
    solo.sync()
    _assert_state_equal(mgr.state_of(t1), solo.state_of(ts), "resume")


def test_auto_restore_with_journal_is_lossless(small_graph, tmp_path):
    """With a journal armed, auto-restore replays the suffix past the
    snapshot cursor — the rounds the tenant missed while quarantined
    (dropped by ``SessionManager.step``) AND the round that poisoned it
    — so the recovered tenant is bitwise identical to an unfaulted twin
    that applied every round, not just bitwise-at-the-snapshot."""
    from repro.serving.journal import EventJournal

    g = small_graph
    root = str(tmp_path / "snaps")
    journal = EventJournal(str(tmp_path / "wal"))
    mgr = _make_mgr(g)
    t0, t1 = mgr.add_tenant(), mgr.add_tenant()
    clock = FakeClock()
    guard = FleetGuard(mgr, snapshot_root=root, clock=clock, backoff_s=1.0,
                       journal=journal)

    r0, r1 = _rounds(g, 0, n=6), _rounds(g, 1, n=6)
    for k in range(2):
        journal.append_batch(t1, r1[k])
        guard.step({t0: r0[k], t1: r1[k]})
    mgr.sync()
    snapshot_tenant(mgr, t1, root, step=2,
                    extra_meta={"journal": journal.cursor(t1)})

    _poison(mgr, t1)
    journal.append_batch(t1, r1[2])
    guard.step({t0: r0[2], t1: r1[2]})          # detect + quarantine
    assert mgr.is_quarantined(t1)
    journal.append_batch(t1, r1[3])
    guard.step({t0: r0[3], t1: r1[3]})          # outage round: dropped
    clock.advance(1.0)
    journal.append_batch(t1, r1[4])
    guard.step({t0: r0[4], t1: r1[4]})          # restore + replay 2..4
    mgr.sync()
    assert not mgr.is_quarantined(t1)
    assert guard.restores == 1
    journal.append_batch(t1, r1[5])
    guard.step({t0: r0[5], t1: r1[5]})          # healthy again: live
    mgr.sync()

    twin = _make_mgr(g)
    tw = twin.add_tenant()
    for k in range(6):
        twin.step({tw: r1[k]})
    twin.sync()
    _assert_state_equal(mgr.state_of(t1), twin.state_of(tw), "lossless")
    # the survivor never saw the episode
    solo = _make_mgr(g)
    ts = solo.add_tenant()
    for k in range(6):
        solo.step({ts: r0[k]})
    solo.sync()
    _assert_state_equal(mgr.state_of(t0), solo.state_of(ts), "survivor")


def test_backoff_schedule_and_eviction_are_deterministic(small_graph):
    """With no snapshot root a NaN tenant can never heal: restore
    attempts fire exactly at the capped-doubling backoff marks on the
    injected clock (1s, +2s, +4s), and the ``max_restores``-th failure
    evicts permanently."""
    g = small_graph
    mgr = _make_mgr(g)
    t0, t1 = mgr.add_tenant(), mgr.add_tenant()
    clock = FakeClock()
    guard = FleetGuard(mgr, clock=clock, max_restores=3, backoff_s=1.0)

    r0 = _rounds(g, 0, n=8)
    guard.step({t0: r0[0], t1: _rounds(g, 1, n=1)[0]})
    _poison(mgr, t1)
    guard.step({t0: r0[1]})                     # t=0: quarantine
    assert mgr.is_quarantined(t1)

    clock.advance(0.5)                          # t=0.5: before the mark
    guard.step({t0: r0[2]})
    assert guard._t[t1]["attempts"] == 0
    for t in (1.0, 3.0, 7.0):                   # due marks: 1, +2, +4
        clock.t = t
        guard.step({t0: r0[3]})
    assert guard._t[t1]["attempt_times"] == [1.0, 3.0, 7.0]
    assert guard.evictions == 1 and guard.restores == 0
    view = guard.tenant_view(t1)
    assert view["evicted"] and not view["quarantined"]
    assert "evicted after 3 failed restores" in view["last_reason"]
    assert t1 not in mgr.tenants
    assert guard.snapshot()["evicted"] == [t1]
    # the survivor is untouched by the whole episode
    assert not mgr.is_quarantined(t0)


def test_kernel_fault_degrades_tier_in_one_relayout(small_graph):
    """A classified launch failure moves the cohort one tier down
    (staged -> ref) as a lane move — exactly one extra relayout, the
    faulted round retried and completed, quarantine flags carried over."""
    g = small_graph
    mgr = _make_mgr(g, use_kernels="staged")
    t0, t1 = mgr.add_tenant(), mgr.add_tenant()
    clock = FakeClock()
    injector = FaultInjector([Fault(kind="kernel_fail", tenant=t0, at=1)])
    mgr.set_faults(injector)
    guard = FleetGuard(mgr, clock=clock, backoff_s=100.0, backoff_cap_s=100.0)

    r0, r1 = _rounds(g, 0), _rounds(g, 1)
    guard.step({t0: r0[0], t1: r1[0]})
    mgr.sync()
    assert mgr.cohort_of(t0).tier == "staged"
    c0 = mgr.compile_counters()
    guard.quarantine(t1, reason="manual")       # must survive the move

    outs = guard.step({t0: r0[1], t1: r1[1]})
    mgr.sync()
    assert injector.pending() == []
    assert t0 in outs                           # the retry completed
    assert guard.degradations == 1
    assert mgr.cohort_of(t0).tier == "ref"
    assert mgr.cohort_of(t1).tier == "ref"
    assert mgr.is_quarantined(t1)               # flag carried over
    assert mgr.compile_counters()["relayouts"] == c0["relayouts"] + 1

    # ref is the ladder floor: a fault there re-raises to the caller
    # (a fresh injector restarts its round cursor at 0)
    mgr.set_faults(FaultInjector(
        [Fault(kind="kernel_fail", tenant=t0, at=0)]))
    from repro.serving.faults import KernelFault
    with pytest.raises(KernelFault):
        guard.step({t0: r0[2]})


def test_slo_burn_covers_the_outage_window(small_graph):
    """Every round a tenant sits quarantined burns its SLO error budget
    as an outage violation — the outage is never invisible in the burn
    accounting."""
    g = small_graph
    mgr = _make_mgr(g)
    t0, t1 = mgr.add_tenant(), mgr.add_tenant()
    mgr.set_slo(25.0)
    clock = FakeClock()
    guard = FleetGuard(mgr, clock=clock, backoff_s=100.0, backoff_cap_s=100.0)

    r0 = _rounds(g, 0)
    guard.quarantine(t1, reason="manual")
    before = mgr.slo.tenant(t1)
    for k in range(3):
        guard.step({t0: r0[k]})
    after = mgr.slo.tenant(t1)
    assert after["violations"] == before["violations"] + 3
    assert after["events"] == before["events"] + 3
    assert after["burn_rate"] > 0.0
    # the healthy tenant's budget is not charged by the outage
    assert mgr.slo.tenant(t0)["violations"] == 0
