"""Shared LM layers: chunked/windowed/decode/ring attention equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _mk(cfg, seed=0):
    return L.init_attention(jax.random.key(seed), cfg)


@pytest.mark.parametrize("qk_norm", [False, True])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_chunked_equals_full(kv, qk_norm):
    cfg = L.AttnCfg(d_model=64, n_heads=4, n_kv_heads=kv, d_head=16,
                    qk_norm=qk_norm)
    p = _mk(cfg)
    x = jax.random.normal(jax.random.key(1), (2, 64, 64), jnp.float32)
    pos = jnp.arange(64, dtype=jnp.int32)
    want, _ = L.attention(p, cfg, x, pos)
    got = L.chunked_attention(p, cfg, x, pos, q_block=16, k_block=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window", [8, 24, 200])
def test_windowed_chunked_equals_full(window):
    cfg = L.AttnCfg(d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                    window=window)
    p = _mk(cfg, 2)
    x = jax.random.normal(jax.random.key(3), (1, 64, 32), jnp.float32)
    pos = jnp.arange(64, dtype=jnp.int32)
    want, _ = L.attention(p, cfg, x, pos)
    got = L.chunked_attention(p, cfg, x, pos, q_block=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_softcap_applied():
    cfg = L.AttnCfg(d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                    softcap=5.0)
    p = _mk(cfg, 4)
    x = 10.0 * jax.random.normal(jax.random.key(5), (1, 32, 32), jnp.float32)
    pos = jnp.arange(32, dtype=jnp.int32)
    want, _ = L.attention(p, cfg, x, pos)
    got = L.chunked_attention(p, cfg, x, pos, q_block=8, k_block=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_decode_matches_full_attention():
    cfg = L.AttnCfg(d_model=32, n_heads=4, n_kv_heads=2, d_head=8)
    p = _mk(cfg, 6)
    B, S = 2, 40
    x = jax.random.normal(jax.random.key(7), (B, S, 32), jnp.float32)
    cache = L.init_kv_cache(B, S, cfg, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = L.decode_attention(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    want, _ = L.attention(p, cfg, x, jnp.arange(S, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_ring_cache_decode_matches_windowed():
    cfg = L.AttnCfg(d_model=32, n_heads=2, n_kv_heads=1, d_head=16,
                    window=12)
    p = _mk(cfg, 8)
    B, S = 2, 48
    x = jax.random.normal(jax.random.key(9), (B, S, 32), jnp.float32)
    cache = L.init_ring_cache(B, 12, cfg, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = L.decode_attention(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    want, _ = L.attention(p, cfg, x, jnp.arange(S, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    # ring cache memory is O(window), not O(S)
    assert cache["k"].shape[1] == 12


def test_cross_attention_chunked():
    cfg = L.AttnCfg(d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                    use_rope=False)
    p = _mk(cfg, 10)
    x = jax.random.normal(jax.random.key(11), (2, 32, 32), jnp.float32)
    kvx = jax.random.normal(jax.random.key(12), (2, 16, 32), jnp.float32)
    pos = jnp.arange(32, dtype=jnp.int32)
    kpos = jnp.arange(16, dtype=jnp.int32)
    want, _ = L.attention(p, cfg, x, pos, kv_x=kvx, kv_positions=kpos,
                          causal=False)
    got = L.chunked_attention(p, cfg, x, pos, kv_x=kvx, kv_positions=kpos,
                              causal=False, q_block=8, k_block=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.key(13), (2, 8, 4, 16), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)
    y = L.rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    q = jax.random.normal(jax.random.key(14), (1, 1, 1, 16), jnp.float32)
    k = jax.random.normal(jax.random.key(15), (1, 1, 1, 16), jnp.float32)

    def score(m, n):
        qm = L.rope(q, jnp.asarray([m], jnp.int32))
        kn = L.rope(k, jnp.asarray([n], jnp.int32))
        return float(jnp.sum(qm * kn))

    assert abs(score(5, 3) - score(10, 8)) < 1e-4
    assert abs(score(7, 7) - score(0, 0)) < 1e-4
