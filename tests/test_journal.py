"""Crash-consistency tests for the durable event journal (tier 1).

Covers the recovery contract end to end: torn tails truncated on open,
crc-corrupt records stopping replay with a warning (and the restore path
falling back to an older snapshot whose longer replay suffix is still
bitwise), crash-mid-truncation leaving a replayable prefix, segment
rotation boundaries, kill-and-recover bitwise identity through
``cluster.restore_tenant(journal=...)``, duplicate-ingest idempotency,
and the snapshot-GC floor that anchors un-truncated journal records.
"""

import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipeline as pl, tgn
from repro.data import temporal_graph as tgd
from repro.serving import cluster
from repro.serving.faults import FakeClock, Fault, FaultInjector
from repro.serving.frontend import (
    DuplicateEvent,
    FrontendConfig,
    RetryAfter,
    ServingFrontend,
)
from repro.serving.journal import EventJournal, _HEADER
from repro.serving.session import SessionManager


@pytest.fixture(scope="module")
def small_graph():
    return tgd.wikipedia_like(n_edges=400)


def _dims(g, f=16):
    return dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f, f_time=f, f_emb=f, m_r=10)


def _make_mgr(g):
    cfg = pl.variant_config("sat+lut+np4", **_dims(g))
    params = tgn.init_params(jax.random.key(0), cfg)
    return SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)


def _events(g, n, seed=0):
    rng = np.random.RandomState(seed)
    src = rng.randint(0, g.cfg.n_nodes, n)
    dst = rng.randint(0, g.cfg.n_nodes, n)
    return [(int(src[i]), int(dst[i]), i, float(i) * 0.5, 0)
            for i in range(n)]


def _frontend(mgr, journal=None, clock=None):
    cfg = FrontendConfig(max_rows=8, pad_quantum=8, max_wait_s=0.001)
    return ServingFrontend(mgr, cfg, clock=clock or FakeClock(),
                           journal=journal)


def _assert_state_equal(a, b, msg=""):
    for f in a._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}: field {f}")


def _run(mgr, fe, tid, events, start=0, client="c"):
    """Feed events in rounds of 8 through the frontend."""
    for i, e in enumerate(events, start=start):
        fe.submit(tid, *e, client_id=client, seq=i)
        if (i + 1) % 8 == 0:
            fe.pump(force=True)


# ---------------------------------------------------------------------------
# journal primitives
# ---------------------------------------------------------------------------

def test_append_cursor_reopen(tmp_path):
    j = EventJournal(str(tmp_path))
    for i in range(10):
        j.append_event("t", i, i + 1, i, float(i), client_id="c", seq=i)
    j.note_flush("t", 8, 8)
    cur = j.cursor("t")
    assert cur["events"] == 8
    assert j.last_seq("t", "c") == 9
    j.close()

    j2 = EventJournal(str(tmp_path))
    cur2 = j2.cursor("t")
    assert cur2 == cur
    assert j2.last_seq("t", "c") == 9
    assert j2.is_duplicate("t", "c", 9)
    assert not j2.is_duplicate("t", "c", 10)


def test_dedup_window_semantics(tmp_path):
    j = EventJournal(str(tmp_path), dedup_window=4)
    for i in range(10):
        assert not j.is_duplicate("t", "c", i)
        j.append_event("t", 0, 1, i, 0.0, client_id="c", seq=i)
    # in-window duplicates
    for i in range(6, 10):
        assert j.is_duplicate("t", "c", i)
    # below the window: conservatively treated as duplicates
    assert j.is_duplicate("t", "c", 0)
    assert j.is_duplicate("t", "c", 5)
    assert not j.is_duplicate("t", "c", 10)


def test_torn_tail_truncated_on_open(tmp_path):
    j = EventJournal(str(tmp_path))
    for i in range(4):
        j.append_event("t", i, i + 1, i, float(i))
    with pytest.raises(OSError):
        j.append_event("t", 9, 9, 99, 9.0, torn=True)
    # journal is wedged after a torn write, like a crashed process
    with pytest.raises(OSError):
        j.append_event("t", 9, 9, 100, 9.0)
    j.close()

    j2 = EventJournal(str(tmp_path))
    with pytest.warns(UserWarning, match="torn"):
        j2.log_for("t")  # tenant logs scan (and truncate) lazily
    recs = [r for r in j2.records("t", 0, 0) if r is not None]
    assert [r["i"] for r in recs if r["k"] == "ev"] == [0, 1, 2, 3]
    # the log accepts appends again at the truncated tail
    j2.append_event("t", 5, 6, 4, 4.0)
    recs = [r for r in j2.records("t", 0, 0) if r is not None]
    assert [r["i"] for r in recs if r["k"] == "ev"] == [0, 1, 2, 3, 4]


def test_crc_corrupt_record_stops_replay(tmp_path):
    j = EventJournal(str(tmp_path))
    offs = []
    for i in range(6):
        j.append_event("t", i, i + 1, i, float(i))
        offs.append(j.cursor("t"))
    j.close()

    # flip a payload byte inside record 3
    seg = os.path.join(str(tmp_path), "t", "seg_00000000.wal")
    with open(seg, "r+b") as f:
        data = bytearray(f.read())
    # locate record 3's payload start by walking frames
    off = 0
    for _ in range(3):
        n, _ = _HEADER.unpack_from(data, off)
        off += _HEADER.size + n
    data[off + _HEADER.size + 2] ^= 0xFF
    with open(seg, "wb") as f:
        f.write(bytes(data))

    j2 = EventJournal(str(tmp_path))
    with pytest.warns(UserWarning, match="corrupt"):
        j2.log_for("t")
    got = []
    with pytest.warns(UserWarning, match="corrupt"):
        for r in j2.records("t", 0, 0):
            if r is None:
                break
            got.append(r["i"])
    assert got == [0, 1, 2]


def test_segment_rotation_boundary_replay(tmp_path):
    j = EventJournal(str(tmp_path), segment_bytes=128)
    for i in range(12):
        j.append_event("t", i, i + 1, i, float(i), client_id="c", seq=i)
    log = j.log_for("t")
    assert len(log.segments()) > 1
    j.close()

    j2 = EventJournal(str(tmp_path), segment_bytes=128)
    recs = [r for r in j2.records("t", 0, 0) if r is not None]
    assert [r["i"] for r in recs if r["k"] == "ev"] == list(range(12))
    assert j2.last_seq("t", "c") == 11


def test_truncate_upto_and_crash_mid_truncation(tmp_path):
    j = EventJournal(str(tmp_path), segment_bytes=128)
    for i in range(24):
        j.append_event("t", i, i + 1, i, float(i))
    j.note_flush("t", 24, 8)
    cur = j.cursor("t")
    log = j.log_for("t")
    segs = log.segments()
    assert cur["segment"] >= 2 and len(segs) >= 3

    # crash mid-truncation: only the oldest segment got removed
    victim = os.path.join(str(tmp_path), "t", "seg_00000000.wal")
    os.remove(victim)
    j.close()

    # reopen: scan starts at the oldest *present* segment; the cursor
    # still replays cleanly because it points past the removed prefix
    j2 = EventJournal(str(tmp_path), segment_bytes=128)
    recs = [r for r in j2.records("t", cur["segment"], cur["offset"])
            if r is not None]
    assert recs == []  # nothing after the flush cursor: fully applied

    # finish the truncation: idempotent, removes the remaining old segs
    removed = j2.truncate_upto("t", cur)
    assert removed >= 1
    left = j2.log_for("t").segments()
    assert min(left) == cur["segment"]


# ---------------------------------------------------------------------------
# end-to-end recovery
# ---------------------------------------------------------------------------

def test_kill_and_recover_bitwise(small_graph, tmp_path):
    g = small_graph
    ev = _events(g, 48)
    jroot, sroot = str(tmp_path / "wal"), str(tmp_path / "snaps")

    # interrupted run: snapshot after 24 events, crash after 32
    j = EventJournal(jroot, fsync_s=0.005, clock=FakeClock())
    mgr = _make_mgr(g)
    t0 = mgr.add_tenant(name="t0")
    fe = _frontend(mgr, journal=j)
    for i, e in enumerate(ev[:32]):
        fe.submit(t0, *e, client_id="c", seq=i)
        if (i + 1) % 8 == 0:
            fe.pump(force=True)
        if (i + 1) == 24:
            mgr.sync()
            cluster.snapshot_tenant(mgr, t0, sroot, step=3,
                                    extra_meta={"journal": j.cursor(t0)})
    mgr.sync()
    crashed = mgr.state_of(t0)
    # no close(): simulate the process dying with the fd open

    j2 = EventJournal(jroot)
    mgr2 = _make_mgr(g)
    new = cluster.restore_tenant(mgr2, sroot, "t0", journal=j2)
    assert j2.last_replay.rounds == 1
    assert j2.last_replay.events == 8
    assert not j2.last_replay.corrupt
    mgr2.sync()
    _assert_state_equal(mgr2.state_of(new), crashed, "post-replay")

    # continue with the remaining events; must match an uninterrupted twin
    fe2 = _frontend(mgr2, journal=j2)
    _run(mgr2, fe2, new, ev[32:], start=32)
    mgr2.sync()

    mgrT = _make_mgr(g)
    tT = mgrT.add_tenant(name="tw")
    feT = _frontend(mgrT)
    for i, e in enumerate(ev):
        feT.submit(tT, *e)
        if (i + 1) % 8 == 0:
            feT.pump(force=True)
    mgrT.sync()
    _assert_state_equal(mgr2.state_of(new), mgrT.state_of(tT), "vs twin")


def test_corrupt_journal_falls_back_one_snapshot(small_graph, tmp_path):
    """Corruption after snapshot B's cursor: replay from A's older cursor
    still reaches every intact record before the corruption point."""
    g = small_graph
    ev = _events(g, 32)
    jroot, sroot = str(tmp_path / "wal"), str(tmp_path / "snaps")

    j = EventJournal(jroot)
    mgr = _make_mgr(g)
    t0 = mgr.add_tenant(name="t0")
    fe = _frontend(mgr, journal=j)
    states = {}
    for i, e in enumerate(ev):
        fe.submit(t0, *e, client_id="c", seq=i)
        if (i + 1) % 8 == 0:
            fe.pump(force=True)
        if (i + 1) in (8, 16):
            mgr.sync()
            step = (i + 1) // 8
            cluster.snapshot_tenant(mgr, t0, sroot, step=step,
                                    extra_meta={"journal": j.cursor(t0)})
            states[step] = mgr.state_of(t0)
    mgr.sync()
    j.close()

    # corrupt the journal just past snapshot 2's cursor so its replay
    # hits the bad record immediately; snapshot 1 replays 8 clean events
    cur2 = None
    meta2 = cluster.snapshot_meta(sroot, "t0", step=2)
    cur2 = meta2["journal"]
    seg = os.path.join(jroot, "t0", f"seg_{cur2['segment']:08d}.wal")
    with open(seg, "r+b") as f:
        f.seek(cur2["offset"] + _HEADER.size + 2)
        b = f.read(1)
        f.seek(cur2["offset"] + _HEADER.size + 2)
        f.write(bytes([b[0] ^ 0xFF]))

    j2 = EventJournal(jroot)
    with pytest.warns(UserWarning, match="corrupt"):
        j2.log_for("t0")
    mgr2 = _make_mgr(g)
    new = mgr2.add_tenant(name="t0")
    cluster.restore_tenant_state(mgr2, sroot, new, step=1)
    with pytest.warns(UserWarning, match="corrupt"):
        res = j2.replay("t0", cluster.snapshot_meta(sroot, "t0", step=1)["journal"],
                        mgr2.step, as_tid=new)
    assert res.corrupt
    assert res.rounds == 1  # events 8..15 replayed before the bad record
    mgr2.sync()
    _assert_state_equal(mgr2.state_of(new), states[2],
                        "fallback snapshot + longer replay suffix")


def test_duplicate_ingest_fuzz_bitwise(small_graph, tmp_path):
    g = small_graph
    ev = _events(g, 24)

    jD = EventJournal(str(tmp_path / "wal"))
    mgrD = _make_mgr(g)
    tD = mgrD.add_tenant(name="t0")
    feD = _frontend(mgrD, journal=jD)
    for i, e in enumerate(ev):
        feD.submit(tD, *e, client_id="c", seq=i)
        with pytest.raises(DuplicateEvent):
            feD.submit(tD, *e, client_id="c", seq=i)
        if (i + 1) % 8 == 0:
            feD.pump(force=True)
    mgrD.sync()
    assert feD.dedups == 24

    mgrO = _make_mgr(g)
    tO = mgrO.add_tenant(name="t0")
    feO = _frontend(mgrO)
    for i, e in enumerate(ev):
        feO.submit(tO, *e)
        if (i + 1) % 8 == 0:
            feO.pump(force=True)
    mgrO.sync()
    _assert_state_equal(mgrD.state_of(tD), mgrO.state_of(tO), "dup fuzz")


def test_dedup_wire_ack_and_retry_after_last_seq(small_graph, tmp_path):
    g = small_graph
    j = EventJournal(str(tmp_path))
    mgr = _make_mgr(g)
    t0 = mgr.add_tenant(name="t0")
    cfg = FrontendConfig(max_rows=8, pad_quantum=8, max_wait_s=0.001,
                         queue_rows=16)
    fe = ServingFrontend(mgr, cfg, clock=FakeClock(), journal=j)
    e = _events(g, 1)[0]
    fe.submit(t0, *e, client_id="c", seq=0)
    r = fe.handle({"op": "ingest", "tid": t0, "src": e[0], "dst": e[1],
                   "eid": e[2], "ts": e[3], "client_id": "c", "seq": 0})
    assert r == {"ok": True, "dedup": True, "tid": t0,
                 "client_id": "c", "seq": 0}

    # queue full -> retry_after carries last_seq for client resync
    for i, ee in enumerate(_events(g, 300, seed=1), start=1):
        try:
            fe.submit(t0, *ee, client_id="c", seq=i)
        except RetryAfter as exc:
            assert exc.last_seq == i - 1
            r = fe.handle({"op": "ingest", "tid": t0, "src": ee[0],
                           "dst": ee[1], "eid": ee[2], "ts": ee[3],
                           "client_id": "c", "seq": i})
            assert r["error"] == "retry_after" and r["last_seq"] == i - 1
            break
    else:
        pytest.fail("queue never filled")


def test_journal_io_fault_then_retry_succeeds(small_graph, tmp_path):
    g = small_graph
    j = EventJournal(str(tmp_path))
    mgr = _make_mgr(g)
    t0 = mgr.add_tenant(name="t0")
    mgr.set_faults(FaultInjector([Fault(kind="journal_io", tenant=t0,
                                        at=0, count=1)]))
    fe = _frontend(mgr, journal=j)
    e = _events(g, 1)[0]
    with pytest.raises(RetryAfter) as exc:
        fe.submit(t0, *e, client_id="c", seq=0)
    assert exc.value.reason == "journal_io"
    assert exc.value.last_seq is None  # seq 0 was NOT committed
    assert not j.is_duplicate(t0, "c", 0)
    # at-least-once client retries the same (client_id, seq): accepted once
    fe.submit(t0, *e, client_id="c", seq=0)
    assert j.last_seq(t0, "c") == 0
    with pytest.raises(DuplicateEvent):
        fe.submit(t0, *e, client_id="c", seq=0)


def test_gc_floor_protects_anchor_snapshot(small_graph, tmp_path):
    g = small_graph
    ev = _events(g, 40)
    jroot, sroot = str(tmp_path / "wal"), str(tmp_path / "snaps")

    j = EventJournal(jroot, segment_bytes=256)
    mgr = _make_mgr(g)
    t0 = mgr.add_tenant(name="t0")
    fe = _frontend(mgr, journal=j)
    floor = None
    for i, e in enumerate(ev):
        fe.submit(t0, *e, client_id="c", seq=i)
        if (i + 1) % 8 == 0:
            fe.pump(force=True)
            mgr.sync()
            step = (i + 1) // 8
            cluster.snapshot_tenant(mgr, t0, sroot, step=step, keep=2,
                                    extra_meta={"journal": j.cursor(t0)},
                                    keep_floor=floor)
            if step == 2:
                anchor = cluster.truncate_journal(j, sroot, t0)
                assert anchor is not None
                floor = anchor

    steps = cluster.ckpt.list_steps(os.path.join(sroot, t0))
    # the anchor snapshot survives GC even with keep=2
    assert floor in steps
    # journal records at/after the anchor cursor are still replayable
    cur = cluster.snapshot_meta(sroot, t0, step=floor)["journal"]
    recs = list(j.records(t0, cur["segment"], cur["offset"]))
    assert all(r is not None for r in recs)
    j.close()
