"""LUT time encoder properties (§III-C)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import time_encode as te


def test_boundaries_equal_frequency():
    rng = np.random.RandomState(0)
    samples = 10 ** rng.uniform(0, 6, 50_000)  # power-law-ish
    bounds = te.fit_boundaries(samples, 128)
    assert len(bounds) == 127
    assert np.all(np.diff(bounds) > 0)
    counts, _ = np.histogram(samples, bins=np.concatenate(
        [[-np.inf], bounds, [np.inf]]))
    # equal-frequency: every bucket within 3x of the mean occupancy
    assert counts.min() > 0 and counts.max() < 3 * counts.mean()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0, 1e8, allow_nan=False), min_size=1,
                max_size=50))
def test_bucket_monotonic_in_dt(dts):
    tcfg = te.TimeEncoderConfig(dim=4, n_entries=16)
    lut = te.init_lut(jax.random.key(0), tcfg,
                      dt_samples=np.logspace(0, 6, 1000))
    dt = jnp.asarray(sorted(dts), jnp.float32)
    b = te.lut_bucket(lut["boundaries"], dt)
    assert np.all(np.diff(np.asarray(b)) >= 0)
    assert int(b.min()) >= 0 and int(b.max()) < 16


def test_fold_projection_equals_encode_then_project():
    tcfg = te.TimeEncoderConfig(dim=12, n_entries=32)
    lut = te.init_lut(jax.random.key(1), tcfg,
                      dt_samples=np.logspace(0, 5, 500))
    rng = np.random.RandomState(2)
    w = jnp.asarray(rng.randn(12, 20), jnp.float32)
    dt = jnp.asarray(10 ** rng.uniform(0, 5, (64,)), jnp.float32)
    want = te.lut_encode(lut, dt) @ w
    folded = te.fold_projection(lut, w)
    got = te.lut_encode(folded, dt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_lut_one_hot_path_matches_gather():
    tcfg = te.TimeEncoderConfig(dim=8, n_entries=16)
    lut = te.init_lut(jax.random.key(3), tcfg,
                      dt_samples=np.logspace(0, 4, 300))
    dt = jnp.asarray(10 ** np.random.RandomState(4).uniform(0, 4, 40),
                     jnp.float32)
    a = te.lut_encode(lut, dt, one_hot=False)
    b = te.lut_encode(lut, dt, one_hot=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_lut_init_from_teacher_is_piecewise_cosine():
    tcfg = te.TimeEncoderConfig(dim=6, n_entries=8)
    cos = te.init_cosine(jax.random.key(5), tcfg)
    lut = te.init_lut(jax.random.key(6), tcfg, cosine_params=cos,
                      dt_samples=np.logspace(0, 3, 200))
    # each table row equals the cosine encoding of some dt in the bucket
    assert np.all(np.abs(np.asarray(lut["table"])) <= 1.0 + 1e-6)
