"""Neighbor ring buffer (FIFO hardware sampler) == most-recent-k oracle."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import mailbox

edges = st.lists(
    st.tuples(st.integers(0, 5), st.integers(0, 5)), min_size=1, max_size=60)


def _oracle_recent(edge_list, m_r, vid):
    """Most recent m_r (neighbor, ts, eid) of vid, newest first."""
    hist = []
    for eid, (s, d) in enumerate(edge_list):
        ts = float(eid + 1)
        if s == vid:
            hist.append((d, ts, eid))
        if d == vid:
            hist.append((s, ts, eid))
    return list(reversed(hist[-m_r:]))


@settings(max_examples=40, deadline=None)
@given(edges, st.integers(1, 2))
def test_ring_buffer_equals_recent_oracle(edge_list, chunk):
    cfg = mailbox.TableConfig(n_nodes=6, f_mem=4, f_edge=4, m_r=3)
    state = mailbox.init_state(cfg)
    # insert in chunks (tests intra-batch multi-occurrence handling)
    for i in range(0, len(edge_list), chunk):
        part = edge_list[i:i + chunk]
        src = jnp.asarray([e[0] for e in part], jnp.int32)
        dst = jnp.asarray([e[1] for e in part], jnp.int32)
        eid = jnp.asarray(list(range(i, i + len(part))), jnp.int32)
        ts = jnp.asarray([float(j + 1) for j in range(i, i + len(part))])
        state = mailbox.insert_neighbors(state, src, dst, eid, ts)

    ids, ts, eid, valid = mailbox.gather_neighbors(
        state, jnp.arange(6, dtype=jnp.int32))
    for v in range(6):
        want = _oracle_recent(edge_list, 3, v)
        got = [(int(ids[v, j]), float(ts[v, j]), int(eid[v, j]))
               for j in range(3) if bool(valid[v, j])]
        assert got == want, (v, got, want)


def test_insert_respects_valid_mask():
    cfg = mailbox.TableConfig(n_nodes=4, f_mem=2, f_edge=2, m_r=2)
    state = mailbox.init_state(cfg)
    src = jnp.asarray([0, 1], jnp.int32)
    dst = jnp.asarray([2, 3], jnp.int32)
    eid = jnp.asarray([0, 1], jnp.int32)
    ts = jnp.asarray([1.0, 2.0])
    valid = jnp.asarray([True, False])
    state = mailbox.insert_neighbors(state, src, dst, eid, ts, valid)
    _, ts0, _, v = mailbox.gather_neighbors(state,
                                            jnp.arange(4, dtype=jnp.int32))
    assert bool(v[0, 0]) and bool(v[2, 0])       # valid edge inserted
    assert not bool(v[1].any()) and not bool(v[3].any())  # masked edge NOT
