"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py fakes 512 devices.

If the real ``hypothesis`` package is unavailable, a minimal deterministic
fallback (tests/_vendor/hypothesis) is put on sys.path so the property-based
modules still collect and run everywhere (requirements-dev.txt installs the
real thing).
"""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
