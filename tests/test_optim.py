"""Optimizers vs numpy oracles + moment-quantization properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.training import optim as O
from repro.training.lr_schedule import ScheduleConfig, schedule


def _numpy_adamw(w, g, m, v, step, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** step)
    vh = v / (1 - cfg.b2 ** step)
    delta = mh / (np.sqrt(vh) + cfg.eps)
    if w.ndim >= 2:
        delta = delta + cfg.weight_decay * w
    return w - cfg.lr * delta, m, v


def test_adamw_multi_step_vs_numpy():
    cfg = O.OptimConfig(lr=3e-3, b1=0.9, b2=0.99, weight_decay=0.02,
                        global_clip=0)
    rng = np.random.RandomState(0)
    w0 = rng.randn(6, 4).astype(np.float32)
    params = {"w": jnp.asarray(w0)}
    state = O.init_state(cfg, params)
    w, m, v = w0.copy(), np.zeros_like(w0), np.zeros_like(w0)
    for step in range(1, 6):
        g = rng.randn(6, 4).astype(np.float32)
        state, params = O.apply_updates(cfg, state, {"w": jnp.asarray(g)},
                                        params)
        w, m, v = _numpy_adamw(w, g, m, v, step, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), w, rtol=1e-5,
                               atol=1e-6)


def test_lion_sign_update():
    cfg = O.OptimConfig(name="lion", lr=1e-2, b1=0.9, b2=0.99,
                        weight_decay=0.0, global_clip=0)
    params = {"w": jnp.zeros((3, 3))}
    state = O.init_state(cfg, params)
    g = {"w": jnp.asarray([[1.0, -2.0, 0.5]] * 3)}
    state, params = O.apply_updates(cfg, state, g, params)
    # first step: m=0 -> sign((1-b1) g) = sign(g)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               -1e-2 * np.sign(np.asarray(g["w"])))


def test_global_clip():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, gn = O.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 3.0 * np.sqrt(10)) < 1e-4
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_int8_moment_roundtrip_error_bound(seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(37, 13).astype(np.float32) *
                    10 ** rng.uniform(-3, 3))
    q = O._quantize(x)
    back = O._dequantize(q, x.shape)
    # block-quantization error <= scale/2 = max|block|/254 per element
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.max(np.abs(np.asarray(x))) / 254 + 1e-12
    assert err.max() <= bound * 1.0001


def test_int8_adamw_tracks_fp32():
    rng = np.random.RandomState(1)
    params = {"w": jnp.asarray(rng.randn(16, 16), jnp.float32)}
    cfg32 = O.OptimConfig(lr=1e-2, global_clip=0)
    cfg8 = cfg32.replace(moment_dtype="int8")
    s32, s8 = O.init_state(cfg32, params), O.init_state(cfg8, params)
    p32 = p8 = params
    for i in range(5):
        g = {"w": jnp.asarray(rng.randn(16, 16), jnp.float32)}
        s32, p32 = O.apply_updates(cfg32, s32, g, p32)
        s8, p8 = O.apply_updates(cfg8, s8, g, p8)
    # quantized moments drift, but updates stay well-correlated: after 5
    # steps of lr=1e-2 the param delta is ~5e-2; drift must stay an order
    # of magnitude below the update magnitude itself.
    diff = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
    moved = float(jnp.max(jnp.abs(p32["w"] - params["w"])))
    assert diff < 0.5 * moved, (diff, moved)


def test_schedule_warmup_cosine():
    cfg = ScheduleConfig(warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(schedule(cfg, 0)) == 0.0
    assert abs(float(schedule(cfg, 10)) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, 100)) - 0.1) < 1e-6
    mid = float(schedule(cfg, 55))
    assert 0.1 < mid < 1.0
