"""End-to-end behaviour of the paper's system (replaces the scaffold stub).

Covers: Algorithm-1 semantics of process_batch, engine == core equivalence,
padding neutrality, distillation pipeline on a small stream, and the
complexity model's exact Table-I/II reproduction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import complexity as cx, tgn
from repro.data import stream as stream_mod
from repro.data import temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine


@pytest.fixture(scope="module")
def small_graph():
    return tgd.wikipedia_like(n_edges=600)


@pytest.fixture(scope="module")
def student_setup(small_graph):
    g = small_graph
    cfg = tgn.TGNConfig(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges,
                        f_edge=172, f_mem=16, f_time=16, f_emb=16, m_r=10,
                        attention="sat", encoder="lut", prune_k=4)
    params = tgn.init_params(jax.random.key(0), cfg)
    return g, cfg, params


def test_engine_equals_core_trajectory(student_setup):
    g, cfg, params = student_setup
    ef = jnp.asarray(g.edge_feats)
    eng = StreamingEngine(EngineConfig(model=cfg, use_kernels=True),
                          params, ef)
    state = tgn.init_state(cfg)
    for batch in stream_mod.fixed_count(g, 50, window=slice(0, 300)):
        hs, hd = eng.process(batch)
        b = tuple(jnp.asarray(x) for x in
                  (batch.src, batch.dst, batch.eid, batch.ts, batch.valid))
        out = tgn.process_batch(params, cfg, state, None, ef, *b)
        state = out.state
        m = jnp.asarray(batch.valid)[:, None]
        np.testing.assert_allclose(np.asarray((hs - out.emb_src) * m), 0.0,
                                   atol=2e-5)
    np.testing.assert_allclose(np.asarray(eng.state.memory),
                               np.asarray(state.memory), atol=2e-5)


def test_padding_rows_do_not_mutate_state(student_setup):
    g, cfg, params = student_setup
    ef = jnp.asarray(g.edge_feats)
    state = tgn.init_state(cfg)
    src = jnp.asarray(g.src[:10]); dst = jnp.asarray(g.dst[:10])
    eid = jnp.arange(10, dtype=jnp.int32); ts = jnp.asarray(g.ts[:10])
    # all-valid on 10 rows
    out_a = tgn.process_batch(params, cfg, state, None, ef, src, dst, eid,
                              ts, jnp.ones((10,), bool))
    # same edges + 6 padding rows repeating the last edge
    def pad(x):
        return jnp.concatenate([x, jnp.repeat(x[-1:], 6, 0)])
    valid = jnp.concatenate([jnp.ones((10,), bool), jnp.zeros((6,), bool)])
    out_b = tgn.process_batch(params, cfg, state, None, ef, pad(src),
                              pad(dst), pad(eid), pad(ts), valid)
    for field in ("memory", "last_update", "mail", "mail_ts", "nbr_ids",
                  "nbr_ts", "nbr_cursor"):
        np.testing.assert_array_equal(
            np.asarray(getattr(out_a.state, field)),
            np.asarray(getattr(out_b.state, field)), err_msg=field)


def test_most_recent_mail_wins(student_setup):
    """Two interactions of the same vertex in one batch: the cached mail
    must reflect the chronologically LAST one (Most-Recent aggregator)."""
    g, cfg, params = student_setup
    ef = jnp.asarray(g.edge_feats)
    state = tgn.init_state(cfg)
    src = jnp.asarray([5, 5], jnp.int32)
    dst = jnp.asarray([700, 800], jnp.int32)
    eid = jnp.asarray([0, 1], jnp.int32)
    ts = jnp.asarray([10.0, 20.0])
    out = tgn.process_batch(params, cfg, state, None, ef, src, dst, eid, ts)
    assert float(out.state.mail_ts[5]) == 20.0
    # vertex 5's mail embeds edge 1's features
    expected = np.asarray(jnp.concatenate(
        [out.state.memory[5], out.state.memory[800], ef[1]]))
    np.testing.assert_allclose(np.asarray(out.state.mail[5]), expected,
                               atol=1e-6)


def test_memory_changes_only_after_mail(student_setup):
    """First-ever appearance of a vertex: no cached mail -> memory stays
    zero through UPDT; second appearance consumes the mail."""
    g, cfg, params = student_setup
    ef = jnp.asarray(g.edge_feats)
    state = tgn.init_state(cfg)
    b1 = (jnp.asarray([1], jnp.int32), jnp.asarray([900], jnp.int32),
          jnp.asarray([0], jnp.int32), jnp.asarray([5.0]))
    out1 = tgn.process_batch(params, cfg, state, None, ef, *b1)
    assert float(jnp.abs(out1.state.memory[1]).sum()) == 0.0
    assert bool(out1.state.mail_valid[1])
    b2 = (jnp.asarray([1], jnp.int32), jnp.asarray([901], jnp.int32),
          jnp.asarray([1], jnp.int32), jnp.asarray([9.0]))
    out2 = tgn.process_batch(params, cfg, out1.state, None, ef, *b2)
    assert float(jnp.abs(out2.state.memory[1]).sum()) > 0.0
    assert float(out2.state.mail_ts[1]) == 9.0


def test_complexity_reproduces_paper_mem_columns():
    """Wikipedia Table II MEM column: 5.7 / 3.8 / 2.9 / 1.9 kMEM exactly
    (to table rounding), and the headline 67% MEM reduction."""
    rows = cx.table2("Wikipedia")
    got = {name: round(mems["total"] / 1e3, 1)
           for name, _, mems, _, _ in rows}
    assert got["Baseline"] == 5.7
    assert got["+NP(L)"] == 3.8
    assert got["+NP(M)"] == 2.9
    assert got["+NP(S)"] == 1.9
    red = cx.headline_reductions("Wikipedia")
    assert abs(red["mem_reduction"] - 0.67) < 0.01
    assert red["mac_reduction"] > 0.70  # paper: 0.84 under its conventions


def test_complexity_stage_split_matches_table1():
    mems = cx.stage_mems(cx.ComplexityConfig())
    tot = mems["total"]
    assert abs(mems["memory"] / tot - 0.914) < 0.01
    assert abs(mems["update"] / tot - 0.083) < 0.01
    macs = cx.stage_macs(cx.ComplexityConfig())
    assert macs["GNN"] > 0.8 * macs["total"]  # GNN dominates compute


def test_distillation_pipeline_learns(small_graph):
    """Teacher AP beats untrained; student stays within tolerance."""
    from repro.training import tgn_trainer as TT
    g = small_graph
    base = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=16, f_time=16, f_emb=16, m_r=10)
    t_cfg = tgn.TGNConfig(**base)
    tcfg = TT.TGNTrainConfig(batch_size=50, epochs=2, lr=2e-3)
    t_params, _ = TT.train_teacher(g, t_cfg, tcfg)
    tr, va, _ = stream_mod.chronological_split(g)
    ap_t = TT.evaluate_ap(t_params, t_cfg, g, va, warm_window=tr)
    p0 = tgn.init_params(jax.random.key(42), t_cfg)
    ap_0 = TT.evaluate_ap(p0, t_cfg, g, va, warm_window=tr)
    assert ap_t > ap_0 + 0.05, (ap_t, ap_0)

    s_cfg = tgn.TGNConfig(**base, attention="sat", encoder="lut", prune_k=4)
    s_params, _ = TT.distill_student(g, t_params, t_cfg, s_cfg, tcfg)
    ap_s = TT.evaluate_ap(s_params, s_cfg, g, va, warm_window=tr)
    assert ap_s > ap_t - 0.10, (ap_s, ap_t)
